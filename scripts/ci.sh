#!/usr/bin/env bash
# Tier-1 CI entrypoint: the full suite on CPU with 8 fake host devices for
# the in-process multi-device tests (the subprocess checks set their own
# device count).  Mirrors ROADMAP.md "Tier-1 verify".
#
#   scripts/ci.sh                  # tier-1 pytest suite
#   scripts/ci.sh --collectives    # planner/executor microbench smoke run:
#                                  # all three modes on a 2-axis mesh, small
#                                  # sizes — fails fast on engine regressions
set -euo pipefail
cd "$(dirname "$0")/.."

export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--collectives" ]]; then
    shift
    out="$(python -m repro.launch.perf --collectives 2,4 --sizes-kb 16,64 \
           --reps 3 "$@")"
    echo "$out"
    # every collective must report all three modes at every size ("$@" may
    # override --sizes-kb, so require consistent non-zero counts rather
    # than a hardcoded size total)
    n_ag=""
    for coll in ag rs ar; do
        n="$(grep -c "\[perf/collectives\] $coll .*oneshot=.*chunked=.*perhop=" \
             <<< "$out" || true)"
        n_ag="${n_ag:-$n}"
        if [[ "$n" -lt 1 || "$n" -ne "$n_ag" ]]; then
            echo "CI FAIL: '$coll' three-mode rows: got $n, want $n_ag >= 1" >&2
            exit 1
        fi
    done
    echo "CI collectives smoke OK"
    exit 0
fi

exec python -m pytest -x -q "$@"
