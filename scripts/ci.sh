#!/usr/bin/env bash
# Tier-1 CI entrypoint: the full suite on CPU with 8 fake host devices for
# the in-process multi-device tests (the subprocess checks set their own
# device count).  Mirrors ROADMAP.md "Tier-1 verify".
set -euo pipefail
cd "$(dirname "$0")/.."

export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m pytest -x -q "$@"
