#!/usr/bin/env bash
# Tier-1 CI entrypoint: the full suite on CPU with 8 fake host devices for
# the in-process multi-device tests (the subprocess checks set their own
# device count).  Mirrors ROADMAP.md "Tier-1 verify".
#
#   scripts/ci.sh                  # tier-1 pytest suite
#   scripts/ci.sh --fast           # fast lane: skip multi-device subprocess
#                                  # tests (-m "not subproc")
#   scripts/ci.sh --collectives    # planner/executor microbench smoke run:
#                                  # all three modes on a 2-axis mesh, small
#                                  # sizes — fails fast on engine regressions
#   scripts/ci.sh --ir-smoke       # CollectivePlan IR round trip: engine
#                                  # plan -> schedule_from_ir -> conflict-
#                                  # checked simulate, plus the 8-device
#                                  # IR-interpreting-executor subprocess check
#   scripts/ci.sh --api-smoke      # context-scoped collectives API: the
#                                  # tests/test_comms_api.py suite + the
#                                  # explicit-TP block vs GSPMD benchmark
#                                  # on 8 host devices
#   scripts/ci.sh --order-smoke    # cross-world stage-order search: the
#                                  # plan-conformance fast subset + the
#                                  # order-search microbench with
#                                  # PlanPolicy(order="optical") driving
#                                  # the engine on 8 host devices
#   scripts/ci.sh --a2a-smoke      # all-to-all as a first-class collective:
#                                  # api.all_to_all bit-identity in every
#                                  # plan mode + the expert-parallel MoE
#                                  # block through the context-planned a2a
#                                  # (launch/perf.py --moe) on 8 host devices
#   scripts/ci.sh --fault-smoke    # fault layer: the 8-device chaos harness
#                                  # (injected ppermute faults detected +
#                                  # retried + degraded bit-identically,
#                                  # report_fault re-plans the cache) + the
#                                  # healthy-vs-degraded modeled-cost report
#                                  # (launch/perf.py --faults)
#   scripts/ci.sh --latency-smoke  # latency regime: the exchange-chain
#                                  # conformance tests + a decode-size
#                                  # microbench on 8 host devices that must
#                                  # report latency-regime plans below the
#                                  # crossover (and rings above it)
#   scripts/ci.sh --serve-smoke    # cluster serving: the tests/test_cluster.py
#                                  # suite (seeded-trace determinism, monotone
#                                  # makespan, policy ordering) + the
#                                  # launch/perf.py --cluster sweep — a small
#                                  # seeded trace through the simulator AND a
#                                  # 2-replica ClusterServer on host devices,
#                                  # with the cost-model-beats-round-robin
#                                  # p99 assertion in both
#   scripts/ci.sh --reconfig-smoke # reconfiguration-aware optical world: the
#                                  # invariant-(g) conformance tests (price==
#                                  # simulate with a per-event circuit delay,
#                                  # zero-delay bit-identity, SWOT overlap
#                                  # dominance) + the launch/perf.py --reconfig
#                                  # modeled sweep asserting the hold-vs-
#                                  # reconfigure flip (pure python, no devices)
set -euo pipefail
cd "$(dirname "$0")/.."

export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# grep gate: model/optimizer code must go through the context-scoped API
# (repro.comms.api), never construct engines directly again.  Runs before
# lane dispatch so EVERY lane enforces it.
api_grep_gate() {
    if grep -rn "StagedCollectiveEngine(" src/repro/models src/repro/optim; then
        echo "CI FAIL: src/repro/models|optim construct StagedCollectiveEngine" \
             "directly; route through repro.comms.api / comm_context" >&2
        exit 1
    fi
    # the EP dispatch must stay on the planned api: models/moe.py may not
    # reacquire the raw XLA exchange primitives
    if grep -n "lax\.all_to_all\|lax\.ppermute" src/repro/models/moe.py; then
        echo "CI FAIL: src/repro/models/moe.py uses raw lax.all_to_all/" \
             "ppermute; route the EP dispatch through api.all_to_all" >&2
        exit 1
    fi
    # the decode hot loop must stay on the planned api so its KiB-scale
    # psums hit the cached latency-regime plans (lax.pmax for the running
    # max is fine — only the reductions must plan; paren-anchored so the
    # docstring mentions of the flat-psum fallback don't trip it)
    if grep -nE "lax\.psum\(|lax\.all_reduce\(" \
            src/repro/comms/decode_attention.py src/repro/runtime/server.py; then
        echo "CI FAIL: decode_attention/runtime.server call raw lax.psum/" \
             "all_reduce; route decode combines through api.all_reduce" >&2
        exit 1
    fi
}
api_grep_gate

# fault gate: the executor's verified/retry path must never swallow errors
# blind — a bare ``except:`` (or blanket ``except Exception``) in the
# executors would mask real faults as "recovered".  Detection is checksum-
# driven, not exception-driven; keep it that way.
fault_grep_gate() {
    if grep -nE "except(\s+Exception)?\s*:" \
            src/repro/comms/plan_executor.py src/repro/comms/ring_executor.py \
            src/repro/comms/exchange_executor.py; then
        echo "CI FAIL: bare except/except Exception in the executors; the" \
             "fault path must detect via checksums, not swallow errors" >&2
        exit 1
    fi
}
fault_grep_gate

# order gate: the cross-world planning contract, in EVERY lane (pure
# python, no devices, <1s) — on the canonical asymmetric links table the
# optical backend must pick a strictly cheaper, strictly different stage
# order than the electrical backend, and the winner's optical price must
# be byte-identical to the conflict-checked simulator's wall time.
order_gate() {
    python - <<'PY'
import dataclasses
from repro.core import TERARACK, price, schedule_from_ir, search_stage_orders
from repro.core.planner import LinkSpec
from repro.optics import simulate

axes = [("a", 2, LinkSpec("fast", 50e9, 1e-6)),
        ("b", 4, LinkSpec("slow", 1e9, 1e-5))]
sys2 = dataclasses.replace(TERARACK, n_nodes=8, wavelengths=2)
for coll in ("ag", "rs", "ar"):
    s = search_stage_orders(axes, 2**20, collective=coll,
                            backend="optical", system=sys2)
    eb, ob = s.best_by("electrical"), s.best_by("optical")
    assert eb.order != ob.order, (coll, "order did not flip")
    assert ob.optical_s < eb.optical_s, (coll, "optical pick not cheaper")
    rep = simulate(schedule_from_ir(ob.plan, sys2.wavelengths), sys2,
                   ob.plan.shard_bytes, check=True)
    assert abs(rep.time_s - price(ob.plan, sys2).total_s) < 1e-12, coll
print("order gate OK (optical flips + price==simulate, ag/rs/ar)")
PY
}
order_gate

if [[ "${1:-}" == "--fast" ]]; then
    shift
    exec python -m pytest -x -q -m "not subproc" "$@"
fi

if [[ "${1:-}" == "--api-smoke" ]]; then
    shift
    python -m pytest -x -q tests/test_comms_api.py
    python -m repro.launch.perf --tp-block 2,4 --reps 2 "$@"
    echo "CI api-smoke OK"
    exit 0
fi

if [[ "${1:-}" == "--ir-smoke" ]]; then
    shift
    # (1) plan -> schedule -> simulate round trip on the paper side and the
    # engine side, single process, no devices needed
    python - <<'PY'
from repro.core import (DCN_LINK, ICI_LINK, OpTreePlan, TERARACK,
                        build_optree_schedule, choose_hop_schedule, price,
                        schedule_from_ir)
from repro.optics import simulate

ir = OpTreePlan(16, (4, 4)).to_ir(shard_bytes=2**20)
s = schedule_from_ir(ir, 64)
ref = build_optree_schedule(OpTreePlan(16, (4, 4)), 64)
assert s.num_steps == ref.num_steps and len(s.txs) == len(ref.txs)
simulate(s, TERARACK, ir.shard_bytes, check=True)

for coll in ("ag", "rs", "ar"):
    hs = choose_hop_schedule([2, 8], [DCN_LINK, ICI_LINK], 2**20,
                             collective=coll)
    plan = hs.to_ir()
    rep = simulate(schedule_from_ir(plan, 64), TERARACK, plan.shard_bytes,
                   check=True)
    po = price(plan, TERARACK)
    assert abs(po.total_s - rep.time_s) < 1e-12, (coll, po.total_s, rep.time_s)
    pe = price(plan)
    assert abs(pe.total_s - hs.time_s) / hs.time_s < 1e-12, (coll,)
print("IR round-trip OK (plan -> schedule -> simulate, priced both worlds)")
PY
    # (2) the 8-device subprocess executor check: engine interprets the IR,
    # outputs bit-identical to XLA, custom_vjp grads match unfused
    python tests/subproc/check_plan_executor.py
    echo "CI ir-smoke OK"
    exit 0
fi

if [[ "${1:-}" == "--order-smoke" ]]; then
    shift
    # (1) the plan-conformance suite (fast, in-process; the deterministic
    # grid runs even without hypothesis — the suite never skips itself away)
    python -m pytest -x -q tests/test_plan_conformance.py
    # (2) the order-search bench: PlanPolicy(order="optical") drives the
    # engine on 8 host devices; each row reports elec-best vs opt-best
    python -m repro.launch.perf --collectives 2,4 --sizes-kb 16 --reps 2 \
        --order optical --optical-w 2 "$@"
    echo "CI order-smoke OK"
    exit 0
fi

if [[ "${1:-}" == "--a2a-smoke" ]]; then
    shift
    # (1) api.all_to_all bit-identity vs the XLA one-shot lax.all_to_all in
    # every plan mode, plus the a2a cross-world order flip (2x3 at w=2:
    # electrical is order-invariant, optical strictly prefers slow-first)
    python - <<'PY'
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comms import comm_context, make_factorized_mesh
from repro.comms.api import CommContext, PlanPolicy, all_to_all
from repro.core import TERARACK, optical_message_bytes, price, schedule_from_ir
from repro.core.planner import LinkSpec
from repro.optics import simulate

mesh = make_factorized_mesh([2, 4], ["a", "b"])
x = jnp.arange(8 * 16, dtype=jnp.float32)
want = shard_map(lambda y: lax.all_to_all(y, ("a", "b"), 0, 0, tiled=True),
                 mesh=mesh, in_specs=P(("a", "b")),
                 out_specs=P(("a", "b")))(x)
with comm_context(mesh, ("a", "b")) as ctx:
    for mode, chunks in ((None, None), ("oneshot", None), ("chunked", 4),
                         ("perhop", None), ("hybrid", 2)):
        got = all_to_all(x, ctx=ctx, mode=mode, num_chunks=chunks)
        assert np.array_equal(np.asarray(got), np.asarray(want)), (mode, chunks)
    assert any(p.collective == "a2a" for p in ctx.plans())

sys6 = dataclasses.replace(TERARACK, n_nodes=6, wavelengths=2)
ctxo = CommContext(
    axis_names=("a", "b"), axis_sizes={"a": 2, "b": 3},
    links={"a": LinkSpec("fast", 50e9, 1e-6),
           "b": LinkSpec("slow", 1e9, 1e-5)},
    policy=PlanPolicy(order="optical", optical=sys6))
plan = ctxo.plan("a2a", 6 * 1024.0)
srch = plan.meta["order_search"]
assert srch["flipped"], "a2a order did not flip on the 2x3 table"
rep = simulate(schedule_from_ir(plan, 2), sys6,
               optical_message_bytes(plan), check=True)
assert abs(rep.time_s - price(plan, sys6).total_s) < 1e-12
print("a2a gate OK (bit-identity every mode + order flip + price==simulate)")
PY
    # (2) the expert-parallel MoE block through the context-planned a2a:
    # modeled elec/optical + measured off the cached plans, checked against
    # the all-experts-local reference per shard
    python -m repro.launch.perf --moe 2,4 --reps 2 "$@"
    echo "CI a2a-smoke OK"
    exit 0
fi

if [[ "${1:-}" == "--fault-smoke" ]]; then
    shift
    # (1) health-model + degraded-planning unit tests, in-process
    python -m pytest -x -q tests/test_health.py
    # (2) the 8-device chaos harness: injected ppermute faults are detected
    # by the conservation checksums, retried, and degraded bit-identically;
    # report_fault re-plans the cache under the degraded world
    python tests/subproc/check_fault_tolerance.py
    # (3) healthy-vs-degraded modeled cost per collective (also asserts
    # degraded >= healthy in both pricing worlds)
    python -m repro.launch.perf --faults 2,4 --sizes-kb 64 --optical-w 8 "$@"
    echo "CI fault-smoke OK"
    exit 0
fi

if [[ "${1:-}" == "--latency-smoke" ]]; then
    shift
    # (1) the latency-regime conformance tests: exchange chains price as
    # simulated (healthy + degraded), the crossover separates the families,
    # the chunk floor clamps KiB payloads to C=1
    python -m pytest -x -q tests/test_plan_conformance.py \
        -k "LatencyRegime or ChunkFloor or latency"
    # (2) decode-size microbench on 8 host devices: the auto regime must
    # plan exchange chains below the crossover (4KB arrays: 512B shards)
    # and rings above it (256KB arrays: 32KB shards)
    out="$(python -m repro.launch.perf --collectives 2,4 --sizes-kb 4,256 \
           --reps 2 "$@")"
    echo "$out"
    if ! grep -q "\[perf/latency\] ar 4KB regime=latency exchange: elec=" \
            <<< "$out"; then
        echo "CI FAIL: 4KB all-reduce did not plan the latency regime" >&2
        exit 1
    fi
    if ! grep -q "\[perf/latency\] ar 256KB regime=bandwidth " <<< "$out"; then
        echo "CI FAIL: 256KB all-reduce left the bandwidth regime" >&2
        exit 1
    fi
    if ! grep -q "\[perf/latency\] crossover mesh=" <<< "$out"; then
        echo "CI FAIL: no crossover telemetry in the collectives sweep" >&2
        exit 1
    fi
    if ! grep -qE "\[perf/latency\] cache: latency_plans=[1-9]" <<< "$out"; then
        echo "CI FAIL: no latency plans counted in the cache split" >&2
        exit 1
    fi
    echo "CI latency-smoke OK"
    exit 0
fi

if [[ "${1:-}" == "--serve-smoke" ]]; then
    shift
    # (1) the cluster suite: seeded-trace determinism (bit-identical event
    # logs + stats), makespan monotone in arrival rate, policy ordering,
    # BatchedServer timestamps, measured-vs-simulated 2-replica validation
    python -m pytest -x -q tests/test_cluster.py
    # (2) the serving-policy sweep: simulated under both cost worlds plus a
    # measured 2-replica host run — cluster_bench itself asserts the
    # cost-model-beats-round-robin p99 ordering (sim AND measured); the
    # greps pin the telemetry lines the assertions ride on
    out="$(python -m repro.launch.perf --cluster --cluster-requests 12 "$@")"
    echo "$out"
    if ! grep -q "\[perf/cluster\] sim: cost-model policies beat round-robin" \
            <<< "$out"; then
        echo "CI FAIL: simulated policy sweep missing its ordering verdict" >&2
        exit 1
    fi
    if ! grep -q "\[perf/cluster\] measured: policy ordering matches" \
            <<< "$out"; then
        echo "CI FAIL: measured 2-replica run missing the simulator-match" \
             "verdict" >&2
        exit 1
    fi
    echo "CI serve-smoke OK"
    exit 0
fi

if [[ "${1:-}" == "--reconfig-smoke" ]]; then
    shift
    # (1) the reconfiguring-world conformance tests: invariant (g) grid +
    # hypothesis, the hold-vs-reconfigure decision pins, the PlanPolicy
    # knob, and the sub-axis factorization guard
    python -m pytest -x -q tests/test_plan_conformance.py \
        -k "reconfig or Reconfig or SubAxis"
    # (2) the modeled sweep: per-event delay swept over the paper-world
    # 16-node axis — reconfig_bench itself asserts price==simulate per
    # point, SWOT overlap dominance, and the flip; the greps pin the
    # telemetry lines the assertions ride on
    out="$(python -m repro.launch.perf --reconfig "$@")"
    echo "$out"
    if ! grep -q "\[perf/reconfig\] hold-vs-reconfigure flip:" <<< "$out"; then
        echo "CI FAIL: --reconfig sweep missing the flip verdict" >&2
        exit 1
    fi
    if ! grep -qE "\[perf/reconfig\] delay=[^ ]+ +best= +16 reconfigs=0" \
            <<< "$out"; then
        echo "CI FAIL: no hold-the-circuit winner past the crossover" >&2
        exit 1
    fi
    if ! grep -qE "\[perf/reconfig\] delay=0.00e\+00s best= +4x4 reconfigs=[1-9]" \
            <<< "$out"; then
        echo "CI FAIL: zero-delay winner is not the factored chain" >&2
        exit 1
    fi
    echo "CI reconfig-smoke OK"
    exit 0
fi

if [[ "${1:-}" == "--collectives" ]]; then
    shift
    out="$(python -m repro.launch.perf --collectives 2,4 --sizes-kb 16,64 \
           --reps 3 "$@")"
    echo "$out"
    # every collective must report all three modes at every size ("$@" may
    # override --sizes-kb, so require consistent non-zero counts rather
    # than a hardcoded size total)
    n_ag=""
    for coll in ag rs ar; do
        n="$(grep -c "\[perf/collectives\] $coll .*oneshot=.*chunked=.*perhop=" \
             <<< "$out" || true)"
        n_ag="${n_ag:-$n}"
        if [[ "$n" -lt 1 || "$n" -ne "$n_ag" ]]; then
            echo "CI FAIL: '$coll' three-mode rows: got $n, want $n_ag >= 1" >&2
            exit 1
        fi
    done
    echo "CI collectives smoke OK"
    exit 0
fi

exec python -m pytest -x -q "$@"
