"""Fill EXPERIMENTS.md §Dry-run and §Roofline tables from runs/dryrun."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.roofline import analyze_dir, format_table  # noqa: E402

ROOT = Path(__file__).resolve().parent.parent
DRYRUN = ROOT / "runs" / "dryrun"


def dryrun_table() -> str:
    rows = []
    for tag in ("singlepod", "multipod"):
        for p in sorted(DRYRUN.glob(f"*__{tag}.json")):
            c = json.loads(p.read_text())
            if not c.get("ok"):
                rows.append(f"| {c['arch']} | {c['shape']} | {tag} | FAIL | - | - | - | {c.get('error','')[:60]} |")
                continue
            mem = c.get("memory") or {}
            args_gb = (mem.get("argument_size_in_bytes") or 0) / 2**30
            temp_gb = (mem.get("temp_size_in_bytes") or 0) / 2**30
            coll = c.get("collectives", {})
            counts = coll.get("counts", {})
            n_coll = sum(counts.values())
            cal = c.get("calibrated") or {}
            rows.append(
                f"| {c['arch']} | {c['shape']} | {tag} | ok "
                f"({c.get('compile_s','?')}s) | {args_gb:.2f} | {temp_gb:.2f} | "
                f"{n_coll} ({'+'.join(f'{k}:{v}' for k, v in sorted(counts.items()))}) | "
                f"{(cal.get('collective_bytes') or coll.get('total_bytes') or 0)/2**20:.1f} MiB |"
            )
    hdr = ("| arch | shape | mesh | compile | args GiB/dev | temp GiB/dev | "
           "collective ops | collective traffic/dev/step |\n|" + "---|" * 8)
    return hdr + "\n" + "\n".join(rows)


def roofline_table() -> str:
    return format_table(analyze_dir(str(DRYRUN)))


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = md.replace("<!-- DRYRUN_TABLE -->", dryrun_table(), 1)
    md = md.replace("<!-- ROOFLINE_TABLE -->", roofline_table(), 1)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated")


def roofline_opt_table() -> str:
    d = ROOT / "runs" / "dryrun_opt"
    if not d.exists():
        return "(runs/dryrun_opt not present)"
    return format_table(analyze_dir(str(d)))


def update_opt():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = md.replace("<!-- ROOFLINE_OPT_TABLE -->", roofline_opt_table(), 1)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md §Roofline-optimized updated")


if __name__ == "__main__":
    main()
