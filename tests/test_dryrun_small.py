"""Dry-run machinery on a small mesh (subprocess with 8 fake devices)."""
import pytest

from tests.test_comms import _run


@pytest.mark.slow
@pytest.mark.subproc
def test_dryrun_machinery_small_mesh():
    out = _run("check_dryrun_small.py", devices=8, timeout=900)
    assert "DRYRUN-SMALL-OK" in out
