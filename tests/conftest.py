"""Lock jax to the default (single) CPU device for the whole in-process
suite BEFORE any test module import can change XLA_FLAGS.

repro.launch.dryrun sets --xla_force_host_platform_device_count=512 at
import time (required for the real dry-run); initializing jax here first
makes that a no-op inside pytest.  Multi-device tests run in subprocesses
(tests/subproc/*) with their own environment.
"""
import jax

jax.devices()  # force backend initialization with the default flags
