"""Drive the 8-device fault-tolerance chaos harness in a subprocess (same
pattern as tests/test_plan_ir_exec.py), plus the crash-safety contract of
the atomic checkpointer: a training run SIGKILLed mid-stream leaves only
committed ``step_*`` directories behind and ``--resume`` picks up from the
latest one."""
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _env(devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _run(script: str, devices: int = 8, timeout: int = 900) -> str:
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "subproc" / script)],
        env=_env(devices),
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.mark.slow
@pytest.mark.subproc
def test_fault_tolerance_multi_device():
    out = _run("check_fault_tolerance.py")
    assert "FAULT-TOLERANCE-OK" in out


def _train_cmd(ckpt_dir: Path, steps: int, resume: bool = False) -> list:
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "granite-3-2b", "--reduced",
        "--mesh", "8,1", "--batch", "8", "--zero1", "explicit",
        "--steps", str(steps),
        "--ckpt-dir", str(ckpt_dir), "--ckpt-interval", "2",
    ]
    if resume:
        cmd.append("--resume")
    return cmd


@pytest.mark.slow
@pytest.mark.subproc
def test_resume_after_kill(tmp_path):
    """SIGKILL a checkpointing train run mid-stream; the atomic writer must
    leave no torn ``step_*`` directory and ``--resume`` must continue from
    the latest committed step."""
    ckpt = tmp_path / "ckpt"
    proc = subprocess.Popen(
        _train_cmd(ckpt, steps=200), env=_env(),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
    )
    try:
        # wait for at least one COMMITTED checkpoint, then kill hard
        deadline = time.time() + 600
        committed = []
        while time.time() < deadline and proc.poll() is None:
            committed = [p for p in ckpt.glob("step_*")
                         if not p.name.endswith(".tmp")]
            if committed:
                break
            time.sleep(0.2)
        assert committed, "train run never committed a checkpoint"
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=60)

    # the atomic write contract: anything committed is complete
    survivors = sorted(p for p in ckpt.glob("step_*")
                       if not p.name.endswith(".tmp"))
    assert survivors, "kill erased the committed checkpoints?"
    for p in survivors:
        assert (p / "meta.json").exists(), f"torn checkpoint {p.name}"
    latest = max(int(p.name.split("_")[1]) for p in survivors)

    # resume from the kill and run a couple more steps to completion
    out = subprocess.run(
        _train_cmd(ckpt, steps=latest + 3, resume=True), env=_env(),
        capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, (
        f"resume run failed\n--- stdout ---\n{out.stdout}\n"
        f"--- stderr ---\n{out.stderr[-4000:]}"
    )
    assert f"[train/resume] resumed from step {latest}" in out.stdout
    assert "done:" in out.stdout
