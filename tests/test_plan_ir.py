"""Unified CollectivePlan IR: builders, pricing, and the paper-simulator
round trip (ISSUE 3 acceptance: one plan object from the OpTree scheduler
to the JAX executor and the optical simulator)."""
import dataclasses
import math

import pytest

from repro.core import (
    DCN_LINK,
    ICI_LINK,
    OpTreePlan,
    TERARACK,
    build_optree_schedule,
    choose_hop_schedule,
    expand_hops,
    price,
    schedule_from_ir,
    validate_schedule,
)
from repro.core.cost_model import plan_exposure
from repro.core.planner import LinkSpec, load_links
from repro.optics import simulate


def _sys(w):
    return dataclasses.replace(TERARACK, wavelengths=w)


class TestOpTreeRoundTrip:
    """OpTreePlan.to_ir() -> schedule_from_ir reproduces the paper's
    schedule builder transmission for transmission."""

    @pytest.mark.parametrize(
        "n,factors,w",
        [(16, (4, 4), 2), (16, (2, 2, 2, 2), 2), (27, (3, 3, 3), 4),
         (64, (4, 4, 4), 8), (24, (2, 3, 4), 4), (36, (6, 6), 16)],
    )
    def test_matches_build_optree_schedule(self, n, factors, w):
        plan = OpTreePlan(n, factors)
        ir = plan.to_ir(shard_bytes=4 * 2**20)
        s_ir = schedule_from_ir(ir, w)
        s_ref = build_optree_schedule(plan, w)
        validate_schedule(s_ir)
        assert s_ir.num_steps == s_ref.num_steps
        assert s_ir.stage_steps == s_ref.stage_steps
        assert len(s_ir.txs) == len(s_ref.txs)

    def test_expand_hops_counts_match_lowering(self):
        ir = OpTreePlan(24, (2, 3, 4)).to_ir(shard_bytes=1.0)
        exp = expand_hops(ir)
        n_tx = sum(len(h.transfers) for st in exp.stages for h in st.hops)
        assert n_tx == len(schedule_from_ir(ir, 4).txs)
        # oneshot stages hold exactly one hop; total volume telescopes
        assert all(len(st.hops) == 1 for st in exp.stages)

    def test_perhop_stage_expands_to_ring_hops(self):
        ir = OpTreePlan(8, (8,)).to_ir(stage_modes=["perhop"])
        ir = ir.with_mode("perhop")
        exp = expand_hops(ir)
        assert len(exp.stages[0].hops) == 7  # m-1 ring hops
        # each hop: every node forwards exactly one item
        assert all(len(h.transfers) == 8 for h in exp.stages[0].hops)
        sched = schedule_from_ir(ir, 64)
        validate_schedule(sched)
        assert sched.num_steps == 7  # one step per ring hop


class TestPriceOpticalMatchesSimulator:
    """price(plan, OpticalSystem) must equal the wall time the step-accurate
    simulator reports for the same plan — one plan, one price."""

    @pytest.mark.parametrize("w", [2, 8, 64])
    @pytest.mark.parametrize("mode", ["oneshot", "perhop"])
    def test_price_equals_simulate(self, w, mode):
        ir = OpTreePlan(16, (4, 4)).to_ir(
            shard_bytes=4 * 2**20,
            stage_modes=["perhop", "perhop"] if mode == "perhop" else None,
        ).with_mode(mode)
        sys = _sys(w)
        rep = simulate(schedule_from_ir(ir, w), sys, ir.shard_bytes, check=True)
        pr = price(ir, sys)
        assert pr.total_s == pytest.approx(rep.time_s, abs=0, rel=1e-12)
        assert pr.steps == rep.steps
        assert pr.stage_times_s == pytest.approx(rep.stage_times_s)


class TestEnginePlanRoundTrip:
    """Acceptance: an engine-chosen plan (choose_hop_schedule) round-trips
    to a Schedule that passes simulate(check=True); single-axis oneshot
    matches build_optree_schedule's step count."""

    @pytest.mark.parametrize("coll", ["ag", "rs", "ar"])
    @pytest.mark.parametrize("shard", [64, 1 * 2**20])
    def test_simulates_conflict_free(self, coll, shard):
        hs = choose_hop_schedule(
            [2, 8], [DCN_LINK, ICI_LINK], shard, collective=coll)
        ir = hs.to_ir()
        for mode in ("oneshot", "chunked", "perhop"):
            sched = schedule_from_ir(ir.with_mode(mode), 64)
            rep = simulate(sched, TERARACK, ir.shard_bytes, check=True)
            assert rep.steps == sched.num_steps > 0

    @pytest.mark.parametrize("n", [8, 16])
    def test_single_axis_oneshot_matches_optree(self, n):
        hs = choose_hop_schedule([n], [ICI_LINK], 1 * 2**20, collective="ag")
        s_ir = schedule_from_ir(hs.to_ir(("x",), mode="oneshot"), 64)
        s_ref = build_optree_schedule(OpTreePlan(n, (n,)), 64)
        assert s_ir.num_steps == s_ref.num_steps
        assert len(s_ir.txs) == len(s_ref.txs)

    def test_rs_stage_attribution_in_execution_order(self):
        """Regression: the RS schedule is the mirrored AG, but stage_steps
        must pair with the PLAN's execution order — the big-factor stage
        carries the big step count."""
        hs = choose_hop_schedule(
            [16, 2], [ICI_LINK, DCN_LINK], 1 * 2**20, collective="rs")
        ir = hs.to_ir()
        assert ir.factors == (16, 2)
        sched = schedule_from_ir(ir.with_mode("perhop"), 64)
        assert len(sched.stage_steps) == 2
        assert sched.stage_steps[0] > sched.stage_steps[1]  # 15 hops vs 1
        # ar: the RS half mirrors back too -> palindromic attribution
        hs_ar = choose_hop_schedule(
            [16, 2], [ICI_LINK, DCN_LINK], 1 * 2**20, collective="ar")
        s_ar = schedule_from_ir(hs_ar.to_ir().with_mode("perhop"), 64)
        assert s_ar.stage_steps == list(reversed(s_ar.stage_steps))

    def test_factor1_stage_keeps_attribution_aligned(self):
        """Regression: a size-1 mesh axis must yield a zero stage_steps
        entry (not be dropped), so attribution pairs with plan.factors even
        through the rs mirror reversal — and the optical/electrical
        PriceReports agree on stage count."""
        for coll in ("ag", "rs"):
            hs = choose_hop_schedule(
                [4, 1, 2], [ICI_LINK, ICI_LINK, DCN_LINK], 1 * 2**20,
                collective=coll)
            ir = hs.to_ir()
            sched = schedule_from_ir(ir, 64)
            assert len(sched.stage_steps) == len(ir.stages) == 3
            one_idx = ir.factors.index(1)
            assert sched.stage_steps[one_idx] == 0
            po = price(ir, TERARACK)
            pe = price(ir)
            assert len(po.stage_times_s) == len(pe.stage_times_s) == 3
            simulate(sched, TERARACK, ir.shard_bytes, check=True)

    @pytest.mark.parametrize("n", [8, 16])
    def test_single_axis_perhop_is_ring(self, n):
        hs = choose_hop_schedule([n], [ICI_LINK], 8 * 2**20, collective="ag")
        assert hs.mode == "perhop"
        rep = simulate(
            schedule_from_ir(hs.to_ir(("x",)), 64), TERARACK,
            hs.shard_bytes, check=True)
        assert rep.steps == n - 1  # classic ring: one step per hop


class TestPriceElectricalNoDrift:
    """price(plan) must reproduce choose_hop_schedule's modeled times for
    every mode — the planner and the pricer share one cost model."""

    @pytest.mark.parametrize("coll", ["ag", "rs", "ar"])
    @pytest.mark.parametrize("shard", [1024, 64 * 2**10, 8 * 2**20])
    def test_all_modes_match(self, coll, shard):
        hs = choose_hop_schedule(
            [2, 16], [DCN_LINK, ICI_LINK], shard, collective=coll)
        ir = hs.to_ir()
        want = {"oneshot": hs.oneshot_time_s, "chunked": hs.chunked_time_s,
                "perhop": hs.perhop_time_s}
        for mode, t in want.items():
            got = price(ir.with_mode(mode))
            assert got.total_s == pytest.approx(t, rel=1e-12), mode
        # the plan's own mode is the planner's pick
        assert price(ir).total_s == pytest.approx(hs.time_s, rel=1e-12)
        # exposure accounting carried over unchanged
        exposed, hidden = plan_exposure(ir)
        assert sum(exposed) == pytest.approx(hs.exposed_bytes)
        assert sum(hidden) == pytest.approx(hs.hidden_bytes)

    def test_electrical_needs_links(self):
        ir = OpTreePlan(16, (4, 4)).to_ir()
        with pytest.raises(ValueError, match="LinkSpec"):
            price(ir)


class TestLinkSpecJson:
    def test_round_trip(self):
        spec = LinkSpec("ici", 50e9, 1e-6)
        assert LinkSpec.from_json(spec.to_json()) == spec

    def test_calibrate_output_null_bandwidth_falls_back(self):
        d = {"name": "s0", "bandwidth_bytes": None, "alpha_s": 2e-4,
             "hardcoded": {"bandwidth_bytes": 6.25e9, "alpha_s": 1e-5}}
        spec = LinkSpec.from_json(d)
        assert spec.bandwidth_bytes == 6.25e9 and spec.alpha_s == 2e-4
        fb = LinkSpec("x", 1e9, 1e-7)
        assert LinkSpec.from_json(d, fallback=fb).bandwidth_bytes == 1e9

    def test_load_links_calibrate_format(self, tmp_path):
        import json

        doc = {"mesh": [2, 4], "fitted_links": {
            "s0": {"name": "s0", "bandwidth_bytes": 1e9, "alpha_s": 1e-5},
            "s1": {"name": "s1", "bandwidth_bytes": None, "alpha_s": 2e-6,
                   "hardcoded": {"bandwidth_bytes": 50e9, "alpha_s": 1e-6}},
        }}
        p = tmp_path / "fitted.json"
        p.write_text(json.dumps(doc))
        links = load_links(p)
        assert links["s0"] == LinkSpec("s0", 1e9, 1e-5)
        assert links["s1"].bandwidth_bytes == 50e9


class TestIRValidation:
    def test_bad_mode_rejected(self):
        ir = OpTreePlan(4, (4,)).to_ir()
        with pytest.raises(ValueError):
            ir.with_mode("warp")

    def test_factors_must_cover_n(self):
        from repro.core.plan_ir import CollectivePlan, PlanStage

        with pytest.raises(ValueError, match="cover"):
            CollectivePlan("ag", 8, 1.0,
                           (PlanStage(4, "oneshot", 1.0),))


# ---------------------------------------------------------------------------
# hypothesis property: the IR round trip holds for arbitrary factorizations
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @given(
        factors=st.lists(st.integers(min_value=2, max_value=5),
                         min_size=1, max_size=3),
        w=st.integers(min_value=1, max_value=64),
    )
    @settings(max_examples=25, deadline=None)
    def test_ir_roundtrip_property(factors, w):
        """For any single-ring factorization: schedule_from_ir(to_ir())
        matches build_optree_schedule in steps and transmissions, and
        price(plan, optical) matches the simulator wall time."""
        n = math.prod(factors)
        plan = OpTreePlan(n, tuple(factors))
        ir = plan.to_ir(shard_bytes=2**20)
        s_ir = schedule_from_ir(ir, w)
        s_ref = build_optree_schedule(plan, w)
        validate_schedule(s_ir)
        assert s_ir.num_steps == s_ref.num_steps
        assert len(s_ir.txs) == len(s_ref.txs)
        sys = _sys(w)
        rep = simulate(s_ir, sys, ir.shard_bytes, check=True)
        assert price(ir, sys).total_s == pytest.approx(rep.time_s, rel=1e-12)

    @given(
        factors=st.lists(st.integers(min_value=2, max_value=5),
                         min_size=1, max_size=3),
        shard=st.floats(min_value=256.0, max_value=1e8),
    )
    @settings(max_examples=25, deadline=None)
    def test_engine_plan_simulates_property(factors, shard):
        """Any engine-chosen hop schedule lowers to a conflict-free,
        causally valid, complete schedule."""
        links = [DCN_LINK] + [ICI_LINK] * (len(factors) - 1)
        hs = choose_hop_schedule(factors, links, shard, collective="ag")
        sched = schedule_from_ir(hs.to_ir(), 64)
        validate_schedule(sched)
        simulate(sched, TERARACK, shard, check=True)
