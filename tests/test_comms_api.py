"""Context-scoped collectives API (ISSUE 4): context nesting/override
semantics, the auto-invalidating plan cache (links fingerprint), the
chunk-collapse mode normalization, load_links validation, and the
deprecation shims on the legacy entry points.

Everything here is single-process: planning is meshless (``axis_sizes=``)
so no fake devices are needed; executor semantics are covered by
``tests/subproc/check_plan_executor.py``.
"""
import json

import jax.numpy as jnp
import pytest

from repro.comms import api
from repro.comms.api import (
    CacheStats,
    CommContext,
    PlanPolicy,
    comm_context,
    current_context,
    links_fingerprint,
)
from repro.core.cost_model import price
from repro.core.planner import DCN_LINK, ICI_LINK, LinkSpec, load_links

SIZES = {"pod": 2, "tp": 4}
NAMES = ("pod", "tp")


def ctx_for_tests(**kw):
    return CommContext(axis_names=NAMES, axis_sizes=SIZES, **kw)


# --------------------------------------------------------------------------
# context install / nesting / overrides
# --------------------------------------------------------------------------

class TestContextNesting:
    def test_install_and_restore(self):
        assert current_context(None) is None
        with comm_context(axis_names=NAMES, axis_sizes=SIZES) as ctx:
            assert current_context() is ctx
        assert current_context(None) is None

    def test_nested_inherits_axes_and_links(self):
        links = {"pod": DCN_LINK, "tp": ICI_LINK}
        with comm_context(axis_names=NAMES, axis_sizes=SIZES, links=links):
            with comm_context() as inner:
                assert inner.axis_names == NAMES
                assert inner.links == links
                assert inner.axis_sizes == SIZES

    def test_nested_policy_override_merges(self):
        with comm_context(axis_names=NAMES, axis_sizes=SIZES,
                          policy=PlanPolicy(max_chunks=4)):
            with comm_context(mode="perhop") as inner:
                assert inner.policy.mode == "perhop"
                assert inner.policy.max_chunks == 4  # inherited
            outer = current_context()
            assert outer.policy.mode is None  # untouched

    def test_policy_mode_applies_to_plans(self):
        with comm_context(axis_names=NAMES, axis_sizes=SIZES,
                          mode="perhop") as ctx:
            assert ctx.plan("ag", 2**20).mode == "perhop"
        with comm_context(axis_names=NAMES, axis_sizes=SIZES,
                          num_chunks=4) as ctx:
            plan = ctx.plan("ag", 2**20)
            # forced chunk count resizes the wavefront; a planner-picked
            # hybrid keeps its ring stages (chunked-family), anything else
            # is forced to the chunked wavefront
            assert plan.mode in ("chunked", "hybrid") and plan.num_chunks == 4

    def test_policy_forced_order(self):
        for order in (("pod", "tp"), ("tp", "pod")):
            ctx = ctx_for_tests(policy=PlanPolicy(order=order))
            assert ctx.plan("ag", 2**20).axes == order
            # RS runs the reverse (duality), AR is RS-order + reversed
            assert ctx.plan("rs", 2**20).axes == tuple(reversed(order))
            ar = ctx.plan("ar", 2**20)
            assert ar.axes == (tuple(reversed(order)) + order)

    def test_bad_policy_rejected(self):
        with pytest.raises(ValueError, match="oneshot|chunked|perhop"):
            PlanPolicy(mode="warp")
        ctx = ctx_for_tests(policy=PlanPolicy(order=("pod", "nope")))
        with pytest.raises(ValueError, match="permute"):
            ctx.plan("ag", 2**20)

    def test_no_axes_anywhere_raises(self):
        with pytest.raises(ValueError, match="axes"):
            CommContext()._names(None)


# --------------------------------------------------------------------------
# plan cache: hit / miss / invalidation
# --------------------------------------------------------------------------

class TestPlanCache:
    def test_hit_and_miss_counters(self):
        ctx = ctx_for_tests()
        p1 = ctx.plan("ag", 2**20)
        p2 = ctx.plan("ag", 2**20)
        assert p1 is p2
        assert ctx.cache_stats == CacheStats(hits=1, misses=1, invalidated=0,
                                             ring_plans=1)
        ctx.plan("ag", 2**10)  # different payload -> new entry
        ctx.plan("rs", 2**20)  # different collective -> new entry
        assert ctx.cache_stats.misses == 3

    def test_shape_dtype_in_key(self):
        ctx = ctx_for_tests()
        ctx.plan("ag", 2**20, shape=(8, 32), dtype=jnp.float32)
        ctx.plan("ag", 2**20, shape=(8, 32), dtype=jnp.float32)
        ctx.plan("ag", 2**20, shape=(4, 64), dtype=jnp.float32)
        assert ctx.cache_stats.hits == 1 and ctx.cache_stats.misses == 2

    def test_shard_bytes_always_in_key(self):
        # the same (shape, dtype) means a LOCAL shard inside shard_map but a
        # GLOBAL array outside it — the payload keeps those entries apart
        ctx = ctx_for_tests()
        p_local = ctx.plan("ag", 8 * 32 * 4, shape=(8, 32), dtype=jnp.float32)
        p_global = ctx.plan("ag", 8 * 32 * 4 / 8, shape=(8, 32),
                            dtype=jnp.float32)
        assert p_local is not p_global
        assert ctx.cache_stats.misses == 2 and ctx.cache_stats.hits == 0

    def test_axis_sizes_in_key(self):
        # the same axis NAME with a different size (another mesh seen by a
        # shared/default context) must not collide
        ctx = CommContext(axis_names=("tp",), axis_sizes={"tp": 4})
        p4 = ctx.plan("ag", 2**10)
        ctx.axis_sizes["tp"] = 8
        p8 = ctx.plan("ag", 2**10)
        assert p4.n == 4 and p8.n == 8
        assert ctx.cache_stats.misses == 2 and ctx.cache_stats.hits == 0

    def test_plan_usage_counts_issuance(self):
        ctx = ctx_for_tests()
        ctx.plan("ar", 2**20)
        ctx.plan("ar", 2**20)  # same entry, issued twice
        ctx.plan("rs", 2**20)
        usage = dict()
        for p, c in ctx.plan_usage():
            usage[p.collective] = c
        assert usage == {"ar": 2, "rs": 1}

    def test_links_fingerprint_stability(self):
        t1 = {"pod": DCN_LINK, "tp": ICI_LINK}
        t2 = {"tp": ICI_LINK, "pod": DCN_LINK}  # order-insensitive
        assert links_fingerprint(t1) == links_fingerprint(t2)
        t3 = {"pod": DCN_LINK,
              "tp": LinkSpec("ici", ICI_LINK.bandwidth_bytes, 2e-6)}
        assert links_fingerprint(t1) != links_fingerprint(t3)
        assert links_fingerprint(None) == "default"

    def test_update_links_invalidates_and_replans(self):
        ctx = ctx_for_tests(links={"pod": DCN_LINK, "tp": ICI_LINK})
        before = ctx.plan("ag", 2**20)
        ctx.plan("rs", 2**20)
        assert ctx.cache_stats.invalidated == 0
        # a fitted pod link 100x slower flips the planner's cost picture
        ctx.update_links({"pod": LinkSpec("dcn-fitted", 62.5e6, 1e-4)})
        assert ctx.cache_stats.invalidated == 2
        after = ctx.plan("ag", 2**20)
        assert after is not before
        assert ctx.cache_stats.misses == 3  # re-planned, not served stale
        pod_stage = [s for s in after.stages if s.axis == "pod"][0]
        assert pod_stage.link.name == "dcn-fitted"

    def test_update_links_noop_keeps_cache(self):
        links = {"pod": DCN_LINK, "tp": ICI_LINK}
        ctx = ctx_for_tests(links=links)
        ctx.plan("ag", 2**20)
        ctx.update_links(dict(links))  # identical table -> same fingerprint
        assert ctx.cache_stats.invalidated == 0
        ctx.plan("ag", 2**20)
        assert ctx.cache_stats.hits == 1

    def test_update_links_from_calibrate_file(self, tmp_path):
        p = tmp_path / "fitted.json"
        p.write_text(json.dumps({"fitted_links": {
            "pod": {"name": "dcn", "bandwidth_bytes": 1e9, "alpha_s": 5e-5},
        }}))
        ctx = ctx_for_tests(links={"pod": DCN_LINK, "tp": ICI_LINK})
        ctx.plan("ar", 2**20)
        ctx.update_links(str(p))
        assert ctx.cache_stats.invalidated == 1
        assert ctx.links["pod"].bandwidth_bytes == 1e9
        assert ctx.links["tp"] == ICI_LINK  # merged, not replaced

    def test_plans_snapshot(self):
        ctx = ctx_for_tests()
        ctx.plan("ag", 2**20)
        ctx.plan("rs", 2**20)
        assert len(ctx.plans()) == 2


# --------------------------------------------------------------------------
# chunk-collapse normalization (satellite: labeled-chunked-executes-oneshot)
# --------------------------------------------------------------------------

class TestChunkNormalization:
    def _chunked_plan(self):
        ctx = ctx_for_tests(policy=PlanPolicy(mode="chunked", num_chunks=8))
        plan = ctx.plan("ag", 8 * 2**20)
        assert plan.mode == "chunked" and plan.num_chunks == 8
        return plan

    def test_collapse_to_one_normalizes_mode(self):
        plan = self._chunked_plan()
        fitted = plan.with_chunks(1)  # what fit_chunks does on a tiny shard
        assert fitted.num_chunks == 1
        assert fitted.mode == "oneshot"

    def test_price_no_drift(self):
        plan = self._chunked_plan()
        t_norm = price(plan.with_chunks(1)).total_s
        t_oneshot = price(plan.with_mode("oneshot")).total_s
        assert t_norm == pytest.approx(t_oneshot, rel=1e-12)

    def test_multi_chunk_keeps_mode(self):
        plan = self._chunked_plan()
        assert plan.with_chunks(4).mode == "chunked"
        with pytest.raises(ValueError):
            plan.with_chunks(0)


# --------------------------------------------------------------------------
# load_links / LinkSpec validation (satellite: silent-ignore bugfix)
# --------------------------------------------------------------------------

class TestLoadLinksValidation:
    def _write(self, tmp_path, entries):
        p = tmp_path / "links.json"
        p.write_text(json.dumps(entries))
        return p

    def test_unknown_axis_raises_with_name(self, tmp_path):
        p = self._write(tmp_path, {
            "pod": DCN_LINK.to_json(), "typo": ICI_LINK.to_json()})
        with pytest.raises(ValueError, match=r"unknown axes \['typo'\]"):
            load_links(p, expect_axes=NAMES, allow_missing=True)

    def test_missing_axis_raises_unless_allowed(self, tmp_path):
        p = self._write(tmp_path, {"pod": DCN_LINK.to_json()})
        with pytest.raises(ValueError, match=r"missing axes \['tp'\]"):
            load_links(p, expect_axes=NAMES)
        out = load_links(p, expect_axes=NAMES, allow_missing=True)
        assert set(out) == {"pod"}

    def test_no_expect_axes_keeps_old_behavior(self, tmp_path):
        p = self._write(tmp_path, {"whatever": ICI_LINK.to_json()})
        assert set(load_links(p)) == {"whatever"}

    def test_from_json_rejects_negative_values(self):
        with pytest.raises(ValueError, match="alpha_s"):
            LinkSpec.from_json(
                {"name": "x", "bandwidth_bytes": 1e9, "alpha_s": -1e-6})
        with pytest.raises(ValueError, match="bandwidth"):
            LinkSpec.from_json(
                {"name": "x", "bandwidth_bytes": -1.0, "alpha_s": 1e-6})
        with pytest.raises(ValueError, match="bandwidth"):
            LinkSpec.from_json(
                {"name": "x", "bandwidth_bytes": 0.0, "alpha_s": 1e-6})


# --------------------------------------------------------------------------
# deprecation shims
# --------------------------------------------------------------------------

class TestDeprecationShims:
    def test_engine_warns_and_delegates(self):
        from repro.comms import StagedCollectiveEngine, make_factorized_mesh

        mesh = make_factorized_mesh([1], ["solo"])
        with pytest.warns(DeprecationWarning, match="comm_context"):
            eng = StagedCollectiveEngine(mesh, ("solo",))
        assert isinstance(eng.ctx, CommContext)
        x = jnp.arange(8, dtype=jnp.float32)
        plan = eng.plan(x, "ag")
        assert plan.meta["axis_names"] == ("solo",)
        # the engine's cache IS the context cache
        eng.plan(x, "ag")
        assert eng.ctx.cache_stats.hits == 1

    def test_tp_all_reduce_warns(self):
        from repro.comms.staged_collectives import tp_all_reduce

        with pytest.warns(DeprecationWarning, match="api.all_reduce"):
            with pytest.raises(Exception):
                # outside shard_map with a meshless default context the op
                # cannot execute — the shim still warns first
                tp_all_reduce(jnp.zeros((4, 4)), ("nope",))


# --------------------------------------------------------------------------
# module-op resolution errors
# --------------------------------------------------------------------------

class TestOpResolution:
    def test_meshless_context_outside_shard_map_raises(self):
        with comm_context(axis_names=NAMES, axis_sizes=SIZES):
            with pytest.raises(ValueError, match="no mesh"):
                api.all_gather(jnp.zeros((8,), jnp.float32))

    def test_explicit_ctx_beats_installed(self):
        inner = ctx_for_tests(policy=PlanPolicy(mode="perhop"))
        with comm_context(axis_names=NAMES, axis_sizes=SIZES):
            plan = inner.plan("ag", 2**20)
            assert plan.mode == "perhop"
            assert current_context().cache_stats.misses == 0
