"""Drive the per-hop ring-executor / collective-matmul checks in
subprocesses (8 and 16 fake CPU devices) so the main pytest process keeps
jax at a single device — same pattern as tests/test_comms.py."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(script: str, devices: int, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "subproc" / script)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.mark.slow
@pytest.mark.parametrize("devices", [8, 16])
def test_ring_executor_multi_device(devices):
    out = _run("check_ring_executor.py", devices)
    assert "RING-EXECUTOR-OK" in out
