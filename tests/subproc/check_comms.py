"""Multi-device correctness checks for repro.comms — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (pytest drives this via
tests/test_comms.py so the main test process keeps a single device).

Every staged/ring/NE collective must be bit-identical to the XLA one-shot
collective it replaces.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), (
    "run me via tests/test_comms.py"
)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comms import (
    StagedCollectiveEngine,
    hierarchical_all_reduce,
    make_factorized_mesh,
    neighbor_exchange_all_gather,
    one_stage_all_gather,
    optree_all_gather,
    ring_all_gather,
    staged_all_gather,
    staged_all_gather_chunked,
    staged_all_reduce,
    staged_reduce_scatter,
    tp_all_reduce,
)

rng = np.random.default_rng(0)
checks = []


def check(name, got, want, atol=0.0, exact=False):
    got = np.asarray(got)
    want = np.asarray(want)
    ok = got.shape == want.shape and (
        np.array_equal(got, want) if exact else np.allclose(got, want, atol=atol)
    )
    checks.append((name, ok))
    if not ok:
        print(f"FAIL {name}: shapes {got.shape} vs {want.shape}")
        print(" got ", got.ravel()[:8])
        print(" want", want.ravel()[:8])


from repro.compat import shard_map as _shard_map


def shmap(fn, mesh, in_specs, out_specs):
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


# ---- staged all-gather over factorized axes ------------------------------
mesh2 = make_factorized_mesh([2, 4], ["a", "b"])
x = rng.normal(size=(16, 3)).astype(np.float32)
want = x  # all-gather of shards along axis 0 == the global array

for order in [("a", "b"), ("b", "a")]:
    got = shmap(
        lambda y, order=order: staged_all_gather(y, ("a", "b"), stage_order=order),
        mesh2, P(("a", "b")), P(),
    )(x)
    check(f"staged_ag order={order}", got, want)

mesh3 = make_factorized_mesh([2, 2, 2], ["a", "b", "c"])
for order in [("a", "b", "c"), ("c", "b", "a"), ("b", "a", "c"), ("a", "c", "b")]:
    got = shmap(
        lambda y, order=order: staged_all_gather(y, ("a", "b", "c"), stage_order=order),
        mesh3, P(("a", "b", "c")), P(),
    )(x)
    check(f"staged_ag3 order={order}", got, want)

# non-zero gather axis
x2 = rng.normal(size=(3, 16)).astype(np.float32)
got = shmap(
    lambda y: staged_all_gather(y, ("a", "b"), stage_order=("a", "b"), axis=1),
    mesh2, P(None, ("a", "b")), P(None, None),
)(x2)
check("staged_ag axis=1 major-first", got, x2)

# one-stage (flat) reference
got = shmap(lambda y: one_stage_all_gather(y, ("a", "b")), mesh2, P(("a", "b")), P())(x)
check("one_stage_ag", got, x)

# ---- optree_all_gather top-level wrapper (pod-aware planning) ------------
meshp = make_factorized_mesh([2, 4], ["pod", "data"])
xs = jax.device_put(x, NamedSharding(meshp, P(("pod", "data"))))
got = optree_all_gather(xs, meshp, ("pod", "data"))
check("optree_all_gather wrapper", got, x)

# ---- ring / neighbor-exchange on a 1-D axis ------------------------------
mesh1 = make_factorized_mesh([8], ["r"])
got = shmap(lambda y: ring_all_gather(y, "r"), mesh1, P("r"), P())(x)
check("ring_ag", got, x)

got = shmap(lambda y: ring_all_gather(y, "r", axis=1), mesh1, P(None, "r"), P())(x2)
check("ring_ag axis=1", got, x2)

got = shmap(lambda y: neighbor_exchange_all_gather(y, "r"), mesh1, P("r"), P())(x)
check("ne_ag", got, x)

for n_small in (2, 4):
    msub = make_factorized_mesh([n_small], ["r"])
    xsml = rng.normal(size=(n_small * 2, 3)).astype(np.float32)
    got = shmap(lambda y: neighbor_exchange_all_gather(y, "r"), msub, P("r"), P())(xsml)
    check(f"ne_ag n={n_small}", got, xsml)

# ring inside a 2-D mesh (gather only over 'b', batch stays on 'a')
got = shmap(lambda y: ring_all_gather(y, "b"), mesh2, P(("a", "b")), P("a"))(x)
check("ring_ag inner axis", got, x)

# ---- hierarchical all-reduce ---------------------------------------------
g = rng.normal(size=(8, 4)).astype(np.float32)
want_sum = 8 * g  # psum over all 8 devices of identical replicas

got = shmap(
    lambda y: hierarchical_all_reduce(y, fast_axes=("data",), slow_axes=("pod",)),
    meshp, P(), P(),
)(g)
check("hier_allreduce", got, want_sum, atol=1e-5)

got = shmap(
    lambda y: hierarchical_all_reduce(y, fast_axes=("data",), slow_axes=("pod",),
                                      gather=False),
    meshp, P(), P("data"),
)(g)
check("hier_allreduce zero1 (scattered)", got, want_sum, atol=1e-5)

# sharded-input all-reduce matches psum exactly
xr = rng.normal(size=(8, 8, 4)).astype(np.float32)  # leading dim = device
def _ref_psum(y):
    return jax.lax.psum(y, ("pod", "data"))
want2 = shmap(_ref_psum, meshp, P(("pod", "data")), P())(xr.reshape(64, 4))
got2 = shmap(
    lambda y: hierarchical_all_reduce(y, ("data",), ("pod",)),
    meshp, P(("pod", "data")), P(),
)(xr.reshape(64, 4))
check("hier_allreduce sharded input", got2, want2, atol=1e-5)


# ---- staged reduce-scatter / all-reduce (the duals) -----------------------
# Integer-valued fp32 so the sums are exact: staged must be BIT-identical to
# the XLA one-shot collective in every stage order and chunking mode.
xi = rng.integers(-8, 8, size=(256, 3)).astype(np.float32)

want_rs = shmap(
    lambda y: lax.psum_scatter(y, ("a", "b"), scatter_dimension=0, tiled=True),
    mesh2, P(("a", "b")), P(("a", "b")),
)(xi)
for order in [None, ("a", "b"), ("b", "a")]:
    for C in (1, 2, 4):
        got = shmap(
            lambda y, o=order, c=C: staged_reduce_scatter(
                y, ("a", "b"), stage_order=o, num_chunks=c),
            mesh2, P(("a", "b")), P(("a", "b")),
        )(xi)
        check(f"staged_rs order={order} C={C}", got, want_rs, exact=True)

want_ar = shmap(
    lambda y: lax.psum(y, ("a", "b")), mesh2, P(("a", "b")), P(("a", "b")),
)(xi)
for C in (1, 2, 4):
    got = shmap(
        lambda y, c=C: staged_all_reduce(y, ("a", "b"), num_chunks=c),
        mesh2, P(("a", "b")), P(("a", "b")),
    )(xi)
    check(f"staged_ar C={C}", got, want_ar, exact=True)

# 3-axis RS, default (reversed = slow-last) order
want_rs3 = shmap(
    lambda y: lax.psum_scatter(y, ("a", "b", "c"), scatter_dimension=0, tiled=True),
    mesh3, P(("a", "b", "c")), P(("a", "b", "c")),
)(xi)
got = shmap(
    lambda y: staged_reduce_scatter(y, ("a", "b", "c"), num_chunks=2),
    mesh3, P(("a", "b", "c")), P(("a", "b", "c")),
)(xi)
check("staged_rs3 default C=2", got, want_rs3, exact=True)

# non-zero axis
xi2 = rng.integers(-8, 8, size=(3, 256)).astype(np.float32)
want_rs_ax1 = shmap(
    lambda y: lax.psum_scatter(y, ("a", "b"), scatter_dimension=1, tiled=True),
    mesh2, P(None, ("a", "b")), P(None, ("a", "b")),
)(xi2)
got = shmap(
    lambda y: staged_reduce_scatter(y, ("a", "b"), axis=1, num_chunks=2),
    mesh2, P(None, ("a", "b")), P(None, ("a", "b")),
)(xi2)
check("staged_rs axis=1 C=2", got, want_rs_ax1, exact=True)

# chunked all-gather == unchunked == XLA one-shot
xg = rng.integers(-8, 8, size=(32, 3)).astype(np.float32)
want_ag = shmap(
    lambda y: lax.all_gather(y, ("a", "b"), axis=0, tiled=True),
    mesh2, P(("a", "b")), P(),
)(xg)
for order in [("a", "b"), ("b", "a")]:
    for C in (2, 4):
        got = shmap(
            lambda y, o=order, c=C: staged_all_gather_chunked(
                y, ("a", "b"), stage_order=o, num_chunks=c),
            mesh2, P(("a", "b")), P(),
        )(xg)
        check(f"chunked_ag order={order} C={C}", got, want_ag, exact=True)

# engine wrappers (planner-driven order + chunking)
eng = StagedCollectiveEngine(mesh2, ("a", "b"))
check("engine all_reduce", eng.all_reduce(jnp.asarray(xi)), 8 * xi, exact=True)
check("engine reduce_scatter", eng.reduce_scatter(jnp.asarray(xi)), 8 * xi, exact=True)
xs_eng = jax.device_put(jnp.asarray(xi), NamedSharding(mesh2, P(("a", "b"))))
check("engine all_gather", eng.all_gather(xs_eng), xi, exact=True)

# multi-fast-axis hierarchical all-reduce (regression: the scatter must land
# canonical blocks, not stage-order-permuted ones)
mesh3p = make_factorized_mesh([2, 2, 2], ["pod", "da", "db"])
xr3 = rng.integers(-8, 8, size=(64, 4)).astype(np.float32)
want3 = shmap(lambda y: lax.psum(y, ("pod", "da", "db")),
              mesh3p, P(("pod", "da", "db")), P())(xr3)
got3 = shmap(lambda y: hierarchical_all_reduce(y, ("da", "db"), ("pod",)),
             mesh3p, P(("pod", "da", "db")), P())(xr3)
check("hier_allreduce multi-fast", got3, want3, exact=True)

# ---- explicit-TP model blocks (staged all-reduce combine) ------------------
from repro.models.attention import attention_tp_out
from repro.models.mlp import ffn_apply, ffn_apply_tp, ffn_init

d_model, d_ff = 16, 64
key = jax.random.key(0)
pf = ffn_init(key, d_model, d_ff, num_layers=2, dtype=jnp.float32)
xa = jnp.asarray(rng.normal(size=(2, 4, d_model)).astype(np.float32))
want_ffn = ffn_apply(pf, xa)

def ffn_tp(x):
    # each device holds its d_ff/8 slice: gate/up column-parallel, down
    # row-parallel — built here from the replicated params via the linear
    # device index over ("a","b")
    idx = lax.axis_index(("a", "b"))
    n, local_ff = 8, d_ff // 8
    p_local = {
        "gate": {"w": lax.dynamic_slice_in_dim(
            pf["gate"]["w"], idx * local_ff, local_ff, axis=1)},
        "up": {"w": lax.dynamic_slice_in_dim(
            pf["up"]["w"], idx * local_ff, local_ff, axis=1)},
        "down": {"w": lax.dynamic_slice_in_dim(
            pf["down"]["w"], idx * local_ff, local_ff, axis=0)},
    }
    return ffn_apply_tp(p_local, x, ("a", "b"), num_chunks=2)

got_ffn = shmap(ffn_tp, mesh2, P(), P())(xa)
check("ffn_apply_tp == ffn_apply", got_ffn, want_ffn, atol=2e-5)

# attention output projection: heads sharded over the TP axes
B, S, H, hd = 2, 4, 8, 8
q_dim = H * hd
wo = jnp.asarray(rng.normal(size=(q_dim, d_model)).astype(np.float32)) * 0.1
heads_out = jnp.asarray(rng.normal(size=(B, S, q_dim)).astype(np.float32))
want_attn = heads_out @ wo

def attn_tp(x):
    idx = lax.axis_index(("a", "b"))
    n = 8
    local_x = lax.dynamic_slice_in_dim(x, idx * (q_dim // n), q_dim // n, axis=2)
    local_wo = lax.dynamic_slice_in_dim(wo, idx * (q_dim // n), q_dim // n, axis=0)
    return attention_tp_out({"wo": {"w": local_wo}}, local_x, ("a", "b"))

got_attn = shmap(attn_tp, mesh2, P(), P())(heads_out)
check("attention_tp_out == dense", got_attn, want_attn, atol=2e-5)

# ---- explicit ZeRO-1 gradient sharding -------------------------------------
from repro.optim import zero1_shard_grads, zero1_unshard_params

grads = {
    "w": jnp.asarray(rng.integers(-8, 8, size=(64, 4)).astype(np.float32)),
    "b": jnp.asarray(rng.integers(-8, 8, size=(5,)).astype(np.float32)),  # 5 % 8 != 0
}

def z1(g):
    sharded = zero1_shard_grads(g, ("a", "b"), num_chunks=2)
    return zero1_unshard_params(sharded, ("a", "b"), reference=g)

got_z1 = shmap(z1, mesh2, P(), {"w": P(), "b": P()})(grads)
check("zero1 w (rs+ag)", got_z1["w"], 8 * np.asarray(grads["w"]), exact=True)
check("zero1 b (psum fallback)", got_z1["b"], 8 * np.asarray(grads["b"]), exact=True)

def z1_scattered(g):
    return zero1_shard_grads(g, ("a", "b"))["w"]

got_sc = shmap(z1_scattered, mesh2, P(), P(("a", "b")))(grads)
check("zero1 scattered == psum_scatter", got_sc,
      shmap(lambda g: lax.psum_scatter(g["w"], ("a", "b"), scatter_dimension=0,
                                       tiled=True),
            mesh2, P(), P(("a", "b")))(grads))

# ---- sharded-KV decode attention (flash-decoding combine) -----------------
from repro.comms.decode_attention import sharded_decode_attention
from repro.kernels import ref as kref

B, H, Hkv, T, hd = 2, 4, 2, 64, 16
q = jnp.asarray(rng.normal(size=(B, H, 1, hd)).astype(np.float32)) * 0.4
kc = jnp.asarray(rng.normal(size=(B, Hkv, T, hd)).astype(np.float32)) * 0.4
vc = jnp.asarray(rng.normal(size=(B, Hkv, T, hd)).astype(np.float32))
for valid_len in (1, 17, 40, 64):
    vl = jnp.asarray(valid_len, jnp.int32)
    mask = jnp.arange(T)[None, :] < vl
    want_att = kref.flash_attention(
        q, kc, vc, causal=False, kv_mask=jnp.broadcast_to(mask, (B, T))
    )
    got_att = shmap(
        lambda qq, kk, vv: sharded_decode_attention(
            qq, kk, vv, axis_name="r", valid_len=vl
        ),
        mesh1, (P(), P(None, None, "r", None), P(None, None, "r", None)), P(),
    )(q, kc, vc)
    check(f"sharded_decode_attention len={valid_len}", got_att, want_att, atol=2e-5)

# ---- decode collectives hit the context's plan cache (ISSUE 5) ------------
# sharded_decode_attention's psum combines route through api.all_reduce:
# installed context = planned collectives + ONE cache entry per combine
# shape; a second trace re-uses the plans (hits), it does not re-plan.
from repro.comms.api import comm_context

with comm_context(mesh1, ("r",)) as dctx:
    vl = jnp.asarray(40, jnp.int32)
    mask = jnp.arange(T)[None, :] < vl
    want_att = kref.flash_attention(
        q, kc, vc, causal=False, kv_mask=jnp.broadcast_to(mask, (B, T)))
    run = lambda: shmap(
        lambda qq, kk, vv: sharded_decode_attention(
            qq, kk, vv, axis_name="r", valid_len=vl),
        mesh1, (P(), P(None, None, "r", None), P(None, None, "r", None)), P(),
    )(q, kc, vc)
    got_ctx = run()
    check("decode attention under comm_context", got_ctx, want_att, atol=2e-5)
    misses_after_first = dctx.cache_stats.misses
    check("decode all-reduces planned via context",
          misses_after_first >= 1, True, exact=True)
    run()  # second trace: plans come from the cache
    check("decode re-trace hits the plan cache",
          dctx.cache_stats.hits >= 1
          and dctx.cache_stats.misses == misses_after_first, True, exact=True)
    # the cached plans are the real IR objects (priceable)
    from repro.core import price as _price
    check("decode cached plans priceable",
          all(_price(p).total_s > 0 for p in dctx.plans()), True, exact=True)

# ---- report ---------------------------------------------------------------
bad = [n for n, ok in checks if not ok]
print(f"{len(checks) - len(bad)}/{len(checks)} comms checks passed")
if bad:
    raise SystemExit(f"FAILED: {bad}")
print("COMMS-OK")
