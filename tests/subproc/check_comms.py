"""Multi-device correctness checks for repro.comms — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (pytest drives this via
tests/test_comms.py so the main test process keeps a single device).

Every staged/ring/NE collective must be bit-identical to the XLA one-shot
collective it replaces.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), (
    "run me via tests/test_comms.py"
)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comms import (
    hierarchical_all_reduce,
    make_factorized_mesh,
    neighbor_exchange_all_gather,
    one_stage_all_gather,
    optree_all_gather,
    ring_all_gather,
    staged_all_gather,
)

rng = np.random.default_rng(0)
checks = []


def check(name, got, want, atol=0.0):
    got = np.asarray(got)
    want = np.asarray(want)
    ok = got.shape == want.shape and np.allclose(got, want, atol=atol)
    checks.append((name, ok))
    if not ok:
        print(f"FAIL {name}: shapes {got.shape} vs {want.shape}")
        print(" got ", got.ravel()[:8])
        print(" want", want.ravel()[:8])


def shmap(fn, mesh, in_specs, out_specs):
    return jax.shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_vma=False)


# ---- staged all-gather over factorized axes ------------------------------
mesh2 = make_factorized_mesh([2, 4], ["a", "b"])
x = rng.normal(size=(16, 3)).astype(np.float32)
want = x  # all-gather of shards along axis 0 == the global array

for order in [("a", "b"), ("b", "a")]:
    got = shmap(
        lambda y, order=order: staged_all_gather(y, ("a", "b"), stage_order=order),
        mesh2, P(("a", "b")), P(),
    )(x)
    check(f"staged_ag order={order}", got, want)

mesh3 = make_factorized_mesh([2, 2, 2], ["a", "b", "c"])
for order in [("a", "b", "c"), ("c", "b", "a"), ("b", "a", "c"), ("a", "c", "b")]:
    got = shmap(
        lambda y, order=order: staged_all_gather(y, ("a", "b", "c"), stage_order=order),
        mesh3, P(("a", "b", "c")), P(),
    )(x)
    check(f"staged_ag3 order={order}", got, want)

# non-zero gather axis
x2 = rng.normal(size=(3, 16)).astype(np.float32)
got = shmap(
    lambda y: staged_all_gather(y, ("a", "b"), stage_order=("a", "b"), axis=1),
    mesh2, P(None, ("a", "b")), P(None, None),
)(x2)
check("staged_ag axis=1 major-first", got, x2)

# one-stage (flat) reference
got = shmap(lambda y: one_stage_all_gather(y, ("a", "b")), mesh2, P(("a", "b")), P())(x)
check("one_stage_ag", got, x)

# ---- optree_all_gather top-level wrapper (pod-aware planning) ------------
meshp = make_factorized_mesh([2, 4], ["pod", "data"])
xs = jax.device_put(x, NamedSharding(meshp, P(("pod", "data"))))
got = optree_all_gather(xs, meshp, ("pod", "data"))
check("optree_all_gather wrapper", got, x)

# ---- ring / neighbor-exchange on a 1-D axis ------------------------------
mesh1 = make_factorized_mesh([8], ["r"])
got = shmap(lambda y: ring_all_gather(y, "r"), mesh1, P("r"), P())(x)
check("ring_ag", got, x)

got = shmap(lambda y: ring_all_gather(y, "r", axis=1), mesh1, P(None, "r"), P())(x2)
check("ring_ag axis=1", got, x2)

got = shmap(lambda y: neighbor_exchange_all_gather(y, "r"), mesh1, P("r"), P())(x)
check("ne_ag", got, x)

for n_small in (2, 4):
    msub = make_factorized_mesh([n_small], ["r"])
    xsml = rng.normal(size=(n_small * 2, 3)).astype(np.float32)
    got = shmap(lambda y: neighbor_exchange_all_gather(y, "r"), msub, P("r"), P())(xsml)
    check(f"ne_ag n={n_small}", got, xsml)

# ring inside a 2-D mesh (gather only over 'b', batch stays on 'a')
got = shmap(lambda y: ring_all_gather(y, "b"), mesh2, P(("a", "b")), P("a"))(x)
check("ring_ag inner axis", got, x)

# ---- hierarchical all-reduce ---------------------------------------------
g = rng.normal(size=(8, 4)).astype(np.float32)
want_sum = 8 * g  # psum over all 8 devices of identical replicas

got = shmap(
    lambda y: hierarchical_all_reduce(y, fast_axes=("data",), slow_axes=("pod",)),
    meshp, P(), P(),
)(g)
check("hier_allreduce", got, want_sum, atol=1e-5)

got = shmap(
    lambda y: hierarchical_all_reduce(y, fast_axes=("data",), slow_axes=("pod",),
                                      gather=False),
    meshp, P(), P("data"),
)(g)
check("hier_allreduce zero1 (scattered)", got, want_sum, atol=1e-5)

# sharded-input all-reduce matches psum exactly
xr = rng.normal(size=(8, 8, 4)).astype(np.float32)  # leading dim = device
def _ref_psum(y):
    return jax.lax.psum(y, ("pod", "data"))
want2 = shmap(_ref_psum, meshp, P(("pod", "data")), P())(xr.reshape(64, 4))
got2 = shmap(
    lambda y: hierarchical_all_reduce(y, ("data",), ("pod",)),
    meshp, P(("pod", "data")), P(),
)(xr.reshape(64, 4))
check("hier_allreduce sharded input", got2, want2, atol=1e-5)


# ---- sharded-KV decode attention (flash-decoding combine) -----------------
from repro.comms.decode_attention import sharded_decode_attention
from repro.kernels import ref as kref

B, H, Hkv, T, hd = 2, 4, 2, 64, 16
q = jnp.asarray(rng.normal(size=(B, H, 1, hd)).astype(np.float32)) * 0.4
kc = jnp.asarray(rng.normal(size=(B, Hkv, T, hd)).astype(np.float32)) * 0.4
vc = jnp.asarray(rng.normal(size=(B, Hkv, T, hd)).astype(np.float32))
for valid_len in (1, 17, 40, 64):
    vl = jnp.asarray(valid_len, jnp.int32)
    mask = jnp.arange(T)[None, :] < vl
    want_att = kref.flash_attention(
        q, kc, vc, causal=False, kv_mask=jnp.broadcast_to(mask, (B, T))
    )
    got_att = shmap(
        lambda qq, kk, vv: sharded_decode_attention(
            qq, kk, vv, axis_name="r", valid_len=vl
        ),
        mesh1, (P(), P(None, None, "r", None), P(None, None, "r", None)), P(),
    )(q, kc, vc)
    check(f"sharded_decode_attention len={valid_len}", got_att, want_att, atol=2e-5)

# ---- report ---------------------------------------------------------------
bad = [n for n, ok in checks if not ok]
print(f"{len(checks) - len(bad)}/{len(checks)} comms checks passed")
if bad:
    raise SystemExit(f"FAILED: {bad}")
print("COMMS-OK")
