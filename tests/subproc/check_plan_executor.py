"""Multi-device checks for the IR-interpreting executor and the
collective-matmul custom_vjp — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8
(tests/test_plan_ir_exec.py drives it).

Contracts (ISSUE 3):
  * ``StagedCollectiveEngine`` executes by interpreting the CollectivePlan
    IR; its AG/RS outputs stay BIT-identical to the XLA one-shot
    collectives in every mode (AR exact here too: integer-valued inputs);
  * ``execute_plan`` run directly on an engine plan equals the engine;
  * the same plan object lowers through ``schedule_from_ir`` and passes
    the conflict-checked optical simulator;
  * ``allgather_matmul`` / ``matmul_reduce_scatter`` gradients (custom_vjp,
    fused-ring backward) match the unfused XLA composition's gradients.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), (
    "run me via tests/test_plan_ir_exec.py"
)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.comms import StagedCollectiveEngine, execute_plan, make_factorized_mesh
from repro.core import TERARACK, price, schedule_from_ir
from repro.kernels.collective_matmul import allgather_matmul, matmul_reduce_scatter
from repro.optics import simulate

checks = []


def check(name, got, want, atol=0.0, exact=False):
    got = np.asarray(got)
    want = np.asarray(want)
    ok = got.shape == want.shape and (
        np.array_equal(got, want) if exact else np.allclose(got, want, atol=atol)
    )
    checks.append((name, ok))
    if not ok:
        print(f"FAIL {name}: shapes {got.shape} vs {want.shape}")
        print(" got ", got.ravel()[:8])
        print(" want", want.ravel()[:8])


mesh = make_factorized_mesh([2, 4], ["a", "b"])
names = ("a", "b")
eng = StagedCollectiveEngine(mesh, names)

x = jnp.arange(64, dtype=jnp.float32)
xs = jax.device_put(x, NamedSharding(mesh, P(names)))

# ---- engine (IR-interpreting) vs XLA one-shot, every mode -----------------
for mode in (None, "oneshot", "chunked", "perhop"):
    tag = mode or "planned"
    check(f"engine ag {tag}", eng.all_gather(xs, mode=mode), x, exact=True)
    check(f"engine rs {tag}", eng.reduce_scatter(x, mode=mode), 8 * x,
          exact=True)
    check(f"engine ar {tag}", eng.all_reduce(x, mode=mode), 8 * x, exact=True)

# ---- execute_plan on the engine's own plan == the engine ------------------
plan_ag = eng.plan(x, "ag")
direct = shard_map(
    lambda y: execute_plan(y, plan_ag), mesh=mesh,
    in_specs=P(names), out_specs=P(),
)(xs)
check("execute_plan direct == engine", direct, eng.all_gather(xs), exact=True)

# ---- the SAME plan object validates in the optical simulator --------------
for coll in ("ag", "rs", "ar"):
    plan = eng.plan(x, coll)
    sched = schedule_from_ir(plan, TERARACK.wavelengths)
    rep = simulate(sched, TERARACK, plan.shard_bytes, check=True)
    po = price(plan, TERARACK)
    check(f"plan {coll} price==sim", po.total_s, rep.time_s)
    check(f"plan {coll} steps", po.steps, rep.steps, exact=True)

# ---- collective-matmul custom_vjp vs unfused XLA composition --------------
key = jax.random.PRNGKey(0)
S, D, F = 16, 6, 10
xr = jax.random.normal(key, (S, D))
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F))
w2 = jax.random.normal(jax.random.PRNGKey(2), (D, F))


def ag_loss(fused):
    def inner(xs_, w1_, w2_):
        if fused:
            g, (o1, o2) = allgather_matmul(xs_, (w1_, w2_), names)
        else:
            g = lax.all_gather(xs_, names, axis=0, tiled=True)
            o1, o2 = g @ w1_, g @ w2_
        return (jnp.sum(o1 * o1) + jnp.sum(o2) + 3.0 * jnp.sum(g)) / 100.0

    def loss(x_, w1_, w2_):
        return shard_map(inner, mesh=mesh, in_specs=(P(names), P(), P()),
                         out_specs=P())(x_, w1_, w2_).mean()

    return jax.grad(loss, argnums=(0, 1, 2))(xr, w1, w2)


gf, gr = ag_loss(True), ag_loss(False)
for i, tag in enumerate(("dx", "dw1", "dw2")):
    check(f"ag_matmul vjp {tag}", gf[i], gr[i], atol=1e-5)

h = jax.random.normal(jax.random.PRNGKey(3), (S, D))
wr = jax.random.normal(jax.random.PRNGKey(4), (D, F))


def rs_loss(fused):
    def inner(h_, w_):
        if fused:
            y = matmul_reduce_scatter(h_, w_, names)
        else:
            y = lax.psum_scatter(h_ @ w_, names, scatter_dimension=0,
                                 tiled=True)
        return jnp.sum(y * y) / 100.0

    def loss(h_, w_):
        return shard_map(inner, mesh=mesh, in_specs=(P(), P()),
                         out_specs=P())(h_, w_).mean()

    return jax.grad(loss, argnums=(0, 1))(h, wr)


gf, gr = rs_loss(True), rs_loss(False)
for i, tag in enumerate(("dh", "dw")):
    check(f"mm_rs vjp {tag}", gf[i], gr[i], atol=1e-5)

# ---- model layer: SP-FFN fused fwd+grad vs the unfused staged path --------
from repro.models.mlp import ffn_apply_tp_sp

meshf = make_factorized_mesh([8], ["tp"])
B, S2, D2, F2 = 2, 16, 8, 16
pf = {"gate": {"w": jax.random.normal(jax.random.PRNGKey(5), (D2, F2 // 8))},
      "up": {"w": jax.random.normal(jax.random.PRNGKey(6), (D2, F2 // 8))},
      "down": {"w": jax.random.normal(jax.random.PRNGKey(7), (F2 // 8, D2))}}
xf = jax.random.normal(jax.random.PRNGKey(8), (B, S2, D2))


def ffn_grads(fuse):
    f = shard_map(
        lambda xs, pp: ffn_apply_tp_sp(pp, xs, ("tp",), fuse=fuse),
        mesh=meshf, in_specs=(P(None, "tp"), P()), out_specs=P(None, "tp"))

    def loss(x_, pp):
        return jnp.sum(f(x_, pp) ** 2)

    return jax.value_and_grad(loss, argnums=(0, 1))(xf, pf)


(vf, gf), (vr, gr) = ffn_grads(True), ffn_grads(False)
check("ffn_tp_sp fused loss", vf, vr, atol=1e-4)
check("ffn_tp_sp dx", gf[0], gr[0], atol=1e-4)
for k in ("gate", "up", "down"):
    check(f"ffn_tp_sp dw[{k}]", gf[1][k]["w"], gr[1][k]["w"], atol=1e-4)

# ---------------------------------------------------------------------------
failed = [n for n, ok in checks if not ok]
print(f"{len(checks) - len(failed)}/{len(checks)} checks passed")
if failed:
    raise SystemExit(f"FAILED: {failed}")
print("PLAN-EXECUTOR-OK")
