"""Multi-device checks for the IR-interpreting executor and the
collective-matmul custom_vjp — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8
(tests/test_plan_ir_exec.py drives it).

Contracts (ISSUE 3):
  * ``StagedCollectiveEngine`` executes by interpreting the CollectivePlan
    IR; its AG/RS outputs stay BIT-identical to the XLA one-shot
    collectives in every mode (AR exact here too: integer-valued inputs);
  * ``execute_plan`` run directly on an engine plan equals the engine;
  * the same plan object lowers through ``schedule_from_ir`` and passes
    the conflict-checked optical simulator;
  * ``allgather_matmul`` / ``matmul_reduce_scatter`` gradients (custom_vjp,
    fused-ring backward) match the unfused XLA composition's gradients.

Contracts (ISSUE 5, cross-world order search + hybrid execution):
  * on an asymmetric links table, ``PlanPolicy(order="optical")`` picks a
    DIFFERENT stage order than ``order="electrical"`` with strictly lower
    simulated Eq.-3 time, and the executor runs that exact plan
    bit-identically to the XLA one-shot collectives;
  * the ``hybrid`` mode (chunk wavefront over per-hop ring stages) stays
    bit-identical too, in both stage orders.

Contracts (ISSUE 8, latency-regime exchange plans):
  * decode-size payloads auto-plan recursive-doubling exchange chains and
    the exchange executor runs them bit-identically to the XLA one-shot
    collectives — auto pick AND forced ``regime="latency"`` — with the
    executed plan's optical price equal to the conflict-checked simulator.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), (
    "run me via tests/test_plan_ir_exec.py"
)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.comms import StagedCollectiveEngine, execute_plan, make_factorized_mesh
from repro.core import TERARACK, price, schedule_from_ir
from repro.kernels.collective_matmul import allgather_matmul, matmul_reduce_scatter
from repro.optics import simulate

checks = []


def check(name, got, want, atol=0.0, exact=False):
    got = np.asarray(got)
    want = np.asarray(want)
    ok = got.shape == want.shape and (
        np.array_equal(got, want) if exact else np.allclose(got, want, atol=atol)
    )
    checks.append((name, ok))
    if not ok:
        print(f"FAIL {name}: shapes {got.shape} vs {want.shape}")
        print(" got ", got.ravel()[:8])
        print(" want", want.ravel()[:8])


mesh = make_factorized_mesh([2, 4], ["a", "b"])
names = ("a", "b")
eng = StagedCollectiveEngine(mesh, names)

x = jnp.arange(64, dtype=jnp.float32)
xs = jax.device_put(x, NamedSharding(mesh, P(names)))

# ---- engine (IR-interpreting) vs XLA one-shot, every mode -----------------
for mode in (None, "oneshot", "chunked", "perhop"):
    tag = mode or "planned"
    check(f"engine ag {tag}", eng.all_gather(xs, mode=mode), x, exact=True)
    check(f"engine rs {tag}", eng.reduce_scatter(x, mode=mode), 8 * x,
          exact=True)
    check(f"engine ar {tag}", eng.all_reduce(x, mode=mode), 8 * x, exact=True)

# ---- execute_plan on the engine's own plan == the engine ------------------
plan_ag = eng.plan(x, "ag")
direct = shard_map(
    lambda y: execute_plan(y, plan_ag), mesh=mesh,
    in_specs=P(names), out_specs=P(),
)(xs)
check("execute_plan direct == engine", direct, eng.all_gather(xs), exact=True)

# ---- the SAME plan object validates in the optical simulator --------------
for coll in ("ag", "rs", "ar"):
    plan = eng.plan(x, coll)
    sched = schedule_from_ir(plan, TERARACK.wavelengths)
    rep = simulate(sched, TERARACK, plan.shard_bytes, check=True)
    po = price(plan, TERARACK)
    check(f"plan {coll} price==sim", po.total_s, rep.time_s)
    check(f"plan {coll} steps", po.steps, rep.steps, exact=True)

# ---- collective-matmul custom_vjp vs unfused XLA composition --------------
key = jax.random.PRNGKey(0)
S, D, F = 16, 6, 10
xr = jax.random.normal(key, (S, D))
w1 = jax.random.normal(jax.random.PRNGKey(1), (D, F))
w2 = jax.random.normal(jax.random.PRNGKey(2), (D, F))


def ag_loss(fused):
    def inner(xs_, w1_, w2_):
        if fused:
            g, (o1, o2) = allgather_matmul(xs_, (w1_, w2_), names)
        else:
            g = lax.all_gather(xs_, names, axis=0, tiled=True)
            o1, o2 = g @ w1_, g @ w2_
        return (jnp.sum(o1 * o1) + jnp.sum(o2) + 3.0 * jnp.sum(g)) / 100.0

    def loss(x_, w1_, w2_):
        return shard_map(inner, mesh=mesh, in_specs=(P(names), P(), P()),
                         out_specs=P())(x_, w1_, w2_).mean()

    return jax.grad(loss, argnums=(0, 1, 2))(xr, w1, w2)


gf, gr = ag_loss(True), ag_loss(False)
for i, tag in enumerate(("dx", "dw1", "dw2")):
    check(f"ag_matmul vjp {tag}", gf[i], gr[i], atol=1e-5)

h = jax.random.normal(jax.random.PRNGKey(3), (S, D))
wr = jax.random.normal(jax.random.PRNGKey(4), (D, F))


def rs_loss(fused):
    def inner(h_, w_):
        if fused:
            y = matmul_reduce_scatter(h_, w_, names)
        else:
            y = lax.psum_scatter(h_ @ w_, names, scatter_dimension=0,
                                 tiled=True)
        return jnp.sum(y * y) / 100.0

    def loss(h_, w_):
        return shard_map(inner, mesh=mesh, in_specs=(P(), P()),
                         out_specs=P())(h_, w_).mean()

    return jax.grad(loss, argnums=(0, 1))(h, wr)


gf, gr = rs_loss(True), rs_loss(False)
for i, tag in enumerate(("dh", "dw")):
    check(f"mm_rs vjp {tag}", gf[i], gr[i], atol=1e-5)

# ---- model layer: SP-FFN fused fwd+grad vs the unfused staged path --------
from repro.models.mlp import ffn_apply_tp_sp

meshf = make_factorized_mesh([8], ["tp"])
B, S2, D2, F2 = 2, 16, 8, 16
pf = {"gate": {"w": jax.random.normal(jax.random.PRNGKey(5), (D2, F2 // 8))},
      "up": {"w": jax.random.normal(jax.random.PRNGKey(6), (D2, F2 // 8))},
      "down": {"w": jax.random.normal(jax.random.PRNGKey(7), (F2 // 8, D2))}}
xf = jax.random.normal(jax.random.PRNGKey(8), (B, S2, D2))


def ffn_grads(fuse):
    f = shard_map(
        lambda xs, pp: ffn_apply_tp_sp(pp, xs, ("tp",), fuse=fuse),
        mesh=meshf, in_specs=(P(None, "tp"), P()), out_specs=P(None, "tp"))

    def loss(x_, pp):
        return jnp.sum(f(x_, pp) ** 2)

    return jax.value_and_grad(loss, argnums=(0, 1))(xf, pf)


(vf, gf), (vr, gr) = ffn_grads(True), ffn_grads(False)
check("ffn_tp_sp fused loss", vf, vr, atol=1e-4)
check("ffn_tp_sp dx", gf[0], gr[0], atol=1e-4)
for k in ("gate", "up", "down"):
    check(f"ffn_tp_sp dw[{k}]", gf[1][k]["w"], gr[1][k]["w"], atol=1e-4)

# ---- api fused ops OUTSIDE shard_map, rank-3 activations ------------------
# regression: the outside-path out_specs must shard the OUTPUT's feature
# dim (last of x's rank), not mirror the rank-2 weight layout
from repro.comms.api import (
    allgather_matmul as api_agmm,
    comm_context,
    matmul_reduce_scatter as api_mmrs,
)

with comm_context(mesh, names):
    x3 = jnp.arange(2 * 8 * 4, dtype=jnp.float32).reshape(2, 8, 4)
    w3 = (jnp.arange(4 * 16, dtype=jnp.float32).reshape(4, 16) % 5) - 2
    g3, o3 = api_agmm(x3, w3, axis=1)
    check("api ag_matmul rank3 gathered", g3, x3, exact=True)
    check("api ag_matmul rank3 out", o3, x3 @ w3, exact=True)
    h3 = jnp.arange(2 * 8 * 16, dtype=jnp.float32).reshape(2, 8, 16) % 7
    w3r = (jnp.arange(16 * 4, dtype=jnp.float32).reshape(16, 4) % 3) - 1
    check("api mm_rs rank3", api_mmrs(h3, w3r, axis=1), h3 @ w3r, exact=True)

# ---- explicit-TP transformer block vs the GSPMD block (ISSUE 4) -----------
# Bit-exactness construction: x entries are ±1 (token rms is exactly 1, so
# rmsnorm is exact), positions are 0 (RoPE multiplies by cos0=1/sin0=0 —
# identity), and the row-parallel weights (wo, down) are zero outside shard
# 0's rows — every cross-shard reduction sums exact 0.0s onto shard 0's
# partial, so ANY reduction order (staged AR, fused RS ring, GSPMD psum,
# the reference's full-width matmul) produces the same bits.  A second pass
# with fully dense weights checks all-shards-contributing semantics at
# float tolerance.
import dataclasses

from repro.comms.api import comm_context
from repro.configs import ModelConfig
from repro.models.model import (
    _layer_init,
    transformer_block_ref,
    transformer_block_tp,
    tp_block_specs,
)

cfg_tp = ModelConfig(
    name="check-tp-block", family="dense", dtype="float32", remat=False,
    qkv_bias=False, qk_norm=False, num_layers=2, d_model=32, num_heads=8,
    num_kv_heads=8, head_dim=8, d_ff=64, vocab_size=64,
)
NTP = 8
B, ST = 2, 16  # seq divisible by the 8 devices (SP shards the seq axis)
key = jax.random.PRNGKey(9)


def int_weights(layer, *, shard0_rows: bool):
    """Integer-valued params; with ``shard0_rows`` the row-parallel weights
    (wo, down) keep only shard 0's row block."""
    import zlib

    def intify(path, leaf):
        keys = [getattr(k, "key", None) for k in path]
        # crc32, not hash(): str hashing is PYTHONHASHSEED-randomized and
        # would draw different weights every run
        seed = zlib.crc32("/".join(str(k) for k in keys).encode())
        a = jnp.round(
            2.0 * jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(11), seed % (2**31)),
                leaf.shape)
        ).astype(jnp.float32)
        if "scale" in keys:
            return jnp.ones_like(leaf)
        if shard0_rows and leaf.ndim == 2 and any(k in ("wo", "down") for k in keys):
            rows = leaf.shape[0] // NTP
            mask = (jnp.arange(leaf.shape[0]) < rows)[:, None]
            a = jnp.where(mask, a, 0.0)
        return a

    return jax.tree_util.tree_map_with_path(intify, layer)


layer0 = _layer_init(key, cfg_tp, dtype=jnp.float32)
x_pm1 = jnp.where(
    jax.random.bernoulli(jax.random.PRNGKey(12), 0.5, (B, ST, cfg_tp.d_model)),
    1.0, -1.0).astype(jnp.float32)
pos0 = jnp.zeros((B, ST), jnp.int32)

mesh_tp = make_factorized_mesh([2, 4], ["ta", "tb"])
names_tp = ("ta", "tb")

for shard0, tag, exact in ((True, "bitexact", True), (False, "dense", False)):
    layer_tp = int_weights(layer0, shard0_rows=shard0)
    ref = jax.jit(lambda lx, ll: transformer_block_ref(
        ll, cfg_tp, lx, positions=pos0))(x_pm1, layer_tp)
    with comm_context(mesh_tp, names_tp) as ctx_tp:
        for sp in (False, True):
            x_spec, l_spec = tp_block_specs(
                layer_tp, names_tp, sequence_parallel=sp)
            fn = jax.jit(shard_map(
                lambda lx, ll, sp=sp: transformer_block_tp(
                    ll, cfg_tp, lx, positions=pos0, sequence_parallel=sp),
                mesh=mesh_tp, in_specs=(x_spec, l_spec), out_specs=x_spec,
            ))
            got = fn(x_pm1, layer_tp)
            # dense pass: integer weights drive activations to ~1e3, so the
            # reduction-order differences show up at ~1e-4 absolute — a
            # semantic (allclose) check, the bit-level contract is above
            check(f"tp_block {tag} sp={sp}", got, ref,
                  exact=exact, atol=0.0 if exact else 5e-3)
        # the GSPMD path proper: jit partitions the reference block from
        # TP shardings; with the bit-exact construction it matches too
        if shard0:
            from jax.sharding import NamedSharding

            x_spec, l_spec = tp_block_specs(layer_tp, names_tp)
            gspmd = jax.jit(
                lambda lx, ll: transformer_block_ref(
                    ll, cfg_tp, lx, positions=pos0),
                in_shardings=(
                    NamedSharding(mesh_tp, x_spec),
                    jax.tree.map(lambda s: NamedSharding(mesh_tp, s), l_spec),
                ),
                out_shardings=NamedSharding(mesh_tp, x_spec),
            )
            check("tp_block gspmd-partitioned bitexact",
                  gspmd(x_pm1, layer_tp), ref, exact=True)
    assert ctx_tp.cache_stats.misses > 0  # the block planned via the context

# ---- ISSUE 5: optical stage-order search + hybrid execution ---------------
# Asymmetric links: the size-4 axis rides the SLOW transport.  The
# electrical planner puts it first for the all-gather (smallest payload on
# the slow link); the optical Eq.-3/RWA pricer at w=2 prefers running its
# ring hops as stage 1 (whole-ring wavelength reuse) — a strictly cheaper,
# strictly different order.  The executor must run BOTH plans (and the new
# hybrid mode) bit-identically to the XLA one-shot collectives.
import dataclasses as _dc

from repro.comms.api import PlanPolicy, all_gather, all_reduce, reduce_scatter
from repro.comms.api import CommContext
from repro.core.planner import LinkSpec

ASYM_LINKS = {"a": LinkSpec("fast", 50e9, 1e-6),
              "b": LinkSpec("slow", 1e9, 1e-5)}
SYS_W2 = _dc.replace(TERARACK, n_nodes=8, wavelengths=2)
ctx_elec = CommContext(mesh, names, links=ASYM_LINKS,
                       policy=PlanPolicy(order="electrical", optical=SYS_W2))
ctx_opt = CommContext(mesh, names, links=ASYM_LINKS,
                      policy=PlanPolicy(order="optical", optical=SYS_W2))

xb = jnp.arange(2**14, dtype=jnp.float32)  # 64 KiB: big enough to chunk
xbs = jax.device_put(xb, NamedSharding(mesh, P(names)))
shard_b = xb.size * xb.dtype.itemsize / 8

for coll in ("ag", "rs", "ar"):
    pe = ctx_elec.plan(coll, shard_b, shape=tuple(xb.shape), dtype=xb.dtype)
    po = ctx_opt.plan(coll, shard_b, shape=tuple(xb.shape), dtype=xb.dtype)
    srch = po.meta["order_search"]
    checks.append((f"order {coll} flipped", pe.axes != po.axes
                   and srch["flipped"]))
    # the optical pick is STRICTLY cheaper under Eq. 3 (not a tie-break)
    checks.append((
        f"order {coll} optical strictly cheaper",
        price(po, SYS_W2).total_s < price(pe, SYS_W2).total_s,
    ))
    # price == simulate for the winner, conflict-checked
    rep = simulate(schedule_from_ir(po, SYS_W2.wavelengths), SYS_W2,
                   po.shard_bytes, check=True)
    checks.append((f"order {coll} price==sim",
                   abs(rep.time_s - price(po, SYS_W2).total_s) < 1e-12))

# both contexts' searched plans execute bit-identically to XLA, in the
# planned mode AND forced hybrid (chunk wavefront over ring stages)
for tag, ctx_i in (("elec", ctx_elec), ("optical", ctx_opt)):
    for mode, chunks in ((None, None), ("hybrid", 2), ("hybrid", 4)):
        mtag = f"{tag}/{mode or 'planned'}" + (f"x{chunks}" if chunks else "")
        check(f"order ag {mtag}",
              all_gather(xbs, ctx=ctx_i, mode=mode, num_chunks=chunks),
              xb, exact=True)
        check(f"order rs {mtag}",
              reduce_scatter(xb, ctx=ctx_i, mode=mode, num_chunks=chunks),
              8 * xb, exact=True)
        check(f"order ar {mtag}",
              all_reduce(xb, axis=0, ctx=ctx_i, mode=mode, num_chunks=chunks),
              8 * xb, exact=True)

# hybrid via the default (symmetric-links) engine too: planned mode at this
# size may already BE hybrid; force a chunked wavefront explicitly as well
check("engine ag hybrid", eng.all_gather(xs, mode="hybrid"), x, exact=True)
check("engine rs hybrid", eng.reduce_scatter(x, mode="hybrid"), 8 * x,
      exact=True)
check("engine ar hybrid", eng.all_reduce(x, mode="hybrid"), 8 * x,
      exact=True)

# ---- ISSUE 6: all-to-all as a first-class collective ----------------------
# api.all_to_all must stay BIT-identical to the XLA one-shot
# lax.all_to_all in every plan mode (the staged digit-transposes commute,
# the ring stages restore origin order exactly), and the expert-parallel
# MoE dispatch must cross the mesh through it.
from repro.comms.api import all_to_all as api_a2a

xa = jnp.arange(8 * 16, dtype=jnp.float32)
xa_want = shard_map(
    lambda y: lax.all_to_all(y, names, 0, 0, tiled=True), mesh=mesh,
    in_specs=P(names), out_specs=P(names))(xa)
with comm_context(mesh, names) as ctx_a2a:
    for mode, chunks in ((None, None), ("oneshot", None), ("chunked", 4),
                         ("perhop", None), ("hybrid", 2)):
        mtag = (mode or "planned") + (f"x{chunks}" if chunks else "")
        check(f"a2a {mtag}",
              api_a2a(xa, ctx=ctx_a2a, mode=mode, num_chunks=chunks),
              xa_want, exact=True)
    checks.append(("a2a planned via context cache",
                   any(pl.collective == "a2a" for pl in ctx_a2a.plans())))

# a2a order search: electrical cost is stage-order invariant, so the flip
# is tie-break vs strict optical preference; 2x4 ties optically — the 2x3
# table at w=2 separates (6 vs 7 RWA steps).  Meshless context: no devices.
ctx_a2a_o = CommContext(
    axis_names=("a", "b"), links=ASYM_LINKS, axis_sizes={"a": 2, "b": 3},
    policy=PlanPolicy(order="optical",
                      optical=_dc.replace(TERARACK, n_nodes=6, wavelengths=2)))
po6 = ctx_a2a_o.plan("a2a", 6 * 1024.0)
srch6 = po6.meta["order_search"]
checks.append(("a2a order flipped", srch6["flipped"]
               and po6.axes == ("b", "a")))
from repro.core import optical_message_bytes

SYS6 = _dc.replace(TERARACK, n_nodes=6, wavelengths=2)
rep6 = simulate(schedule_from_ir(po6, 2), SYS6,
                optical_message_bytes(po6), check=True)
checks.append(("a2a order price==sim",
               abs(rep6.time_s - price(po6, SYS6).total_s) < 1e-12))

# ---- MoE expert-parallel dispatch through api.all_to_all ------------------
from repro.configs import MoEConfig, expert_parallel
from repro.models.moe import moe_block, moe_init

mesh_ep = make_factorized_mesh([8], ["ep"])
cfg_moe = ModelConfig(
    name="check-moe-ep", family="moe", dtype="float32", remat=False,
    num_layers=2, d_model=16, num_heads=2, num_kv_heads=2, head_dim=8,
    d_ff=32, vocab_size=64,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24,
                  shared_expert=True))
cfg_ep = expert_parallel(cfg_moe, axis="ep")
p_moe = moe_init(jax.random.PRNGKey(13), cfg_ep, dtype=jnp.float32)
x_moe = jax.random.normal(jax.random.PRNGKey(14), (16, 4, 16), jnp.float32)
# group-local dispatch never crosses shards: the EP block must equal the
# all-experts-local block run per device shard
ref_moe = jnp.concatenate(
    [moe_block(p_moe, cfg_moe, x_moe[i * 2:(i + 1) * 2])[0]
     for i in range(8)], axis=0)
with comm_context(mesh_ep, ("ep",)) as ctx_ep:
    got_moe = jax.jit(shard_map(
        lambda pp, xx: moe_block(pp, cfg_ep, xx)[0], mesh=mesh_ep,
        in_specs=(P(), P("ep")), out_specs=P("ep")))(p_moe, x_moe)
    check("moe ep == local reference", got_moe, ref_moe, exact=True)
    checks.append(("moe ep issued a2a plans",
                   any(pl.collective == "a2a" for pl in ctx_ep.plans())
                   and ctx_ep.cache_stats.hits > 0))

# ---- ISSUE 8: latency-regime exchange execution ---------------------------
# Decode-size payloads auto-plan recursive-doubling exchange chains; the
# exchange executor must run them BIT-identically to the XLA one-shot
# collectives on the 8-device mesh, for the auto pick AND the forced
# regime="latency" policy, and the executed plan's optical price must be
# the conflict-checked simulator's wall time.
ctx_auto8 = CommContext(mesh, names, links=ASYM_LINKS)
ctx_lat8 = CommContext(mesh, names, links=ASYM_LINKS,
                       policy=PlanPolicy(regime="latency"))

x_sm = jnp.arange(256, dtype=jnp.float32)  # 1 KiB total: 128 B shards
x_sms = jax.device_put(x_sm, NamedSharding(mesh, P(names)))
shard_sm = x_sm.size * x_sm.dtype.itemsize / 8

p_auto = ctx_auto8.plan("ar", shard_sm, shape=tuple(x_sm.shape),
                        dtype=x_sm.dtype)
checks.append(("regime auto picks latency at decode size",
               p_auto.meta["regime"] == "latency"
               and all(s.mode == "exchange" for s in p_auto.stages)))
xov8 = ctx_auto8.latency_crossover("ar")
checks.append(("regime crossover bounds the auto pick",
               xov8 is not None and shard_sm < xov8))

for tag, ctx_i in (("auto", ctx_auto8), ("forced", ctx_lat8)):
    check(f"exchange ag {tag}", all_gather(x_sms, ctx=ctx_i), x_sm,
          exact=True)
    check(f"exchange rs {tag}", reduce_scatter(x_sm, ctx=ctx_i), 8 * x_sm,
          exact=True)
    check(f"exchange ar {tag}", all_reduce(x_sm, axis=0, ctx=ctx_i),
          8 * x_sm, exact=True)

for coll in ("ag", "rs", "ar"):
    pl8 = ctx_lat8.plan(coll, shard_sm, shape=tuple(x_sm.shape),
                        dtype=x_sm.dtype)
    checks.append((f"exchange {coll} all-exchange stages",
                   all(s.mode == "exchange" for s in pl8.stages)))
    rep8 = simulate(schedule_from_ir(pl8, SYS_W2.wavelengths), SYS_W2,
                    optical_message_bytes(pl8), check=True)
    checks.append((f"exchange {coll} price==sim",
                   abs(rep8.time_s - price(pl8, SYS_W2).total_s) < 1e-12))
checks.append(("regime cache counters split",
               ctx_lat8.cache_stats.latency_plans == 3
               and ctx_auto8.cache_stats.latency_plans >= 1))

# ---------------------------------------------------------------------------
failed = [n for n, ok in checks if not ok]
print(f"{len(checks) - len(failed)}/{len(checks)} checks passed")
if failed:
    raise SystemExit(f"FAILED: {failed}")
print("PLAN-EXECUTOR-OK")
