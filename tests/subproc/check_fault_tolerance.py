"""8-device chaos harness for the fault layer (ISSUE 7) — run in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(tests/test_fault_tolerance.py drives it).

Contracts:
  * the verified executor DETECTS every injected ppermute fault via its
    conservation checksums: a one-attempt drop is caught on attempt 0 and
    the bounded retry recovers bit-identically; a persistent corruption
    fails every attempt and degrades to the bit-identical XLA one-shot
    collective (``used_fallback`` raised, data never corrupted);
  * the api ops under ``PlanPolicy(verify=True)`` count executor fallbacks
    in ``CacheStats.fallbacks`` and still return bit-identical results;
  * ``ctx.report_fault`` folds a fault event into the health table,
    re-plans every cached entry in place under the degraded world
    (``CacheStats.replans_on_fault``), and subsequent ops keep producing
    bit-identical outputs;
  * an axis dead in BOTH ring directions makes staged planning impossible:
    the context degrades to a forced one-shot plan (``meta["fallback"]``,
    ``CacheStats.fallbacks``) that still executes bit-identically;
  * a seeded ``FaultTrace`` replayed over a multi-step loop leaves every
    step's collective outputs bit-identical to the healthy run while the
    cache re-plans under each new health state.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), (
    "run me via tests/test_fault_tolerance.py"
)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.comms import make_factorized_mesh
from repro.comms.api import (
    CommContext,
    PlanPolicy,
    all_gather,
    all_reduce,
    comm_context,
)
from repro.comms.plan_executor import execute_plan_verified
from repro.comms.ring_executor import FaultInjection, fault_injection
from repro.core import FaultTrace, LinkHealth
from repro.core.health import CCW, CW

checks = []


def check(name, got, want, exact=True):
    got = np.asarray(got)
    want = np.asarray(want)
    ok = got.shape == want.shape and (
        np.array_equal(got, want) if exact else np.allclose(got, want)
    )
    checks.append((name, ok))
    if not ok:
        print(f"FAIL {name}: shapes {got.shape} vs {want.shape}")
        print(" got ", got.ravel()[:8])
        print(" want", want.ravel()[:8])


def expect(name, cond):
    checks.append((name, bool(cond)))
    if not cond:
        print(f"FAIL {name}")


mesh = make_factorized_mesh([2, 4], ["a", "b"])
names = ("a", "b")
x = jnp.arange(64, dtype=jnp.float32)
xs = jax.device_put(x, NamedSharding(mesh, P(names)))

# ---- 1. verified executor detects injected faults -------------------------
# per-hop plan so the ring stages trace through the injection sites
base_ctx = CommContext(mesh, names)
plan_ag = base_ctx.plan("ag", x.size * x.dtype.itemsize / 8,
                        shape=tuple(x.shape), dtype=x.dtype).with_mode("perhop")


def run_verified(plan, retries=1):
    def fn(y):
        out, diag = execute_plan_verified(y, plan, retries=retries)
        fell = lax.psum(diag["used_fallback"].astype(jnp.int32), names)
        bad0 = lax.psum((~diag["attempt_ok"][0]).astype(jnp.int32), names)
        return out, fell, bad0

    return shard_map(fn, mesh=mesh, in_specs=P(names),
                     out_specs=(P(), P(), P()))(xs)


out, fell, bad0 = run_verified(plan_ag)
check("verified ag healthy", out, x)
expect("healthy: no fallback", int(fell) == 0)
expect("healthy: attempt 0 clean", int(bad0) == 0)

# one-attempt drop (a lost lightpath): attempt 0 must FAIL its checksums on
# every device (the zeroed block is missing mass), the retry recovers
with fault_injection(FaultInjection(axis="b", hop=1, mode="drop", times=1)) as spec:
    out, fell, bad0 = run_verified(plan_ag)
check("drop x1: recovered bits", out, x)
expect("drop x1: detected on all devices", int(bad0) == 8)
expect("drop x1: retry recovered (no fallback)", int(fell) == 0)
expect("drop x1: injection consumed once", spec.applied == 1)

# persistent corruption (+1 payload bit flips on every attempt): every
# attempt fails, the executor degrades to the XLA one-shot — bit-identical
with fault_injection(FaultInjection(axis="b", hop=2, mode="corrupt",
                                    times=999)) as spec:
    out, fell, bad0 = run_verified(plan_ag)
check("corrupt forever: fallback bits", out, x)
expect("corrupt forever: detected", int(bad0) == 8)
expect("corrupt forever: used fallback on all devices", int(fell) == 8)
expect("corrupt forever: both attempts injected", spec.applied == 2)

# ---- 2. api ops under PlanPolicy(verify=True) count fallbacks -------------
ctx_v = CommContext(mesh, names,
                    policy=PlanPolicy(verify=True, verify_retries=1))
with fault_injection(FaultInjection(axis="b", hop=1, mode="drop", times=1)):
    got = all_gather(xs, ctx=ctx_v, mode="perhop")
check("api verify: drop x1 bits", got, x)
expect("api verify: retry not counted as fallback",
       ctx_v.cache_stats.fallbacks == 0)
with fault_injection(FaultInjection(axis="b", hop=1, mode="corrupt",
                                    times=999)):
    got = all_gather(xs, ctx=ctx_v, mode="perhop")
check("api verify: corrupt-forever bits", got, x)
expect("api verify: executor fallback counted",
       ctx_v.cache_stats.fallbacks == 1)

# ---- 3. report_fault -> self-healing cache --------------------------------
with comm_context(mesh, names) as ctx:
    want_ag = all_gather(xs, ctx=ctx)
    want_ar = all_reduce(x, axis=0, ctx=ctx)
    n_plans = len(ctx.plans())
    expect("cache primed", n_plans >= 2)
    fp0 = ctx.health_fp
    ctx.report_fault(axis="a", derate=0.5)
    expect("fault changed the health fingerprint", ctx.health_fp != fp0)
    expect("every cached plan re-planned in place",
           ctx.cache_stats.replans_on_fault == n_plans)
    misses0 = ctx.cache_stats.misses
    check("degraded ag bits", all_gather(xs, ctx=ctx), want_ag)
    check("degraded ar bits", all_reduce(x, axis=0, ctx=ctx), want_ar)
    expect("degraded ops hit the re-planned cache",
           ctx.cache_stats.misses == misses0)
    expect("degraded plans stamped with the health fp",
           all(pl.meta.get("health_fp") == ctx.health_fp
               for pl in ctx.plans()))

# ---- 4. dead axis -> forced one-shot planning fallback --------------------
dead = LinkHealth.make(dead=[("a", CW), ("a", CCW)])
ctx_d = CommContext(mesh, names, health=dead)
got = all_gather(xs, ctx=ctx_d)
check("dead-axis fallback bits", got, x)
plans_d = ctx_d.plans()
expect("dead axis planned as one-shot fallback",
       len(plans_d) == 1 and plans_d[0].is_fallback
       and plans_d[0].mode == "oneshot")
expect("dead axis counted in CacheStats.fallbacks",
       ctx_d.cache_stats.fallbacks == 1)

# ---- 5. seeded FaultTrace over a multi-step loop --------------------------
STEPS = 16
trace = FaultTrace.generate(["a", "b"], STEPS, seed=11, rate=0.4,
                            wavelengths=8)
expect("trace has events", len(trace.events) > 0)
expect("trace is deterministic",
       trace == FaultTrace.generate(["a", "b"], STEPS, seed=11, rate=0.4,
                                    wavelengths=8))


def loop_outputs(ctx, with_faults):
    outs = []
    for step in range(STEPS):
        if with_faults and trace.at(step):
            ctx.update_health(trace.replay(step))
        y = x + float(step)
        ys = jax.device_put(y, NamedSharding(mesh, P(names)))
        outs.append((np.asarray(all_gather(ys, ctx=ctx)),
                     np.asarray(all_reduce(y, axis=0, ctx=ctx))))
    return outs


with comm_context(mesh, names) as ctx_h:
    healthy = loop_outputs(ctx_h, with_faults=False)
with comm_context(mesh, names) as ctx_f:
    faulty = loop_outputs(ctx_f, with_faults=True)
    expect("trace loop re-planned on faults",
           ctx_f.cache_stats.replans_on_fault > 0)
ok = all(
    np.array_equal(hg, fg) and np.array_equal(hr, fr)
    for (hg, hr), (fg, fr) in zip(healthy, faulty)
)
expect(f"all {STEPS} trace-loop steps bit-identical to healthy run", ok)

# ---------------------------------------------------------------------------
failed = [n for n, ok in checks if not ok]
print(f"{len(checks) - len(failed)}/{len(checks)} checks passed")
if failed:
    raise SystemExit(f"FAILED: {failed}")
print("FAULT-TOLERANCE-OK")
