"""Small-mesh dry-run machinery check (subprocess, 8 fake devices).

Exercises build_cell/lower/compile + the HLO collective parser for one cell
of every model family on a (data=2, model=4) mesh with reduced configs —
the same code path the production 16x16 / 2x16x16 dry-run uses.
"""
import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

import dataclasses

import jax

from repro.compat import cost_analysis, make_mesh
from repro.configs import get_config, reduced
from repro.launch.dryrun import build_cell, collective_bytes_from_hlo

mesh = make_mesh((2, 4), ("data", "model"))

CELLS = [
    ("qwen3-32b", "train_4k"),        # dense + qk_norm
    ("arctic-480b", "train_4k"),      # moe top-2 + dense residual
    ("llama4-scout-17b-a16e", "prefill_32k"),  # moe top-1 prefill
    ("rwkv6-7b", "decode_32k"),       # ssm decode
    ("zamba2-2.7b", "long_500k"),     # hybrid long-context decode
    ("hubert-xlarge", "train_4k"),    # encoder-only audio
    ("phi-3-vision-4.2b", "prefill_32k"),  # vlm prefix embeds
]

REDUCE_FIELDS = (
    "num_layers", "d_model", "num_heads", "num_kv_heads", "head_dim",
    "d_ff", "vocab_size", "moe", "ssm", "hybrid_attn_every",
    "num_prefix_embeds", "dtype", "remat",
)

for arch, shape in CELLS:
    r = reduced(get_config(arch))
    overrides = {k: getattr(r, k) for k in REDUCE_FIELDS}
    fn, args, ins, outs, meta = build_cell(arch, shape, mesh, overrides=overrides)
    with mesh:
        compiled = jax.jit(fn, in_shardings=ins, out_shardings=outs).lower(*args).compile()
    cost = cost_analysis(compiled)
    coll = collective_bytes_from_hlo(compiled.as_text())
    assert cost.get("flops", 0) > 0, (arch, shape, "no flops")
    print(f"ok {arch} x {shape}: flops={cost.get('flops'):.3e} "
          f"coll_ops={sum(coll['counts'].values())}")

print("DRYRUN-SMALL-OK")
