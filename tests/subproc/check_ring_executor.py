"""Multi-device correctness checks for the per-hop ring executor and the
collective-matmul fusion — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count={8,16}
(tests/test_ring_executor.py drives both device counts).

Contracts (ISSUE 2):
  * perhop AG / RS are BIT-identical to the XLA one-shot collective for
    every stage order, stage-mode mix, and mesh factorization — including
    non-power-of-two factorizations ([2,3], [3,4]).
  * perhop AR and the fused collective-matmuls are allclose (ring reduction
    order); with integer-valued inputs the sums are exact, so we check
    bit-equality there too.
"""
import math
import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), (
    "run me via tests/test_ring_executor.py"
)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comms import (
    StagedCollectiveEngine,
    make_factorized_mesh,
    perhop_all_gather,
    perhop_all_reduce,
    perhop_reduce_scatter,
)
from repro.kernels.collective_matmul import allgather_matmul, matmul_reduce_scatter

N_DEV = len(jax.devices())
rng = np.random.default_rng(0)
checks = []


def check(name, got, want, atol=0.0, exact=False):
    got = np.asarray(got)
    want = np.asarray(want)
    ok = got.shape == want.shape and (
        np.array_equal(got, want) if exact else np.allclose(got, want, atol=atol)
    )
    checks.append((name, ok))
    if not ok:
        print(f"FAIL {name}: shapes {got.shape} vs {want.shape}")
        print(" got ", got.ravel()[:8])
        print(" want", want.ravel()[:8])


def shmap(fn, mesh, in_specs, out_specs):
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


# factorizations to exercise per device budget (incl. non-power-of-two);
# the flagged mesh gets the full order x stage-mode matrix, the rest the
# minimal set (compile time on fake devices is the budget)
CASES = {
    8: [([8], ["r"], False), ([2, 4], ["a", "b"], True),
        ([2, 2, 2], ["a", "b", "c"], False), ([2, 3], ["a", "b"], False)],
    16: [([16], ["r"], False), ([4, 4], ["a", "b"], True),
         ([3, 4], ["a", "b"], False)],
}[N_DEV]

for factors, names, full in CASES:
    n = math.prod(factors)
    mesh = make_factorized_mesh(factors, names)
    tag = "x".join(map(str, factors))
    names_t = tuple(names)
    k = len(names)

    # ---- all-gather: bit-identical, stage orders x stage-mode mixes ------
    x = rng.normal(size=(n * 3, 5)).astype(np.float32)
    combos = {(names_t, None)}
    if full:
        combos |= {
            (tuple(reversed(names_t)), None),
            (names_t, ("oneshot",) * k),
            (names_t, tuple("ring" if i % 2 == 0 else "oneshot"
                            for i in range(k))),
        }
    for order, modes in sorted(combos, key=repr):
        got = shmap(
            lambda y, o=order, m=modes: perhop_all_gather(
                y, names_t, stage_order=o, stage_modes=m),
            mesh, P(names_t), P(),
        )(x)
        check(f"perhop_ag {tag} order={order} modes={modes}", got, x,
              exact=True)

    # ---- reduce-scatter: bit-identical on integer-valued f32 -------------
    # (sharded input: the local shard must still divide into n blocks)
    xi = rng.integers(-8, 8, size=(n * n * 2, 3)).astype(np.float32)
    want_rs = shmap(
        lambda y: lax.psum_scatter(y, names_t, scatter_dimension=0, tiled=True),
        mesh, P(names_t), P(names_t),
    )(xi)
    rs_orders = [None, names_t] if full else [None]
    for order in rs_orders:
        got = shmap(
            lambda y, o=order: perhop_reduce_scatter(y, names_t, stage_order=o),
            mesh, P(names_t), P(names_t),
        )(xi)
        check(f"perhop_rs {tag} order={order}", got, want_rs, exact=True)
    if full:
        got = shmap(
            lambda y: perhop_reduce_scatter(
                y, names_t, stage_modes=("oneshot",) * k),
            mesh, P(names_t), P(names_t),
        )(xi)
        check(f"perhop_rs {tag} oneshot-stages", got, want_rs, exact=True)

    # ---- all-reduce: exact on integer sums, allclose contract ------------
    want_ar = shmap(
        lambda y: lax.psum(y, names_t), mesh, P(names_t), P(names_t),
    )(xi)
    got = shmap(
        lambda y: perhop_all_reduce(y, names_t), mesh, P(names_t), P(names_t),
    )(xi)
    check(f"perhop_ar {tag}", got, want_ar, exact=True)

    if full:
        # non-zero gather axis
        x2 = rng.normal(size=(5, n * 2)).astype(np.float32)
        got = shmap(
            lambda y: perhop_all_gather(y, names_t, axis=1),
            mesh, P(None, names_t), P(None, None),
        )(x2)
        check(f"perhop_ag {tag} axis=1", got, x2, exact=True)

        # engine dispatch: planner-driven perhop mode
        eng = StagedCollectiveEngine(mesh, names_t)
        check(f"engine perhop ar {tag}",
              eng.all_reduce(jnp.asarray(xi), mode="perhop"), n * xi,
              exact=True)
        xs = jax.device_put(
            jnp.asarray(xi),
            jax.sharding.NamedSharding(mesh, P(names_t)),
        )
        check(f"engine perhop ag {tag}",
              eng.all_gather(xs, mode="perhop"), xi, exact=True)
        check(f"engine perhop rs {tag}",
              eng.reduce_scatter(jnp.asarray(xi), mode="perhop"),
              n * xi, exact=True)

    # ---- collective-matmul fusion ----------------------------------------
    d_in, d_out = 8, 5
    xm = rng.normal(size=(2, n * 2, d_in)).astype(np.float32)
    w = rng.normal(size=(d_in, d_out)).astype(np.float32)
    want_mm = np.einsum("bsd,df->bsf", xm, w)
    g, got = shmap(
        lambda y: allgather_matmul(y, w, names_t, axis=1),
        mesh, P(None, names_t, None), (P(), P()),
    )(xm)
    check(f"ag_matmul {tag} gathered", g, xm, exact=True)
    check(f"ag_matmul {tag} out", got, want_mm, atol=1e-5)

    h = rng.normal(size=(2, n * 2, d_in)).astype(np.float32)
    want_mmrs = shmap(
        lambda y: lax.psum_scatter(
            jnp.einsum("bsd,df->bsf", y, w), names_t,
            scatter_dimension=1, tiled=True),
        mesh, P(), P(None, names_t, None),
    )(h)
    got = shmap(
        lambda y: matmul_reduce_scatter(y, w, names_t, axis=1),
        mesh, P(), P(None, names_t, None),
    )(h)
    check(f"matmul_rs {tag}", got, want_mmrs, atol=1e-5)


# ---- fused SP FFN vs the unfused explicit-TP path (bf16 tolerances) ------
from repro.models.mlp import ffn_apply, ffn_apply_tp_sp, ffn_init
from repro.models.attention import attention_tp_out_sp

factors, names, _ = CASES[1]  # 2-axis mesh
n = math.prod(factors)
mesh = make_factorized_mesh(factors, names)
names_t = tuple(names)
d_model, d_ff, B = 16, 16 * n, 2
S = 4 * n
key = jax.random.key(0)
pf = ffn_init(key, d_model, d_ff, num_layers=2, dtype=jnp.float32)
xa = jnp.asarray(rng.normal(size=(B, S, d_model)).astype(np.float32))
want_ffn = ffn_apply(pf, xa)


def tp_sp(x, fuse):
    idx = lax.axis_index(names_t)
    lff = d_ff // n
    p_local = {
        k: {"w": lax.dynamic_slice_in_dim(
            pf[k]["w"], idx * lff, lff, axis=(0 if k == "down" else 1))}
        for k in ("gate", "up", "down")
    }
    return ffn_apply_tp_sp(p_local, x, names_t, fuse=fuse)


for fuse in (True, False, "auto"):
    got = shmap(
        lambda y, f=fuse: tp_sp(y, f),
        mesh, P(None, names_t, None), P(None, names_t, None),
    )(xa)
    check(f"ffn_tp_sp fuse={fuse}", got, want_ffn, atol=3e-5)

q_dim = 2 * n
wo = jnp.asarray(rng.normal(size=(q_dim, d_model)).astype(np.float32)) * 0.1
bias = jnp.asarray(rng.normal(size=(d_model,)).astype(np.float32))
heads_out = jnp.asarray(rng.normal(size=(B, S, q_dim)).astype(np.float32))
want_attn = heads_out @ wo + bias


def attn_sp(x, fuse):
    idx = lax.axis_index(names_t)
    lq = q_dim // n
    lx = lax.dynamic_slice_in_dim(x, idx * lq, lq, axis=2)
    lw = lax.dynamic_slice_in_dim(wo, idx * lq, lq, axis=0)
    return attention_tp_out_sp({"wo": {"w": lw, "b": bias}}, lx, names_t,
                               fuse=fuse)


for fuse in (True, False, "auto"):
    got = shmap(
        lambda y, f=fuse: attn_sp(y, f),
        mesh, P(), P(None, names_t, None),
    )(heads_out)
    check(f"attn_tp_out_sp fuse={fuse}", got, want_attn, atol=3e-5)


# ---- report ---------------------------------------------------------------
bad = [nm for nm, ok in checks if not ok]
print(f"{len(checks) - len(bad)}/{len(checks)} ring-executor checks passed "
      f"({N_DEV} devices)")
if bad:
    raise SystemExit(f"FAILED: {bad}")
print("RING-EXECUTOR-OK")
