"""Property-based tests (hypothesis) for the scheduling invariants."""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")

from hypothesis import given, settings, strategies as st

from repro.core import (
    DCN_LINK,
    ICI_LINK,
    OpTreePlan,
    build_ne_schedule,
    build_one_stage_schedule,
    build_optree_schedule,
    plan_axis_order,
    plan_staged_allgather,
    steps,
    validate_schedule,
)

factors_strategy = st.lists(st.integers(min_value=2, max_value=5), min_size=1, max_size=3)


@given(factors=factors_strategy, w=st.integers(min_value=1, max_value=64))
@settings(max_examples=40, deadline=None)
def test_optree_schedule_always_valid(factors, w):
    n = math.prod(factors)
    plan = OpTreePlan(n, tuple(factors))
    sched = build_optree_schedule(plan, w)
    validate_schedule(sched)  # conflict-free + causal + complete
    # stages >= 2 exactly match the analytic line-demand step count
    for j, got in enumerate(sched.stage_steps[1:], start=2):
        assert got == math.ceil(steps.optree_stage_demand(plan, j) / w)
    # stage 1 (ring) within +1 of the analytic demand
    assert sched.stage_steps[0] <= math.ceil(steps.optree_stage_demand(plan, 1) / w) + 1


@given(n=st.integers(min_value=3, max_value=40), w=st.integers(min_value=1, max_value=16))
@settings(max_examples=30, deadline=None)
def test_one_stage_schedule_always_valid(n, w):
    sched = build_one_stage_schedule(n, w)
    validate_schedule(sched)
    assert sched.num_steps <= steps.one_stage_steps(n, w) + math.ceil(2 / w) + 1


@given(n=st.integers(min_value=2, max_value=24).map(lambda x: 2 * x))
@settings(max_examples=20, deadline=None)
def test_ne_schedule_always_valid(n):
    sched = build_ne_schedule(n, 64)
    validate_schedule(sched)
    assert sched.num_steps == n // 2


@given(
    axis=st.integers(min_value=2, max_value=512),
    shard=st.floats(min_value=1e3, max_value=1e9),
)
@settings(max_examples=30, deadline=None)
def test_planner_volume_telescopes(axis, shard):
    plan = plan_staged_allgather(axis, shard)
    assert math.prod(plan.factors) == axis
    # total moved volume is invariant: sum (m_j - 1) * payload_j == (N-1)*shard
    vol = sum((s.factor - 1) * s.payload_bytes for s in plan.stages)
    assert abs(vol - (axis - 1) * shard) / ((axis - 1) * shard) < 1e-9


@given(
    pods=st.integers(min_value=2, max_value=8),
    per_pod=st.sampled_from([4, 8, 16]),
    shard=st.floats(min_value=1e5, max_value=1e8),
)
@settings(max_examples=20, deadline=None)
def test_planner_orders_slow_axis_first(pods, per_pod, shard):
    # the OpTree stage-1 analogue: gather the slow (DCN/pod) axis while the
    # payload is small
    plan = plan_axis_order([(pods, DCN_LINK), (per_pod, ICI_LINK)], shard)
    assert plan.stages[0].link.name == "dcn"
    assert plan.stages[0].payload_bytes <= plan.stages[-1].payload_bytes
