"""Substrate tests: optimizer, data pipeline, checkpointing, fault-tolerant
trainer, batched serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLMPipeline
from repro.checkpoint import Checkpointer
from repro.models import init_params
from repro.optim import OptimizerConfig, adamw_init, adamw_update, cosine_lr
from repro.runtime import BatchedServer, ServerConfig, Trainer, TrainerConfig, make_train_step


def tiny_cfg():
    return dataclasses.replace(
        reduced(get_config("granite-3-2b")), num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
    )


class TestOptimizer:
    def test_adamw_descends_quadratic(self):
        params = {"w": jnp.ones((4,), jnp.float32) * 5.0}
        opt = adamw_init(params)
        cfg = OptimizerConfig(peak_lr=0.5, warmup_steps=0, decay_steps=1000,
                              weight_decay=0.0)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, opt = adamw_update(grads, opt, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_clip_and_schedule(self):
        cfg = OptimizerConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10,
                              decay_steps=100)
        assert float(cosine_lr(cfg, jnp.asarray(0))) == 0.0
        assert float(cosine_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(cosine_lr(cfg, jnp.asarray(100))) == pytest.approx(0.1)
        # huge grads get clipped -> finite update
        params = {"w": jnp.ones((4,))}
        opt = adamw_init(params)
        p2, _ = adamw_update({"w": jnp.full((4,), 1e12)}, opt, params, cfg)
        assert bool(jnp.isfinite(p2["w"]).all())

    def test_zero1_specs(self):
        from jax.sharding import PartitionSpec as P

        from repro.optim import opt_state_specs

        from repro.compat import make_mesh

        mesh = make_mesh((1, 1), ("data", "model"))
        pspecs = {"a": P(None, "model"), "b": P("model", None)}
        shapes = {"a": jax.ShapeDtypeStruct((8, 4), jnp.float32),
                  "b": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
        ospecs = opt_state_specs(pspecs, shapes, mesh)
        assert ospecs["m"]["a"] == P("data", "model")
        assert ospecs["m"]["b"] == P("model", "data")


class TestData:
    def test_deterministic_and_restorable(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4, seed=3)
        p1 = SyntheticLMPipeline(cfg)
        batches = [next(p1) for _ in range(5)]
        p2 = SyntheticLMPipeline(cfg)
        p2.restore({"step": 3, "seed": 3})
        np.testing.assert_array_equal(next(p2)["tokens"], batches[3]["tokens"])

    def test_host_sharding_disjoint(self):
        kw = dict(vocab_size=512, seq_len=16, global_batch=8, seed=1, num_hosts=2)
        a = next(SyntheticLMPipeline(DataConfig(host_id=0, **kw)))
        b = next(SyntheticLMPipeline(DataConfig(host_id=1, **kw)))
        assert a["tokens"].shape == (4, 16)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_prefetch_thread(self):
        cfg = DataConfig(vocab_size=128, seq_len=8, global_batch=2, seed=5)
        p = SyntheticLMPipeline(cfg).start()
        try:
            ref = SyntheticLMPipeline(cfg)
            for _ in range(4):
                np.testing.assert_array_equal(next(p)["tokens"], next(ref)["tokens"])
        finally:
            p.stop()

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2, seed=7)
        b = next(SyntheticLMPipeline(cfg))
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestCheckpoint:
    def test_roundtrip_and_latest(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        state = {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
                 "step_count": np.asarray(7)}
        ck.save(10, state)
        ck.save(20, state)
        ck.save(30, state)
        assert ck.latest_step() == 30
        # keep=2 garbage-collects step 10
        assert not (tmp_path / "step_00000010").exists()
        step, restored = ck.restore(state)
        assert step == 30
        np.testing.assert_array_equal(restored["params"]["w"], state["params"]["w"])

    def test_uncommitted_tmp_ignored(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        (tmp_path / "step_00000099.tmp").mkdir()
        assert ck.latest_step() is None

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(5, {"x": np.ones(3)}, blocking=False)
        ck.wait()
        assert ck.latest_step() == 5


class TestTrainer:
    def _mk(self, tmp_path, fault_injector=None, steps=12):
        cfg = tiny_cfg()
        params = init_params(jax.random.key(0), cfg)
        opt_state = adamw_init(params)
        pipe = SyntheticLMPipeline(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
        )
        ocfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)
        tcfg = TrainerConfig(total_steps=steps, ckpt_interval=4,
                             ckpt_dir=str(tmp_path))
        return Trainer(cfg, ocfg, tcfg, params=params, opt_state=opt_state,
                       pipeline=pipe, fault_injector=fault_injector)

    def test_loss_decreases(self, tmp_path):
        t = self._mk(tmp_path, steps=15)
        out = t.run()
        assert out["final_step"] == 15
        assert out["losses"][-1] < out["losses"][0]

    def test_crash_restart(self, tmp_path):
        crashed = {"done": False}

        def injector(step):
            if step == 6 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("injected node failure")

        t = self._mk(tmp_path, fault_injector=injector, steps=10)
        out = t.run()
        assert out["restarts"] == 1
        assert out["final_step"] == 10  # resumed from step-4 checkpoint

    def test_straggler_detection(self, tmp_path):
        t = self._mk(tmp_path, steps=8)
        t.step_time_ema = 1e-9  # everything is now a straggler
        t.run()
        assert len(t.straggler_events) >= 1


class TestServer:
    def test_continuous_batching_drains(self):
        cfg = tiny_cfg()
        params = init_params(jax.random.key(1), cfg)
        server = BatchedServer(cfg, params, ServerConfig(batch_size=2, max_seq=64,
                                                         max_new_tokens=4))
        rng = np.random.default_rng(0)
        rids = [server.submit(rng.integers(0, cfg.vocab_size, size=n))
                for n in (5, 3, 7)]
        results = server.run_until_drained()
        assert set(results) == set(rids)
        for rid in rids:
            assert len(results[rid]) == 4

    def test_server_matches_plain_decode(self):
        """Slot-batched serving produces the same greedy continuation as a
        standalone prefill+decode of the same prompt."""
        from repro.models import decode_step, forward, init_decode_state

        cfg = tiny_cfg()
        params = init_params(jax.random.key(2), cfg)
        prompt = np.asarray([3, 14, 15, 92, 6], np.int32)

        server = BatchedServer(cfg, params, ServerConfig(batch_size=2, max_seq=32,
                                                         max_new_tokens=3))
        rid = server.submit(prompt)
        got = server.run_until_drained()[rid]

        state = init_decode_state(cfg, 1, 32)
        logits, state, _ = forward(cfg, params, {"tokens": jnp.asarray(prompt[None])},
                                   cache=state, cache_pos=jnp.zeros((), jnp.int32))
        tok = int(jnp.argmax(logits[0, -1]))
        want = [tok]
        pos = len(prompt)
        for _ in range(2):
            l1, state = decode_step(cfg, params, state,
                                    jnp.asarray([[tok]], jnp.int32),
                                    jnp.asarray(pos, jnp.int32))
            tok = int(jnp.argmax(l1[0]))
            want.append(tok)
            pos += 1
        assert got == want


class TestOptimizerCompression:
    def test_bf16_master_free_descends(self):
        import jax.numpy as jnp

        params = {"w": jnp.ones((128,), jnp.bfloat16) * 3.0}
        cfg = OptimizerConfig(peak_lr=0.1, warmup_steps=0, decay_steps=500,
                              weight_decay=0.0, state_dtype="bfloat16",
                              use_master=False)
        opt = adamw_init(params, cfg)
        assert "master" not in opt
        assert opt["m"]["w"].dtype == jnp.bfloat16
        for _ in range(100):
            grads = {"w": 2 * params["w"].astype(jnp.float32)}
            params, opt = adamw_update(grads, opt, params, cfg)
        assert float(jnp.abs(params["w"].astype(jnp.float32)).max()) < 1.0


class TestGradAccum:
    def test_accumulated_equals_fullbatch(self):
        """grad_accum=N produces the same update as the full batch (linear
        loss in batch => mean of microbatch grads == full grad)."""
        import dataclasses as dc

        import jax
        import jax.numpy as jnp

        from repro.models import loss_fn

        cfg = tiny_cfg()
        params = init_params(jax.random.key(0), cfg)
        pipe = SyntheticLMPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                              seq_len=16, global_batch=4))
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}

        (_, m), g_full = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)

        micro = jax.tree.map(lambda a: a.reshape((2, 2) + a.shape[1:]), batch)
        g_acc = jax.tree.map(jnp.zeros_like, params)
        for i in range(2):
            mb = jax.tree.map(lambda a: a[i], micro)
            (_, _), g = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, mb), has_aux=True)(params)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
        g_acc = jax.tree.map(lambda g: g / 2, g_acc)
        import numpy as np

        for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
