"""Step-count closed forms vs. the paper's printed numbers (Table I, §III-C)."""
import math

import pytest

from repro.core import OpTreePlan, steps
from repro.core import tree


class TestTable1:
    """Table I @ N=1024, w=64."""

    def test_ring(self):
        assert steps.ring_steps(1024) == 1023

    def test_ne(self):
        assert steps.neighbor_exchange_steps(1024) == 512

    def test_optree(self):
        k, s = steps.optree_optimal_steps(1024, 64)
        assert s == 70  # paper: 70 (k*=7; k=6 also gives 70)

    def test_one_stage_formula(self):
        # Formula value; the printed "128" is inconsistent with w=64 (see
        # DESIGN.md / steps.py docstrings) and with the paper's own Fig.-4
        # "96.85% avg reduction vs one-stage" claim, which needs 2048.
        assert steps.one_stage_steps(1024, 64) == 2048

    def test_wrht_formula_vs_paper(self):
        # Printed formula (theta = ceil(log_p N), p = 2w+1) != printed 259.
        assert steps.wrht_steps_paper_table(1024, 64) == 259
        assert steps.wrht_steps_formula(1024, 64) == 24  # literal reading


class TestMotivatingExample:
    """§III-C: N=16, w=2."""

    def test_one_stage(self):
        assert steps.one_stage_steps(16, 2) == 16

    def test_two_stage_4ary(self):
        plan = OpTreePlan(16, (4, 4))
        assert steps.optree_stage_demand(plan, 1) == 8  # 4 * ceil(16/8)
        assert steps.optree_stage_demand(plan, 2) == 16  # 4 * floor(16/4)
        assert steps.optree_steps_exact(plan, 2) == 12  # 4 + 8


def test_lemma1():
    assert steps.lemma1_wavelengths_line(16) == 64
    assert steps.lemma1_wavelengths_ring(16) == 32


def test_thm1_matches_exact_for_perfect_powers():
    # For N = m^k the closed form and per-stage accounting agree up to the
    # merged-vs-per-stage ceiling (<= k-1 steps).
    for n, k in [(16, 2), (64, 2), (64, 3), (256, 2), (256, 4), (1024, 5)]:
        w = 64
        plan = OpTreePlan(n, tree.balanced_factors(n, k))
        exact = steps.optree_steps_exact(plan, w)
        thm1 = steps.optree_steps_thm1(n, k, w)
        assert abs(exact - thm1) <= k, (n, k, exact, thm1)


def test_optree_beats_baselines_at_scale():
    for n in [512, 1024, 2048, 4096]:
        w = 64
        _, s = steps.optree_optimal_steps(n, w)
        assert s < steps.one_stage_steps(n, w)
        assert s < steps.neighbor_exchange_steps(n)
        assert s < steps.ring_steps(n)


def test_fig4_one_stage_reduction_claim():
    # Paper: "Compared with the one-stage model ... reduce communication time
    # by 96.85% on average" over N in {512,1024,2048,4096} (w=64).  Time is
    # proportional to steps (same per-step duration).
    reds = []
    for n in [512, 1024, 2048, 4096]:
        _, s = steps.optree_optimal_steps(n, 64)
        reds.append(1 - s / steps.one_stage_steps(n, 64))
    avg = sum(reds) / len(reds)
    assert avg == pytest.approx(0.9685, abs=0.01), avg
