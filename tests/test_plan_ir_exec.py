"""Drive the 8-device IR-executor + collective-matmul-vjp checks in a
subprocess so the main pytest process keeps jax at a single device — same
pattern as tests/test_comms.py."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(script: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "subproc" / script)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.mark.slow
@pytest.mark.subproc
def test_plan_executor_multi_device():
    out = _run("check_plan_executor.py")
    assert "PLAN-EXECUTOR-OK" in out
