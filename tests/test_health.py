"""Unit tests for the fault layer (ISSUE 7): the LinkHealth table, fault
events/traces, JSON round-trips, degraded planning (derates, dead axes,
dead-direction pruning), health-aware RWA lowering, and the validator's /
simulator's rejection of transmissions the health table forbids."""
import dataclasses
import json
import math

import pytest

from repro.core import (
    DeadAxisError,
    DeadDirectionError,
    FaultEvent,
    FaultTrace,
    LinkHealth,
    choose_hop_schedule,
    health_fingerprint,
    load_health,
    schedule_from_ir,
    search_stage_orders,
    validate_health,
    validate_schedule,
)
from repro.core.cost_model import TERARACK, price
from repro.core.health import CCW, CW
from repro.core.planner import ICI_LINK, LinkSpec
from repro.core.validate import ScheduleError
from repro.optics import simulate

SLOW = LinkSpec("slow", 1e9, 1e-5)
FAST = LinkSpec("fast", 50e9, 1e-6)


def _sys(n, w):
    return dataclasses.replace(TERARACK, n_nodes=n, wavelengths=w)


# --------------------------------------------------------------------------
# LinkHealth table semantics
# --------------------------------------------------------------------------

class TestLinkHealth:
    def test_empty_is_healthy(self):
        h = LinkHealth()
        assert h.is_healthy
        assert h.fingerprint() == "healthy"
        assert health_fingerprint(None) == "healthy"
        assert h.axis_factor("x") == 1.0
        assert h.describe() == "healthy"

    def test_axis_factor_best_alive_direction(self):
        h = LinkHealth.make(derate={("x", CW): 0.25})
        # CCW is untouched: the planner can route around the slow direction
        assert h.axis_factor("x") == 1.0
        h2 = LinkHealth.make(derate={("x", CW): 0.25, ("x", CCW): 0.5})
        assert h2.axis_factor("x") == 0.5
        h3 = LinkHealth.make(derate={("x", CW): 0.25}, dead=[("x", CCW)])
        assert h3.axis_factor("x") == 0.25
        h4 = LinkHealth.make(dead=[("x", CW), ("x", CCW)])
        assert h4.axis_factor("x") == 0.0 and h4.axis_dead("x")
        # unnamed axes (paper-world plans) are assumed healthy
        assert h4.axis_factor(None) == 1.0

    def test_derate_range_enforced(self):
        with pytest.raises(ValueError, match=r"derate must be in \(0, 1\]"):
            LinkHealth.make(derate={("x", CW): 0.0})
        with pytest.raises(ValueError, match=r"derate must be in \(0, 1\]"):
            LinkHealth.make(derate={("x", CW): 1.5})
        with pytest.raises(ValueError, match="direction"):
            LinkHealth.make(derate={("x", 2): 0.5})
        # dataclasses.replace re-validates through __post_init__
        h = LinkHealth.make(derate={("x", CW): 0.5})
        with pytest.raises(ValueError):
            dataclasses.replace(h, derate=((("x", CW), -1.0),))

    def test_degrade_link(self):
        h = LinkHealth.make(derate={("x", CW): 0.5, ("x", CCW): 0.5})
        got = h.degrade_link("x", ICI_LINK)
        assert got.bandwidth_bytes == pytest.approx(
            ICI_LINK.bandwidth_bytes * 0.5)
        assert h.degrade_link("y", ICI_LINK) is ICI_LINK  # untouched axis
        dead = LinkHealth.make(dead=[("x", CW), ("x", CCW)])
        with pytest.raises(DeadAxisError, match="dead in both"):
            dead.degrade_link("x", ICI_LINK)

    def test_union_semantics_shared_ring(self):
        h = LinkHealth.make(lost_wavelengths={"a": (0, 1), "b": (3,)},
                            dead=[("b", CCW)])
        assert h.lost_for(["a"]) == frozenset({0, 1})
        assert h.lost_for(["a", "b"]) == frozenset({0, 1, 3})
        assert h.lost_for(None) == frozenset({0, 1, 3})
        assert h.lost_for([None]) == frozenset({0, 1, 3})  # unnamed -> all
        assert h.dead_directions(["a"]) == frozenset()
        assert h.dead_directions(["a", "b"]) == frozenset({CCW})

    def test_apply_and_recover(self):
        h = LinkHealth()
        h = h.apply(FaultEvent(0, "derate", "x", direction=CW, derate=0.5))
        h = h.apply(FaultEvent(1, "lose_wavelength", "x", wavelength=3))
        h = h.apply(FaultEvent(2, "dead", "y", direction=CCW))
        assert not h.is_healthy
        assert h.direction_factor("x", CW) == 0.5
        assert h.lost_for(["x"]) == frozenset({3})
        assert h.dead_directions(["y"]) == frozenset({CCW})
        # recover piecewise, then wholesale
        h = h.apply(FaultEvent(3, "recover", "x", wavelength=3))
        assert h.lost_for(["x"]) == frozenset()
        h = h.apply(FaultEvent(4, "recover", "x", direction=CW))
        assert h.direction_factor("x", CW) == 1.0
        h = h.apply(FaultEvent(5, "recover", "y"))
        assert h.is_healthy and h.fingerprint() == "healthy"

    def test_fingerprint_stable_and_order_free(self):
        a = LinkHealth.make(derate={("x", CW): 0.5, ("y", CCW): 0.25})
        b = LinkHealth.make(derate={("y", CCW): 0.25, ("x", CW): 0.5})
        assert a.fingerprint() == b.fingerprint()
        c = LinkHealth.make(derate={("x", CW): 0.75})
        assert a.fingerprint() != c.fingerprint()

    def test_event_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent(0, "explode", "x")
        with pytest.raises(ValueError, match="derate"):
            FaultEvent(0, "derate", "x")
        with pytest.raises(ValueError, match="wavelength"):
            FaultEvent(0, "lose_wavelength", "x")
        with pytest.raises(ValueError, match="direction"):
            FaultEvent(0, "dead", "x", direction=7)


class TestHealthJson:
    H = LinkHealth.make(
        derate={("pod", CW): 0.5, ("tp", CCW): 0.25},
        dead=[("pod", CCW)],
        lost_wavelengths={"tp": (1, 5)},
    )

    def test_round_trip(self, tmp_path):
        doc = self.H.to_json()
        assert LinkHealth.from_json(doc) == self.H
        p = tmp_path / "health.json"
        p.write_text(json.dumps(doc))
        assert load_health(p, expect_axes=["pod", "tp"]) == self.H

    def test_expect_axes_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown axes \\['pod', 'tp'\\]"):
            LinkHealth.from_json(self.H.to_json(), expect_axes=["data"])
        # sparse tables are fine: missing axes are simply healthy
        sub = LinkHealth.make(derate={("tp", CW): 0.5})
        got = LinkHealth.from_json(sub.to_json(),
                                   expect_axes=["pod", "tp", "data"])
        assert got == sub

    def test_bad_payloads_never_load(self):
        with pytest.raises(ValueError, match=r"derate must be in \(0, 1\]"):
            LinkHealth.from_json({"derate": [["x", "cw", 2.0]]})
        with pytest.raises(ValueError, match="'cw' or 'ccw'"):
            LinkHealth.from_json({"dead": [["x", "sideways"]]})
        with pytest.raises(ValueError, match="unknown health table keys"):
            LinkHealth.from_json({"derates": []})
        with pytest.raises(ValueError, match="mapping"):
            LinkHealth.from_json([1, 2])


class TestFaultTrace:
    def test_deterministic(self):
        a = FaultTrace.generate(["x", "y"], 50, seed=7, rate=0.3)
        b = FaultTrace.generate(["x", "y"], 50, seed=7, rate=0.3)
        assert a == b and a.events
        c = FaultTrace.generate(["x", "y"], 50, seed=8, rate=0.3)
        assert a != c

    def test_replay_folds_recoveries(self):
        tr = FaultTrace(events=(
            FaultEvent(1, "derate", "x", direction=CW, derate=0.5),
            FaultEvent(3, "recover", "x", direction=CW),
        ))
        assert tr.replay(0).is_healthy
        assert tr.replay(1).direction_factor("x", CW) == 0.5
        assert tr.replay(3).is_healthy
        assert tr.at(1) and not tr.at(2)


# --------------------------------------------------------------------------
# degraded planning: derated links, dead axes, dead-direction pruning
# --------------------------------------------------------------------------

class TestDegradedPlanning:
    def test_choose_hop_schedule_derates_named_axes(self):
        h = LinkHealth.make(derate={("a", CW): 0.5, ("a", CCW): 0.5})
        healthy = choose_hop_schedule([2, 4], [SLOW, FAST], 2**20)
        degraded = choose_hop_schedule([2, 4], [SLOW, FAST], 2**20,
                                       health=h, axis_names=("a", "b"))
        assert degraded.time_s >= healthy.time_s

    def test_choose_hop_schedule_dead_axis_raises(self):
        h = LinkHealth.make(dead=[("a", CW), ("a", CCW)])
        with pytest.raises(DeadAxisError, match="'a' is dead"):
            choose_hop_schedule([2, 4], [SLOW, FAST], 2**20,
                                health=h, axis_names=("a", "b"))

    def test_single_axis_ring_survives_dead_ccw(self):
        """The pure ring order (stride-1 CW hops) survives a dead CCW
        direction while every multi-stage factorization is pruned — the
        non-vacuous pruning case."""
        h = LinkHealth.make(dead=[("x", CCW)])
        srch = search_stage_orders([(None, 8, SLOW)], 2**20,
                                   backend="optical", system=_sys(8, 2),
                                   health=h)
        assert len(srch.candidates) == 1
        assert len(srch.candidates[0].plan.stages) == 1  # the pure ring
        assert srch.pruned  # the (2,4)/(4,2)/(2,2,2) factorizations died
        for sched_order in srch.pruned:
            assert len(sched_order) > 1

    def test_mesh_all_orders_pruned_raises(self):
        """On a named 2x4 mesh every candidate contains a factor-2 pair
        exchange that uses both ring directions, so one dead direction
        prunes everything -> DeadDirectionError names the pruned orders."""
        h = LinkHealth.make(dead=[("a", CCW)])
        axes = [("a", 2, FAST), ("b", 4, SLOW)]
        with pytest.raises(DeadDirectionError,
                           match="every ag stage-order candidate"):
            search_stage_orders(axes, 2**20, backend="optical",
                                system=_sys(8, 8), health=h)

    @pytest.mark.parametrize("coll", ["ag", "rs", "ar", "a2a"])
    def test_electrical_price_monotone(self, coll):
        hs = choose_hop_schedule([2, 4], [SLOW, FAST], 2**20,
                                 collective=coll)
        names = ("a", "b") * (len(hs.stages) // 2)  # ar lowers to RS+AG
        plan = hs.to_ir(names)
        h = LinkHealth.make(derate={("b", CW): 0.5, ("b", CCW): 0.5})
        assert price(plan, health=h).total_s >= price(plan).total_s


# --------------------------------------------------------------------------
# health-aware lowering + validation + simulation
# --------------------------------------------------------------------------

class TestHealthLowering:
    def _plan(self, coll="ag"):
        hs = choose_hop_schedule([2, 4], [FAST, FAST], 2**20,
                                 collective=coll)
        names = ("a", "b") * (len(hs.stages) // 2)  # ar lowers to RS+AG
        return hs.to_ir(names)

    @pytest.mark.parametrize("coll", ["ag", "rs", "ar", "a2a"])
    def test_lowering_avoids_lost_wavelengths(self, coll):
        plan = self._plan(coll)
        h = LinkHealth.make(lost_wavelengths={"a": (0,), "b": (2,)})
        w = 4
        sched = schedule_from_ir(plan, w, health=h)
        assert sched.w == w  # physical wavelength count is preserved
        assert sched.meta["lost_wavelengths"] == (0, 2)
        assert sched.meta["w_effective"] == 2
        used = {t.wavelength for t in sched.txs}
        assert used.isdisjoint({0, 2})
        validate_schedule(sched, health=h)
        rep = simulate(sched, _sys(8, w), 2**20, check=True, health=h)
        assert rep.steps == sched.num_steps

    def test_all_wavelengths_lost_refuses(self):
        plan = self._plan()
        h = LinkHealth.make(lost_wavelengths={"a": (0, 1)})
        with pytest.raises(Exception, match="all 2 wavelengths lost"):
            schedule_from_ir(plan, 2, health=h)

    def test_validator_names_the_offender(self):
        plan = self._plan()
        sched = schedule_from_ir(plan, 4)
        wl = sched.txs[0].wavelength
        h = LinkHealth.make(lost_wavelengths={"a": (wl,)})
        with pytest.raises(ScheduleError,
                           match=f"LOST wavelength.*wl={wl}"):
            validate_health(sched, h)
        d = sched.txs[0].direction
        h2 = LinkHealth.make(dead=[("a", d)])
        with pytest.raises(ScheduleError, match="DEAD ring direction"):
            validate_health(sched, h2)

    def test_simulator_rejects_forbidden_transmissions(self):
        plan = self._plan()
        sched = schedule_from_ir(plan, 4)
        wl = sched.txs[0].wavelength
        h = LinkHealth.make(lost_wavelengths={"b": (wl,)})
        with pytest.raises(AssertionError, match="LOST wavelength"):
            simulate(sched, _sys(8, 4), 2**20, check=True, health=h)

    def test_degraded_lowering_matches_shrunken_w(self):
        """Losing wavelengths is exactly planning at the reduced w: the
        degraded schedule's step structure equals the healthy lowering at
        w_eff (the slots are just renamed onto surviving wavelengths)."""
        plan = self._plan()
        h = LinkHealth.make(lost_wavelengths={"a": (1, 3)})
        degraded = schedule_from_ir(plan, 4, health=h)
        shrunk = schedule_from_ir(plan, 2)
        assert degraded.num_steps == shrunk.num_steps
        assert degraded.stage_steps == shrunk.stage_steps
