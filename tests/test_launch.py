"""Launch-layer unit tests: HLO collective parser, roofline math, cell
enumeration, elastic replanning.  (The heavy lower+compile path is covered
by tests/test_dryrun_small.py in a subprocess.)"""
import json

import pytest

from repro.launch.dryrun import collective_bytes_from_hlo, iter_cells
from repro.launch.roofline import (
    CHIPS,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    model_flops_per_device,
    roofline_for_cell,
)


HLO_SAMPLE = """
  %p0 = bf16[4,512,128]{2,1,0} parameter(0)
  %fus = f32[16,4096]{1,0} fusion(%p0), kind=kLoop
  %ag.1 = bf16[4,1024,128]{2,1,0} all-gather(%p0), channel_id=1
  %ar = f32[16,4096]{1,0} all-reduce(%fus), to_apply=%add
  %rs.2 = f32[8,4096]{1,0} reduce-scatter(%ar), channel_id=3
  %cp = bf16[4,512,128]{2,1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %a2a.7 = f32[16,4096]{1,0} all-to-all(%fus), channel_id=9
"""


class TestHloParser:
    def test_counts_and_operand_bytes(self):
        r = collective_bytes_from_hlo(HLO_SAMPLE)
        assert r["counts"] == {
            "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
            "collective-permute": 1, "all-to-all": 1,
        }
        p0 = 4 * 512 * 128 * 2
        fus = 16 * 4096 * 4
        assert r["bytes_by_kind"]["all-gather"] == p0
        assert r["bytes_by_kind"]["all-reduce"] == fus
        assert r["bytes_by_kind"]["collective-permute"] == p0
        assert r["bytes_by_kind"]["all-to-all"] == fus
        # result bytes differ from operand bytes for gather/scatter
        assert r["result_bytes_by_kind"]["all-gather"] == 2 * p0
        assert r["result_bytes_by_kind"]["reduce-scatter"] == 8 * 4096 * 4

    def test_ignores_non_collectives(self):
        r = collective_bytes_from_hlo("%x = f32[2]{0} add(%a, %b)\n")
        assert r["total_bytes"] == 0 and not r["counts"]


class TestCellEnumeration:
    def test_31_runnable_9_skipped(self):
        cells = list(iter_cells())
        runnable = [c for c in cells if c[2]]
        skipped = [c for c in cells if not c[2]]
        assert len(runnable) == 31
        assert len(skipped) == 9
        assert all(why for *_, why in skipped)


class TestRooflineMath:
    def _cell(self, flops=1e15, hbytes=1e12, cbytes=1e11):
        return {
            "ok": True, "arch": "qwen3-32b", "shape": "train_4k",
            "calibrated": {
                "flops": flops, "bytes_accessed": hbytes,
                "collective_bytes": cbytes,
            },
        }

    def test_terms_and_bottleneck(self):
        r = roofline_for_cell(self._cell())
        assert r.compute_s == pytest.approx(1e15 / PEAK_FLOPS)
        assert r.memory_s == pytest.approx(1e12 / HBM_BW)
        assert r.collective_s == pytest.approx(1e11 / ICI_BW)
        assert r.bottleneck == "compute"
        assert 0 < r.roofline_fraction <= 1.0

    def test_bottleneck_flips(self):
        r = roofline_for_cell(self._cell(flops=1e12, cbytes=1e13))
        assert r.bottleneck == "collective"
        assert r.roofline_fraction < 0.1

    def test_model_flops_scaling(self):
        train = model_flops_per_device("qwen3-32b", "train_4k")
        prefill = model_flops_per_device("qwen3-32b", "prefill_32k")
        decode = model_flops_per_device("qwen3-32b", "decode_32k")
        assert train == pytest.approx(3 * prefill)  # 6ND vs 2ND, same tokens
        assert decode < prefill / 1000  # 1 token vs 32768

    def test_failed_cell_returns_none(self):
        assert roofline_for_cell({"ok": False}) is None


class TestElasticReplan:
    def test_replan_adapts_to_world_size(self):
        from repro.runtime.trainer import replan

        p256 = replan(256, 4 * 2**20)
        p64 = replan(64, 4 * 2**20)
        import math

        assert math.prod(p256.factors) == 256
        assert math.prod(p64.factors) == 64
        assert p256.total_time_s > 0


class TestCommTelemetry:
    """launch/train.py emits per-plan comm telemetry every --log-every
    steps (ISSUE 5): cache counters + per-plan mode/chunks/order/issue
    counts, including the order-search verdict when the policy ran one.
    Exercised meshless (axis_sizes planning) — no devices needed."""

    def _ctx(self, **policy):
        from repro.comms.api import CommContext, PlanPolicy
        from repro.core.planner import LinkSpec

        links = {"a": LinkSpec("fast", 50e9, 1e-6),
                 "b": LinkSpec("slow", 1e9, 1e-5)}
        return CommContext(axis_names=("a", "b"), links=links,
                           axis_sizes={"a": 2, "b": 4},
                           policy=PlanPolicy(**policy))

    def test_lines_cover_cache_and_plans(self):
        from repro.launch.train import comm_plan_telemetry

        ctx = self._ctx()
        ctx.plan("ag", 2**20)
        ctx.plan("ar", 2**16)
        ctx.plan("ag", 2**20)  # hit
        lines = comm_plan_telemetry(ctx)
        assert lines[0].startswith("comm plans=2 ")
        assert "hits=1" in lines[0] and "misses=2" in lines[0]
        assert "latency_plans=" in lines[0] and "ring_plans=" in lines[0]
        # header + crossover note + one line per cached plan
        assert len(lines) == 4
        assert "regime crossover(ar)" in lines[1]
        ag_line = next(l for l in lines[2:] if l.strip().startswith("ag"))
        assert "order=[" in ag_line and "mode=" in ag_line
        assert "regime=bandwidth" in ag_line  # 1 MiB: rings win
        assert "issued=x2" in ag_line  # deduplicated plan, issued twice

    def test_order_search_verdict_surfaces(self):
        import dataclasses

        from repro.core.cost_model import TERARACK
        from repro.launch.train import comm_plan_telemetry

        sys2 = dataclasses.replace(TERARACK, n_nodes=8, wavelengths=2)
        ctx = self._ctx(order="optical", optical=sys2)
        ctx.plan("ag", 2**20)
        lines = comm_plan_telemetry(ctx)
        ag_line = next(l for l in lines[1:] if l.strip().startswith("ag"))
        assert "picked_by=optical" in ag_line
        assert "flipped=True" in ag_line  # asymmetric table: worlds disagree

    def test_invalidation_visible(self):
        from repro.core.planner import LinkSpec
        from repro.launch.train import comm_plan_telemetry

        ctx = self._ctx()
        ctx.plan("ag", 2**20)
        ctx.update_links({"a": LinkSpec("fitted", 40e9, 2e-6)})
        lines = comm_plan_telemetry(ctx)
        assert "invalidated=1" in lines[0]
        # cache dropped; no stale plan lines (crossover note remains)
        assert len(lines) == 2 and "crossover" in lines[1]

    def test_regime_telemetry_and_crossover(self):
        """Decode-size psums plan latency (exchange) plans, training-size
        payloads keep rings, and the telemetry reports the split plus the
        crossover payload between the two families (ISSUE 8)."""
        from repro.launch.train import comm_plan_telemetry

        ctx = self._ctx()
        small = ctx.plan("ar", 1024)        # decode-size: latency regime
        big = ctx.plan("ar", 2**20)         # training-size: rings
        assert small.meta["regime"] == "latency"
        assert all(s.mode == "exchange" for s in small.stages)
        assert big.meta["regime"] == "bandwidth"
        assert not any(s.mode == "exchange" for s in big.stages)
        st = ctx.cache_stats
        assert st.latency_plans == 1 and st.ring_plans == 1
        xover = ctx.latency_crossover("ar")
        assert xover is not None and 1024 <= xover <= 2**20
        lines = comm_plan_telemetry(ctx)
        assert "latency_plans=1" in lines[0] and "ring_plans=1" in lines[0]
        assert f"{xover:.0f}B" in lines[1]
        lat_line = next(l for l in lines[2:] if "regime=latency" in l)
        assert "mode=oneshot" in lat_line


class TestArtifacts:
    """The committed dry-run artifacts stay self-consistent."""

    def test_dryrun_artifacts_if_present(self):
        from pathlib import Path

        d = Path("runs/dryrun")
        if not d.exists():
            pytest.skip("no dry-run artifacts in this checkout")
        cells = [json.loads(p.read_text()) for p in d.glob("*__singlepod.json")]
        assert len(cells) == 31
        assert all(c["ok"] for c in cells)
        multien = list(d.glob("*__multipod.json"))
        assert len(multien) == 31
