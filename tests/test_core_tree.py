"""Tree algebra: coordinates, factorizations, optimal depth (paper Thm 2 / Fig 4)."""
import math

import pytest

from repro.core import tree


def test_coords_roundtrip():
    plan = tree.OpTreePlan(n=24, factors=(2, 3, 4))
    for p in range(24):
        assert plan.node(plan.coords(p)) == p


def test_sizes_mixed_radix():
    plan = tree.OpTreePlan(n=24, factors=(2, 3, 4))
    assert plan.sizes == (12, 4, 1)


def test_balanced_factors_exact_product():
    for n in [16, 24, 36, 64, 81, 100, 128, 512, 1024, 4096]:
        for k in range(1, 7):
            fs = tree.balanced_factors(n, k)
            prod = math.prod(fs)
            assert prod == n, (n, k, fs)


def test_balanced_factors_prime_collapses():
    assert tree.balanced_factors(13, 3) == (13,)


def test_balanced_factors_perfect_power():
    assert tree.balanced_factors(16, 2) == (4, 4)
    assert tree.balanced_factors(64, 3) == (4, 4, 4)
    assert tree.balanced_factors(1024, 5) == (4, 4, 4, 4, 4)


@pytest.mark.parametrize(
    "n,expected_depth",
    # Fig. 4: optimal depths 6, 6, 7, 8 for N = 512, 1024, 2048, 4096 at w=64.
    # (512 is a 5/6 tie in Thm 1 — Fig. 4 reports "flat then optimal at 6";
    #  argmin tie-breaks low, and we assert both give the same step count.)
    [(1024, 6), (2048, 7), (4096, 8)],
)
def test_optimal_depth_matches_fig4(n, expected_depth):
    assert tree.optimal_depth_argmin(n, 64) == expected_depth


def test_depth_512_tie():
    from repro.core import steps

    k = tree.optimal_depth_argmin(512, 64)
    assert steps.optree_steps_thm1(512, k, 64) == steps.optree_steps_thm1(512, 6, 64)


def test_thm2_closed_form_near_argmin():
    # The continuous Thm-2 k* is within 1 of the integer argmin and never
    # worse than 1 step off in the resulting step count.
    from repro.core import steps

    for n in [256, 512, 1024, 2048, 4096, 8192]:
        k_arg = tree.optimal_depth_argmin(n, 64)
        for rounding in ("round", "ceil"):
            k_cf = tree.optimal_depth_thm2(n, rounding=rounding)
            assert abs(k_cf - k_arg) <= 1, (n, k_cf, k_arg)
            assert (
                steps.optree_steps_thm1(n, k_cf, 64)
                <= steps.optree_steps_thm1(n, k_arg, 64) + 1
            )


def test_table1_kstar_1024():
    # Table I prints k*=7 for N=1024 (ceil reading); Fig. 4 shows 6; both
    # give exactly 70 steps — the paper's flat region.
    from repro.core import steps

    assert tree.optimal_depth_thm2(1024, rounding="ceil") == 7
    assert steps.optree_steps_thm1(1024, 6, 64) == 70
    assert steps.optree_steps_thm1(1024, 7, 64) == 70


def test_items_held_progression():
    plan = tree.OpTreePlan(n=16, factors=(4, 4))
    p = 6  # coords (1, 2)
    assert plan.coords(p) == (1, 2)
    held1 = plan.items_held_after(1, p)
    assert held1 == (2, 6, 10, 14)  # vary c_1, fixed position 2
    held2 = plan.items_held_after(2, p)
    assert held2 == tuple(range(16))


def test_subsets_match_paper_example():
    # Paper Fig. 2(b): 16 nodes, 4-ary, stage 1 subsets are {1,5,9,13} etc.
    # (paper is 1-indexed; we are 0-indexed)
    plan = tree.OpTreePlan(n=16, factors=(4, 4))
    stage1 = [s.members for s in plan.subsets(1)]
    assert (0, 4, 8, 12) in stage1
    assert (1, 5, 9, 13) in stage1
    assert all(s.segment is None for s in plan.subsets(1))
    stage2 = list(plan.subsets(2))
    assert (0, 1, 2, 3) in [s.members for s in stage2]
    segs = {s.segment for s in stage2}
    assert segs == {(0, 4), (4, 4), (8, 4), (12, 4)}
