"""Cluster serving subsystem tests (ISSUE 9): seeded-trace determinism,
simulator determinism (bit-identical event logs), makespan monotone in
arrival rate (deterministic grid + optional hypothesis), routing-policy
ordering on heterogeneous replicas, BatchedServer per-request timestamps,
and the measured-vs-simulated 2-replica validation."""
import dataclasses
import json

import numpy as np
import pytest

from repro.cluster import (
    BYTES_PER_TOKEN,
    ClusterServer,
    ClusterSim,
    ReplicaSpec,
    Request,
    bursty_trace,
    make_policy,
    make_trace,
    measure_replica_times,
    poisson_trace,
    replay_trace,
    trace_to_json,
)
from repro.core.planner import DCN_LINK, ICI_LINK

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def hetero_specs(batch=4):
    """The canonical fast+slow pair: 4x decode-step gap, different links."""
    return [
        ReplicaSpec.from_times("fast", batch, prefill_token_s=1e-4,
                               decode_step_s=5e-4, link=ICI_LINK),
        ReplicaSpec.from_times("slow", batch, prefill_token_s=4e-4,
                               decode_step_s=2e-3, link=DCN_LINK),
    ]


class TestTraces:
    def test_same_seed_bit_identical(self):
        for gen in (lambda s: poisson_trace(32, rate_rps=100.0, seed=s),
                    lambda s: bursty_trace(32, rate_rps=100.0, burst=4,
                                           seed=s)):
            a, b = gen(7), gen(7)
            assert a == b  # frozen dataclasses: full field equality
            assert gen(7) != gen(8)

    def test_arrivals_sorted_rids_in_order(self):
        t = bursty_trace(20, rate_rps=50.0, burst=3, seed=1)
        assert [r.rid for r in t] == list(range(20))
        assert all(t[i].arrival_s <= t[i + 1].arrival_s
                   for i in range(len(t) - 1))

    def test_bursts_share_instants(self):
        t = bursty_trace(12, rate_rps=100.0, burst=4, seed=0)
        instants = {r.arrival_s for r in t}
        assert len(instants) == 3  # 12 requests / burst 4

    def test_same_seed_rate_scaling(self):
        """Same seed at 2x the rate: arrivals exactly halve (the coupling
        the monotonicity property rides on); shapes unchanged."""
        lo = poisson_trace(16, rate_rps=50.0, seed=3)
        hi = poisson_trace(16, rate_rps=100.0, seed=3)
        for a, b in zip(lo, hi):
            assert b.arrival_s == pytest.approx(a.arrival_s / 2.0, rel=1e-12)
            assert (a.prompt_tokens, a.new_tokens) == \
                (b.prompt_tokens, b.new_tokens)

    def test_replay_round_trip(self, tmp_path):
        t = poisson_trace(10, rate_rps=30.0, seed=2)
        p = tmp_path / "trace.json"
        p.write_text(json.dumps(trace_to_json(t)))
        assert replay_trace(str(p)) == t

    def test_make_trace_specs(self):
        assert make_trace("poisson:100", n=8, seed=1) == \
            poisson_trace(8, rate_rps=100.0, seed=1)
        assert make_trace("bursty:100,2", n=8, seed=1) == \
            bursty_trace(8, rate_rps=100.0, burst=2, seed=1)
        with pytest.raises(ValueError):
            poisson_trace(4, rate_rps=0.0, seed=0)


class TestSimDeterminism:
    @pytest.mark.parametrize("tname,trace", [
        ("poisson", poisson_trace(48, rate_rps=200.0, seed=11)),
        ("bursty", bursty_trace(48, rate_rps=200.0, burst=4, seed=11)),
    ])
    @pytest.mark.parametrize("policy", ["round-robin", "jsq", "greedy",
                                        "max-flow"])
    def test_bit_identical_event_log_and_stats(self, tname, trace, policy):
        runs = []
        for _ in range(2):
            sim = ClusterSim(hetero_specs(), make_policy(policy))
            st_ = sim.run(trace)
            runs.append((list(sim.event_log),
                         json.dumps(st_.to_json(), sort_keys=True)))
        assert runs[0][0] == runs[1][0]
        assert runs[0][1] == runs[1][1]

    def test_worlds_price_differently_but_route_deterministically(self):
        trace = poisson_trace(32, rate_rps=200.0, seed=4)
        for world in ("electrical", "optical"):
            a = ClusterSim(hetero_specs(), make_policy("greedy"), world=world)
            b = ClusterSim(hetero_specs(), make_policy("greedy"), world=world)
            assert a.run(trace).to_json() == b.run(trace).to_json()

    def test_all_requests_finish_with_full_timestamps(self):
        st_ = ClusterSim(hetero_specs(), make_policy("jsq")).run(
            bursty_trace(24, rate_rps=150.0, burst=3, seed=9))
        assert len(st_.records) == 24
        for r in st_.records:
            assert r.enqueue_s is not None
            assert r.arrival_s <= r.enqueue_s <= r.prefill_start_s \
                <= r.prefill_done_s <= r.finish_s
            if r.new_tokens > 1:
                assert r.prefill_done_s <= r.decode_start_s <= r.finish_s


class TestMonotoneMakespan:
    def _makespan(self, rate, seed=13, n=40):
        trace = poisson_trace(n, rate_rps=rate, seed=seed)
        return ClusterSim(hetero_specs(),
                          make_policy("round-robin")).run(trace).makespan_s

    def test_monotone_in_rate_grid(self):
        """Same seed => time-scaled arrivals; with arrival-order routing
        and work-conserving FIFO replicas, compressing the arrivals can
        never stretch the makespan."""
        for seed in (0, 7, 21):
            prev = None
            for rate in (25.0, 50.0, 100.0, 200.0, 400.0, 800.0):
                m = self._makespan(rate, seed=seed)
                if prev is not None:
                    assert m <= prev + 1e-12, (seed, rate)
                prev = m

    if HAVE_HYPOTHESIS:
        @settings(max_examples=25, deadline=None)
        @given(seed=st.integers(0, 2**20),
               rate=st.floats(10.0, 500.0),
               factor=st.floats(1.1, 8.0))
        def test_monotone_in_rate_property(self, seed, rate, factor):
            assert self._makespan(rate * factor, seed=seed) <= \
                self._makespan(rate, seed=seed) + 1e-12


class TestPolicyOrdering:
    def test_greedy_strictly_beats_round_robin_p99(self):
        """The acceptance criterion: on a seeded heterogeneous trace the
        cost-model-aware policy strictly beats round-robin on simulated
        p99 — under BOTH cost worlds."""
        trace = poisson_trace(64, rate_rps=200.0, seed=7)
        for world in ("electrical", "optical"):
            rr = ClusterSim(hetero_specs(), make_policy("round-robin"),
                            world=world).run(trace)
            gr = ClusterSim(hetero_specs(), make_policy("greedy"),
                            world=world).run(trace)
            assert gr.latency_p99_s() < rr.latency_p99_s(), world
            assert gr.routed["fast"] > rr.routed["fast"]

    def test_max_flow_spreads_bursts_within_capacity(self):
        """On simultaneous-arrival bursts the flow round must not overfill
        any replica while free slots exist elsewhere: a burst the size of
        the total free slots lands split, not piled on one replica."""
        specs = hetero_specs(batch=4)
        trace = bursty_trace(8, rate_rps=50.0, burst=8, seed=3)
        sim = ClusterSim(specs, make_policy("max-flow"))
        st_ = sim.run(trace)
        assert st_.routed["fast"] >= 4 and st_.routed["slow"] >= 1
        assert st_.latency_p99_s() <= ClusterSim(
            specs, make_policy("round-robin")).run(trace).latency_p99_s() + 1e-12

    def test_jsq_balances_in_flight(self):
        trace = bursty_trace(16, rate_rps=100.0, burst=4, seed=5)
        st_ = ClusterSim(hetero_specs(), make_policy("jsq")).run(trace)
        assert st_.routed["fast"] > 0 and st_.routed["slow"] > 0

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError):
            make_policy("nope")


class TestReplicaSpec:
    def test_from_times_calibration(self):
        s = ReplicaSpec.from_times("r", 2, prefill_token_s=1e-3,
                                   decode_step_s=4e-3)
        assert s.prefill_time_s(8) == pytest.approx(8e-3)
        # single-token prompts never prefill faster than one engine step
        assert s.prefill_time_s(1) == pytest.approx(4e-3)
        assert s.decode_step_time_s(1) == pytest.approx(4e-3)
        assert s.decode_step_time_s(2) == pytest.approx(4e-3)  # memory-bound
        req = Request(rid=0, arrival_s=0.0, prompt_tokens=8, new_tokens=5)
        assert s.request_service_s(req) == pytest.approx(8e-3 + 4 * 4e-3)

    def test_from_config_uses_roofline(self):
        from repro.configs import get_config, reduced
        from repro.launch.roofline import decode_step_time_s, prefill_time_s

        cfg = reduced(get_config("granite-3-2b"))
        s = ReplicaSpec.from_config("r", cfg, 4)
        assert s.prefill_time_s(64) == pytest.approx(prefill_time_s(cfg, 64))
        assert s.decode_step_time_s(2) == pytest.approx(
            decode_step_time_s(cfg, 2))

    def test_tx_pricing_worlds(self):
        spec = hetero_specs()[0]
        sim_e = ClusterSim([spec], make_policy("round-robin"))
        sim_o = ClusterSim([spec], make_policy("round-robin"),
                           world="optical")
        nbytes = 64 * BYTES_PER_TOKEN
        assert sim_e.tx_time_s(spec, nbytes) == pytest.approx(
            ICI_LINK.alpha_s + nbytes / ICI_LINK.bandwidth_bytes)
        from repro.core.cost_model import TERARACK, step_time
        assert sim_o.tx_time_s(spec, nbytes) == pytest.approx(
            step_time(TERARACK, nbytes))


# ---------------------------------------------------------------------------
# measured side: BatchedServer timestamps + the 2-replica validation
# ---------------------------------------------------------------------------

def tiny_cfg(layers=2, d_ff=64):
    from repro.configs import get_config, reduced

    return dataclasses.replace(
        reduced(get_config("granite-3-2b")), num_layers=layers, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=d_ff, vocab_size=128,
    )


class TestServerTimestamps:
    def test_phase_timestamps_ordered(self):
        import jax
        from repro.models import init_params
        from repro.runtime import BatchedServer, ServerConfig

        cfg = tiny_cfg()
        srv = BatchedServer(cfg, init_params(jax.random.key(0), cfg),
                            ServerConfig(batch_size=2, max_seq=32,
                                         max_new_tokens=4))
        rng = np.random.default_rng(0)
        rids = [srv.submit(rng.integers(0, cfg.vocab_size, size=6))
                for _ in range(3)]
        srv.run_until_drained()
        rep = srv.drain_report()
        assert rep["requests"] == 3 and rep["tokens"] == 12
        assert rep["latency_p99_s"] >= rep["latency_p50_s"] > 0
        assert len(rep["per_request"]) == 3
        for rid in rids:
            t = srv.records[rid]
            assert t.enqueue_s <= t.prefill_start_s <= t.prefill_done_s \
                <= t.decode_start_s <= t.finish_s
            assert t.generated == 4
            assert t.ttft_s >= 0 and t.queue_s >= 0

    def test_reset_returns_server_to_fresh_state(self):
        """Public reset (ISSUE 10): drains in-flight work, clears queue/
        results/records/ids and the decode state, keeps the compiled jits
        — a reset server re-serves identically from rid 0."""
        import jax
        from repro.models import init_params
        from repro.runtime import BatchedServer, ServerConfig

        cfg = tiny_cfg()
        srv = BatchedServer(cfg, init_params(jax.random.key(0), cfg),
                            ServerConfig(batch_size=2, max_seq=32,
                                         max_new_tokens=4))
        prompt = np.arange(6, dtype=np.int32)
        rid = srv.submit(prompt)
        srv.run_until_drained()
        first = list(srv.results[rid])
        srv.submit(prompt)  # left in flight: reset must drain, not abandon
        srv.reset()
        assert not srv.pending_work()
        assert srv.results == {} and srv.records == {}
        assert srv.active_count() == 0
        rid2 = srv.submit(prompt)
        assert rid2 == 0  # id space restarts
        srv.run_until_drained()
        # same prompt on the reset (zeroed-state) server decodes the same
        assert srv.results[rid2] == first

    def test_single_token_request_finishes_at_prefill(self):
        import jax
        from repro.models import init_params
        from repro.runtime import BatchedServer, ServerConfig

        cfg = tiny_cfg()
        srv = BatchedServer(cfg, init_params(jax.random.key(0), cfg),
                            ServerConfig(batch_size=2, max_seq=32,
                                         max_new_tokens=1))
        rid = srv.submit(np.arange(5, dtype=np.int32))
        srv.run_until_drained()
        t = srv.records[rid]
        assert t.finish_s is not None and t.decode_start_s is None
        assert len(srv.results[rid]) == 1


class TestClusterServerMeasured:
    def test_measured_ordering_matches_simulation(self):
        """Acceptance: a 2-replica ClusterServer run on host meshes gives
        measured per-request latencies whose greedy-vs-round-robin p99
        ordering matches the simulator's prediction (underloaded regime —
        see docs/serving.md for why ordering, not absolute times, is the
        validated signal).

        The two sides are deliberately decoupled (ISSUE 10): the SIM side
        runs on FIXED synthetic ``ReplicaSpec.from_times`` constants — the
        simulator's greedy < round-robin prediction is a property of the
        model, not of this host's wall clock, so it must hold on every
        seed deterministically.  Only the MEASURED side uses
        ``measure_replica_times`` wall-clock constants (that's the signal
        being validated), with a seed-retry loop absorbing host noise."""
        import jax
        from repro.models import init_params
        from repro.runtime import BatchedServer, ServerConfig

        fast_cfg, slow_cfg = tiny_cfg(2), tiny_cfg(24, d_ff=512)
        fp = init_params(jax.random.key(0), fast_cfg)
        sp = init_params(jax.random.key(1), slow_cfg)
        scfg = ServerConfig(batch_size=2, max_seq=64, max_new_tokens=6)
        pf, df = measure_replica_times(fast_cfg, fp, scfg, prompt_tokens=8,
                                       warmup=2)
        ps, ds = measure_replica_times(slow_cfg, sp, scfg, prompt_tokens=8,
                                       warmup=2)
        assert ds > df  # structurally slower replica measures slower
        mspecs = [
            ReplicaSpec.from_times("fast", 2, prefill_token_s=pf,
                                   decode_step_s=df),
            ReplicaSpec.from_times("slow", 2, prefill_token_s=ps,
                                   decode_step_s=ds),
        ]
        sim_specs = hetero_specs(batch=2)
        probe = Request(rid=0, arrival_s=0.0, prompt_tokens=8, new_tokens=6)
        sim_rate = 0.25 / sim_specs[1].request_service_s(probe)
        rate = 0.25 / mspecs[1].request_service_s(probe)
        attempts = []
        for seed in (5, 17, 29):
            # sim side: synthetic constants, deterministic on EVERY seed
            sim_trace = poisson_trace(12, rate_rps=sim_rate, seed=seed,
                                      prompt_tokens=(8, 8), new_tokens=(6, 6))
            sim_p99 = {
                pol: ClusterSim(sim_specs,
                                make_policy(pol)).run(sim_trace).latency_p99_s()
                for pol in ("round-robin", "greedy")}
            assert sim_p99["greedy"] < sim_p99["round-robin"], (seed, sim_p99)
            # measured side: wall clock — accept the first seed whose
            # measured ordering matches (the strict one-shot gate lives in
            # `launch/perf.py --cluster`)
            trace = poisson_trace(12, rate_rps=rate, seed=seed,
                                  prompt_tokens=(8, 8), new_tokens=(6, 6))
            meas_p99 = {}
            for pol in ("round-robin", "greedy"):
                servers = [BatchedServer(fast_cfg, fp, scfg),
                           BatchedServer(slow_cfg, sp, scfg)]
                for srv in servers:  # warm jits out of the measured window
                    srv.submit(np.arange(8, dtype=np.int32) % 128)
                    srv.run_until_drained()
                    srv.reset()
                cs = ClusterServer(servers, mspecs, make_policy(pol))
                meas = cs.run_trace(trace, prompts=[
                    np.arange(r.prompt_tokens, dtype=np.int32) % 128
                    for r in trace])
                assert len(meas.records) == len(trace)
                for r in meas.records:
                    assert r.finish_s is not None and r.latency_s > 0
                meas_p99[pol] = meas.latency_p99_s()
            attempts.append({"sim": sim_p99, "measured": meas_p99})
            if meas_p99["greedy"] < meas_p99["round-robin"]:
                break
        else:
            pytest.fail(f"measured ordering never matched sim: {attempts}")

    def test_results_and_routing_accounting(self):
        import jax
        from repro.models import init_params
        from repro.runtime import BatchedServer, ServerConfig

        cfg = tiny_cfg()
        params = init_params(jax.random.key(0), cfg)
        scfg = ServerConfig(batch_size=2, max_seq=32, max_new_tokens=3)
        specs = [ReplicaSpec.from_times(f"r{i}", 2, prefill_token_s=1e-4,
                                        decode_step_s=1e-3)
                 for i in range(2)]
        servers = [BatchedServer(cfg, params, scfg) for _ in range(2)]
        cs = ClusterServer(servers, specs, make_policy("round-robin"))
        gids = cs.submit_batch([np.arange(4, dtype=np.int32)
                                for _ in range(4)])
        res = cs.run_until_drained()
        assert sorted(res) == sorted(gids)
        assert all(len(v) == 3 for v in res.values())
        assert cs.routed == {"r0": 2, "r1": 2}  # round-robin striping
        rep = cs.drain_report()
        assert rep.total_tokens() == 12
        assert set(rep.to_json()["routed"]) == {"r0", "r1"}
