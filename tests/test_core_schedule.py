"""Transmission-level schedules: validity + step counts vs. closed forms."""
import pytest

from repro.core import (
    OpTreePlan,
    build_ne_schedule,
    build_one_stage_schedule,
    build_optree_schedule,
    build_ring_schedule,
    steps,
    validate_schedule,
)
from repro.core import tree


class TestRouting:
    def test_ring_shortest(self):
        from repro.core.schedule import CW, CCW, route_ring

        d, links = route_ring(16, 0, 3)
        assert d == CW and links == (0, 1, 2)
        d, links = route_ring(16, 0, 14)
        assert d == CCW and links == (15, 14)

    def test_line_no_wrap(self):
        from repro.core.schedule import CW, CCW, route_line

        d, links = route_line(16, 4, 4, 4, 7)
        assert d == CW and links == (4, 5, 6)
        d, links = route_line(16, 4, 4, 7, 5)
        assert d == CCW and links == (6, 5)
        with pytest.raises(ValueError):
            route_line(16, 4, 4, 4, 9)


class TestOpTreeSchedule:
    def test_motivating_example_2stage_4ary(self):
        # N=16, w=2: paper says 4 + 8 = 12 steps.
        plan = OpTreePlan(16, (4, 4))
        sched = build_optree_schedule(plan, w=2)
        validate_schedule(sched)
        assert sched.stage_steps == [4, 8]
        assert sched.num_steps == 12

    @pytest.mark.parametrize(
        "n,factors,w",
        [
            (16, (4, 4), 2),
            (16, (2, 2, 2, 2), 2),
            (27, (3, 3, 3), 4),
            (64, (4, 4, 4), 8),
            (64, (8, 8), 8),
            (24, (2, 3, 4), 4),
            (36, (6, 6), 16),
            (81, (3, 3, 3, 3), 64),
        ],
    )
    def test_valid_and_matches_exact_steps(self, n, factors, w):
        plan = OpTreePlan(n, factors)
        sched = build_optree_schedule(plan, w)
        validate_schedule(sched)
        # the greedy RWA must achieve the analytic per-stage step count
        # (first-fit interval coloring is optimal on lines; near-optimal on
        # the ring stage — allow it one extra step per stage there).
        exact = steps.optree_steps_exact(plan, w)
        assert sched.num_steps <= exact + 1, (sched.stage_steps, exact)
        # per-stage: stages >= 2 are line segments => exactly optimal
        for j, got in enumerate(sched.stage_steps[1:], start=2):
            import math

            want = math.ceil(steps.optree_stage_demand(plan, j) / w)
            assert got == want, (j, got, want)


class TestBaselineSchedules:
    def test_one_stage_16_w2(self):
        sched = build_one_stage_schedule(16, 2)
        validate_schedule(sched)
        assert sched.num_steps == steps.one_stage_steps(16, 2) == 16

    @pytest.mark.parametrize("n,w", [(8, 2), (12, 4), (16, 8), (32, 64)])
    def test_one_stage_valid(self, n, w):
        sched = build_one_stage_schedule(n, w)
        validate_schedule(sched)
        assert sched.num_steps <= steps.one_stage_steps(n, w) + 1

    @pytest.mark.parametrize("n", [4, 8, 16, 32])
    def test_ring(self, n):
        sched = build_ring_schedule(n, 64)
        validate_schedule(sched)
        assert sched.num_steps == steps.ring_steps(n) == n - 1

    @pytest.mark.parametrize("n", [4, 6, 8, 16, 32])
    def test_neighbor_exchange(self, n):
        sched = build_ne_schedule(n, 64)
        validate_schedule(sched)
        assert sched.num_steps == steps.neighbor_exchange_steps(n) == n // 2


class TestSimulator:
    def test_simulate_matches_eq3(self):
        from repro.core import TERARACK, eq3_time
        from repro.optics import simulate

        plan = OpTreePlan(16, (4, 4))
        sched = build_optree_schedule(plan, w=2)
        rep = simulate(sched, TERARACK, message_bytes=4 * 2**20)
        assert rep.steps == 12
        assert rep.time_s == pytest.approx(eq3_time(TERARACK, 4 * 2**20, 12))

    def test_simulator_ranks_algorithms_like_paper(self):
        # Schedule-level at N=64, w=4: OpTree beats one-stage and ring (NE's
        # N/2 steps only lose to OpTree at paper scale, N>=512 w=64 — checked
        # at formula level in test_core_steps).
        from repro.core import TERARACK
        from repro.optics import simulate

        w = 4
        n = 64
        plan = OpTreePlan.balanced(n, w=w)
        t_optree = simulate(build_optree_schedule(plan, w), TERARACK, 4e6).time_s
        t_one = simulate(build_one_stage_schedule(n, w), TERARACK, 4e6).time_s
        t_ring = simulate(build_ring_schedule(n, w), TERARACK, 4e6).time_s
        t_ne = simulate(build_ne_schedule(n, w), TERARACK, 4e6).time_s
        assert t_optree < t_one
        assert t_optree < t_ring
        assert t_ne < t_ring


class TestWavelengthUsage:
    """Lemma 1 faithfulness: peak wavelength demand of constructed
    schedules matches the paper's bounds."""

    @pytest.mark.parametrize("n", [8, 12, 16, 24])
    def test_one_stage_peak_load_lemma1(self, n):
        import math
        from collections import defaultdict

        from repro.core import lemma1_wavelengths_ring

        # build with unlimited wavelengths => one step; peak per-(dir,link)
        # color usage equals the ring clique bound
        w = lemma1_wavelengths_ring(n) + 8
        sched = build_one_stage_schedule(n, w)
        load = defaultdict(set)
        for tx in sched.txs:
            for link in tx.links:
                load[(tx.direction, link)].add(tx.wavelength)
        peak = max(len(v) for v in load.values())
        assert peak <= lemma1_wavelengths_ring(n)
        # and the bound is tight within the tiling constructor's +2 slack
        assert sched.num_steps <= math.ceil(
            (lemma1_wavelengths_ring(n) + 2) / w
        )

    def test_optree_stage1_wavelength_demand(self):
        # stage-1 subsets: per-subset ring demand ceil(m^2/8), paper §III-C
        from repro.core import steps as S

        plan = OpTreePlan(16, (4, 4))
        assert S.optree_stage_demand(plan, 1) == 4 * 2  # 4 subsets x 2
