"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, input_specs, list_archs, reduced, shape_supported
from repro.models import decode_step, forward, init_decode_state, init_params, loss_fn

ARCHS = list_archs()


def _batch(cfg, B=2, S=16, train=True, key=0):
    rng = np.random.default_rng(key)
    batch = {}
    if cfg.frontend == "audio":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        )
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
        )
    if cfg.frontend == "vision":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix_embeds, cfg.d_model)).astype(np.float32)
        )
    if train:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = reduced(get_config(arch))
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, train=False)
    logits, cache, aux = jax.jit(
        lambda p, b: forward(cfg, p, b)
    )(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert cache is None
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nan(arch):
    cfg = reduced(get_config(arch))
    params = init_params(jax.random.key(1), cfg)
    batch = _batch(cfg, train=True)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, b), has_aux=True
        )(p)
        # one plain SGD application proves grads are usable
        p2 = jax.tree.map(lambda a, g: a - 1e-3 * g.astype(a.dtype), p, grads)
        return loss, metrics, p2, grads

    loss, metrics, p2, grads = step(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    gnorm = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads)
    )
    assert jnp.isfinite(gnorm), f"{arch}: non-finite grad norm"
    assert float(gnorm) > 0.0, f"{arch}: zero gradients"


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).is_encoder_only])
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = init_params(jax.random.key(2), cfg)
    B, T = 2, 32
    state = init_decode_state(cfg, B, T)
    # prefill 8 tokens, then decode 3
    prefill = _batch(cfg, B=B, S=8, train=False)
    logits, state, _ = jax.jit(lambda p, b, c: forward(cfg, p, b, cache=c,
                                                       cache_pos=jnp.zeros((), jnp.int32)))(
        params, prefill, state
    )
    assert not bool(jnp.isnan(logits).any())
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    step = jax.jit(lambda p, s, t, pos: decode_step(cfg, p, s, t, pos))
    for i in range(3):
        pos = jnp.asarray(8 + i, jnp.int32)
        logits1, state = step(params, state, tok, pos)
        assert logits1.shape == (B, cfg.vocab_size)
        assert not bool(jnp.isnan(logits1).any()), f"{arch}: NaN at decode {i}"
        tok = jnp.argmax(logits1[:, None], axis=-1).astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Incremental decode == teacher-forced forward (dense arch)."""
    cfg = reduced(get_config("qwen3-32b"))
    params = init_params(jax.random.key(3), cfg)
    B, S = 1, 12
    batch = _batch(cfg, B=B, S=S, train=False, key=7)
    full_logits, _, _ = forward(cfg, params, batch)

    state = init_decode_state(cfg, B, S)
    toks = batch["tokens"]
    # prefill the first 4, decode the rest one by one
    pre = {"tokens": toks[:, :4]}
    logits_p, state, _ = forward(cfg, params, pre, cache=state,
                                 cache_pos=jnp.zeros((), jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, :4]), rtol=2e-4, atol=2e-4
    )
    for t in range(4, S):
        logits1, state = decode_step(cfg, params, state, toks[:, t : t + 1],
                                     jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits1), np.asarray(full_logits[:, t]), rtol=2e-4, atol=2e-4,
            err_msg=f"decode step {t}",
        )


def test_decode_matches_forward_rwkv():
    cfg = reduced(get_config("rwkv6-7b"))
    params = init_params(jax.random.key(4), cfg)
    B, S = 1, 10
    batch = _batch(cfg, B=B, S=S, train=False, key=9)
    full_logits, _, _ = forward(cfg, params, batch)
    state = init_decode_state(cfg, B, S)
    toks = batch["tokens"]
    pre = {"tokens": toks[:, :5]}
    logits_p, state, _ = forward(cfg, params, pre, cache=state,
                                 cache_pos=jnp.zeros((), jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full_logits[:, :5]),
                               rtol=2e-4, atol=2e-4)
    for t in range(5, S):
        logits1, state = decode_step(cfg, params, state, toks[:, t : t + 1],
                                     jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits1), np.asarray(full_logits[:, t]), rtol=3e-4, atol=3e-4,
            err_msg=f"rwkv decode step {t}",
        )


def test_shape_skip_rules():
    skips = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_supported(cfg, shape)
            if not ok:
                skips.append((arch, shape.name))
    # 7 full-attention archs skip long_500k; hubert skips both decode shapes
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    assert ("rwkv6-7b", "long_500k") not in skips
    assert ("zamba2-2.7b", "long_500k") not in skips
    assert len(skips) == 9, skips


def test_input_specs_cover_all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = shape_supported(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert specs, (arch, shape.name)
            for k, s in specs.items():
                assert all(d >= 0 for d in s.shape), (arch, shape.name, k)
