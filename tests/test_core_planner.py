"""Planner tests for the staged-collective engine: RS/AG duality and the
chunked-overlap decision."""
import math

import pytest

from repro.core.planner import (
    DCN_LINK,
    ICI_LINK,
    LinkSpec,
    choose_num_chunks,
    pipeline_makespan,
    plan_all_reduce,
    plan_axis_order,
    plan_reduce_scatter_order,
)

POD_AXES = [(2, DCN_LINK), (16, ICI_LINK)]
SHARD = 8 * 2**20


class TestDuality:
    def test_rs_order_is_reverse_of_ag_order(self):
        ag = plan_axis_order(POD_AXES, SHARD)
        rs = plan_reduce_scatter_order(POD_AXES, SHARD)
        assert rs.factors == tuple(reversed(ag.factors))
        assert [s.link.name for s in rs.stages] == \
            [s.link.name for s in reversed(ag.stages)]
        # OpTree order: AG slow-first (payload grows), RS slow-last
        assert ag.stages[0].link.name == "dcn"
        assert rs.stages[-1].link.name == "dcn"

    def test_rs_total_time_equals_ag_total_time(self):
        # exact duality: mirrored stage costs => identical totals
        ag = plan_axis_order(POD_AXES, SHARD)
        rs = plan_reduce_scatter_order(POD_AXES, SHARD)
        assert rs.total_time_s == pytest.approx(ag.total_time_s, rel=1e-12)

    def test_rs_stagewise_mirror(self):
        ag = plan_axis_order(POD_AXES, SHARD)
        rs = plan_reduce_scatter_order(POD_AXES, SHARD)
        for s_rs, s_ag in zip(rs.stages, reversed(ag.stages)):
            assert s_rs.time_s == pytest.approx(s_ag.time_s, rel=1e-12)

    def test_three_axes(self):
        axes = [(2, DCN_LINK), (4, ICI_LINK), (8, ICI_LINK)]
        ag = plan_axis_order(axes, SHARD)
        rs = plan_reduce_scatter_order(axes, SHARD)
        assert rs.factors == tuple(reversed(ag.factors))

    def test_all_reduce_shares_one_plan(self):
        ar = plan_all_reduce(POD_AXES, SHARD)
        assert ar.all_gather.factors == \
            tuple(reversed(ar.reduce_scatter.factors))
        assert ar.total_time_s == pytest.approx(
            ar.reduce_scatter.total_time_s + ar.all_gather.total_time_s
        )

    def test_all_reduce_single_shared_chunk_count(self):
        # the chunk decision models ONE 2k-stage pipeline (what
        # staged_all_reduce executes), never split per half
        ar = plan_all_reduce(POD_AXES, SHARD, max_chunks=8)
        assert ar.num_chunks >= 1
        assert ar.pipelined_time_s <= ar.total_time_s * (1 + 1e-9)
        assert plan_all_reduce(POD_AXES, SHARD, max_chunks=1).num_chunks == 1


class TestChunking:
    def test_makespan_formula(self):
        assert pipeline_makespan([1.0, 2.0, 3.0], 1) == pytest.approx(6.0)
        # C chunks: fill (sum) + (C-1) paced by the slowest stage
        assert pipeline_makespan([1.0, 2.0, 3.0], 4) == pytest.approx(6.0 + 9.0)

    def test_bandwidth_bound_prefers_chunks(self):
        # huge payload, negligible alpha: pipelining must win
        link = LinkSpec("fat", 1e9, 1e-9)
        axes_f = [4, 4]
        c, t = choose_num_chunks(axes_f, [link, link], 64 * 2**20, max_chunks=8)
        assert c > 1
        t1 = pipeline_makespan(
            [s.time_s for s in plan_axis_order(
                [(4, link), (4, link)], 64 * 2**20, max_chunks=1).stages], 1)
        assert t < t1

    def test_alpha_bound_prefers_no_chunks(self):
        # tiny payload, huge alpha: chunking only multiplies latency
        link = LinkSpec("lag", 1e12, 1e-3)
        c, _ = choose_num_chunks([4, 4], [link, link], 1024, max_chunks=8)
        assert c == 1

    def test_plan_carries_chunk_decision(self):
        plan = plan_axis_order(POD_AXES, SHARD, max_chunks=8)
        assert plan.num_chunks >= 1
        assert plan.pipelined_time_s is not None
        assert plan.pipelined_time_s <= plan.total_time_s * (1 + 1e-9)

    def test_max_chunks_one_is_unpipelined(self):
        plan = plan_axis_order(POD_AXES, SHARD, max_chunks=1)
        assert plan.num_chunks == 1
        assert plan.pipelined_time_s == pytest.approx(plan.total_time_s)
