"""Planner tests for the staged-collective engine: RS/AG duality and the
chunked-overlap decision."""
import math

import pytest

from repro.core.planner import (
    DCN_LINK,
    ICI_LINK,
    LinkSpec,
    choose_num_chunks,
    pipeline_makespan,
    plan_all_reduce,
    plan_axis_order,
    plan_reduce_scatter_order,
)

POD_AXES = [(2, DCN_LINK), (16, ICI_LINK)]
SHARD = 8 * 2**20


class TestDuality:
    def test_rs_order_is_reverse_of_ag_order(self):
        ag = plan_axis_order(POD_AXES, SHARD)
        rs = plan_reduce_scatter_order(POD_AXES, SHARD)
        assert rs.factors == tuple(reversed(ag.factors))
        assert [s.link.name for s in rs.stages] == \
            [s.link.name for s in reversed(ag.stages)]
        # OpTree order: AG slow-first (payload grows), RS slow-last
        assert ag.stages[0].link.name == "dcn"
        assert rs.stages[-1].link.name == "dcn"

    def test_rs_total_time_equals_ag_total_time(self):
        # exact duality: mirrored stage costs => identical totals
        ag = plan_axis_order(POD_AXES, SHARD)
        rs = plan_reduce_scatter_order(POD_AXES, SHARD)
        assert rs.total_time_s == pytest.approx(ag.total_time_s, rel=1e-12)

    def test_rs_stagewise_mirror(self):
        ag = plan_axis_order(POD_AXES, SHARD)
        rs = plan_reduce_scatter_order(POD_AXES, SHARD)
        for s_rs, s_ag in zip(rs.stages, reversed(ag.stages)):
            assert s_rs.time_s == pytest.approx(s_ag.time_s, rel=1e-12)

    def test_three_axes(self):
        axes = [(2, DCN_LINK), (4, ICI_LINK), (8, ICI_LINK)]
        ag = plan_axis_order(axes, SHARD)
        rs = plan_reduce_scatter_order(axes, SHARD)
        assert rs.factors == tuple(reversed(ag.factors))

    def test_all_reduce_shares_one_plan(self):
        ar = plan_all_reduce(POD_AXES, SHARD)
        assert ar.all_gather.factors == \
            tuple(reversed(ar.reduce_scatter.factors))
        assert ar.total_time_s == pytest.approx(
            ar.reduce_scatter.total_time_s + ar.all_gather.total_time_s
        )

    def test_all_reduce_single_shared_chunk_count(self):
        # the chunk decision models ONE 2k-stage pipeline (what
        # staged_all_reduce executes), never split per half
        ar = plan_all_reduce(POD_AXES, SHARD, max_chunks=8)
        assert ar.num_chunks >= 1
        assert ar.pipelined_time_s <= ar.total_time_s * (1 + 1e-9)
        assert plan_all_reduce(POD_AXES, SHARD, max_chunks=1).num_chunks == 1


class TestChunking:
    def test_makespan_formula(self):
        assert pipeline_makespan([1.0, 2.0, 3.0], 1) == pytest.approx(6.0)
        # C chunks: fill (sum) + (C-1) paced by the slowest stage
        assert pipeline_makespan([1.0, 2.0, 3.0], 4) == pytest.approx(6.0 + 9.0)

    def test_bandwidth_bound_prefers_chunks(self):
        # huge payload, negligible alpha: pipelining must win
        link = LinkSpec("fat", 1e9, 1e-9)
        axes_f = [4, 4]
        c, t = choose_num_chunks(axes_f, [link, link], 64 * 2**20, max_chunks=8)
        assert c > 1
        t1 = pipeline_makespan(
            [s.time_s for s in plan_axis_order(
                [(4, link), (4, link)], 64 * 2**20, max_chunks=1).stages], 1)
        assert t < t1

    def test_alpha_bound_prefers_no_chunks(self):
        # tiny payload, huge alpha: chunking only multiplies latency
        link = LinkSpec("lag", 1e12, 1e-3)
        c, _ = choose_num_chunks([4, 4], [link, link], 1024, max_chunks=8)
        assert c == 1

    def test_plan_carries_chunk_decision(self):
        plan = plan_axis_order(POD_AXES, SHARD, max_chunks=8)
        assert plan.num_chunks >= 1
        assert plan.pipelined_time_s is not None
        assert plan.pipelined_time_s <= plan.total_time_s * (1 + 1e-9)

    def test_max_chunks_one_is_unpipelined(self):
        plan = plan_axis_order(POD_AXES, SHARD, max_chunks=1)
        assert plan.num_chunks == 1
        assert plan.pipelined_time_s == pytest.approx(plan.total_time_s)


class TestPacketClamp:
    """Regression: tiny messages must never be chunked below one packet
    (OpticalSystem.packet_bytes) — the linear d/B model breaks down there
    and modeled wins would not materialize."""

    def test_tiny_message_clamps_chunks(self):
        from repro.core.cost_model import TERARACK

        # bandwidth-bound link: unclamped, the makespan model would happily
        # split 256 B into 8 chunks; the packet floor allows at most 2
        link = LinkSpec("fat", 1e6, 1e-12)
        c, t = choose_num_chunks([4, 4], [link, link], 256, max_chunks=8)
        assert c <= 256 // TERARACK.packet_bytes == 2
        # sanity: same link, ample payload still chunks deep
        c_big, _ = choose_num_chunks([4, 4], [link, link], 64 * 2**10,
                                     max_chunks=8)
        assert c_big == 8

    def test_chunking_never_increases_modeled_time(self):
        link = LinkSpec("fat", 1e6, 1e-12)
        for shard in (64, 256, 1024, 64 * 2**10):
            c, t = choose_num_chunks([4, 4], [link, link], shard, max_chunks=8)
            _, t1 = choose_num_chunks([4, 4], [link, link], shard, max_chunks=1)
            assert t <= t1 * (1 + 1e-12)

    def test_sub_packet_payload_stays_unchunked(self):
        link = LinkSpec("fat", 1e6, 1e-12)
        c, _ = choose_num_chunks([4, 4], [link, link], 100, max_chunks=8)
        assert c == 1


class TestHopSchedule:
    def test_perhop_stage_time_is_overlap_max(self):
        from repro.core.planner import perhop_stage_time

        link = LinkSpec("l", 1e9, 1e-6)
        p = 1e6  # p/B = 1ms >> alpha: bandwidth-bound
        t = perhop_stage_time(8, p, link)
        assert t == pytest.approx(7 * p / 1e9 + 1e-6)
        # latency-bound: tiny payload
        t = perhop_stage_time(8, 10.0, link)
        assert t == pytest.approx(7 * 1e-6 + 10.0 / 1e9)
        assert perhop_stage_time(1, p, link) == 0.0

    def test_perhop_never_worse_than_oneshot(self):
        from repro.core.planner import choose_hop_schedule

        for shard in (1024, 64 * 2**10, 8 * 2**20):
            for coll in ("ag", "rs", "ar"):
                hs = choose_hop_schedule(
                    [2, 16], [DCN_LINK, ICI_LINK], shard, collective=coll)
                assert hs.perhop_time_s <= hs.oneshot_time_s * (1 + 1e-12)
                # the hybrid wavefront dominates both chunked and perhop
                # (ISSUE 5); the chosen mode is the argmin of all four
                assert hs.hybrid_time_s <= min(
                    hs.chunked_time_s, hs.perhop_time_s) * (1 + 1e-12)
                assert hs.time_s == min(
                    hs.oneshot_time_s, hs.chunked_time_s, hs.perhop_time_s,
                    hs.hybrid_time_s)

    def test_factor2_stages_stay_oneshot(self):
        from repro.core.planner import choose_hop_schedule

        hs = choose_hop_schedule(
            [2, 16], [DCN_LINK, ICI_LINK], 8 * 2**20, collective="ag")
        assert hs.stage_modes[0] == "oneshot"  # single hop: nothing to overlap
        assert hs.stage_modes[1] == "ring"

    def test_ar_schedule_covers_2k_stages(self):
        from repro.core.planner import choose_hop_schedule

        hs = choose_hop_schedule(
            [16, 2], [ICI_LINK, DCN_LINK], 1 * 2**20, collective="ar")
        assert len(hs.stage_modes) == 4
        assert len(hs.stage_exposed_bytes) == 4

    def test_exposure_accounting(self):
        from repro.core.planner import choose_hop_schedule

        # bandwidth-bound: every moved byte exposed, alphas hidden
        hs = choose_hop_schedule([8], [ICI_LINK], 8 * 2**20, collective="ag")
        assert hs.stage_modes == ("ring",)
        assert hs.exposed_bytes == pytest.approx(7 * 8 * 2**20)
        assert hs.hidden_bytes == 0.0
        # latency-bound: all but one hop's payload hides under the α chain
        hs = choose_hop_schedule([8], [ICI_LINK], 64, collective="ag")
        assert hs.exposed_bytes == pytest.approx(64)
        assert hs.hidden_bytes == pytest.approx(6 * 64)


class TestCollectiveMatmulPlan:
    def test_fusion_wins_when_compute_covers_hops(self):
        from repro.core.planner import matmul_block_time, plan_collective_matmul

        t_blk = matmul_block_time(1024, 4096, 16384)
        fm = plan_collective_matmul(
            (2, 16), (DCN_LINK, ICI_LINK), 1024 * 4096 * 2, t_blk)
        assert fm.fuse
        assert fm.fused_time_s < fm.unfused_time_s
        assert fm.hidden_comm_s > 0

    def test_fusion_loses_under_kernel_alpha(self):
        from repro.core.planner import plan_collective_matmul

        # negligible compute per block, large per-block launch penalty:
        # decomposing into N skinny matmuls only adds overhead
        fm = plan_collective_matmul(
            (16,), (ICI_LINK,), 1024, 1e-9, kernel_alpha_s=1e-3)
        assert not fm.fuse

    def test_unfused_is_comm_plus_full_matmul(self):
        from repro.core.planner import plan_collective_matmul

        t_blk = 1e-5
        fm = plan_collective_matmul((8,), (ICI_LINK,), 2**20, t_blk)
        comm = 7 * (ICI_LINK.alpha_s + 2**20 / ICI_LINK.bandwidth_bytes)
        assert fm.unfused_time_s == pytest.approx(comm + 8 * t_blk)

    def test_trailing_size1_axis_does_not_flip_fusion(self):
        # regression: a trailing factor-1 axis used to count every block's
        # matmul as exposed (blocks // factors[-1] with factors[-1] == 1)
        from repro.core.planner import matmul_block_time, plan_collective_matmul

        t_blk = matmul_block_time(1024, 4096, 16384)
        base = plan_collective_matmul((8,), (ICI_LINK,), 1024 * 4096 * 2, t_blk)
        padded = plan_collective_matmul(
            (8, 1), (ICI_LINK, ICI_LINK), 1024 * 4096 * 2, t_blk)
        assert padded.fuse == base.fuse
        assert padded.fused_time_s == pytest.approx(base.fused_time_s)
