"""Pallas kernels vs. pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    flash_attention_pallas,
    rmsnorm_pallas,
    rwkv6_scan_pallas,
    swiglu_pallas,
)
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (3, 5, 256), (2, 7, 384), (1, 1, 512)])
def test_rmsnorm_kernel(shape, dtype):
    x = _rand(shape, dtype)
    scale = _rand(shape[-1:], dtype) * 0.1 + 1.0
    got = rmsnorm_pallas(x, scale, interpret=True)
    want = ref.rmsnorm(x, scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 128), (2, 3, 512), (5, 77), (1, 1000)])
def test_swiglu_kernel(shape, dtype):
    g, u = _rand(shape, dtype), _rand(shape, dtype)
    got = swiglu_pallas(g, u, interpret=True)
    want = ref.swiglu(g, u)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,S,hd,causal",
    [
        (1, 2, 2, 128, 64, True),
        (2, 4, 2, 256, 64, True),
        (1, 8, 2, 128, 128, True),
        (2, 2, 1, 256, 32, False),
        (1, 2, 2, 200, 64, True),  # unaligned S -> padding path
    ],
)
def test_flash_attention_kernel(B, H, Hkv, S, hd, causal, dtype):
    q = _rand((B, H, S, hd), dtype) * 0.5
    k = _rand((B, Hkv, S, hd), dtype) * 0.5
    v = _rand((B, Hkv, S, hd), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, interpret=True,
                                 block_q=64, block_k=64)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,H,S,hd,chunk", [(1, 2, 64, 16, 32), (2, 3, 128, 32, 64),
                                            (1, 1, 32, 8, 32)])
def test_rwkv6_kernel(B, H, S, hd, chunk, dtype):
    r = _rand((B, H, S, hd), dtype) * 0.5
    k = _rand((B, H, S, hd), dtype) * 0.5
    v = _rand((B, H, S, hd), dtype)
    w = jnp.asarray(jax.nn.sigmoid(_rand((B, H, S, hd), jnp.float32)) * 0.5 + 0.45, dtype)
    u = _rand((H, hd), dtype) * 0.1
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    got_y, got_s = rwkv6_scan_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    want_y, want_s = ref.rwkv6_scan(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), rtol=1e-4, atol=1e-4)


def test_rwkv6_state_chaining():
    # running two half-sequences with state carry == one full run
    B, H, S, hd = 1, 2, 64, 16
    args = [_rand((B, H, S, hd), jnp.float32) * 0.3 for _ in range(3)]
    w = jnp.asarray(RNG.uniform(0.5, 0.95, (B, H, S, hd)), jnp.float32)
    u = _rand((H, hd), jnp.float32) * 0.1
    y_full, s_full = ref.rwkv6_scan(*args, w, u)
    half = S // 2
    y1, s1 = ref.rwkv6_scan(*(a[:, :, :half] for a in args), w[:, :, :half], u)
    y2, s2 = ref.rwkv6_scan(*(a[:, :, half:] for a in args), w[:, :, half:], u, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 2)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=1e-5, atol=1e-5)


def test_ops_backend_dispatch_and_grad():
    x = _rand((4, 128), jnp.float32)
    scale = jnp.ones((128,), jnp.float32)

    def loss_ref(x):
        return jnp.sum(ops.rmsnorm(x, scale) ** 2)

    g_ref = jax.grad(loss_ref)(x)
    with ops.backend_scope("pallas"):
        assert ops.get_backend() == "pallas"
        g_pal = jax.grad(loss_ref)(x)
        y = ops.swiglu(x, x)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_pal), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.swiglu(x, x)), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,chunk,causal,Hkv", [(2048, 512, True, 2), (4096, 1024, True, 4),
                                                (2048, 512, False, 1)])
def test_flash_attention_chunked_matches_naive(S, chunk, causal, Hkv):
    """The q/kv-chunked online-softmax path (dry-run/prefill default above
    4k context) is numerically identical to the naive oracle."""
    B, H, hd = 1, 4, 32
    q = _rand((B, H, S, hd), jnp.float32) * 0.3
    k = _rand((B, Hkv, S, hd), jnp.float32) * 0.3
    v = _rand((B, Hkv, S, hd), jnp.float32)
    want = ref.flash_attention(q, k, v, causal=causal)
    got = ref.flash_attention_chunked(q, k, v, causal=causal, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-6, atol=3e-6)


def test_flash_attention_chunked_grad_matches():
    B, H, Hkv, S, hd = 1, 2, 2, 2048, 16
    q = _rand((B, H, S, hd), jnp.float32) * 0.3
    k = _rand((B, Hkv, S, hd), jnp.float32) * 0.3
    v = _rand((B, Hkv, S, hd), jnp.float32)
    g1 = jax.grad(lambda q: ref.flash_attention(q, k, v, causal=True).sum())(q)
    g2 = jax.grad(
        lambda q: ref.flash_attention_chunked(q, k, v, causal=True, chunk=512).sum()
    )(q)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(g1), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,H,S,P,N,chunk", [(1, 2, 64, 16, 8, 32),
                                             (2, 2, 32, 8, 8, 32)])
def test_mamba2_ssd_kernel(B, H, S, P, N, chunk):
    from repro.kernels.mamba2_scan import mamba2_ssd_pallas

    x = _rand((B, S, H, P), jnp.float32) * 0.5
    Bm = _rand((B, S, N), jnp.float32) * 0.5
    Cm = _rand((B, S, N), jnp.float32) * 0.5
    decay = jnp.asarray(RNG.uniform(0.6, 0.95, (B, S, H)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, (B, S, H)), jnp.float32)
    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    got_y, got_s = mamba2_ssd_pallas(x, Bm, Cm, decay, dt, s0, chunk=chunk,
                                     interpret=True)
    want_y, want_s = ref.mamba2_ssd_scan(x, Bm, Cm, decay, dt, s0)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-4, atol=1e-4)


def test_mamba2_ssd_ref_matches_model_block():
    """The extracted SSD ref oracle equals the recurrence inside
    models.mamba2 (state chaining over two halves)."""
    B, S, H, P, N = 1, 32, 2, 8, 8
    x = _rand((B, S, H, P), jnp.float32) * 0.3
    Bm = _rand((B, S, N), jnp.float32) * 0.3
    Cm = _rand((B, S, N), jnp.float32) * 0.3
    decay = jnp.asarray(RNG.uniform(0.7, 0.95, (B, S, H)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.2, 0.8, (B, S, H)), jnp.float32)
    y_full, s_full = ref.mamba2_ssd_scan(x, Bm, Cm, decay, dt)
    h = S // 2
    y1, s1 = ref.mamba2_ssd_scan(x[:, :h], Bm[:, :h], Cm[:, :h],
                                 decay[:, :h], dt[:, :h])
    y2, s2 = ref.mamba2_ssd_scan(x[:, h:], Bm[:, h:], Cm[:, h:],
                                 decay[:, h:], dt[:, h:], s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-5, atol=1e-5)
