"""Plan-conformance suite (ISSUE 5): planner, pricer, simulator and
executor semantics locked to each other across the searched plan space.

The invariants, swept over factorizations / stage orders / chunk counts /
link tables / payloads:

  (a) ``price(plan, optical)`` equals ``simulate(schedule_from_ir(plan))``
      wall time for EVERY searched candidate — the optical pricer that
      ranks stage orders IS the conflict-checked simulator, byte for byte;
  (b) electrical ``price`` reproduces ``choose_hop_schedule``'s modeled
      time for every mode (oneshot / chunked / perhop / hybrid) — the
      planner's decision signal and the pricer cannot drift;
  (c) the hybrid wavefront's modeled makespan never exceeds the better of
      the pure modes (it degenerates to perhop at C=1 and its stage times
      are elementwise <= the chunked stage times);
  (d) ``with_chunks(1)`` normalization is drift-free: a chunked plan
      normalizes to oneshot and a hybrid plan to perhop, at identical
      prices — the label and the execution never disagree;
  (f) latency-regime exchange chains (ISSUE 8) obey (a) verbatim —
      healthy AND degraded — exist exactly where their structure applies
      (pow-2 ag/rs/ar, both ring directions alive), are invariant under
      the chunk helpers, and the modeled crossover genuinely separates
      the exchange family from every ring candidate.
  (g) the RECONFIGURING optical world (ISSUE 10): with a per-event
      circuit-reconfiguration delay on the system, price == simulate for
      every searched candidate (time AND event count), the price
      decomposes exactly as fixed-ring + exposed reconfiguration time,
      SWOT overlap never prices worse than paying the delay exposed,
      zero delay reproduces today's fixed-ring prices bit for bit, and
      the search's hold-vs-reconfigure pick follows the priced argmin.

Each invariant is one check function with TWO drivers: hypothesis
``@given`` sweeps when hypothesis is installed, and a deterministic
parametrized grid otherwise — the suite locks the contracts down in both
environments instead of skipping itself away.  Everything here is
single-process planner/cost-model work (no devices); the executor side of
the same contracts runs in ``tests/subproc/check_plan_executor.py``
(subproc lane).
"""
import dataclasses
import itertools
import math

import pytest

from repro.core import (
    TERARACK,
    HealthError,
    LinkHealth,
    choose_hop_schedule,
    price,
    schedule_from_ir,
    search_stage_orders,
    validate_schedule,
)
from repro.core.planner import (
    DCN_LINK,
    ICI_LINK,
    SMALL_MESSAGE_FLOOR_PACKETS,
    LinkSpec,
    latency_crossover_bytes,
    pipeline_makespan,
    plan_latency_collective,
)
from repro.core.plan_ir import optical_message_bytes
from repro.optics import simulate

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the grid drivers
    HAVE_HYPOTHESIS = False

SLOW = LinkSpec("slow", 1e9, 1e-5)
FAST = LinkSpec("fast", 50e9, 1e-6)
FAT = LinkSpec("fat", 1e6, 1e-12)  # bandwidth-bound: chunking pays deep

# deterministic grid (the no-hypothesis driver): factorizations incl.
# factor-1 stages and non-powers of two, payloads from alpha-bound to
# bandwidth-bound, heterogeneous link tables
GRID_FACTORS = [(2,), (8,), (2, 4), (16, 2), (2, 3, 4), (1, 4, 2)]
GRID_SHARDS = [64.0, 64 * 2**10, 1 * 2**20, 8 * 2**20]
# "a2a" rides the same grid: shard_bytes is the node's full exchange
# buffer there, and its optical item is the (origin, dest) block — the
# invariants hold verbatim (price==simulate per candidate, hybrid
# dominance, with_chunks(1)/meta round-trip no-drift)
GRID_COLLS = ["ag", "rs", "ar", "a2a"]


def _grid_links(factors, variant):
    if variant == "dcn_ici":
        return [DCN_LINK] + [ICI_LINK] * (len(factors) - 1)
    if variant == "slow_last":
        return [FAST] * (len(factors) - 1) + [SLOW]
    return [FAT] * len(factors)  # "fat"


GRID = [
    pytest.param(f, s, c, lv, id=f"{'x'.join(map(str, f))}-{int(s)}B-{c}-{lv}")
    for f, s, c, lv in itertools.product(
        GRID_FACTORS, GRID_SHARDS, GRID_COLLS,
        ["dcn_ici", "slow_last", "fat"])
]


def _sys(n, w):
    return dataclasses.replace(TERARACK, n_nodes=n, wavelengths=w)


# --------------------------------------------------------------------------
# (b) electrical price == planner modeled time, every mode
# --------------------------------------------------------------------------

def check_electrical_no_drift(factors, shard, coll, links):
    hs = choose_hop_schedule(factors, links, shard, collective=coll)
    ir = hs.to_ir()
    want = {"oneshot": hs.oneshot_time_s, "chunked": hs.chunked_time_s,
            "perhop": hs.perhop_time_s, "hybrid": hs.hybrid_time_s}
    for mode, t in want.items():
        got = price(ir.with_mode(mode))
        assert got.total_s == pytest.approx(t, rel=1e-12), mode
    # the plan's own mode is the planner's pick, priced identically
    assert price(ir).total_s == pytest.approx(hs.time_s, rel=1e-12)


def check_forced_chunks_price_as_makespan(factors, shard, coll, links,
                                          chunks):
    """Forced chunk counts price as the C-chunk pipeline makespan — for
    the chunked AND hybrid wavefronts.  The forced state is built exactly
    the way the api's override path builds it (mode + count honored
    verbatim); the helper chain is checked separately since a one-chunk
    wavefront normalizes to its pure mode."""
    hs = choose_hop_schedule(factors, links, shard, collective=coll)
    ir = hs.to_ir()
    for mode in ("chunked", "hybrid"):
        helper = ir.with_mode(mode).with_chunks(chunks)
        if chunks == 1:
            # helpers never leave a one-chunk wavefront labeled as one
            assert helper.mode == ("oneshot" if mode == "chunked"
                                   else "perhop")
            continue
        forced = dataclasses.replace(ir, mode=mode, num_chunks=chunks)
        got = price(forced)
        assert got.num_chunks == chunks
        assert got.total_s == pytest.approx(
            pipeline_makespan(got.stage_times_s, chunks), rel=1e-12)
        # the helper chain agrees whenever it lands in the same state
        if helper.mode == mode and helper.num_chunks == chunks:
            assert price(helper).total_s == pytest.approx(
                got.total_s, rel=1e-12)


# --------------------------------------------------------------------------
# (c) hybrid dominance
# --------------------------------------------------------------------------

def check_hybrid_dominance(factors, shard, coll, links):
    hs = choose_hop_schedule(factors, links, shard, collective=coll)
    assert hs.hybrid_time_s <= min(
        hs.chunked_time_s, hs.perhop_time_s) * (1 + 1e-12)
    # the chosen mode is the argmin of all four modeled times
    assert hs.time_s == min(hs.oneshot_time_s, hs.chunked_time_s,
                            hs.perhop_time_s, hs.hybrid_time_s)
    # hybrid never labels a one-chunk wavefront (that IS perhop)
    if hs.mode == "hybrid":
        assert hs.hybrid_chunks > 1


# --------------------------------------------------------------------------
# (d) with_chunks(1) normalization, per-mode chunk decisions
# --------------------------------------------------------------------------

def check_chunk_normalization_no_drift(factors, shard, coll, links):
    hs = choose_hop_schedule(factors, links, shard, collective=coll)
    ir = hs.to_ir()
    chunked1 = ir.with_mode("chunked").with_chunks(1)
    assert chunked1.mode == "oneshot"
    assert price(chunked1).total_s == pytest.approx(
        price(ir.with_mode("oneshot")).total_s, rel=1e-12)
    hybrid1 = ir.with_mode("hybrid").with_chunks(1)
    assert hybrid1.mode == "perhop"
    assert price(hybrid1).total_s == pytest.approx(
        price(ir.with_mode("perhop")).total_s, rel=1e-12)
    # with_mode restores each wavefront's own chunk count (meta mode_chunks)
    assert ir.with_mode("hybrid").with_mode("chunked").num_chunks \
        == hs.num_chunks
    assert ir.with_mode("chunked").with_mode("hybrid").num_chunks \
        == hs.hybrid_chunks


# --------------------------------------------------------------------------
# (a) optical price == simulator, every searched candidate
# --------------------------------------------------------------------------

def check_candidates_price_as_simulated(sizes, w, coll, slow_idx, shard):
    axes = [(f"x{i}", s, SLOW if i == slow_idx % len(sizes) else FAST)
            for i, s in enumerate(sizes)]
    sys_w = _sys(math.prod(sizes), w)
    srch = search_stage_orders(axes, shard, collective=coll,
                               backend="optical", system=sys_w)
    assert srch.candidates
    for cand in srch.candidates:
        sched = schedule_from_ir(cand.plan, w)
        validate_schedule(sched)
        # optical_message_bytes: the per-item payload the RWA schedule
        # moves — shard_bytes for gather traffic, shard/n per
        # (origin, dest) block for the a2a exchange
        rep = simulate(sched, sys_w, optical_message_bytes(cand.plan),
                       check=True)
        assert cand.optical_s == pytest.approx(rep.time_s, rel=1e-12)
        assert cand.optical_steps == rep.steps
        assert price(cand.plan, sys_w).total_s == pytest.approx(
            rep.time_s, rel=1e-12)
        # the electrical figure is the plan's own priced mode
        assert cand.electrical_s == pytest.approx(
            price(cand.plan).total_s, rel=1e-12)
    # ranked: the search backend's best leads the candidate list
    opt_times = [c.optical_s for c in srch.candidates]
    assert opt_times[0] == min(opt_times)


# --------------------------------------------------------------------------
# (g) the reconfiguring optical world: price == simulate (time and event
# count) for every searched candidate, exact fixed-ring + exposed
# decomposition, overlap dominance, zero-delay bit-identity
# --------------------------------------------------------------------------

def check_reconfig_conformance(sizes, w, coll, shard, delay, overlap):
    """Invariant (g) over every searched candidate.  A single size uses
    the unnamed paper-world axis (so balanced factorizations — the
    candidates that actually differ in reconfiguration count — are in the
    space); multi-size worlds use named mesh axes."""
    if len(sizes) == 1:
        axes = [(None, sizes[0], ICI_LINK)]
    else:
        axes = [(f"x{i}", s, SLOW if i % 2 else FAST)
                for i, s in enumerate(sizes)]
    n = math.prod(sizes)
    base = _sys(n, w)
    sys_r = dataclasses.replace(base, circuit_reconfig_s=delay,
                                reconfig_overlap=overlap)
    sys_exposed = dataclasses.replace(sys_r, reconfig_overlap=False)
    srch = search_stage_orders(axes, shard, collective=coll,
                               backend="optical", system=sys_r)
    assert srch.candidates
    for cand in srch.candidates:
        sched = schedule_from_ir(cand.plan, w)
        validate_schedule(sched)
        rep = simulate(sched, sys_r, optical_message_bytes(cand.plan),
                       check=True)
        # price == simulate: wall time AND reconfiguration accounting
        assert cand.optical_s == pytest.approx(rep.time_s, rel=1e-12)
        p = price(cand.plan, sys_r)
        assert p.total_s == pytest.approx(rep.time_s, rel=1e-12)
        assert p.reconfigurations == rep.reconfigurations \
            == cand.reconfigurations
        assert p.reconfig_exposed_s == rep.reconfig_exposed_s
        # exposure is bounded by events * delay and is exactly the price
        # delta over the fixed-ring world (the decomposition is literal)
        assert 0.0 <= rep.reconfig_exposed_s \
            <= rep.reconfigurations * delay + 1e-18
        base_t = price(cand.plan, base).total_s
        if delay == 0.0:
            # bit-identity, not approx: the zero-delay reconfiguring
            # world IS the fixed-ring world of PRs 3-8
            assert cand.optical_s == base_t
        else:
            assert cand.optical_s == pytest.approx(
                base_t + rep.reconfig_exposed_s, rel=1e-12)
        # SWOT overlap dominance: hiding reconfig behind the previous
        # stage's in-flight last step never prices worse than exposed
        assert cand.optical_s <= price(
            cand.plan, sys_exposed).total_s * (1 + 1e-12)
    # the ranking followed the reconfig-aware prices
    opt_times = [c.optical_s for c in srch.candidates]
    assert opt_times[0] == min(opt_times)
    # the hold-vs-reconfigure decision rule: whichever family is
    # STRICTLY cheaper under the delay-inclusive price is the pick
    hold = [c.optical_s for c in srch.candidates if c.reconfigurations == 0]
    rec = [c.optical_s for c in srch.candidates if c.reconfigurations > 0]
    if hold and rec:
        if min(rec) < min(hold):
            assert srch.best.reconfigurations > 0
        elif min(hold) < min(rec):
            assert srch.best.reconfigurations == 0


# --------------------------------------------------------------------------
# (e) fault-aware pricing: degraded >= healthy under BOTH backends, and
# price == simulate for every searched candidate under the faults
# --------------------------------------------------------------------------

def _health_for(names, derates, lost):
    """Build a LinkHealth from index-keyed pieces (indices wrap into the
    axis list so the same case applies to any factorization length)."""
    return LinkHealth.make(
        derate={(names[i % len(names)], d): f for (i, d), f in derates.items()},
        lost_wavelengths={names[i % len(names)]: tuple(sorted(wl))
                          for i, wl in lost.items() if wl})


def check_degraded_conformance(sizes, w, coll, shard, health):
    """Degrade-the-world invariants over every searched candidate: the
    electrical price under ``health`` never drops below healthy (bandwidth
    only shrinks), the optical price under ``health`` never drops below
    healthy (wavelengths only disappear), and the degraded optical price
    still equals the conflict-checked simulator on the health-lowered
    schedule byte for byte.  A lost-wavelength union covering ALL of ``w``
    must refuse to lower at all (HealthError), never mis-price."""
    names = [f"x{i}" for i in range(len(sizes))]
    axes = [(nm, s, FAST) for nm, s in zip(names, sizes)]
    sys_w = _sys(math.prod(sizes), w)
    srch = search_stage_orders(axes, shard, collective=coll,
                               backend="optical", system=sys_w)
    all_lost = len([x for x in health.lost_for(names) if x < w]) >= w
    for cand in srch.candidates:
        healthy_e = price(cand.plan).total_s
        degraded_e = price(cand.plan, health=health).total_s
        assert degraded_e >= healthy_e * (1 - 1e-12)
        if all_lost:
            with pytest.raises(HealthError):
                price(cand.plan, sys_w, health=health)
            continue
        healthy_o = price(cand.plan, sys_w)
        degraded_o = price(cand.plan, sys_w, health=health)
        assert degraded_o.total_s >= healthy_o.total_s * (1 - 1e-12)
        sched = schedule_from_ir(cand.plan, w, health=health)
        validate_schedule(sched, health=health)
        rep = simulate(sched, sys_w, optical_message_bytes(cand.plan),
                       check=True, health=health)
        assert degraded_o.total_s == pytest.approx(rep.time_s, rel=1e-12)
        assert degraded_o.steps == rep.steps


# --------------------------------------------------------------------------
# (f) latency-regime (exchange-chain) plans: price == simulate healthy AND
# degraded, chunk helpers are no-drift no-ops, and the modeled crossover
# genuinely separates the two plan families
# --------------------------------------------------------------------------

HEALTH_GRID = [
    pytest.param({}, {}, id="healthy"),
    pytest.param({(0, 0): 0.5, (0, 1): 0.5}, {}, id="derate-both"),
    pytest.param({(0, 0): 0.25}, {}, id="derate-cw-only"),
    pytest.param({}, {0: (0, 1)}, id="lost-two-wl"),
    pytest.param({(0, 0): 0.5, (1, 1): 0.75}, {1: (1, 3)}, id="mixed"),
]


def check_latency_conformance(sizes, w, coll, shard, health=None):
    """Exchange-chain invariants: the structure only exists for pow-2
    ag/rs/ar meshes with both ring directions alive; where it exists, every
    stage is a factor-2 exchange round, the optical price equals the
    conflict-checked simulator byte for byte (healthy and under ``health``),
    and the single-shot chain is invariant under the chunk helpers."""
    names = [f"x{i}" for i in range(len(sizes))]
    axes = [(nm, s, SLOW if i % 2 else FAST)
            for i, (nm, s) in enumerate(zip(names, sizes))]
    plan = plan_latency_collective(axes, shard, collective=coll,
                                   health=health)
    structural = (coll in ("ag", "rs", "ar")
                  and all(s & (s - 1) == 0 for s in sizes)
                  and math.prod(sizes) >= 2
                  and not (health is not None
                           and health.dead_directions(names)))
    if not structural:
        assert plan is None
        return
    assert plan is not None
    assert plan.meta["regime"] == "latency"
    assert all(s.mode == "exchange" and s.factor == 2 for s in plan.stages)
    rounds = sum(int(math.log2(s)) for s in sizes if s > 1)
    assert len(plan.stages) == (2 * rounds if coll == "ar" else rounds)
    # chunk helpers: a single-shot exchange chain never grows a wavefront
    norm = plan.with_chunks(1)
    assert norm.stage_modes == plan.stage_modes
    assert price(norm).total_s == pytest.approx(
        price(plan).total_s, rel=1e-12)
    # optical price == conflict-checked simulator, byte for byte
    sys_w = _sys(max(math.prod(sizes), 2), w)
    if health is not None and \
            len([x for x in health.lost_for(names) if x < w]) >= w:
        with pytest.raises(HealthError):
            price(plan, sys_w, health=health)
        return
    opt = price(plan, sys_w, health=health)
    sched = schedule_from_ir(plan, w, health=health)
    validate_schedule(sched, health=health)
    rep = simulate(sched, sys_w, optical_message_bytes(plan),
                   check=True, health=health)
    assert opt.total_s == pytest.approx(rep.time_s, rel=1e-12)
    assert opt.steps == rep.steps


class TestLatencyRegime:
    """Latency-regime conformance grid (ISSUE 8)."""

    AXES = [("a", 2, FAST), ("b", 4, SLOW)]
    LAT_COLLS = ["ag", "rs", "ar"]

    @pytest.mark.parametrize("coll", LAT_COLLS)
    @pytest.mark.parametrize("w", [1, 2, 8])
    @pytest.mark.parametrize("sizes", [
        (2,), (4,), (2, 4), (2, 2, 2), (8, 2),   # pow-2: the family exists
        (3, 4), (6,), (1, 2),                     # non-pow-2 factor: refused
    ])
    def test_price_is_simulated(self, sizes, w, coll):
        check_latency_conformance(list(sizes), w, coll, 1 * 2**10)

    @pytest.mark.parametrize("coll", LAT_COLLS)
    @pytest.mark.parametrize("derates,lost", HEALTH_GRID)
    def test_degraded_conformance(self, coll, derates, lost):
        names = ["x0", "x1"]
        health = _health_for(names, derates, lost)
        check_latency_conformance([2, 4], 8, coll, 1 * 2**10, health)

    def test_dead_direction_disqualifies(self):
        # exchange rounds move payload BOTH ways: one dead direction on
        # any axis kills the whole family (api then falls back gracefully)
        health = LinkHealth.make(dead=[("b", 0)])
        assert plan_latency_collective(
            self.AXES, 1024, collective="ar", health=health) is None

    def test_a2a_has_no_latency_family(self):
        assert plan_latency_collective(
            self.AXES, 1024, collective="a2a") is None

    @pytest.mark.parametrize("coll", LAT_COLLS)
    def test_crossover_separates_families(self, coll):
        """Below the modeled crossover the exchange chain is strictly
        cheaper than EVERY ring candidate; above it the ring family wins —
        the contract api.latency_crossover surfaces to telemetry."""
        xover = latency_crossover_bytes(self.AXES, collective=coll)
        assert xover is not None and 0.0 < xover < math.inf

        def ring_best(s):
            srch = search_stage_orders(self.AXES, s, collective=coll,
                                       backend="electrical",
                                       include_latency=False)
            return min(c.electrical_s for c in srch.candidates)

        for s in (xover / 8, xover / 2):
            lat = plan_latency_collective(self.AXES, s, collective=coll)
            assert price(lat).total_s < ring_best(s), s
        for s in (xover * 2, xover * 8):
            lat = plan_latency_collective(self.AXES, s, collective=coll)
            assert price(lat).total_s >= ring_best(s), s

    def test_crossover_none_when_family_absent(self):
        axes = [("a", 3, FAST)]  # non-pow-2: no exchange chain exists
        assert latency_crossover_bytes(axes, collective="ar") is None

    def test_search_latency_candidates_price_as_simulated(self):
        """The order search's latency-family candidates obey invariant (a)
        verbatim: candidate price == simulator, and the regime tag is
        consistent with the stage structure."""
        sys_w = _sys(8, 2)
        srch = search_stage_orders(self.AXES, 1 * 2**10, collective="ar",
                                   backend="optical", system=sys_w)
        lat = [c for c in srch.candidates if c.regime == "latency"]
        assert lat  # pow-2 mesh: the family rides along
        for cand in lat:
            assert all(s.mode == "exchange" for s in cand.plan.stages)
            rep = simulate(schedule_from_ir(cand.plan, 2), sys_w,
                           optical_message_bytes(cand.plan), check=True)
            assert cand.optical_s == pytest.approx(rep.time_s, rel=1e-12)


class TestChunkFloor:
    """The small-message chunk floor (ISSUE 8 satellite): KiB-scale
    payloads never pay chunk-wavefront overhead — ``_best_chunks`` clamps
    straight to C=1 below ``packet_bytes * SMALL_MESSAGE_FLOOR_PACKETS``,
    and above the floor no chunk ever carries less than one packet."""

    FLOOR = TERARACK.packet_bytes * SMALL_MESSAGE_FLOOR_PACKETS

    @pytest.mark.parametrize("coll", GRID_COLLS)
    def test_below_floor_clamps_to_one_chunk(self, coll):
        # FAT link: bandwidth-bound, so chunking would otherwise pay
        links = _grid_links((2, 4), "fat")
        hs = choose_hop_schedule([2, 4], links, self.FLOOR - 1,
                                 collective=coll)
        assert hs.num_chunks == 1 and hs.hybrid_chunks == 1
        assert hs.mode in ("oneshot", "perhop")

    def test_floor_boundary_is_exact(self):
        links = _grid_links((2, 4), "fat")
        at = choose_hop_schedule([2, 4], links, float(self.FLOOR),
                                 collective="ag")
        below = choose_hop_schedule([2, 4], links, float(self.FLOOR) - 1.0,
                                    collective="ag")
        assert below.num_chunks == 1  # clamped outright
        assert at.num_chunks > 1      # floor is exclusive: chunking resumes

    def test_above_floor_chunks_stay_packet_sized(self):
        links = _grid_links((2, 4), "fat")
        for shard in (self.FLOOR, 4 * self.FLOOR, 64 * self.FLOOR):
            hs = choose_hop_schedule([2, 4], links, float(shard),
                                     collective="ag")
            for c in (hs.num_chunks, hs.hybrid_chunks):
                if c > 1:
                    assert shard / c >= TERARACK.packet_bytes


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------

class TestConformanceGrid:
    """Deterministic sweep — runs everywhere, hypothesis or not."""

    @pytest.mark.parametrize("factors,shard,coll,link_variant", GRID)
    def test_all_invariants(self, factors, shard, coll, link_variant):
        links = _grid_links(factors, link_variant)
        check_electrical_no_drift(factors, shard, coll, links)
        check_hybrid_dominance(factors, shard, coll, links)
        check_chunk_normalization_no_drift(factors, shard, coll, links)

    @pytest.mark.parametrize("chunks", [1, 2, 8])
    @pytest.mark.parametrize("coll", GRID_COLLS)
    def test_forced_chunks(self, coll, chunks):
        check_forced_chunks_price_as_makespan(
            (2, 4), 1 * 2**20, coll, _grid_links((2, 4), "dcn_ici"), chunks)
        check_forced_chunks_price_as_makespan(
            (16, 2), 8 * 2**20, coll, _grid_links((16, 2), "fat"), chunks)

    @pytest.mark.parametrize("coll", GRID_COLLS)
    @pytest.mark.parametrize("w", [1, 2, 8])
    @pytest.mark.parametrize("sizes,slow_idx", [
        ((2, 4), 1), ((4, 2), 0), ((2, 2, 2), 2), ((3, 4), 1), ((8,), 0),
    ])
    def test_candidates_price_as_simulated(self, sizes, slow_idx, w, coll):
        check_candidates_price_as_simulated(
            list(sizes), w, coll, slow_idx, 1 * 2**20)

    @pytest.mark.parametrize("coll", GRID_COLLS)
    @pytest.mark.parametrize("w", [1, 2, 8])
    @pytest.mark.parametrize("derates,lost", HEALTH_GRID)
    @pytest.mark.parametrize("sizes", [(2, 4), (8,)])
    def test_degraded_conformance(self, sizes, w, coll, derates, lost):
        names = [f"x{i}" for i in range(len(sizes))]
        health = _health_for(names, derates, lost)
        check_degraded_conformance(list(sizes), w, coll, 1 * 2**20, health)

    @pytest.mark.parametrize("coll", GRID_COLLS)
    @pytest.mark.parametrize("overlap", [True, False])
    @pytest.mark.parametrize("delay", [0.0, 1e-5, 1e-3])
    @pytest.mark.parametrize("sizes,w", [
        ((16,), 2), ((8,), 1), ((2, 4), 2), ((3, 4), 2),
    ])
    def test_reconfig_conformance(self, sizes, w, coll, delay, overlap):
        check_reconfig_conformance(list(sizes), w, coll, 1 * 2**20,
                                   delay, overlap)


if HAVE_HYPOTHESIS:
    factors_st = st.lists(st.integers(min_value=1, max_value=5),
                          min_size=1, max_size=3).filter(
                              lambda f: math.prod(f) > 1)
    shard_st = st.floats(min_value=64.0, max_value=1e8)
    coll_st = st.sampled_from(GRID_COLLS)
    links_st = st.lists(
        st.tuples(st.floats(min_value=1e8, max_value=1e11),
                  st.floats(min_value=1e-7, max_value=1e-4)),
        min_size=3, max_size=3)

    def _links_for(factors, raw):
        return [LinkSpec(f"l{i}", bw, a)
                for i, ((bw, a), _) in enumerate(zip(raw, factors))]

    @given(factors=factors_st, shard=shard_st, coll=coll_st, raw=links_st)
    @settings(max_examples=60, deadline=None)
    def test_electrical_no_drift_property(factors, shard, coll, raw):
        check_electrical_no_drift(factors, shard, coll,
                                  _links_for(factors, raw))

    @given(factors=factors_st, shard=shard_st, coll=coll_st, raw=links_st,
           chunks=st.sampled_from([1, 2, 4, 8]))
    @settings(max_examples=40, deadline=None)
    def test_forced_chunks_property(factors, shard, coll, raw, chunks):
        check_forced_chunks_price_as_makespan(
            factors, shard, coll, _links_for(factors, raw), chunks)

    @given(factors=factors_st, shard=shard_st, coll=coll_st, raw=links_st)
    @settings(max_examples=60, deadline=None)
    def test_hybrid_dominance_property(factors, shard, coll, raw):
        check_hybrid_dominance(factors, shard, coll,
                               _links_for(factors, raw))

    @given(factors=factors_st, shard=shard_st, coll=coll_st, raw=links_st)
    @settings(max_examples=40, deadline=None)
    def test_chunk_normalization_property(factors, shard, coll, raw):
        check_chunk_normalization_no_drift(factors, shard, coll,
                                           _links_for(factors, raw))

    @given(
        sizes=st.lists(st.integers(min_value=2, max_value=4),
                       min_size=1, max_size=3),
        w=st.sampled_from([1, 2, 8, 64]),
        coll=coll_st,
        slow_idx=st.integers(min_value=0, max_value=2),
        shard=st.floats(min_value=1024.0, max_value=1e7),
    )
    @settings(max_examples=25, deadline=None)
    def test_candidates_price_as_simulated_property(
            sizes, w, coll, slow_idx, shard):
        check_candidates_price_as_simulated(sizes, w, coll, slow_idx, shard)

    @given(
        sizes=st.lists(st.integers(min_value=2, max_value=4),
                       min_size=1, max_size=3),
        w=st.sampled_from([1, 2, 8]),
        coll=coll_st,
        shard=st.floats(min_value=1024.0, max_value=1e7),
        derates=st.dictionaries(
            st.tuples(st.integers(min_value=0, max_value=2),
                      st.integers(min_value=0, max_value=1)),
            st.floats(min_value=0.05, max_value=1.0), max_size=4),
        lost=st.dictionaries(
            st.integers(min_value=0, max_value=2),
            st.sets(st.integers(min_value=0, max_value=7), max_size=6),
            max_size=3),
    )
    @settings(max_examples=30, deadline=None)
    def test_degraded_conformance_property(sizes, w, coll, shard, derates,
                                           lost):
        """ANY random health table: degraded >= healthy for both backends
        and price==simulate for every searched candidate under faults."""
        names = [f"x{i}" for i in range(len(sizes))]
        health = _health_for(names, derates, lost)
        check_degraded_conformance(sizes, w, coll, shard, health)

    @given(
        sizes=st.lists(st.integers(min_value=1, max_value=8),
                       min_size=1, max_size=3),
        w=st.sampled_from([1, 2, 8]),
        coll=st.sampled_from(["ag", "rs", "ar", "a2a"]),
        shard=st.floats(min_value=64.0, max_value=1e6),
        derates=st.dictionaries(
            st.tuples(st.integers(min_value=0, max_value=2),
                      st.integers(min_value=0, max_value=1)),
            st.floats(min_value=0.05, max_value=1.0), max_size=4),
        lost=st.dictionaries(
            st.integers(min_value=0, max_value=2),
            st.sets(st.integers(min_value=0, max_value=7), max_size=6),
            max_size=3),
    )
    @settings(max_examples=30, deadline=None)
    def test_latency_conformance_property(sizes, w, coll, shard, derates,
                                          lost):
        """ANY mesh/collective/health: the exchange family exists exactly
        where its structure applies, and wherever it exists its price is
        the simulator's wall time — healthy or degraded."""
        names = [f"x{i}" for i in range(len(sizes))]
        health = _health_for(names, derates, lost)
        check_latency_conformance(sizes, w, coll, shard, health)

    @given(
        sizes=st.one_of(
            st.lists(st.integers(min_value=4, max_value=16), min_size=1,
                     max_size=1),
            st.lists(st.integers(min_value=2, max_value=4), min_size=2,
                     max_size=3)),
        w=st.sampled_from([1, 2, 8]),
        coll=coll_st,
        shard=st.floats(min_value=1024.0, max_value=1e7),
        delay=st.sampled_from([0.0, 1e-6, 1e-4, 1e-2]),
        overlap=st.booleans(),
    )
    @settings(max_examples=30, deadline=None)
    def test_reconfig_conformance_property(sizes, w, coll, shard, delay,
                                           overlap):
        """ANY world x ANY reconfiguration delay: invariant (g) — the
        reconfiguring optical price is the simulator's wall time for
        every searched candidate, decomposes as fixed-ring + exposed,
        and the hold-vs-reconfigure pick follows the priced argmin."""
        check_reconfig_conformance(sizes, w, coll, shard, delay, overlap)


# --------------------------------------------------------------------------
# deterministic pins for the cross-world decision itself
# --------------------------------------------------------------------------

class TestOrderSearchDecisions:
    """The asymmetric table where the two worlds provably disagree: the
    size-4 axis on the SLOW transport — electrically the AG wants it first
    (smallest payload over the slow link), optically its ring hops are
    cheaper as stage 1 (whole-ring wavelength reuse), so at w<=2 the
    optical winner is a strictly different, strictly cheaper order."""

    AXES = [("a", 2, FAST), ("b", 4, SLOW)]
    # a2a's electrical cost is stage-order invariant (every stage moves
    # 1/m of every peer's shard regardless of position), so its "flip" is
    # electrical tie-break vs a strict optical preference — and the 2x4
    # table ties optically too.  2x3 at w<=2 separates: ("b","a") beats
    # the tie-break order ("a","b") on RWA step count (6 vs 7 at w=2).
    AXES_A2A = [("a", 2, FAST), ("b", 3, SLOW)]

    @pytest.mark.parametrize("coll", GRID_COLLS)
    def test_optical_flips_and_strictly_wins(self, coll):
        axes = self.AXES_A2A if coll == "a2a" else self.AXES
        n = math.prod(s for _, s, _ in axes)
        srch = search_stage_orders(axes, 1 * 2**20, collective=coll,
                                   backend="optical", system=_sys(n, 2))
        eb, ob = srch.best_by("electrical"), srch.best_by("optical")
        assert eb.order != ob.order
        assert ob.optical_s < eb.optical_s  # strictly, not a tie-break
        assert eb.electrical_s <= ob.electrical_s  # each world's own argmin
        assert srch.best == ob  # backend="optical" ranks by optical

    def test_electrical_backend_matches_default_planner_order(self):
        srch = search_stage_orders(self.AXES, 1 * 2**20, collective="ag",
                                   backend="electrical", system=_sys(8, 2))
        assert srch.best.order == ("b", "a")  # slow axis first

    def test_single_axis_factorization_candidates(self):
        """Paper-world search: one unnamed axis also enumerates balanced
        factorizations; every candidate still prices == simulates."""
        srch = search_stage_orders([(None, 16, ICI_LINK)], 1 * 2**20,
                                   backend="optical", system=_sys(16, 2))
        assert len(srch.candidates) > 1  # factorizations, not just (16,)
        for cand in srch.candidates:
            rep = simulate(schedule_from_ir(cand.plan, 2), _sys(16, 2),
                           cand.plan.shard_bytes, check=True)
            assert cand.optical_s == pytest.approx(rep.time_s, rel=1e-12)

    def test_backend_validated(self):
        with pytest.raises(ValueError, match="electrical|optical"):
            search_stage_orders(self.AXES, 1024, backend="fastest")

    def test_candidate_cap(self):
        # the cap truncates the ring-chain enumeration; the latency family
        # (at most axes! extra candidates) rides outside it by design
        srch = search_stage_orders(self.AXES, 1024, backend="electrical",
                                   max_candidates=1)
        ring = [c for c in srch.candidates if c.regime == "bandwidth"]
        assert len(ring) == 1 and srch.capped
        srch_ring_only = search_stage_orders(
            self.AXES, 1024, backend="electrical", max_candidates=1,
            include_latency=False)
        assert len(srch_ring_only.candidates) == 1 and srch_ring_only.capped


class TestPolicyOrderHook:
    """PlanPolicy.order="optical" drives the context's cached plan (the
    meshless axis_sizes path — no devices needed)."""

    def _ctx(self, backend, b_size=4):
        from repro.comms.api import CommContext, PlanPolicy

        links = {"a": FAST, "b": SLOW}
        return CommContext(
            axis_names=("a", "b"), links=links,
            axis_sizes={"a": 2, "b": b_size},
            policy=PlanPolicy(order=backend, optical=_sys(2 * b_size, 2)))

    def test_optical_policy_picks_different_order(self):
        for coll in GRID_COLLS:
            # a2a needs the 2x3 table — 2x4 ties optically (see
            # TestOrderSearchDecisions.AXES_A2A)
            b = 3 if coll == "a2a" else 4
            ctx_e, ctx_o = self._ctx("electrical", b), self._ctx("optical", b)
            pe, po = ctx_e.plan(coll, 2**20), ctx_o.plan(coll, 2**20)
            assert pe.axes != po.axes
            srch = po.meta["order_search"]
            assert srch["backend"] == "optical" and srch["flipped"]
            assert price(po, _sys(2 * b, 2)).total_s \
                < price(pe, _sys(2 * b, 2)).total_s

    def test_winner_cached_per_key(self):
        ctx = self._ctx("optical")
        p1 = ctx.plan("ag", 2**20)
        p2 = ctx.plan("ag", 2**20)
        assert p1 is p2  # the search ran once; the winner is the cache entry
        assert ctx.cache_stats.hits == 1 and ctx.cache_stats.misses == 1

    def test_policy_rejects_unknown_backend(self):
        from repro.comms.api import PlanPolicy

        with pytest.raises(ValueError, match="electrical"):
            PlanPolicy(order="fastest")


class TestReconfigDecisions:
    """Deterministic pins for the hold-vs-reconfigure planning dimension
    (ISSUE 10): the paper-world 16-node axis at w=2, where the balanced
    4x4 chain (half the ring steps, one circuit change) competes with the
    single-stage ring (more steps, one circuit held throughout)."""

    AXES = [(None, 16, ICI_LINK)]
    SHARD = 1 * 2**20

    def _search(self, delay, **kw):
        sysd = dataclasses.replace(_sys(16, 2), circuit_reconfig_s=delay)
        return search_stage_orders(self.AXES, self.SHARD, collective="ag",
                                   backend="optical", system=sysd, **kw)

    def test_flip_on_asymmetric_topology(self):
        """The acceptance flip: at zero delay a factored chain (>= 1
        reconfiguration) strictly beats the hold-the-circuit ring; at a
        large delay the search flips to the zero-reconfiguration ring."""
        cheap = self._search(0.0).best
        assert cheap.reconfigurations > 0
        dear = self._search(1.0).best
        assert dear.reconfigurations == 0
        assert dear.order == (None,)  # the single-stage ring holds
        ring0 = next(c for c in self._search(0.0).candidates
                     if c.reconfigurations == 0)
        assert cheap.optical_s < ring0.optical_s  # strict at delay=0

    def test_swot_overlap_hides_small_delays(self):
        """A delay shorter than the previous stage's last in-flight step
        is FULLY hidden: the reconfiguring winner's price is bit-equal to
        its zero-delay price, exposure 0 — while the no-overlap world
        pays it."""
        srch = self._search(1e-5)
        best = srch.best
        assert best.reconfigurations > 0  # still worth reconfiguring
        zero = price(best.plan, _sys(16, 2)).total_s
        assert best.optical_s == zero
        noov = dataclasses.replace(
            _sys(16, 2), circuit_reconfig_s=1e-5, reconfig_overlap=False)
        assert price(best.plan, noov).total_s == pytest.approx(
            zero + 1e-5 * best.reconfigurations, rel=1e-12)

    def test_reconfig_knob_constrains_the_space(self):
        hold = self._search(0.0, reconfig="hold")
        assert all(c.reconfigurations == 0 for c in hold.candidates)
        assert hold.best.order == (None,)
        rec = self._search(0.0, reconfig="reconfigure")
        assert rec.candidates
        assert all(c.reconfigurations > 0 for c in rec.candidates)

    def test_reconfig_knob_validated(self):
        with pytest.raises(ValueError, match="auto|hold|reconfigure"):
            self._search(0.0, reconfig="never")

    def test_hold_impossible_raises(self):
        """A multi-stage named mesh must re-circuit between axes — every
        candidate reconfigures, so reconfig='hold' empties the space and
        raises a clear error instead of silently relaxing."""
        axes = [("a", 2, FAST), ("b", 4, SLOW)]
        with pytest.raises(ValueError, match="hold"):
            search_stage_orders(axes, self.SHARD, collective="ag",
                                backend="optical", system=_sys(8, 2),
                                reconfig="hold")

    def test_policy_reconfig_validation(self):
        from repro.comms.api import PlanPolicy

        with pytest.raises(ValueError, match="auto|hold|reconfigure"):
            PlanPolicy(order="optical", reconfig="never")
        # the knob only constrains the searched-order path
        with pytest.raises(ValueError, match="order"):
            PlanPolicy(reconfig="hold")
        PlanPolicy(order="optical", reconfig="hold")  # valid

    def test_policy_reconfigurations_reach_telemetry(self):
        from repro.comms.api import CommContext, PlanPolicy

        ctx = CommContext(
            axis_names=("a", "b"), links={"a": FAST, "b": SLOW},
            axis_sizes={"a": 2, "b": 4},
            policy=PlanPolicy(order="optical", optical=_sys(8, 2),
                              reconfig="reconfigure"))
        plan = ctx.plan("ag", 2**20)
        assert plan.meta["order_search"]["reconfigurations"] >= 1
        snap = ctx.telemetry_snapshot()
        rec = snap["per_plan"][0]["order_search"]
        assert rec["reconfigurations"] >= 1


class TestSubAxisFactorizationGuard:
    """Satellite (ISSUE 10): sub-axis factorization of a PHYSICAL mesh
    axis used to be a silent no-op — ``max_k`` simply did nothing unless
    the world was a single unnamed axis.  It is now a loud ValueError:
    named axes are atomic (shard_map cannot split a physical axis into
    ppermute sub-stages)."""

    def test_named_single_axis_rejects_max_k(self):
        with pytest.raises(ValueError, match="atomic"):
            search_stage_orders([("a", 16, ICI_LINK)], 2**20, max_k=2)

    def test_multi_axis_rejects_max_k(self):
        with pytest.raises(ValueError, match="atomic"):
            search_stage_orders([("a", 4, FAST), ("b", 2, SLOW)], 2**20,
                                max_k=3)

    def test_unnamed_single_axis_still_factors(self):
        srch = search_stage_orders([(None, 16, ICI_LINK)], 2**20, max_k=2,
                                   backend="optical", system=_sys(16, 2))
        assert any(len(c.order) == 2 for c in srch.candidates)

    def test_max_k_one_is_a_no_op_everywhere(self):
        # explicitly asking for NO factorization is legal on any world
        srch = search_stage_orders([("a", 4, FAST), ("b", 2, SLOW)], 2**20,
                                   max_k=1)
        assert srch.candidates
