import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# (must precede jax import — same rule as dryrun.py; setdefault so CI can
# run the --collectives smoke on its own 8-device setting)

DOC = """Perf hillclimb driver (§Perf): re-lower one cell under a set of
named override variants and report the three roofline terms per variant.

  python -m repro.launch.perf --arch qwen3-32b --shape train_4k \
      --variants baseline,no_sp,dots_remat

Variants are defined in VARIANTS below; each is a dict of ModelConfig
overrides (the knobs: remat / remat_policy / sequence_parallel /
loss_chunk / kv_shard / dtype / moe capacity).

  python -m repro.launch.perf --collectives 2,4 --sizes-kb 64,1024

runs the staged-collective microbenchmarks instead: modeled-electrical
(LinkSpec alpha/bandwidth), modeled-optical (paper Eq. 3 on the RWA-lowered
schedule) and measured time — all three priced/measured off the SAME
CollectivePlan IR object the engine executes — for each execution mode
(one-shot stage barriers / chunked wavefront / per-hop ppermute rings /
the perhop-chunked hybrid) per AG/RS/AR per size, plus the XLA flat
one-shot baseline, on a fake-device mesh of the given factorization.

  --calibrate          fit per-axis LinkSpec alpha/bandwidth from the
                       measured sweep (least squares; printed as JSON and,
                       with --links PATH, written there)
  --links fitted.json  feed a previous --calibrate output back into the
                       comms context: plans are re-planned with the FITTED
                       specs instead of the hard-coded v5e constants (the
                       context's links-fingerprinted plan cache invalidates
                       itself) — the ROADMAP auto-calibration loop
  --order electrical|optical
                       run the cross-world stage-order search per plan
                       (PlanPolicy.order): every candidate stage order is
                       priced under BOTH cost worlds and the named
                       backend's winner drives the executor.  Each
                       collective also reports the electrical-best vs
                       optical-best order and whether they disagree
                       ("flipped") on this links table.
  --optical-w W        wavelength count for the optical pricer in the
                       order search (default: TERARACK's 64; small meshes
                       need small w for step counts to differentiate)

  python -m repro.launch.perf --reconfig

runs the modeled hold-vs-reconfigure sweep on the reconfigurable photonic
fabric (pure python, no devices): the per-event circuit-reconfiguration
delay is swept over the paper-world single-axis topology, the order
search ranks every candidate at each point (price==simulate re-checked),
and the sweep asserts the planning flip — factored multi-stage chains
win at small delay, hold-the-circuit single-ring plans past the
crossover.  SWOT-style overlap (reconfiguration hidden behind the
previous stage's in-flight last step) is asserted never to price worse
than paying the delay exposed.

  python -m repro.launch.perf --tp-block 2,4

benchmarks the explicit-TP transformer block (context-scoped collectives,
TP and SP variants — models.model.transformer_block_tp) against the GSPMD
path: modeled-electrical, modeled-optical and measured time off the same
CollectivePlan objects the context cached while the block ran.

  python -m repro.launch.perf --moe 2,4

benchmarks the expert-parallel MoE block: experts sharded over the last
mesh axis, dispatch/combine crossing the mesh through the context-planned
``api.all_to_all`` (two a2a issues per block).  Reports modeled-electrical,
modeled-optical and measured time off the cached CollectivePlan objects,
checks the EP block against the all-experts-local reference per device
shard, and times the replicated-experts GSPMD path for contrast.
"""

import argparse
import dataclasses
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.dryrun import dryrun_cell
from repro.launch.roofline import roofline_for_cell

VARIANTS = {
    # paper-faithful baseline = the framework defaults
    "baseline": {},
    # compute knobs
    "no_remat": {"remat": False},
    "dots_remat": {"remat_policy": "dots"},
    # comms/layout knobs
    "no_sp": {"sequence_parallel": False},
    "kv_heads": {"kv_shard": "heads"},
    "kv_seq": {"kv_shard": "seq"},
    "fsdp": {"fsdp": True},
    # attention head alignment (qwen2.5: 40 -> 48 = 3/shard on TP16;
    # adds zero-capacity-cost padded heads, +4% attn params, documented)
    "heads48": {"num_heads": 48},
    "heads64": {"num_heads": 64},
    # loss pipeline
    "chunk_128": {"loss_chunk": 128},
    "chunk_2048": {"loss_chunk": 2048},
    # optimizer state compression
    "opt_bf16": {"opt_state_dtype": "bfloat16"},
    "opt_lean": {"opt_state_dtype": "bfloat16", "opt_use_master": False},
    # microbatching
    "accum4": {"grad_accum": 4},
    "accum8": {"grad_accum": 8},
}


def ssm_chunk_override(arch: str, chunk: int):
    cfg = get_config(arch)
    if cfg.ssm is None:
        return None
    return {"ssm": dataclasses.replace(cfg.ssm, scan_chunk=chunk)}


def moe_capacity_override(arch: str, factor: float):
    cfg = get_config(arch)
    if cfg.moe is None:
        return None
    return {"moe": dataclasses.replace(cfg.moe, capacity_factor=factor)}


def run_variant(arch, shape, name, overrides, out_dir):
    res = dryrun_cell(arch, shape, multi_pod=False, overrides=overrides,
                      calibrate=True)
    r = roofline_for_cell(res)
    row = {
        "variant": name,
        "compute_ms": r.compute_s * 1e3,
        "memory_ms": r.memory_s * 1e3,
        "collective_ms": r.collective_s * 1e3,
        "bottleneck": r.bottleneck,
        "useful": r.useful_ratio,
        "temp_gb": (res["memory"]["temp_size_in_bytes"] / 2**30
                    if res.get("memory") else None),
        "step_roofline_ms": r.step_s * 1e3,
    }
    print(f"[perf] {name:<12} compute={row['compute_ms']:.2f}ms "
          f"memory={row['memory_ms']:.2f}ms coll={row['collective_ms']:.2f}ms "
          f"bound={row['bottleneck']} temp={row['temp_gb'] and round(row['temp_gb'],1)}GB")
    if out_dir:
        p = Path(out_dir)
        p.mkdir(parents=True, exist_ok=True)
        (p / f"{arch}__{shape}__{name}.json").write_text(
            json.dumps({"overrides": {k: str(v) for k, v in overrides.items()},
                        "row": row, "cell": res}, indent=2, default=str))
    return row


def _bench_setup(factors_csv: str, links_path=None, order=None,
                 optical_w=None):
    import dataclasses as dc

    import numpy as np

    from repro.comms import make_factorized_mesh
    from repro.comms.api import CommContext, PlanPolicy
    from repro.core.cost_model import TERARACK, derive_wavelengths
    from repro.core.planner import DCN_LINK, ICI_LINK, load_links

    try:
        factors = [int(x) for x in factors_csv.split(",")]
    except ValueError:
        raise SystemExit(f"wanted comma-separated mesh factors, "
                         f"got {factors_csv!r}")
    names = [f"s{i}" for i in range(len(factors))]
    n = int(np.prod(factors))
    mesh = make_factorized_mesh(factors, names)
    # one link model for the modeled plans AND the context being measured:
    # the major axis is DCN-class (the pod analogue), the rest ICI — unless
    # a --links file (a --calibrate output) overrides with fitted specs
    link_map = {names[i]: (DCN_LINK if i == 0 and len(factors) > 1 else ICI_LINK)
                for i in range(len(factors))}
    fitted = None
    if links_path:
        # load_links validates the axis set against this mesh (unknown axes
        # raise; fitted first so the wavelength budget derives from it)
        fitted = load_links(links_path, fallbacks=link_map,
                            expect_axes=names, allow_missing=True)
    w = optical_w
    if w is None and fitted is not None and order:
        # derive the per-mesh wavelength budget from calibration: enough
        # WDM channels to carry the fastest fitted link, instead of
        # hand-picking --optical-w (ROADMAP follow-up, ISSUE 10)
        w = derive_wavelengths(fitted)
        print(f"[perf/collectives] derived optical wavelengths w={w} "
              f"from fitted links (override with --optical-w)")
    optical_sys = dc.replace(
        TERARACK, n_nodes=n, wavelengths=w if w else TERARACK.wavelengths)
    policy = PlanPolicy(order=order, optical=optical_sys) if order \
        else PlanPolicy()
    ctx = CommContext(mesh, tuple(names), links=link_map, policy=policy)
    if fitted is not None:
        # update_links invalidates any cached plans and re-plans — the
        # auto-calibration loop, no new engine/context required
        ctx.update_links(fitted)
        link_map = ctx.links
        print(f"[perf/collectives] using fitted links from {links_path}: "
              + " ".join(f"{k}=(B={v.bandwidth_bytes:.3g},a={v.alpha_s:.3g})"
                         for k, v in sorted(fitted.items())))
    return factors, names, n, mesh, link_map, ctx


def _timed(fn, *args, reps=10):
    import time

    fn(*args).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def collectives_bench(factors_csv: str, sizes_kb_csv: str, reps: int = 10,
                      links_path=None, order=None, optical_w=None,
                      bench_json=None) -> None:
    """Staged-collective microbenchmarks off the CollectivePlan IR: for each
    collective and size, the modeled-electrical (LinkSpec), modeled-optical
    (Eq. 3 on the RWA-lowered schedule) and measured time of all four
    execution modes (oneshot / chunked / perhop / hybrid) — every number
    derived from the SAME plan object the engine interprets — vs the XLA
    flat single-shot baseline.  With ``order=`` the context runs the
    cross-world stage-order search and each row reports the
    electrical-best vs optical-best order ("flipped" when the two worlds
    disagree).

    Each (collective, size) point also reports the LATENCY REGIME (ISSUE
    8): the recursive-doubling exchange chain's modeled electrical/optical
    cost against the best ring mode, which family ``regime="auto"``
    actually planned at that size, and the measured wall-clock of the
    auto-planned path — decode-size payloads hit cached latency plans
    while the large sizes keep their ring/hybrid modes.  ``bench_json``
    writes the whole sweep (per-mode modeled + measured + the latency
    rows + crossovers + cache counters) to that path."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.comms import api
    from repro.compat import shard_map
    from repro.core.cost_model import TERARACK, plan_exposure, price

    factors, names, n, mesh, link_map, ctx = _bench_setup(
        factors_csv, links_path, order=order, optical_w=optical_w)
    sys_n = dc.replace(
        TERARACK, n_nodes=n,
        wavelengths=optical_w if optical_w else TERARACK.wavelengths)
    bench_rows = []

    for kb in (int(s) for s in sizes_kb_csv.split(",")):
        rows = kb * 256 // n * n  # f32 rows, divisible by the device count
        x = jnp.arange(rows, dtype=jnp.float32)
        xs = jax.device_put(x, NamedSharding(mesh, P(tuple(names))))

        flat = {
            "ar": shard_map(
                lambda y: jax.lax.psum(y, tuple(names)), mesh=mesh,
                in_specs=P(), out_specs=P()),
            "rs": shard_map(
                lambda y: jax.lax.psum_scatter(
                    y, tuple(names), scatter_dimension=0, tiled=True),
                mesh=mesh, in_specs=P(), out_specs=P(tuple(names))),
            "ag": shard_map(
                lambda y: jax.lax.all_gather(y, tuple(names), axis=0, tiled=True),
                mesh=mesh, in_specs=P(tuple(names)), out_specs=P()),
        }
        entry = {
            "ag": (lambda y, mode=None: api.all_gather(y, ctx=ctx, mode=mode),
                   xs),
            "rs": (lambda y, mode=None: api.reduce_scatter(
                y, ctx=ctx, mode=mode), x),
            "ar": (lambda y, mode=None: api.all_reduce(
                y, axis=0, ctx=ctx, mode=mode), x),
        }

        ag_search = None
        for coll in ("ag", "rs", "ar"):
            fn, arg = entry[coll]
            shard = x.size * x.dtype.itemsize / n
            # ring family for the four-mode rows: mode overrides only
            # apply to ring plans, so price/measure them off the
            # bandwidth-regime entry...
            plan = ctx.plan(coll, shard, shape=tuple(x.shape), dtype=x.dtype,
                            regime="bandwidth")
            # ...while the AUTO entry is what a plain (decode-style) op
            # call hits — the per-size regime winner
            auto_plan = ctx.plan(coll, shard, shape=tuple(x.shape),
                                 dtype=x.dtype)
            regime = auto_plan.meta.get("regime", "bandwidth")
            if coll == "ag":
                ag_search = auto_plan.meta.get("order_search")
            modeled = {m: price(plan.with_mode(m)).total_s
                       for m in ("oneshot", "chunked", "perhop", "hybrid")}
            optical = price(plan, TERARACK)
            exposed, hidden = plan_exposure(plan)
            # jit per mode so reps measure execution, not tracing
            measured = {
                m: _timed(jax.jit(lambda y, m=m, fn=fn: fn(y, mode=m)), arg,
                          reps=reps)
                for m in ("oneshot", "chunked", "perhop", "hybrid")
            }
            flat_us = _timed(jax.jit(flat[coll]), arg, reps=reps)
            parts = " ".join(
                f"{m}={modeled[m]*1e6:.1f}/{measured[m]:.0f}us"
                for m in ("oneshot", "chunked", "perhop", "hybrid"))
            srch = plan.meta.get("order_search")
            order_note = ""
            if srch:
                order_note = (
                    f"order[{srch['backend']}]="
                    f"{','.join(srch['order'])} "
                    f"elec_best={','.join(srch['electrical_best_order'])} "
                    f"opt_best={','.join(srch['optical_best_order'])} "
                    f"flipped={srch['flipped']} ")
            print(f"[perf/collectives] {coll} {kb}KB mesh={factors} "
                  f"modeled/measured: {parts} "
                  f"xla_oneshot={flat_us:.0f}us "
                  f"optical={optical.total_s*1e6:.1f}us"
                  f"@{optical.steps}steps "
                  f"chosen={plan.mode} chunks={plan.num_chunks} "
                  f"{order_note}"
                  f"stage_modes={list(plan.stage_modes)} "
                  f"exposed={sum(exposed)/2**10:.0f}KB "
                  f"hidden={sum(hidden)/2**10:.0f}KB "
                  f"(wall-clock on fake host devices; modeled times are the "
                  f"decision signal)")

            # latency regime (ISSUE 8): the recursive-doubling exchange
            # chain vs the best ring mode, plus what "auto" actually
            # planned and executed at this size
            lat_plan = auto_plan if regime == "latency" else None
            if lat_plan is None:
                try:
                    lat_plan = ctx.plan(coll, shard, shape=tuple(x.shape),
                                        dtype=x.dtype, regime="latency")
                except ValueError:
                    lat_plan = None
            lat_row = None
            if lat_plan is not None:
                lat_elec = price(lat_plan).total_s
                lat_opt = price(lat_plan, sys_n)
                auto_us = _timed(
                    jax.jit(lambda y, fn=fn: fn(y, mode=None)), arg,
                    reps=reps)
                ring_best = min(modeled.values())
                lat_row = dict(
                    elec_us=lat_elec * 1e6, opt_us=lat_opt.total_s * 1e6,
                    opt_steps=lat_opt.steps, rounds=len(lat_plan.stages),
                    measured_auto_us=auto_us)
                print(f"[perf/latency] {coll} {kb}KB regime={regime} "
                      f"exchange: elec={lat_elec*1e6:.1f}us vs "
                      f"ring_best={ring_best*1e6:.1f}us "
                      f"optical={lat_opt.total_s*1e6:.1f}us"
                      f"@{lat_opt.steps}steps "
                      f"rounds={len(lat_plan.stages)} "
                      f"measured_auto={auto_us:.0f}us "
                      f"(auto plans the {regime} family at this size)")
            else:
                print(f"[perf/latency] {coll} {kb}KB regime={regime} "
                      f"exchange=n/a (needs power-of-two axis sizes)")
            bench_rows.append(dict(
                collective=coll, kb=kb, shard_bytes=shard, regime=regime,
                modeled_us={m: v * 1e6 for m, v in modeled.items()},
                measured_us=measured, xla_oneshot_us=flat_us,
                optical_us=optical.total_s * 1e6,
                optical_steps=optical.steps, latency=lat_row))
        if order and ag_search:
            # one cross-world summary per size, straight off the cached AG
            # plan's search verdict (the context already priced every
            # candidate under both backends — no second sweep)
            print(f"[perf/order] {kb}KB ag: electrical-best="
                  f"{','.join(ag_search['electrical_best_order'])} "
                  f"optical-best="
                  f"{','.join(ag_search['optical_best_order'])} "
                  f"winner[{ag_search['backend']}]="
                  f"{','.join(ag_search['order'])} "
                  f"({ag_search['electrical_s']*1e6:.1f}us elec, "
                  f"{ag_search['optical_s']*1e6:.1f}us opt"
                  f"@{ag_search['optical_steps']}steps) "
                  f"flipped={ag_search['flipped']} "
                  f"regime={ag_search.get('regime', 'bandwidth')} "
                  f"regime_flipped={ag_search.get('regime_flipped', False)}")

    # per-collective crossovers + the per-size winner cache made visible:
    # payloads below the crossover planned (and executed) exchange chains,
    # larger ones kept their ring modes — same context, same cache
    xovers = {c: ctx.latency_crossover(c) for c in ("ag", "rs", "ar")}
    xnote = " ".join(
        f"{c}={'n/a' if b is None else format(b, '.0f') + 'B'}"
        for c, b in xovers.items())
    st = ctx.cache_stats
    print(f"[perf/latency] crossover mesh={factors} {xnote} "
          f"(electrical; smaller payloads plan exchange chains)")
    print(f"[perf/latency] cache: latency_plans={st.latency_plans} "
          f"ring_plans={st.ring_plans} hits={st.hits} misses={st.misses} "
          f"(decode-size psums hit the cached latency plans)")
    if bench_json:
        doc = {
            "mesh": factors,
            "axis_names": names,
            "links": {k: {"name": v.name,
                          "bandwidth_bytes": v.bandwidth_bytes,
                          "alpha_s": v.alpha_s}
                      for k, v in sorted(link_map.items())},
            "optical_w": sys_n.wavelengths,
            "order": order,
            "reps": reps,
            "rows": bench_rows,
            "crossover_bytes": xovers,
            "cache": dataclasses.asdict(st),
            "note": ("wall-clock measured on fake host devices (ppermutes "
                     "are barriers there); modeled times are the decision "
                     "signal"),
        }
        Path(bench_json).write_text(json.dumps(doc, indent=2) + "\n")
        print(f"[perf/latency] wrote {bench_json}")


def tp_block_bench(factors_csv: str, reps: int = 5, links_path=None,
                   seq: int = 32, batch: int = 2) -> list:
    """Explicit-TP transformer block (context collectives) vs the GSPMD
    path: modeled-electrical, modeled-optical and measured time, all off
    the SAME CollectivePlan objects the context caches while the block
    runs (ROADMAP: "full shard_map transformer block vs GSPMD").

    Runs both variants (TP: replicated activations, staged all-reduce
    combines; SP: sequence-sharded activations, fused AG→matmul /
    matmul→RS) on a fake-device mesh of the given factorization and checks
    the explicit block matches the GSPMD block numerically.
    """
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import shard_map
    from repro.comms import comm_context
    from repro.configs import ModelConfig
    from repro.core.cost_model import TERARACK, price
    from repro.models.model import (
        _layer_init,
        transformer_block_ref,
        transformer_block_tp,
        tp_block_specs,
    )

    factors, names, n, mesh, link_map, _ = _bench_setup(factors_csv, links_path)

    cfg = ModelConfig(
        name="tp-block-bench", family="dense", dtype="float32", remat=False,
        qkv_bias=False, qk_norm=False, num_layers=2, d_model=8 * n,
        num_heads=n, num_kv_heads=n, head_dim=8, d_ff=16 * n, vocab_size=128,
    )
    layer = _layer_init(jax.random.key(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (batch, seq, cfg.d_model),
                          jnp.float32)
    positions = jnp.broadcast_to(
        jnp.arange(seq)[None, :], (batch, seq)).astype(jnp.int32)

    ref = transformer_block_ref(layer, cfg, x, positions=positions)
    rows = []
    for sp in (False, True):
        tag = "sp" if sp else "tp"
        x_spec, l_spec = tp_block_specs(layer, names, sequence_parallel=sp)
        with comm_context(mesh, tuple(names), links=link_map) as ctx:
            explicit = jax.jit(shard_map(
                lambda lx, ll, sp=sp: transformer_block_tp(
                    ll, cfg, lx, positions=positions, sequence_parallel=sp),
                mesh=mesh, in_specs=(x_spec, l_spec), out_specs=x_spec,
            ))
            got = explicit(x, layer)
            ok = bool(np.allclose(np.asarray(got), np.asarray(ref), atol=2e-5))
            t_explicit = _timed(explicit, x, layer, reps=reps)

            # the GSPMD path: same math on full params, the partitioner
            # emits the collectives from the TP in_shardings
            gspmd = jax.jit(
                lambda lx, ll: transformer_block_ref(
                    ll, cfg, lx, positions=positions),
                in_shardings=(
                    NamedSharding(mesh, x_spec),
                    jax.tree.map(lambda s: NamedSharding(mesh, s), l_spec),
                ),
                out_shardings=NamedSharding(mesh, x_spec),
            )
            t_gspmd = _timed(gspmd, x, layer, reps=reps)

            # every collective the block issued, off the context's cache —
            # priced electrical AND optical from the very objects executed,
            # weighted by how often each deduplicated plan was issued (the
            # TP block's two all-reduces share one cache entry)
            usage = ctx.plan_usage()
            issued = sum(c for _, c in usage)
            elec = sum(price(p).total_s * c for p, c in usage)
            opt = sum(
                price(p, dc.replace(TERARACK, n_nodes=p.n)).total_s * c
                for p, c in usage
            )
            row = dict(
                variant=tag, plans=len(usage), issued=issued,
                modeled_elec_us=elec * 1e6, modeled_opt_us=opt * 1e6,
                measured_tp_us=t_explicit, measured_gspmd_us=t_gspmd,
                allclose=ok, cache=dc.asdict(ctx.cache_stats),
                modes=sorted({p.mode for p, _ in usage}),
            )
            rows.append(row)
            print(f"[perf/tp-block] {tag} mesh={factors} B={batch} S={seq} "
                  f"d={cfg.d_model}: plans={row['plans']} "
                  f"issued={issued} "
                  f"modeled elec={row['modeled_elec_us']:.1f}us "
                  f"optical={row['modeled_opt_us']:.1f}us | measured "
                  f"explicit={t_explicit:.0f}us gspmd={t_gspmd:.0f}us "
                  f"allclose={ok} modes={row['modes']} "
                  f"(fake host devices: modeled times are the decision "
                  f"signal)")
            if not ok:
                raise SystemExit(f"tp-block {tag}: explicit block diverged "
                                 f"from the GSPMD block")
    return rows


def moe_block_bench(factors_csv: str, reps: int = 5, links_path=None,
                    archs: str = "llama4-scout-17b-a16e,arctic-480b",
                    seq: int = 8) -> list:
    """Expert-parallel MoE block vs the all-experts-local reference: experts
    sharded over the LAST mesh axis, dispatch/combine crossing the mesh
    through the context-planned ``api.all_to_all`` (``models.moe`` EP path).

    Every number comes off the SAME CollectivePlan objects the context
    cached while the block ran: modeled-electrical (LinkSpec), modeled-
    optical (Eq. 3 on the RWA-lowered a2a schedule) and measured time,
    weighted by issue count.  The EP output must match running the block
    per device shard with all experts local (group-local dispatch never
    crosses shards — only the expert compute location differs); the
    replicated-experts GSPMD jit is timed for contrast.
    """
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import shard_map
    from repro.comms import comm_context
    from repro.configs import expert_parallel, get_config, reduced
    from repro.core.cost_model import TERARACK, price
    from repro.models.moe import moe_block, moe_init

    factors, names, n, mesh, link_map, _ = _bench_setup(factors_csv, links_path)
    ep_axis = names[-1]
    m = factors[-1]
    rows = []
    for arch in archs.split(","):
        cfg = expert_parallel(reduced(get_config(arch)), axis=ep_axis)
        if cfg.moe.num_experts % m:
            raise SystemExit(
                f"--moe: {arch} reduced num_experts={cfg.moe.num_experts} "
                f"not divisible by expert axis {ep_axis!r} size {m}")
        cfg_ref = dc.replace(
            cfg, moe=dc.replace(cfg.moe, expert_axis=None))
        p = moe_init(jax.random.key(0), cfg, dtype=jnp.float32)
        per_dev = 2
        B = per_dev * n
        x = jax.random.normal(jax.random.key(1), (B, seq, cfg.d_model),
                              jnp.float32)

        # all-experts-local reference, shard by shard (P(names) batch order)
        ref = jnp.concatenate(
            [moe_block(p, cfg_ref, x[i * per_dev:(i + 1) * per_dev])[0]
             for i in range(n)], axis=0)

        spec = P(tuple(names))
        with comm_context(mesh, tuple(names), links=link_map) as ctx:
            ep_fn = jax.jit(shard_map(
                lambda pp, xx: moe_block(pp, cfg, xx)[0], mesh=mesh,
                in_specs=(P(), spec), out_specs=spec))
            got = ep_fn(p, x)
            ok = bool(np.allclose(np.asarray(got), np.asarray(ref),
                                  atol=2e-5))
            t_ep = _timed(ep_fn, p, x, reps=reps)

            # GSPMD contrast: replicated experts, the partitioner decides
            gspmd = jax.jit(
                lambda pp, xx: moe_block(pp, cfg_ref, xx)[0],
                in_shardings=(NamedSharding(mesh, P()),
                              NamedSharding(mesh, spec)),
                out_shardings=NamedSharding(mesh, spec))
            t_gspmd = _timed(gspmd, p, x, reps=reps)

            usage = ctx.plan_usage()
            a2a = [(pl, c) for pl, c in usage if pl.collective == "a2a"]
            issued = sum(c for _, c in usage)
            elec = sum(price(pl).total_s * c for pl, c in usage)
            opt = sum(
                price(pl, dc.replace(TERARACK, n_nodes=pl.n)).total_s * c
                for pl, c in usage)
            row = dict(
                arch=arch, plans=len(usage), a2a_plans=len(a2a),
                issued=issued, modeled_elec_us=elec * 1e6,
                modeled_opt_us=opt * 1e6, measured_ep_us=t_ep,
                measured_gspmd_us=t_gspmd, allclose=ok,
                cache=dc.asdict(ctx.cache_stats),
                modes=sorted({pl.mode for pl, _ in usage}),
            )
            rows.append(row)
            print(f"[perf/moe] {arch} mesh={factors} ep_axis={ep_axis} "
                  f"E={cfg.moe.num_experts} top_k={cfg.moe.top_k}: "
                  f"plans={row['plans']} (a2a={row['a2a_plans']}) "
                  f"issued={issued} "
                  f"modeled elec={row['modeled_elec_us']:.1f}us "
                  f"optical={row['modeled_opt_us']:.1f}us | measured "
                  f"ep={t_ep:.0f}us gspmd={t_gspmd:.0f}us "
                  f"allclose={ok} modes={row['modes']} "
                  f"cache={row['cache']} "
                  f"(fake host devices: modeled times are the decision "
                  f"signal)")
            if not ok:
                raise SystemExit(f"--moe {arch}: EP block diverged from "
                                 f"the all-experts-local reference")
            if not a2a:
                raise SystemExit(f"--moe {arch}: no a2a plan in the "
                                 f"context cache — EP dispatch did not go "
                                 f"through api.all_to_all")
    return rows


def faults_bench(factors_csv: str, sizes_kb_csv: str, optical_w=None) -> list:
    """Modeled healthy-vs-degraded collective cost under a canonical fault
    set (``--faults``): both ring directions of the major axis derated to
    half bandwidth plus two lost wavelengths on the minor axis.  For every
    collective and size the SAME CollectivePlan is priced under both cost
    worlds twice — healthy, then with the ``LinkHealth`` table threaded
    through ``price`` (derated LinkSpecs electrically, the lost-wavelength
    union shrinking the RWA coloring optically) — and a second context
    planning UNDER the faults shows what the self-healing re-plan would
    choose.  Degraded prices are asserted monotone (never below healthy).
    """
    import dataclasses as dc

    from repro.comms.api import CommContext
    from repro.core.cost_model import TERARACK, price
    from repro.core.health import LinkHealth

    factors, names, n, mesh, link_map, ctx = _bench_setup(
        factors_csv, optical_w=optical_w)
    sys = dc.replace(
        TERARACK, n_nodes=n,
        wavelengths=optical_w if optical_w else TERARACK.wavelengths)
    health = LinkHealth.make(
        # both directions: axis_factor is the best ALIVE direction, so a
        # single-direction derate is invisible to the electrical model
        derate={(names[0], 0): 0.5, (names[0], 1): 0.5},
        lost_wavelengths={names[-1]: (1, 3)},
    )
    faulted = CommContext(mesh, tuple(names), links=link_map, health=health)
    print(f"[perf/faults] mesh={factors} health: {health.describe()} "
          f"(fp={faulted.health_fp})")

    rows = []
    for kb in (int(s) for s in sizes_kb_csv.split(",")):
        rows_n = kb * 256 // n * n  # f32 rows, divisible by the device count
        shard_bytes = rows_n * 4 / n
        for coll in ("ag", "rs", "ar", "a2a"):
            plan = ctx.plan(coll, shard_bytes)
            e_h = price(plan).total_s
            e_d = price(plan, health=health).total_s
            o_h = price(plan, sys)
            o_d = price(plan, sys, health=health)
            if e_d < e_h or o_d.total_s < o_h.total_s:
                raise SystemExit(
                    f"--faults: degraded price below healthy for {coll} "
                    f"{kb}KB (elec {e_d} < {e_h} or opt {o_d.total_s} < "
                    f"{o_h.total_s})")
            replanned = faulted.plan(coll, shard_bytes)
            row = dict(collective=coll, kb=kb, elec_healthy_us=e_h * 1e6,
                       elec_degraded_us=e_d * 1e6,
                       opt_healthy_us=o_h.total_s * 1e6,
                       opt_degraded_us=o_d.total_s * 1e6,
                       replanned_mode=replanned.mode)
            rows.append(row)
            print(f"[perf/faults] {coll} {kb}KB "
                  f"elec={e_h*1e6:.1f}->{e_d*1e6:.1f}us "
                  f"(x{e_d/e_h:.2f}) "
                  f"optical={o_h.total_s*1e6:.1f}us@{o_h.steps}"
                  f"->{o_d.total_s*1e6:.1f}us@{o_d.steps} steps "
                  f"replanned mode={replanned.mode} "
                  f"chunks={replanned.num_chunks}")
    st = faulted.cache_stats
    print(f"[perf/faults] faulted-context cache: misses={st.misses} "
          f"fallbacks={st.fallbacks}")
    return rows


def cluster_bench(policies_csv: str, *, requests: int = 16, seed: int = 0,
                  bench_json=None, measured: bool = True) -> dict:
    """Serving-policy sweep on a heterogeneous two-replica cluster (ISSUE 9).

    Part 1 — simulated: every routing policy against the SAME seeded
    Poisson and bursty traces on a fast+slow replica pair, priced under
    both cost worlds (electrical LinkSpec transmission vs the paper's
    optical Eq. 3).  The cost-model-aware policies must strictly beat
    round-robin on p99 for the Poisson trace — that ordering is asserted,
    not just printed.

    Part 2 — measured (``measured=True``): the same policies route real
    requests across two live ``BatchedServer`` replicas (2-layer vs
    deep tiny models on host devices), arrivals paced on the wall clock
    (``ClusterServer.run_trace``) in the underloaded regime where p99
    ordering is decided by which policy avoids the slow replica; the
    greedy-vs-round-robin ordering must match the simulator's prediction.

    ``bench_json`` writes the whole sweep (simulated grid + measured rows
    + the ordering verdicts) — e.g. ``BENCH_serving.json``.
    """
    from repro.cluster import (ClusterSim, ReplicaSpec, Request, bursty_trace,
                               make_policy, poisson_trace)
    from repro.core.planner import DCN_LINK, ICI_LINK

    policies = policies_csv.split(",")
    if "round-robin" not in policies:
        policies = ["round-robin"] + policies

    # -- part 1: simulated sweep on synthetic calibrated constants --------
    specs = [
        ReplicaSpec.from_times("fast", 4, prefill_token_s=1e-4,
                               decode_step_s=5e-4, link=ICI_LINK),
        ReplicaSpec.from_times("slow", 4, prefill_token_s=4e-4,
                               decode_step_s=2e-3, link=DCN_LINK),
    ]
    traces = {
        "poisson": poisson_trace(requests * 4, rate_rps=200.0, seed=seed),
        "bursty": bursty_trace(requests * 4, rate_rps=200.0, burst=4,
                               seed=seed),
    }
    sim_rows = []
    for world in ("electrical", "optical"):
        for tname, trace in traces.items():
            for pol in policies:
                st = ClusterSim(specs, make_policy(pol), world=world).run(trace)
                sim_rows.append(dict(
                    world=world, trace=tname, policy=pol,
                    p50_ms=st.latency_p50_s() * 1e3,
                    p99_ms=st.latency_p99_s() * 1e3,
                    makespan_ms=st.makespan_s * 1e3,
                    throughput_tok_s=st.throughput_tok_s(),
                    routed=dict(st.routed)))
                print(f"[perf/cluster] sim {world:10s} {tname:7s} "
                      f"{pol:12s} p50={st.latency_p50_s()*1e3:7.2f}ms "
                      f"p99={st.latency_p99_s()*1e3:7.2f}ms "
                      f"tput={st.throughput_tok_s():6.0f}tok/s "
                      f"routed={dict(st.routed)}")
    by = {(r["world"], r["trace"], r["policy"]): r for r in sim_rows}
    for world in ("electrical", "optical"):
        rr = by[(world, "poisson", "round-robin")]["p99_ms"]
        for pol in policies:
            if pol in ("round-robin", "jsq"):
                continue
            got = by[(world, "poisson", pol)]["p99_ms"]
            if got >= rr:
                raise SystemExit(
                    f"--cluster: {pol} p99 {got:.2f}ms not better than "
                    f"round-robin {rr:.2f}ms ({world}/poisson) — the cost "
                    f"model stopped paying for itself")
    print(f"[perf/cluster] sim: cost-model policies beat round-robin p99 "
          f"on the poisson trace in both worlds")

    measured_rows, verdicts = [], {}
    if measured:
        # -- part 2: measured 2-replica host run --------------------------
        import dataclasses as dc

        import jax
        import numpy as np

        from repro.cluster import (ClusterServer, measure_replica_times)
        from repro.configs import get_config, reduced
        from repro.models import init_params
        from repro.runtime import BatchedServer, ServerConfig

        def tiny(layers, d_ff=64):
            return dc.replace(
                reduced(get_config("granite-3-2b")), num_layers=layers,
                d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                d_ff=d_ff, vocab_size=128)

        fast_cfg, slow_cfg = tiny(2), tiny(24, d_ff=512)
        fp = init_params(jax.random.key(0), fast_cfg)
        sp = init_params(jax.random.key(1), slow_cfg)
        scfg = ServerConfig(batch_size=2, max_seq=64, max_new_tokens=6)
        pf, df = measure_replica_times(fast_cfg, fp, scfg, prompt_tokens=8,
                                       warmup=2)
        ps, ds = measure_replica_times(slow_cfg, sp, scfg, prompt_tokens=8,
                                       warmup=2)
        print(f"[perf/cluster] calibrated fast step={df*1e3:.3f}ms "
              f"slow step={ds*1e3:.3f}ms (x{ds/df:.1f})")
        mspecs = [
            ReplicaSpec.from_times("fast", 2, prefill_token_s=pf,
                                   decode_step_s=df),
            ReplicaSpec.from_times("slow", 2, prefill_token_s=ps,
                                   decode_step_s=ds),
        ]
        probe = Request(rid=0, arrival_s=0.0, prompt_tokens=8, new_tokens=6)
        rate = 0.25 / mspecs[1].request_service_s(probe)
        trace = poisson_trace(requests, rate_rps=rate, seed=seed,
                              prompt_tokens=(8, 8), new_tokens=(6, 6))
        for pol in policies:
            sim = ClusterSim(mspecs, make_policy(pol)).run(trace)
            servers = [BatchedServer(fast_cfg, fp, scfg),
                       BatchedServer(slow_cfg, sp, scfg)]
            for srv in servers:  # warm jits out of the measured window
                srv.submit(np.arange(8, dtype=np.int32) % 128)
                srv.run_until_drained()
                srv.reset()
            cs = ClusterServer(servers, mspecs, make_policy(pol))
            st = cs.run_trace(trace, prompts=[
                np.arange(r.prompt_tokens, dtype=np.int32) % 128
                for r in trace])
            measured_rows.append(dict(
                policy=pol, sim_p99_ms=sim.latency_p99_s() * 1e3,
                measured_p99_ms=st.latency_p99_s() * 1e3,
                sim_p50_ms=sim.latency_p50_s() * 1e3,
                measured_p50_ms=st.latency_p50_s() * 1e3,
                sim_routed=dict(sim.routed), measured_routed=dict(st.routed)))
            print(f"[perf/cluster] measured {pol:12s} "
                  f"sim_p99={sim.latency_p99_s()*1e3:7.2f}ms "
                  f"meas_p99={st.latency_p99_s()*1e3:7.2f}ms "
                  f"sim_routed={dict(sim.routed)} "
                  f"meas_routed={dict(st.routed)}")
        mb = {r["policy"]: r for r in measured_rows}
        rr = mb["round-robin"]
        for pol in policies:
            if pol == "round-robin":
                continue
            verdicts[pol] = dict(
                sim_better=mb[pol]["sim_p99_ms"] < rr["sim_p99_ms"],
                measured_better=mb[pol]["measured_p99_ms"]
                < rr["measured_p99_ms"])
        g = verdicts.get("greedy")
        if g and not (g["sim_better"] and g["measured_better"]):
            raise SystemExit(
                f"--cluster: greedy-vs-round-robin ordering mismatch "
                f"(sim_better={g['sim_better']} "
                f"measured_better={g['measured_better']}) — the simulator's "
                f"prediction no longer matches the measured cluster")
        print(f"[perf/cluster] measured: policy ordering matches the "
              f"simulator's prediction (greedy beats round-robin in both)")

    doc = dict(requests=requests, seed=seed, policies=policies,
               replicas=[dc_spec.name for dc_spec in specs],
               simulated=sim_rows, measured=measured_rows,
               ordering_verdicts=verdicts,
               note=("simulated sweep on synthetic calibrated constants in "
                     "both cost worlds; measured rows from 2 live "
                     "BatchedServer replicas on host devices with wall-"
                     "clock-paced arrivals (underloaded regime — p99 "
                     "ordering, not absolute times, is the validated "
                     "signal)"))
    if bench_json:
        Path(bench_json).write_text(json.dumps(doc, indent=2) + "\n")
        print(f"[perf/cluster] wrote {bench_json}")
    return doc


def reconfig_bench(n: int = 16, w: int = 2, shard_kb: int = 1024,
                   bench_json=None) -> dict:
    """Modeled hold-vs-reconfigure sweep on the reconfigurable photonic
    fabric (pure python — no devices, no jit): sweep the per-event circuit
    reconfiguration delay over the paper-world single-axis topology,
    letting ``search_stage_orders`` rank every candidate stage
    factorization at each point, and re-check ``price == simulate`` for
    the winner everywhere.  Asserts the planning flip the reconfiguring
    world exists for: at zero/small delay a factored multi-stage chain
    (fewer steps, >= 1 circuit change) wins; past the crossover the
    search holds ONE circuit for the whole collective (the single-stage
    ring, zero reconfigurations).  Also asserts SWOT overlap dominance:
    hiding reconfiguration behind the previous stage's in-flight last
    step never prices worse than paying it exposed."""
    import dataclasses as dc

    from repro.core import (
        TERARACK,
        price,
        schedule_from_ir,
        search_stage_orders,
        validate_schedule,
    )
    from repro.core.plan_ir import optical_message_bytes
    from repro.core.planner import ICI_LINK
    from repro.optics import simulate

    axes = [(None, n, ICI_LINK)]
    shard = shard_kb * 1024.0
    rows = []
    for delay in (0.0, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2):
        sysd = dc.replace(TERARACK, n_nodes=n, wavelengths=w,
                          circuit_reconfig_s=delay)
        srch = search_stage_orders(axes, shard, collective="ag",
                                   backend="optical", system=sysd)
        best = srch.best
        sched = schedule_from_ir(best.plan, sysd.wavelengths)
        validate_schedule(sched)
        rep = simulate(sched, sysd, optical_message_bytes(best.plan))
        if abs(best.optical_s - rep.time_s) > 1e-12 * rep.time_s:
            raise SystemExit(
                f"--reconfig: price != simulate at delay={delay:g} "
                f"({best.optical_s} vs {rep.time_s})")
        if rep.reconfigurations != best.reconfigurations:
            raise SystemExit(
                f"--reconfig: pricer/simulator disagree on event count at "
                f"delay={delay:g} ({best.reconfigurations} vs "
                f"{rep.reconfigurations})")
        # SWOT overlap dominance on the same plan
        t_no = price(best.plan,
                     dc.replace(sysd, reconfig_overlap=False)).total_s
        if best.optical_s > t_no * (1 + 1e-12):
            raise SystemExit(
                f"--reconfig: overlap priced WORSE than exposed at "
                f"delay={delay:g} ({best.optical_s} vs {t_no})")
        factors = [s.factor for s in best.plan.stages]
        rows.append(dict(
            delay_s=delay, factors=factors,
            reconfigurations=best.reconfigurations,
            optical_s=best.optical_s, exposed_s=rep.reconfig_exposed_s,
            no_overlap_s=t_no))
        print(f"[perf/reconfig] delay={delay:8.2e}s "
              f"best={'x'.join(map(str, factors)):>8s} "
              f"reconfigs={best.reconfigurations} "
              f"t={best.optical_s*1e3:8.4f}ms "
              f"exposed={rep.reconfig_exposed_s*1e3:8.4f}ms "
              f"no_overlap={t_no*1e3:8.4f}ms")
    if rows[0]["reconfigurations"] == 0:
        raise SystemExit("--reconfig: zero-delay winner already holds the "
                         "circuit — no reconfiguring candidate won, the "
                         "flip cannot be demonstrated")
    if rows[-1]["reconfigurations"] != 0:
        raise SystemExit("--reconfig: large-delay winner still pays "
                         f"{rows[-1]['reconfigurations']} reconfigurations "
                         "— the search never flipped to hold-the-circuit")
    flip_at = next(r["delay_s"] for r in rows if r["reconfigurations"] == 0)
    print(f"[perf/reconfig] hold-vs-reconfigure flip: search holds one "
          f"circuit from delay={flip_at:g}s on (n={n}, w={w}, "
          f"shard={shard_kb}KiB)")
    doc = dict(n=n, w=w, shard_kb=shard_kb, rows=rows, flip_at_s=flip_at,
               note=("modeled sweep: search_stage_orders under "
                     "OpticalSystem.circuit_reconfig_s, price==simulate "
                     "re-checked per point, SWOT overlap dominance "
                     "asserted; flip = winner's reconfiguration count "
                     "drops to zero"))
    if bench_json:
        Path(bench_json).write_text(json.dumps(doc, indent=2) + "\n")
        print(f"[perf/reconfig] wrote {bench_json}")
    return doc


def calibrate_links(factors_csv: str, sizes_kb_csv: str, reps: int = 10,
                    links_path=None) -> None:
    """Fit per-axis LinkSpec alpha/bandwidth from measured wall-clock.

    For each mesh axis, times the flat XLA all-gather over that axis alone
    across the ``--sizes-kb`` sweep, then least-squares the staged model
    ``t = steps·α + steps·shard/B`` over (steps, steps·shard) — replacing the
    hard-coded v5e constants with what this host actually does.  Prints the
    fitted specs as JSON; with ``--links PATH`` also writes them there, so a
    later ``--collectives`` run (or ``core.planner.load_links`` →
    ``StagedCollectiveEngine(links=...)``) plans with the fitted specs —
    the calibration feedback loop.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import shard_map

    factors, names, n, mesh, link_map, _ = _bench_setup(factors_csv)
    sizes_kb = [int(s) for s in sizes_kb_csv.split(",")]
    if len(sizes_kb) < 2:
        raise SystemExit("--calibrate needs >= 2 sizes in --sizes-kb to fit "
                         "alpha and bandwidth")

    fitted = {}
    for i, name in enumerate(names):
        m = factors[i]
        if m == 1:
            continue
        steps = m - 1
        rows_a, rhs = [], []
        ag = shard_map(
            lambda y, name=name: jax.lax.all_gather(y, name, axis=0, tiled=True),
            mesh=mesh, in_specs=P(name), out_specs=P(),
        )
        for kb in sizes_kb:
            rows = kb * 256 // m * m
            shard = rows * 4 / m
            x = jax.device_put(
                jnp.arange(rows, dtype=jnp.float32),
                NamedSharding(mesh, P(name)),
            )
            t = _timed(jax.jit(ag), x, reps=reps) * 1e-6
            rows_a.append([steps, steps * shard])
            rhs.append(t)
        sol, *_ = np.linalg.lstsq(np.asarray(rows_a), np.asarray(rhs),
                                  rcond=None)
        alpha = max(0.0, float(sol[0]))
        inv_b = float(sol[1])
        # a non-positive slope means wall-clock didn't grow with payload over
        # this sweep (launch/barrier cost dominates, e.g. fake host devices):
        # bandwidth is unidentifiable — report null rather than a fake number
        bandwidth = (1.0 / inv_b) if inv_b > 1e-18 else None
        fitted[name] = {
            "name": name,
            "bandwidth_bytes": bandwidth,
            "alpha_s": alpha,
            "hardcoded": {
                "bandwidth_bytes": link_map[name].bandwidth_bytes,
                "alpha_s": link_map[name].alpha_s,
            },
        }
        if bandwidth is None:
            fitted[name]["note"] = (
                "no measurable size dependence over this sweep "
                "(alpha-dominated); widen --sizes-kb to identify bandwidth"
            )
    doc = json.dumps({"mesh": factors, "fitted_links": fitted}, indent=2)
    print(doc)
    if links_path:
        Path(links_path).write_text(doc + "\n")
        print(f"[perf/calibrate] wrote {links_path} "
              f"(feed back via --collectives --links {links_path})")


def main():
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch")
    ap.add_argument("--collectives", default=None, metavar="F1,F2",
                    help="run staged-collective microbenchmarks on this "
                         "mesh factorization instead of the hillclimb")
    ap.add_argument("--tp-block", default=None, metavar="F1,F2",
                    help="benchmark the explicit-TP transformer block "
                         "(context collectives, TP and SP variants) vs the "
                         "GSPMD path on this mesh factorization — modeled "
                         "electrical/optical and measured, off the same "
                         "CollectivePlan objects")
    ap.add_argument("--moe", default=None, metavar="F1,F2",
                    help="benchmark the expert-parallel MoE block (experts "
                         "sharded over the last mesh axis, context-planned "
                         "all-to-all dispatch/combine) vs the replicated-"
                         "experts GSPMD path on this mesh factorization")
    ap.add_argument("--moe-archs", default="llama4-scout-17b-a16e,arctic-480b",
                    help="comma-set of MoE arch names for --moe "
                         "(reduced configs)")
    ap.add_argument("--faults", default=None, metavar="F1,F2",
                    help="report modeled healthy-vs-degraded cost per "
                         "collective on this mesh factorization under a "
                         "canonical link/wavelength fault set (derated CW "
                         "direction + lost wavelengths), plus the mode a "
                         "context planning under the faults would pick")
    ap.add_argument("--reconfig", action="store_true",
                    help="run the modeled hold-vs-reconfigure sweep on the "
                         "reconfigurable photonic fabric (pure python): "
                         "sweeps the per-event circuit reconfiguration "
                         "delay, asserts price==simulate per point and the "
                         "planning flip to hold-the-circuit past the "
                         "crossover (write rows with --bench-json)")
    ap.add_argument("--reconfig-n", type=int, default=16,
                    help="node count for --reconfig (single unnamed axis)")
    ap.add_argument("--reconfig-w", type=int, default=2,
                    help="wavelength count for --reconfig")
    ap.add_argument("--cluster", action="store_true",
                    help="run the serving-policy sweep on a heterogeneous "
                         "two-replica cluster: simulated under both cost "
                         "worlds plus a measured 2-replica host run, with "
                         "policy-beats-round-robin assertions (write the "
                         "sweep with --bench-json BENCH_serving.json)")
    ap.add_argument("--policies", default="round-robin,jsq,greedy,max-flow",
                    help="comma-set of routing policies for --cluster")
    ap.add_argument("--cluster-requests", type=int, default=16,
                    help="measured-trace length for --cluster (the "
                         "simulated sweep uses 4x this)")
    ap.add_argument("--sim-only", action="store_true",
                    help="with --cluster: skip the measured 2-replica run "
                         "(pure-python simulated sweep only)")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed for --cluster")
    ap.add_argument("--calibrate", action="store_true",
                    help="with --collectives: fit LinkSpec alpha/bandwidth "
                         "per mesh axis from measured wall-clock (printed "
                         "as JSON) instead of benchmarking")
    ap.add_argument("--reps", type=int, default=10,
                    help="timing repetitions for --collectives/--calibrate")
    ap.add_argument("--links", default=None, metavar="PATH",
                    help="with --calibrate: write the fitted LinkSpecs to "
                         "this JSON file; with --collectives: load fitted "
                         "specs from it and plan with them instead of the "
                         "hard-coded v5e constants")
    ap.add_argument("--order", default=None,
                    choices=["electrical", "optical"],
                    help="with --collectives: run the cross-world "
                         "stage-order search per plan and let this backend "
                         "pick the executed order; each row reports the "
                         "electrical-best vs optical-best order")
    ap.add_argument("--optical-w", type=int, default=None, metavar="W",
                    help="wavelength count for the optical pricer in the "
                         "--order search (default: TERARACK's 64)")
    ap.add_argument("--bench-json", default=None, metavar="PATH",
                    help="with --collectives: write the whole sweep (per-"
                         "mode modeled + measured, latency-regime rows, "
                         "crossovers, cache counters) to this JSON file, "
                         "e.g. BENCH_collectives.json")
    ap.add_argument("--sizes-kb", default="64,1024")
    ap.add_argument("--shape")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--moe-capacity", type=float, default=None)
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--combine", default=None,
                    help="comma-set of variant names merged into one run")
    ap.add_argument("--out", default="runs/perf")
    args = ap.parse_args()

    if args.reconfig:
        reconfig_bench(n=args.reconfig_n, w=args.reconfig_w,
                       bench_json=args.bench_json)
        return
    if args.cluster:
        cluster_bench(args.policies, requests=args.cluster_requests,
                      seed=args.seed, bench_json=args.bench_json,
                      measured=not args.sim_only)
        return
    if args.tp_block:
        tp_block_bench(args.tp_block, reps=args.reps, links_path=args.links)
        return
    if args.moe:
        moe_block_bench(args.moe, reps=args.reps, links_path=args.links,
                        archs=args.moe_archs)
        return
    if args.faults:
        faults_bench(args.faults, args.sizes_kb, optical_w=args.optical_w)
        return
    if args.collectives:
        if args.calibrate:
            calibrate_links(args.collectives, args.sizes_kb, args.reps,
                            links_path=args.links)
        else:
            collectives_bench(args.collectives, args.sizes_kb, args.reps,
                              links_path=args.links, order=args.order,
                              optical_w=args.optical_w,
                              bench_json=args.bench_json)
        return
    if not args.arch:
        ap.error("--arch is required unless --collectives is given")
    if not args.shape:
        ap.error("--shape is required unless --collectives is given")

    if args.combine:
        ov: dict = {}
        for name in args.combine.split(","):
            ov.update(VARIANTS[name])
        if args.ssm_chunk:
            ov.update(ssm_chunk_override(args.arch, args.ssm_chunk) or {})
        if args.moe_capacity:
            ov.update(moe_capacity_override(args.arch, args.moe_capacity) or {})
        run_variant(args.arch, args.shape,
                    "combo_" + args.combine.replace(",", "+"), ov, args.out)
        return

    for name in args.variants.split(","):
        if name == "cap" and args.moe_capacity is not None:
            ov = moe_capacity_override(args.arch, args.moe_capacity)
            if ov is None:
                print(f"[perf] {args.arch} has no MoE; skip capacity variant")
                continue
            run_variant(args.arch, args.shape, f"cap_{args.moe_capacity}", ov, args.out)
            continue
        if name == "ssm_chunk" and args.ssm_chunk is not None:
            ov = ssm_chunk_override(args.arch, args.ssm_chunk)
            if ov is None:
                print(f"[perf] {args.arch} has no SSM; skip chunk variant")
                continue
            run_variant(args.arch, args.shape, f"ssm_chunk_{args.ssm_chunk}", ov, args.out)
            continue
        if name not in VARIANTS:
            raise SystemExit(f"unknown variant {name}; have {sorted(VARIANTS)}")
        run_variant(args.arch, args.shape, name, VARIANTS[name], args.out)


if __name__ == "__main__":
    main()
