import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede jax import — same rule as dryrun.py)

DOC = """Perf hillclimb driver (§Perf): re-lower one cell under a set of
named override variants and report the three roofline terms per variant.

  python -m repro.launch.perf --arch qwen3-32b --shape train_4k \
      --variants baseline,no_sp,dots_remat

Variants are defined in VARIANTS below; each is a dict of ModelConfig
overrides (the knobs: remat / remat_policy / sequence_parallel /
loss_chunk / kv_shard / dtype / moe capacity).
"""

import argparse
import dataclasses
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.dryrun import dryrun_cell
from repro.launch.roofline import roofline_for_cell

VARIANTS = {
    # paper-faithful baseline = the framework defaults
    "baseline": {},
    # compute knobs
    "no_remat": {"remat": False},
    "dots_remat": {"remat_policy": "dots"},
    # comms/layout knobs
    "no_sp": {"sequence_parallel": False},
    "kv_heads": {"kv_shard": "heads"},
    "kv_seq": {"kv_shard": "seq"},
    "fsdp": {"fsdp": True},
    # attention head alignment (qwen2.5: 40 -> 48 = 3/shard on TP16;
    # adds zero-capacity-cost padded heads, +4% attn params, documented)
    "heads48": {"num_heads": 48},
    "heads64": {"num_heads": 64},
    # loss pipeline
    "chunk_128": {"loss_chunk": 128},
    "chunk_2048": {"loss_chunk": 2048},
    # optimizer state compression
    "opt_bf16": {"opt_state_dtype": "bfloat16"},
    "opt_lean": {"opt_state_dtype": "bfloat16", "opt_use_master": False},
    # microbatching
    "accum4": {"grad_accum": 4},
    "accum8": {"grad_accum": 8},
}


def ssm_chunk_override(arch: str, chunk: int):
    cfg = get_config(arch)
    if cfg.ssm is None:
        return None
    return {"ssm": dataclasses.replace(cfg.ssm, scan_chunk=chunk)}


def moe_capacity_override(arch: str, factor: float):
    cfg = get_config(arch)
    if cfg.moe is None:
        return None
    return {"moe": dataclasses.replace(cfg.moe, capacity_factor=factor)}


def run_variant(arch, shape, name, overrides, out_dir):
    res = dryrun_cell(arch, shape, multi_pod=False, overrides=overrides,
                      calibrate=True)
    r = roofline_for_cell(res)
    row = {
        "variant": name,
        "compute_ms": r.compute_s * 1e3,
        "memory_ms": r.memory_s * 1e3,
        "collective_ms": r.collective_s * 1e3,
        "bottleneck": r.bottleneck,
        "useful": r.useful_ratio,
        "temp_gb": (res["memory"]["temp_size_in_bytes"] / 2**30
                    if res.get("memory") else None),
        "step_roofline_ms": r.step_s * 1e3,
    }
    print(f"[perf] {name:<12} compute={row['compute_ms']:.2f}ms "
          f"memory={row['memory_ms']:.2f}ms coll={row['collective_ms']:.2f}ms "
          f"bound={row['bottleneck']} temp={row['temp_gb'] and round(row['temp_gb'],1)}GB")
    if out_dir:
        p = Path(out_dir)
        p.mkdir(parents=True, exist_ok=True)
        (p / f"{arch}__{shape}__{name}.json").write_text(
            json.dumps({"overrides": {k: str(v) for k, v in overrides.items()},
                        "row": row, "cell": res}, indent=2, default=str))
    return row


def main():
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--moe-capacity", type=float, default=None)
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--combine", default=None,
                    help="comma-set of variant names merged into one run")
    ap.add_argument("--out", default="runs/perf")
    args = ap.parse_args()

    if args.combine:
        ov: dict = {}
        for name in args.combine.split(","):
            ov.update(VARIANTS[name])
        if args.ssm_chunk:
            ov.update(ssm_chunk_override(args.arch, args.ssm_chunk) or {})
        if args.moe_capacity:
            ov.update(moe_capacity_override(args.arch, args.moe_capacity) or {})
        run_variant(args.arch, args.shape,
                    "combo_" + args.combine.replace(",", "+"), ov, args.out)
        return

    for name in args.variants.split(","):
        if name == "cap" and args.moe_capacity is not None:
            ov = moe_capacity_override(args.arch, args.moe_capacity)
            if ov is None:
                print(f"[perf] {args.arch} has no MoE; skip capacity variant")
                continue
            run_variant(args.arch, args.shape, f"cap_{args.moe_capacity}", ov, args.out)
            continue
        if name == "ssm_chunk" and args.ssm_chunk is not None:
            ov = ssm_chunk_override(args.arch, args.ssm_chunk)
            if ov is None:
                print(f"[perf] {args.arch} has no SSM; skip chunk variant")
                continue
            run_variant(args.arch, args.shape, f"ssm_chunk_{args.ssm_chunk}", ov, args.out)
            continue
        if name not in VARIANTS:
            raise SystemExit(f"unknown variant {name}; have {sorted(VARIANTS)}")
        run_variant(args.arch, args.shape, name, VARIANTS[name], args.out)


if __name__ == "__main__":
    main()
