"""Cluster training driver: mesh + pjit + ZeRO-1 + fault-tolerant loop.

On a real TPU cluster this runs under `jax.distributed.initialize()` with
one process per host; offline it can be exercised with fake host devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduced --mesh 2,4 --steps 10

Production invocation (per the assignment's mesh):
  python -m repro.launch.train --arch qwen3-32b --mesh 16,16 --steps 500
"""
import argparse
import contextlib
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comms import comm_context
from repro.configs import (
    SHAPES,
    expert_parallel,
    get_config,
    reduced as reduce_cfg,
)
from repro.data import DataConfig, SyntheticLMPipeline
from repro.models import init_params, loss_fn
from repro.models import sharding as shd
from repro.optim import OptimizerConfig, adamw_init, adamw_update, opt_state_specs
from repro.optim.zero1 import zero1_shard_grads, zero1_unshard_params
from repro.checkpoint import Checkpointer


def comm_plan_telemetry(ctx) -> list:
    """Per-plan telemetry lines for one CommContext: the cache counters
    (hits / misses / invalidated) and, per cached CollectivePlan, the
    collective, payload, chosen execution mode/chunks, the stage order it
    executes, how often it was issued, and — when the policy ran the
    cross-world order search — which backend picked the order and whether
    it flipped vs the other world.  Emitted every ``--log-every`` steps by
    the explicit train loop (not just at exit), so a mid-run links update
    (auto-calibration) is visible as invalidations + re-planned orders."""
    snap = ctx.telemetry_snapshot()
    st = snap["cache"]
    lines = [f"comm plans={snap['plans']} hits={st['hits']} "
             f"misses={st['misses']} invalidated={st['invalidated']} "
             f"replans_on_fault={st['replans_on_fault']} "
             f"fallbacks={st['fallbacks']} "
             f"latency_plans={st['latency_plans']} "
             f"ring_plans={st['ring_plans']} "
             f"health={snap['health_fp']}"]
    if ctx.axis_names:
        xover = snap["crossover_ar_bytes"]
        lines.append(
            f"  regime crossover(ar): "
            f"{'n/a' if xover is None else format(xover, '.0f') + 'B'} — "
            f"payloads below it plan recursive-doubling exchange chains")
    for rec in snap["per_plan"]:
        order = ",".join(rec["order"])
        line = (f"  {rec['collective']} "
                f"shard={rec['shard_bytes'] / 2**10:.1f}KiB "
                f"regime={rec['regime']} "
                f"mode={rec['mode']} chunks={rec['num_chunks']} "
                f"order=[{order}] issued=x{rec['issued']}")
        srch = rec.get("order_search")
        if srch:
            line += (f" picked_by={srch['backend']}"
                     f" flipped={srch['flipped']}"
                     f" regime_flipped={srch['regime_flipped']}"
                     f" reconfigs={srch.get('reconfigurations', 0)}")
        if rec.get("fallback"):
            line += " degraded=oneshot-fallback"
        lines.append(line)
    return lines


def modeled_pod_traffic_note(grad_bytes: float, mesh) -> str:
    """Modeled per-device pod(DCN)-axis gradient-sync traffic per step.

    Spec-based path: GSPMD's flat all-reduce over all data axes moves the
    full gradient over every axis, pod included — 2·G·(pod-1)/pod per device
    (RS+AG halves of the ring).  Explicit ZeRO-1 path
    (``zero1_shard_grads``): the pod axis is reduced on the already
    data-scattered shard, so it carries only G/data of that.
    """
    pod = mesh.shape.get("pod", 1)
    if pod == 1:
        return "pod-axis traffic: n/a (no pod axis in this mesh)"
    data = mesh.shape["data"]
    spec_mb = 2 * grad_bytes * (pod - 1) / pod / 2**20
    expl_mb = spec_mb / data
    return (f"modeled pod-axis traffic/device: spec={spec_mb:.2f}MiB/step "
            f"explicit={expl_mb:.2f}MiB/step ({data:.0f}x less: pod reduces "
            f"the data-scattered shard)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default="16,16", help="data,model axis sizes")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--resume", action="store_true",
                    help="restore params/opt from the latest committed "
                         "checkpoint in --ckpt-dir and continue from the "
                         "following step (no-op when the dir is empty)")
    ap.add_argument("--fault-step", type=int, default=None,
                    help="chaos hook: at this step, report a link fault to "
                         "the comm context (needs --zero1 explicit); the "
                         "context re-plans its cached collectives in place "
                         "under the degraded world")
    ap.add_argument("--fault-axis", default="data",
                    help="mesh axis the injected fault degrades")
    ap.add_argument("--fault-derate", type=float, default=0.5,
                    help="surviving bandwidth fraction for --fault-step")
    ap.add_argument("--verify-collectives", action="store_true",
                    help="run explicit collectives through the verified "
                         "executor (per-stage checksums + bounded retry + "
                         "one-shot fallback; needs --zero1 explicit)")
    ap.add_argument("--log-every", type=int, default=10,
                    help="step-log interval; with --zero1 explicit each log "
                         "also prints the comm context's per-plan telemetry "
                         "(cache stats + chosen order per plan)")
    ap.add_argument("--expert-parallel", action="store_true",
                    help="MoE archs: shard the experts over the 'data' mesh "
                         "axis and route dispatch/combine through the "
                         "context-planned api.all_to_all (models.moe EP "
                         "path).  Requires --zero1 explicit — the EP "
                         "all-to-all only activates inside the shard_map "
                         "train step where the axis is bound; the a2a "
                         "plans show up in the per-plan comm telemetry.")
    ap.add_argument("--zero1", choices=["spec", "explicit"], default="spec",
                    help="gradient sync: 'spec' lets GSPMD emit the "
                         "collectives from the ZeRO-1 sharding specs; "
                         "'explicit' runs the staged shard_map path "
                         "(zero1_shard_grads: reduce-scatter over data, pod "
                         "reduced on the scattered shard, staged re-gather). "
                         "Explicit is the pure-DP path (model axis must be 1).")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
        cfg = dataclasses.replace(cfg, dtype="float32")
    if args.expert_parallel:
        if args.zero1 != "explicit":
            raise SystemExit("--expert-parallel needs --zero1 explicit: the "
                             "EP all-to-all only runs inside the shard_map "
                             "train step where the expert axis is bound")
        cfg = expert_parallel(cfg, axis="data")  # raises if arch has no MoE
    shape = SHAPES["train_4k"]
    seq = args.seq or (64 if args.reduced else shape.seq_len)
    batch = args.batch or (4 if args.reduced else shape.global_batch)

    dims = tuple(int(x) for x in args.mesh.split(","))
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    mesh = compat.make_mesh(dims, names)
    print(f"mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    explicit = args.zero1 == "explicit"
    if explicit and mesh.shape.get("model", 1) != 1:
        raise SystemExit("--zero1 explicit is the pure-DP shard_map path; "
                         "use a mesh with model axis 1")
    # explicit mode runs the model inside shard_map (manual axes): GSPMD
    # activation constraints don't apply there
    shd.set_activation_policy(None if explicit else
                              {"dp": shd.dp_axes(mesh), "tp": "model",
                               "sequence_parallel": not args.reduced})

    params = init_params(jax.random.key(0), cfg)
    opt_state = adamw_init(params)
    pspecs = shd.sanitize_tree(shd.param_specs(cfg, params), params, mesh)
    ospecs = shd.sanitize_tree(
        opt_state_specs(pspecs, params, mesh), opt_state, mesh
    )
    if explicit:
        p_shard = o_shard = NamedSharding(mesh, P())
    else:
        p_shard = shd.named(mesh, pspecs)
        o_shard = shd.named(mesh, ospecs)
    params = jax.device_put(params, p_shard)
    opt_state = jax.device_put(opt_state, o_shard)

    opt_cfg = OptimizerConfig(warmup_steps=min(20, args.steps // 5 + 1),
                              decay_steps=args.steps)

    dp = shd.dp_axes(mesh)
    dp_divides = batch % np.prod([mesh.shape[a] for a in dp]) == 0
    bspec = NamedSharding(mesh, P(dp, None)) if dp_divides \
        else NamedSharding(mesh, P())

    comm_scope = contextlib.ExitStack()
    ctx = None
    if explicit:
        if not dp_divides:
            raise SystemExit(f"--zero1 explicit needs batch {batch} divisible "
                             f"by the data axes {dp}")
        fast = ("data",)
        slow = ("pod",) if "pod" in mesh.shape else ()
        ndp = int(np.prod([mesh.shape[a] for a in fast + slow]))
        # one context scopes every explicit collective (zero1_shard_grads /
        # zero1_unshard_params resolve it at trace time): plans are cached
        # here, and a fitted --links file or a reported fault re-plans them
        # in place
        pol_kw = {"verify": True} if args.verify_collectives else {}
        ctx = comm_scope.enter_context(comm_context(mesh, fast, **pol_kw))

        def explicit_step(params, opt_state, batch):
            # local grads on the local batch shard; the global mean-loss
            # gradient is (1/ndp)·Σ_ranks local, realized by the staged
            # reduce-scatter below (pod only ever sees the scattered shard)
            (_, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
            grads = jax.tree.map(lambda g: g / ndp, grads)
            grads = zero1_shard_grads(grads, fast, slow)
            grads = zero1_unshard_params(grads, fast, reference=params)
            new_p, new_o = adamw_update(grads, opt_state, params, opt_cfg)
            loss = jax.lax.psum(metrics["loss"], fast + slow) / ndp
            return new_p, new_o, loss

        train_step = jax.jit(compat.shard_map(
            explicit_step, mesh=mesh,
            in_specs=(P(), P(), P(dp, None)),
            out_specs=(P(), P(), P()),
        ))
        grad_bytes = sum(l.size * l.dtype.itemsize
                         for l in jax.tree.leaves(params))
        traffic_note = modeled_pod_traffic_note(grad_bytes, mesh)
        print(f"[train/zero1-explicit] {traffic_note}")
    else:
        traffic_note = ""

        @jax.jit
        def train_step(params, opt_state, batch):
            (_, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
            new_p, new_o = adamw_update(grads, opt_state, params, opt_cfg)
            return new_p, new_o, metrics["loss"]

    if args.fault_step is not None and not explicit:
        raise SystemExit("--fault-step reports into the comm context; it "
                         "needs --zero1 explicit")

    pipe = SyntheticLMPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch)).start()
    ckpt = Checkpointer(args.ckpt_dir)

    start_step = 0
    if args.resume:
        latest = ckpt.latest_step()
        if latest is None:
            print(f"[train/resume] no committed checkpoint in "
                  f"{args.ckpt_dir}; starting fresh")
        else:
            _, state = ckpt.restore({"params": params, "opt": opt_state})
            params = jax.device_put(state["params"], p_shard)
            opt_state = jax.device_put(state["opt"], o_shard)
            start_step = latest + 1
            print(f"[train/resume] resumed from step {latest} "
                  f"(next step {start_step})")

    t0 = time.time()
    loss0 = None
    loss = jnp.nan
    with comm_scope, mesh:
        for step in range(start_step, args.steps):
            if (ctx is not None and args.fault_step is not None
                    and step == args.fault_step):
                ctx.report_fault(axis=args.fault_axis,
                                 derate=args.fault_derate)
                st = ctx.cache_stats
                print(f"[train/fault] step {step}: derate "
                      f"{args.fault_derate} on axis {args.fault_axis!r} -> "
                      f"health={ctx.health_fp} "
                      f"replans_on_fault={st.replans_on_fault} "
                      f"fallbacks={st.fallbacks}")
            raw = next(pipe)
            batch_dev = {k: jax.device_put(jnp.asarray(v), bspec)
                         for k, v in raw.items()}
            params, opt_state, loss = train_step(params, opt_state, batch_dev)
            if step % args.log_every == 0 or step == args.steps - 1:
                lv = float(loss)
                loss0 = lv if loss0 is None else loss0
                extra = f" [{traffic_note}]" if traffic_note else ""
                print(f"step {step:5d} loss {lv:.4f} "
                      f"({(time.time()-t0)/(step-start_step+1):.2f}s/step)"
                      f"{extra}")
                if ctx is not None:
                    for line in comm_plan_telemetry(ctx):
                        print(f"[train/comms] {line}")
            if step and step % args.ckpt_interval == 0:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          blocking=False)
    ckpt.wait()
    pipe.stop()
    if ctx is not None:
        print("[train/zero1-explicit] final comm telemetry:")
        for line in comm_plan_telemetry(ctx):
            print(f"[train/comms] {line}")
        # the same data as ONE structured blob (machine-readable twin of
        # the lines above; the cluster front end logs the same shape)
        print("[train/comms-json] "
              + json.dumps(ctx.telemetry_snapshot(), sort_keys=True))
    if loss0 is None:  # resumed at/past --steps: nothing left to run
        print(f"done: no steps to run (resumed at {start_step} "
              f"of {args.steps})")
    else:
        print(f"done: {args.steps} steps in {time.time()-t0:.1f}s; "
              f"loss {loss0:.4f} -> {float(loss):.4f}")


if __name__ == "__main__":
    main()
