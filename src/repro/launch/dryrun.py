import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# init).  Everything below is ordinary code.

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step = fwd+bwd+AdamW;
prefill = forward installing KV; decode = one-token serve step), lowers it
with ShapeDtypeStruct stand-ins (zero allocation), compiles it for the
production mesh, and records:

  * memory_analysis()      — proves the cell fits per-device HBM,
  * cost_analysis()        — HLO FLOPs / bytes for the roofline,
  * collective traffic     — parsed from the optimized HLO text,
  * wall compile time.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out runs/dryrun]
  python -m repro.launch.dryrun --all --both-meshes --out runs/dryrun
"""

import argparse
import dataclasses
import functools
import json
import re
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    SHAPES,
    get_config,
    input_specs,
    list_archs,
    shape_supported,
)
from repro import compat
from repro.launch.mesh import make_production_mesh
from repro.models import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
)
from repro.models import sharding as shd
from repro.optim import OptimizerConfig, adamw_init, adamw_update, opt_state_specs

__all__ = ["dryrun_cell", "main"]


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3fn": 1,
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_DEF_RE = re.compile(
    r"%([\w.-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([a-z0-9-]+)\("
)
_OPERAND_RE = re.compile(r"%([\w.-]+)")


def _parse_result_bytes(type_str: str) -> int:
    total = 0
    for sm in _SHAPE_RE.finditer(type_str):
        total += _shape_bytes(sm.group(1), sm.group(2))
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in the optimized HLO.

    Optimized HLO references operands by name only, so pass 1 builds a
    symbol table name -> result bytes, and pass 2 resolves each collective's
    operand list against it.  (Result bytes are recorded too: for all-gather
    the *result* is the transferred payload upper bound, for reduce-scatter
    the *operand* is.)
    """
    sizes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.search(line)
        if m:
            sizes[m.group(1)] = _parse_result_bytes(m.group(2))

    per_kind_operand: Dict[str, int] = {}
    per_kind_result: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for line in lines:
        m = _DEF_RE.search(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        # operand list: inside the call parens, before attributes
        call = line[m.end() - 1 :]
        depth = 0
        end = len(call)
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_bytes = sum(
            sizes.get(om.group(1), 0) for om in _OPERAND_RE.finditer(call[:end])
        )
        per_kind_operand[kind] = per_kind_operand.get(kind, 0) + operand_bytes
        per_kind_result[kind] = per_kind_result.get(kind, 0) + _parse_result_bytes(type_str)
        counts[kind] = counts.get(kind, 0) + 1
    return {
        "bytes_by_kind": per_kind_operand,
        "result_bytes_by_kind": per_kind_result,
        "counts": counts,
        "total_bytes": sum(per_kind_operand.values()),
        "total_result_bytes": sum(per_kind_result.values()),
    }


# --------------------------------------------------------------------------
# cell construction
# --------------------------------------------------------------------------
def _tree_specs_to_shardings(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell(arch: str, shape_name: str, mesh, *, overrides: Optional[Dict] = None):
    """Returns (fn, arg_sds, in_shardings, out_shardings, meta)."""
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} skipped: {why}")

    # sequence-parallel activations for training: the per-layer remat carry
    # (B, S, d) is sharded over 'model' between blocks — the induced
    # gather/scatter pattern is exactly the staged all-gather the paper
    # optimizes (see DESIGN.md §3); decode/prefill keep replicated hiddens.
    shd.set_activation_policy(
        {"dp": shd.dp_axes(mesh), "tp": "model",
         "sequence_parallel": cfg.sequence_parallel and shape.kind == "train"}
    )

    params_sds = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    pspecs = shd.sanitize_tree(shd.param_specs(cfg, params_sds), params_sds, mesh)
    if cfg.fsdp:
        pspecs = shd.fsdp_tree(pspecs, params_sds, mesh)
    batch_sds = input_specs(cfg, shape)
    bspecs = shd.sanitize_tree(shd.batch_specs(cfg, shape, mesh), batch_sds, mesh)
    dp = shd.dp_axes(mesh)

    if shape.kind == "train":
        opt_cfg = OptimizerConfig(
            state_dtype=cfg.opt_state_dtype, use_master=cfg.opt_use_master
        )
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
        ospecs = opt_state_specs(pspecs, params_sds, mesh,
                                 with_master=cfg.opt_use_master)
        ospecs = shd.sanitize_tree(ospecs, opt_sds, mesh)

        def train_step(params, opt_state, batch):
            A = cfg.grad_accum
            if A <= 1:
                (_, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, batch), has_aux=True
                )(params)
            else:
                # microbatched gradient accumulation: peak activation memory
                # scales with B/A, grads/optimizer traffic unchanged
                micro = jax.tree.map(
                    lambda a: a.reshape((A, a.shape[0] // A) + a.shape[1:]), batch
                )

                def acc_body(carry, mb):
                    gacc, lacc = carry
                    (_, m), g = jax.value_and_grad(
                        lambda p: loss_fn(cfg, p, mb), has_aux=True
                    )(params)
                    return (jax.tree.map(jnp.add, gacc, g),
                            lacc + m["loss"]), 0

                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
                (grads, loss_sum), _ = jax.lax.scan(
                    acc_body, (zeros, jnp.zeros((), jnp.float32)), micro
                )
                grads = jax.tree.map(lambda g: g / A, grads)
                metrics = {"loss": loss_sum / A}
            new_params, new_opt = adamw_update(grads, opt_state, params, opt_cfg)
            return new_params, new_opt, metrics["loss"]

        fn = train_step
        args = (params_sds, opt_sds, batch_sds)
        in_specs = (pspecs, ospecs, bspecs)
        out_specs = (pspecs, ospecs, P())

    elif shape.kind == "prefill":
        cache_sds = jax.eval_shape(
            lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len)
        )
        cspecs = shd.sanitize_tree(shd.cache_specs(cfg, mesh), cache_sds, mesh)

        def prefill_step(params, batch, cache):
            # production prefill: install KV/state, emit last-token logits
            logits, new_cache, _ = forward(
                cfg, params, batch, cache=cache,
                cache_pos=jnp.zeros((), jnp.int32), head_mode="last",
            )
            return logits, new_cache

        fn = prefill_step
        args = (params_sds, batch_sds, cache_sds)
        in_specs = (pspecs, bspecs, cspecs)
        out_specs = (
            shd.sanitize_spec(
                P(dp, "model"), (shape.global_batch, cfg.vocab_size), mesh
            ),
            cspecs,
        )

    else:  # decode
        cache_sds = jax.eval_shape(
            lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len)
        )
        cspecs = shd.sanitize_tree(shd.cache_specs(cfg, mesh), cache_sds, mesh)
        tokens_sds = batch_sds.pop("tokens")
        pos_sds = batch_sds.pop("cache_pos")

        def serve_step(params, state, tokens, pos):
            return decode_step(cfg, params, state, tokens, pos)

        fn = serve_step
        args = (params_sds, cache_sds, tokens_sds, pos_sds)
        in_specs = (
            pspecs,
            cspecs,
            shd.sanitize_spec(P(dp, None), tokens_sds.shape, mesh),
            P(),
        )
        out_specs = (
            shd.sanitize_spec(
                P(dp, "model"), (shape.global_batch, cfg.vocab_size), mesh
            ),
            cspecs,
        )

    in_shard = _tree_specs_to_shardings(mesh, in_specs)
    out_shard = _tree_specs_to_shardings(mesh, out_specs)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "mesh": dict(mesh.shape)}
    return fn, args, in_shard, out_shard, meta


def _compile_cell(arch, shape_name, mesh, overrides):
    fn, args, in_shard, out_shard, meta = build_cell(
        arch, shape_name, mesh, overrides=overrides
    )
    with mesh:
        compiled = (
            jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard)
            .lower(*args)
            .compile()
        )
    return compiled


def calibrated_costs(
    arch: str, shape_name: str, mesh, overrides: Optional[Dict] = None
) -> Dict[str, Any]:
    """Correct for HloCostAnalysis counting while-loop (scan) bodies once:
    lower the same cell UNROLLED at depth u and 2u, then extrapolate
    total = f(u) + (L/u - 1) * (f(2u) - f(u)).  u = hybrid_attn_every for
    the hybrid arch (its repeating unit spans `every` layers), else 1."""
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    u = cfg.hybrid_attn_every if cfg.family == "hybrid" else 1
    probes = {}
    for n in (u, 2 * u):
        ov = dict(overrides or {})
        ov.update(num_layers=n, scan_layers=False)
        compiled = _compile_cell(arch, shape_name, mesh, ov)
        cost = compat.cost_analysis(compiled)
        probes[n] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": collective_bytes_from_hlo(compiled.as_text()),
        }
    scale = cfg.num_layers // u - 1
    a, b = probes[u], probes[2 * u]

    def comb(x, y):
        return x + scale * (y - x)

    kinds = set(a["coll"]["bytes_by_kind"]) | set(b["coll"]["bytes_by_kind"])
    coll_kinds = {
        k: comb(a["coll"]["bytes_by_kind"].get(k, 0),
                b["coll"]["bytes_by_kind"].get(k, 0))
        for k in kinds
    }
    return {
        "flops": comb(a["flops"], b["flops"]),
        "bytes_accessed": comb(a["bytes"], b["bytes"]),
        "collective_bytes_by_kind": coll_kinds,
        "collective_bytes": sum(coll_kinds.values()),
        "probe_depths": [u, 2 * u],
    }


def dryrun_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    overrides: Optional[Dict] = None,
    hlo_out: Optional[Path] = None,
    calibrate: bool = True,
) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_shard, out_shard, meta = build_cell(
        arch, shape_name, mesh, overrides=overrides
    )
    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    if hlo_out is not None:
        hlo_out.parent.mkdir(parents=True, exist_ok=True)
        hlo_out.write_text(hlo)

    result = {
        **meta,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "collectives": coll,
        "memory": {
            k: getattr(mem, k, None)
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
        } if mem is not None else None,
    }
    if calibrate:
        result["calibrated"] = calibrated_costs(
            arch, shape_name, mesh, overrides=overrides
        )
    print(f"[dryrun] {arch} x {shape_name} mesh={meta['mesh']} "
          f"compile={t_compile:.1f}s flops={result['flops']} "
          f"coll={coll['total_bytes']:.3e}B"
          + (f" cal_flops={result['calibrated']['flops']:.3e}" if calibrate else ""))
    print(f"[dryrun]   memory_analysis: {result['memory']}")
    return result


# --------------------------------------------------------------------------
def iter_cells():
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_supported(cfg, shape)
            yield arch, shape.name, ok, why


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    cells = (
        [(a, s) for a, s, ok, _ in iter_cells() if ok]
        if args.all
        else [(args.arch, args.shape)]
    )

    failures = []
    for multi_pod in meshes:
        tag = "multipod" if multi_pod else "singlepod"
        for arch, shape in cells:
            cell_file = out / f"{arch}__{shape}__{tag}.json"
            if cell_file.exists():
                print(f"[dryrun] skip existing {cell_file.name}")
                continue
            try:
                hlo_path = (
                    out / "hlo" / f"{arch}__{shape}__{tag}.txt"
                    if args.save_hlo else None
                )
                res = dryrun_cell(arch, shape, multi_pod=multi_pod,
                                  hlo_out=hlo_path)
            except Exception as e:  # noqa: BLE001 — record and continue
                res = {"arch": arch, "shape": shape, "ok": False,
                       "mesh": tag, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                failures.append((arch, shape, tag))
                print(f"[dryrun] FAIL {arch} x {shape} ({tag}): {e}")
            cell_file.write_text(json.dumps(res, indent=2, default=str))

    # skip report
    skip_file = out / "skips.json"
    skips = [
        {"arch": a, "shape": s, "reason": why}
        for a, s, ok, why in iter_cells() if not ok
    ]
    skip_file.write_text(json.dumps(skips, indent=2))
    print(f"[dryrun] done; {len(failures)} failures; skips -> {skip_file}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
