"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run pins the fake device count
before its first jax call.
"""
from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "POD_SHAPE", "MULTI_POD_SHAPE"]

POD_SHAPE = (16, 16)  # 256 chips/pod: DP x TP
MULTI_POD_SHAPE = (2, 16, 16)  # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
