"""Cluster serving driver: batched continuous decode on a mesh.

Offline smoke (single server):
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
      --requests 5

Multi-replica cluster front end (ISSUE 9):
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
      --replicas 2 --policy greedy --trace poisson:20 --requests 8 --hetero

With ``--replicas N`` the driver builds N ``BatchedServer`` replicas
(``--hetero`` makes odd replicas structurally deeper — the heterogeneous
mesh the routing policies exist for), calibrates each via
``measure_replica_times``, replays the seeded ``--trace`` through BOTH the
event-driven simulator and the live :class:`~repro.cluster.ClusterServer`,
and prints the two drain reports side by side — the simulated-vs-measured
comparison that validates the simulator (see ``docs/serving.md``).

The whole serve loop runs inside ONE ``comm_context`` over the local
devices (axis ``"tp"``): any decode collective — in particular the
sharded-KV combine (``comms/decode_attention.py``), which routes its psums
through ``repro.comms.api.all_reduce`` — plans through this context and
hits its plan cache instead of re-deriving stage orders per trace.  The
cache/plan telemetry is reported when the server drains (including the
same ``telemetry_snapshot()`` JSON blob train.py logs); the reduced
single-device smoke decodes unsharded (0 plans, and the report says so) —
the sharded combine's cache behavior is pinned by
``tests/subproc/check_comms.py`` on an 8-device mesh.
"""
import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.cluster import (ClusterServer, ClusterSim, ReplicaSpec,
                           make_policy, make_trace, measure_replica_times)
from repro.comms import comm_context
from repro.compat import make_mesh
from repro.configs import get_config, reduced as reduce_cfg
from repro.models import init_params
from repro.runtime import BatchedServer, ServerConfig


def _comms_report(ctx):
    n_plans = len(ctx.plans())
    note = ("" if n_plans else
            " — none issued: this run's decode path is unsharded; plans "
            "appear when the KV cache shards across devices "
            "(sharded_decode_attention)")
    print(f"[serve/comms] plan cache: {n_plans} plans, "
          f"{ctx.cache_stats}{note}")
    xover = ctx.latency_crossover("ar")
    print(f"[serve/comms] regimes: latency={ctx.cache_stats.latency_plans} "
          f"ring={ctx.cache_stats.ring_plans} crossover(ar)="
          f"{'n/a' if xover is None else format(xover, '.0f') + 'B'} — "
          f"decode psums below the crossover run recursive-doubling "
          f"exchange plans")
    print(f"[serve/comms] health={ctx.health_fp} "
          f"replans_on_fault={ctx.cache_stats.replans_on_fault} "
          f"fallbacks={ctx.cache_stats.fallbacks}")
    print("[serve/comms-json] " + json.dumps(ctx.telemetry_snapshot(),
                                             sort_keys=True))


def _serve_single(args, cfg):
    params = init_params(jax.random.key(0), cfg)
    server = BatchedServer(cfg, params, ServerConfig(
        batch_size=args.batch_size, max_seq=args.max_seq,
        max_new_tokens=args.new_tokens))

    mesh = make_mesh((len(jax.devices()),), ("tp",))
    with comm_context(mesh, ("tp",)) as ctx:
        rng = np.random.default_rng(args.seed)
        rids = [server.submit(rng.integers(0, cfg.vocab_size,
                                           size=int(rng.integers(4, 20))))
                for _ in range(args.requests)]
        t0 = time.time()
        results = server.run_until_drained()
        dt = time.time() - t0
    toks = sum(len(v) for v in results.values())
    print(f"served {len(rids)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    rep = server.drain_report()
    print(f"[serve/drain] requests={rep['requests']} tokens={rep['tokens']} "
          f"p50={rep['latency_p50_s'] * 1e3:.2f}ms "
          f"p99={rep['latency_p99_s'] * 1e3:.2f}ms "
          f"ttft_p50={rep['ttft_p50_s'] * 1e3:.2f}ms")
    for r in rep["per_request"]:
        print(f"[serve/drain]   rid={r['rid']} prompt={r['prompt_tokens']} "
              f"gen={r['generated']} queue→prefill→decode→finish "
              f"timestamps recorded")
    _comms_report(ctx)


def _serve_cluster(args, cfg):
    cfgs = []
    for i in range(args.replicas):
        c = cfg
        if args.hetero and i % 2 == 1:
            c = dataclasses.replace(
                cfg, num_layers=cfg.num_layers * args.hetero_factor)
        cfgs.append(c)
    scfg = ServerConfig(batch_size=args.batch_size, max_seq=args.max_seq,
                        max_new_tokens=args.new_tokens)
    specs, servers = [], []
    for i, c in enumerate(cfgs):
        params = init_params(jax.random.key(i), c)
        pf, ds = measure_replica_times(c, params, scfg, prompt_tokens=8)
        name = f"r{i}" + ("-deep" if c is not cfg else "")
        print(f"[serve/cluster] {name}: layers={c.num_layers} "
              f"prefill={pf * 1e3:.3f}ms/tok decode={ds * 1e3:.3f}ms/step")
        specs.append(ReplicaSpec.from_times(
            name, scfg.batch_size, prefill_token_s=pf, decode_step_s=ds))
        servers.append(BatchedServer(c, params, scfg))

    trace = make_trace(args.trace, n=args.requests, seed=args.seed,
                       prompt_tokens=(8, 8),
                       new_tokens=(args.new_tokens, args.new_tokens))
    sim = ClusterSim(specs, make_policy(args.policy), world=args.world)
    sim_stats = sim.run(trace)
    print(f"[serve/cluster] simulated({args.policy}) {sim_stats.summary()}")

    # warm each replica's jits so measured timestamps exclude compiles
    for srv in servers:
        srv.submit(np.arange(8, dtype=np.int32) % cfg.vocab_size)
        srv.run_until_drained()
        srv.reset()

    mesh = make_mesh((len(jax.devices()),), ("tp",))
    with comm_context(mesh, ("tp",)) as ctx:
        cluster = ClusterServer(servers, specs, make_policy(args.policy),
                                world=args.world)
        rng = np.random.default_rng(args.seed)
        prompts = [rng.integers(0, cfg.vocab_size, size=r.prompt_tokens)
                   for r in trace]
        meas = cluster.run_trace(trace, prompts=prompts)
    print(f"[serve/cluster] measured({args.policy})  {meas.summary()}")
    print("[serve/cluster-json] " + json.dumps(
        {"policy": args.policy, "world": args.world,
         "trace": args.trace, "seed": args.seed,
         "simulated": sim_stats.to_json(), "measured": meas.to_json()},
        sort_keys=True))
    _comms_report(ctx)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through N BatchedServer replicas behind "
                         "--policy (1: classic single-server path)")
    ap.add_argument("--policy", default="greedy",
                    help="routing policy: round-robin|jsq|greedy|max-flow")
    ap.add_argument("--trace", default="poisson:20",
                    help="arrival trace: poisson:RATE | bursty:RATE[,B] | "
                         "path to a recorded JSON trace")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--world", default="electrical",
                    choices=["electrical", "optical"],
                    help="transmission cost world for routing/simulation")
    ap.add_argument("--hetero", action="store_true",
                    help="make odd replicas deeper (heterogeneous mesh)")
    ap.add_argument("--hetero-factor", type=int, default=8,
                    help="layer multiplier for deep replicas under --hetero")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduce_cfg(cfg), dtype="float32")
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no autoregressive serve")

    if args.replicas > 1:
        _serve_cluster(args, cfg)
    else:
        _serve_single(args, cfg)


if __name__ == "__main__":
    main()
