"""Cluster serving driver: batched continuous decode on a mesh.

Offline smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
      --requests 5

The whole serve loop runs inside ONE ``comm_context`` over the local
devices (axis ``"tp"``): any decode collective — in particular the
sharded-KV combine (``comms/decode_attention.py``), which routes its psums
through ``repro.comms.api.all_reduce`` — plans through this context and
hits its plan cache instead of re-deriving stage orders per trace.  The
cache/plan telemetry is reported when the server drains; the reduced
single-device smoke decodes unsharded (0 plans, and the report says so) —
the sharded combine's cache behavior is pinned by
``tests/subproc/check_comms.py`` on an 8-device mesh.
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.comms import comm_context
from repro.compat import make_mesh
from repro.configs import get_config, reduced as reduce_cfg
from repro.models import init_params
from repro.runtime import BatchedServer, ServerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduce_cfg(cfg), dtype="float32")
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no autoregressive serve")

    params = init_params(jax.random.key(0), cfg)
    server = BatchedServer(cfg, params, ServerConfig(
        batch_size=args.batch_size, max_seq=args.max_seq,
        max_new_tokens=args.new_tokens))

    mesh = make_mesh((len(jax.devices()),), ("tp",))
    with comm_context(mesh, ("tp",)) as ctx:
        rng = np.random.default_rng(0)
        rids = [server.submit(rng.integers(0, cfg.vocab_size,
                                           size=int(rng.integers(4, 20))))
                for _ in range(args.requests)]
        t0 = time.time()
        results = server.run_until_drained()
        dt = time.time() - t0
    toks = sum(len(v) for v in results.values())
    print(f"served {len(rids)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    n_plans = len(ctx.plans())
    note = ("" if n_plans else
            " — none issued: this run's decode path is unsharded; plans "
            "appear when the KV cache shards across devices "
            "(sharded_decode_attention)")
    print(f"[serve/comms] plan cache: {n_plans} plans, "
          f"{ctx.cache_stats}{note}")
    xover = ctx.latency_crossover("ar")
    print(f"[serve/comms] regimes: latency={ctx.cache_stats.latency_plans} "
          f"ring={ctx.cache_stats.ring_plans} crossover(ar)="
          f"{'n/a' if xover is None else format(xover, '.0f') + 'B'} — "
          f"decode psums below the crossover run recursive-doubling "
          f"exchange plans")
    print(f"[serve/comms] health={ctx.health_fp} "
          f"replans_on_fault={ctx.cache_stats.replans_on_fault} "
          f"fallbacks={ctx.cache_stats.fallbacks}")


if __name__ == "__main__":
    main()
