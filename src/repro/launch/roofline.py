"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape) cell on the single-pod mesh, all in seconds
per step, derived from the *calibrated* dry-run costs (see
dryrun.calibrated_costs — scan bodies are extrapolated, since
HloCostAnalysis counts a while body once):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_operand_bytes_per_device / ICI_BW

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis() on the SPMD-partitioned module reports *per-device* numbers
(validated against 6ND in tests), so no further division by chip count.

MODEL_FLOPS = 6 * N * D (dense) or 6 * N_active * D (MoE), D = tokens per
step; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundant compute.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from ..configs import SHAPES, active_param_count, get_config, param_count

__all__ = ["HW", "roofline_for_cell", "analyze_dir", "format_table",
           "prefill_time_s", "decode_step_time_s"]

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link
CHIPS = 256  # single pod

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW, "chips": CHIPS}


@dataclass
class Roofline:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_dev: float
    hlo_flops_per_dev: float
    bottleneck: str
    useful_ratio: float
    fix_hint: str
    step_s: float  # max of the three = roofline-optimal step time
    roofline_fraction: float  # compute_s / step_s (how compute-bound we are)

    def row(self) -> List:
        return [
            self.arch, self.shape,
            f"{self.compute_s*1e3:.2f}", f"{self.memory_s*1e3:.2f}",
            f"{self.collective_s*1e3:.2f}", self.bottleneck,
            f"{self.useful_ratio:.2f}", f"{self.roofline_fraction:.2f}",
            self.fix_hint,
        ]


_HINTS = {
    "compute": ("compute-bound: reduce remat recompute / use a cheaper "
                "checkpoint policy; the MXU is the limit"),
    "memory": ("HBM-bound: fuse elementwise chains, shrink activation "
               "dtypes, or retile so weights/KV stream once"),
    "collective": ("ICI-bound: re-stage the all-gathers (OpTree planner), "
                   "overlap collectives with compute, or reshard to cut "
                   "cross-slice traffic"),
}


def model_flops_per_device(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / CHIPS
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / CHIPS
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch / CHIPS


# --------------------------------------------------------------------------
# serving phase-time queries (repro.cluster consumes these)
# --------------------------------------------------------------------------

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2}


def _weight_bytes(cfg) -> float:
    return float(param_count(cfg)) * _DTYPE_BYTES.get(cfg.dtype, 2)


def prefill_time_s(cfg, prompt_tokens: int, *, chips: int = 1,
                   peak_flops: float = PEAK_FLOPS,
                   hbm_bw: float = HBM_BW) -> float:
    """Roofline prefill time for one request of ``prompt_tokens``.

    ``max(compute, memory)``: 2·N_active FLOPs per token against the MXU
    peak, against one streaming pass over the weights.  The same two-term
    model the roofline table uses, specialized to the request phases the
    cluster simulator prices (``repro.cluster.sim``).
    """
    flops = 2.0 * active_param_count(cfg) * prompt_tokens / chips
    return max(flops / peak_flops, _weight_bytes(cfg) / chips / hbm_bw)


def decode_step_time_s(cfg, batch: int = 1, *, chips: int = 1,
                       peak_flops: float = PEAK_FLOPS,
                       hbm_bw: float = HBM_BW) -> float:
    """Roofline time of ONE decode engine step over ``batch`` active slots.

    Decode streams the full weight set for a handful of tokens, so the HBM
    term dominates until the batch is hundreds wide — the memory-bound
    regime the continuous-batching slot pool exists to amortize.
    """
    flops = 2.0 * active_param_count(cfg) * batch / chips
    return max(flops / peak_flops, _weight_bytes(cfg) / chips / hbm_bw)


def roofline_for_cell(cell: Dict) -> Optional[Roofline]:
    if not cell.get("ok"):
        return None
    cal = cell.get("calibrated") or {}
    flops = float(cal.get("flops") or cell.get("flops") or 0.0)
    hbytes = float(cal.get("bytes_accessed") or cell.get("bytes_accessed") or 0.0)
    cbytes = float(
        cal.get("collective_bytes")
        if cal.get("collective_bytes") is not None
        else cell.get("collectives", {}).get("total_bytes", 0.0)
    )
    compute_s = flops / PEAK_FLOPS
    memory_s = hbytes / HBM_BW
    collective_s = cbytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(cell["arch"], cell["shape"])
    step_s = max(terms.values())
    return Roofline(
        arch=cell["arch"],
        shape=cell["shape"],
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops_per_dev=mf,
        hlo_flops_per_dev=flops,
        bottleneck=bottleneck,
        useful_ratio=(mf / flops) if flops else float("nan"),
        fix_hint=_HINTS[bottleneck],
        step_s=step_s,
        roofline_fraction=(compute_s / step_s) if step_s else float("nan"),
    )


def analyze_dir(dryrun_dir: str, mesh_tag: str = "singlepod") -> List[Roofline]:
    out = []
    for p in sorted(Path(dryrun_dir).glob(f"*__{mesh_tag}.json")):
        cell = json.loads(p.read_text())
        r = roofline_for_cell(cell)
        if r is not None:
            out.append(r)
    return out


def format_table(rows: List[Roofline]) -> str:
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "bottleneck | 6ND/HLO | roofline frac | what moves it |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append("| " + " | ".join(str(c) for c in r.row()) + " |")
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    args = ap.parse_args()
    rows = analyze_dir(args.dir)
    print(format_table(rows))


if __name__ == "__main__":
    main()
