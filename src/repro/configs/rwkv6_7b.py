"""rwkv6-7b (Finch) [ssm] — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,  # rwkv6 head count = d_model / head_size(64)
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6", head_dim=64),
)
