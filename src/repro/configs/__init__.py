"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from typing import Dict, List

from .base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    active_param_count,
    expert_parallel,
    input_specs,
    param_count,
    reduced,
    shape_supported,
)

from . import (
    arctic_480b,
    granite_3_2b,
    hubert_xlarge,
    llama4_scout_17b_a16e,
    phi4_mini_3_8b,
    phi_3_vision_4_2b,
    qwen2_5_32b,
    qwen3_32b,
    rwkv6_7b,
    zamba2_2_7b,
)

_REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_5_32b,
        qwen3_32b,
        phi4_mini_3_8b,
        granite_3_2b,
        rwkv6_7b,
        llama4_scout_17b_a16e,
        arctic_480b,
        zamba2_2_7b,
        phi_3_vision_4_2b,
        hubert_xlarge,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    return sorted(_REGISTRY)
