"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]

The shared attention block (one weight set, reused) runs every 6th layer;
we omit the per-invocation LoRA deltas of the released model (noted in
DESIGN.md §8).
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    hybrid_attn_every=6,
    # scan_chunk: time-chunked remat of the SSD recurrence (train-time
    # activation memory /16; EXPERIMENTS.md §Perf hillclimb result)
    ssm=SSMConfig(kind="mamba2", head_dim=64, state_dim=64, expand=2,
                  scan_chunk=128),
)
