"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend stub.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

Per the assignment the modality frontend is a STUB: `input_specs()` provides
precomputed patch embeddings which the backbone merges into the first
``num_prefix_embeds`` positions of the token stream.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=1e4,
    frontend="vision",
    num_prefix_embeds=576,  # one CLIP-ViT-L/14 336px tile
)
