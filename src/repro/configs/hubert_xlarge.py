"""hubert-xlarge [audio] — encoder-only transformer (w2v2 arch).
[arXiv:2106.07447; unverified]

Per the assignment the conv feature extractor is a STUB: `input_specs()`
provides precomputed frame embeddings (B, S, d_model).  Encoder-only =>
bidirectional attention, no decode shapes.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    frontend="audio",
)
