"""Model/shape configuration system.

Every assigned architecture is a :class:`ModelConfig`; every assigned input
shape is a :class:`ShapeConfig`.  ``input_specs(cfg, shape)`` produces
ShapeDtypeStruct stand-ins for the dry-run (no allocation), and
``reduced(cfg)`` produces the CPU-smoke-test version of the same family.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "MoEConfig",
    "SSMConfig",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "expert_parallel",
    "input_specs",
    "reduced",
    "param_count",
]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False  # llama4-style always-on expert
    dense_residual: bool = False  # arctic-style parallel dense FFN branch
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    # expert parallelism: mesh axis the experts are sharded over.  When set
    # AND the model runs inside shard_map with this axis bound, moe_block
    # dispatches/combines across the mesh through the context-planned
    # ``repro.comms.api.all_to_all`` (num_experts must divide by the axis
    # size).  None = every device holds all experts (the GSPMD EP layout
    # stays available via sharding.param_specs).
    expert_axis: Optional[str] = None


@dataclass(frozen=True)
class SSMConfig:
    kind: str  # 'rwkv6' | 'mamba2'
    head_dim: int = 64  # rwkv6 head size / mamba2 P
    state_dim: int = 64  # mamba2 N (ssm_state)
    expand: int = 2  # mamba2 inner expansion
    conv_dim: int = 4  # mamba2 short conv width
    scan_chunk: int = 0  # >0: remat the time scan per chunk (trains long seqs)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | moe | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    causal: bool = True  # False => encoder-only (hubert)
    logit_softcap: float = 0.0
    # norm / embeddings
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # substructure
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0  # zamba2: shared attn block every k-th layer
    # modality frontend stubs (assignment: embeddings are precomputed inputs)
    frontend: Optional[str] = None  # 'vision' | 'audio'
    num_prefix_embeds: int = 0  # vision patch slots in the token stream
    # numerics / distribution defaults
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"  # 'full' | 'dots' (checkpoint_dots_with_no_batch_dims)
    sequence_parallel: bool = True  # train-time; launcher gates by step kind
    scan_layers: bool = True
    kv_shard: str = "auto"  # KV-cache layout: 'auto' | 'heads' | 'seq'
    fsdp: bool = False  # shard params over 'data' too (ZeRO-3-style)
    opt_state_dtype: str = "float32"  # 'bfloat16' halves m/v HBM
    opt_use_master: bool = True  # False: master-free AdamW (4 B/param total)
    grad_accum: int = 1  # microbatches per step (activation memory / N)
    loss_chunk: int = 512  # seq-chunked vocab xent (never materializes B,S,V)
    vocab_align: int = 256  # embed/head padded so vocab shards evenly

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        a = self.vocab_align
        return ((self.vocab_size + a - 1) // a) * a

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm" or self.hybrid_attn_every > 0

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic token mixing => the 500k decode shape is runnable."""
        return self.family in ("ssm", "hybrid")


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment skip rules (documented in DESIGN.md §5)."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False, "encoder-only architecture has no autoregressive decode"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: O(S^2) at 524k — skipped per assignment"
    return True, ""


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch, shape) cell.

    train:    {tokens, labels}               (full sequence)
    prefill:  {tokens}                       (full sequence, no labels)
    decode:   {tokens (B,1), cache_pos ()}   (KV cache / SSM state is part of
                                              the serve state, see
                                              models.model.decode_state_specs)
    """
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "audio":
        # stub frontend: precomputed frame embeddings replace token ids
        specs["embeds"] = _sds((B, S, cfg.d_model), cfg.dtype)
    else:
        if shape.kind == "decode":
            specs["tokens"] = _sds((B, 1), "int32")
        else:
            specs["tokens"] = _sds((B, S), "int32")
    if cfg.frontend == "vision" and shape.kind != "decode":
        specs["image_embeds"] = _sds((B, cfg.num_prefix_embeds, cfg.d_model), cfg.dtype)
    if shape.kind == "train":
        if cfg.frontend == "audio":
            specs["labels"] = _sds((B, S), "int32")
        else:
            specs["labels"] = _sds((B, S), "int32")
    if shape.kind == "decode":
        specs["cache_pos"] = _sds((), "int32")
    return specs


def expert_parallel(cfg: ModelConfig, axis: str = "data") -> ModelConfig:
    """The expert-parallel variant of an MoE config: experts sharded over
    mesh axis ``axis``, dispatch/combine crossing the mesh through
    ``repro.comms.api.all_to_all`` (the CLI knob behind
    ``examples/train_lm.py --expert-parallel`` and ``launch/train.py`` /
    ``launch/perf.py --moe`` — no config hand-editing)."""
    if cfg.moe is None:
        raise ValueError(f"{cfg.name} has no MoE block to expert-parallelize")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, expert_axis=axis))


# --------------------------------------------------------------------------
# reduced configs for CPU smoke tests
# --------------------------------------------------------------------------
def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config: few layers, narrow width, tiny vocab."""
    changes: Dict = dict(
        num_layers=2 if cfg.hybrid_attn_every == 0 else max(2, cfg.hybrid_attn_every),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_prefix_embeds=4 if cfg.frontend == "vision" else 0,
        dtype="float32",
        remat=False,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=128
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, head_dim=16, state_dim=8)
    if cfg.hybrid_attn_every:
        changes["hybrid_attn_every"] = 2
        changes["num_layers"] = 4
    return dataclasses.replace(cfg, **changes)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for 6*N*D model-flops in the roofline)."""
    d, L = cfg.d_model, cfg.num_layers
    attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    if cfg.qkv_bias:
        attn += cfg.q_dim + 2 * cfg.kv_dim
    per_layer = 2 * d  # norms
    if cfg.family == "ssm" and cfg.ssm and cfg.ssm.kind == "rwkv6":
        h = d // cfg.ssm.head_dim
        tmix = 4 * d * d + d * d  # r,k,v,g,o projections
        tmix += 6 * d + 2 * d  # decay/tokenshift params (approx; small)
        cmix = d * cfg.d_ff + cfg.d_ff * d
        per_layer += tmix + cmix
    elif cfg.family in ("hybrid",) and cfg.ssm and cfg.ssm.kind == "mamba2":
        d_in = cfg.ssm.expand * d
        mamba = d * (2 * d_in + 2 * cfg.ssm.state_dim)  # in_proj (z,x,B,C)
        mamba += d_in // cfg.ssm.head_dim  # dt per head
        mamba += d_in * d  # out proj
        per_layer += mamba + d * cfg.d_ff * 3 // 2  # + glu mlp approx
    else:
        per_layer += attn
        if cfg.moe is not None:
            e = cfg.moe
            expert = 3 * d * e.d_ff_expert
            per_layer += e.num_experts * expert + d * e.num_experts
            if e.shared_expert:
                per_layer += expert
            if e.dense_residual:
                per_layer += 3 * d * cfg.d_ff
        else:
            per_layer += 3 * d * cfg.d_ff  # swiglu
    total = L * per_layer
    total += cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    if cfg.hybrid_attn_every:
        total += attn  # one shared attention block
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Active-per-token params (MoE: top_k + shared + dense residual only)."""
    if cfg.moe is None:
        return param_count(cfg)
    full = param_count(cfg)
    e = cfg.moe
    d = cfg.d_model
    expert = 3 * d * e.d_ff_expert
    inactive = (e.num_experts - e.top_k) * expert * cfg.num_layers
    return full - inactive
