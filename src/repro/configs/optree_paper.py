"""The paper's own evaluation configurations (§IV): TeraRack WDM ring sweeps."""
from ..core.cost_model import OpticalSystem

#: §IV-A defaults
SYSTEM = OpticalSystem()

#: Fig. 4: depth sweep
FIG4_NODES = (512, 1024, 2048, 4096)
FIG4_MESSAGE_BYTES = 4 * 2**20
FIG4_DEPTHS = tuple(range(1, 11))

#: Fig. 5: message-size sweep at w=64
FIG5_NODES = (1024, 2048)
FIG5_MESSAGES = tuple(m * 2**20 for m in (4, 8, 16, 32, 64, 128))

#: Fig. 6: wavelength sweep at N=1024
FIG6_WAVELENGTHS = (96, 128)
FIG6_MESSAGES = FIG5_MESSAGES

#: Table I
TABLE1_N = 1024
TABLE1_W = 64
