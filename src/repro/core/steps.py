"""Closed-form communication-step counts (paper §III-D.2, Table I, Lemma 1).

All functions count *communication steps* (time slots): one step = every
wavelength carries at most one data item of size d, conflict-free.

Two families:
  * ``*_thm1`` / Table-I closed forms — the paper's analytic expressions
    (real-valued m = N^(1/k), merged ceilings).
  * ``optree_steps_exact`` — per-stage integer accounting for a concrete
    ``OpTreePlan`` (what the generated schedule actually achieves; equals the
    closed form for perfect powers).

Table-I reproduction notes (also in DESIGN.md):
  * OpTree / Ring / NE reproduce the printed numbers exactly.
  * One-stage: the printed formula ceil(N^2/(8w)) gives 2048 at
    (N=1024, w=64); the paper prints 128 (consistent with w=N, a typo).  The
    paper's own Fig.-4 claim ("96.85% average reduction vs one-stage") matches
    the *formula*, not the printed 128 — we follow the formula.
  * WRHT: the footnote formula with p=2w+1 and any natural base for theta
    cannot produce the printed 259; we implement the formula literally
    (theta = ceil(log_p N)) and pin the paper's printed value separately.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

from .tree import OpTreePlan, balanced_factors, optimal_depth_argmin

__all__ = [
    "lemma1_wavelengths_line",
    "lemma1_wavelengths_ring",
    "one_stage_subset_wavelengths_ring",
    "one_stage_subset_wavelengths_line",
    "optree_stage_demand",
    "optree_steps_exact",
    "optree_steps_thm1",
    "optree_optimal_steps",
    "ring_steps",
    "neighbor_exchange_steps",
    "one_stage_steps",
    "wrht_steps_formula",
    "wrht_steps_paper_table",
]


# --------------------------------------------------------------------------
# Lemma 1: one-stage all-to-all wavelength demand
# --------------------------------------------------------------------------
def lemma1_wavelengths_line(n: int) -> int:
    """Minimum wavelengths for one-stage all-to-all on an n-node *line*."""
    return (n * n) // 4


def lemma1_wavelengths_ring(n: int) -> int:
    """Minimum wavelengths for one-stage all-to-all on an n-node *ring*."""
    return math.ceil(n * n / 8)


# The per-subset demands OpTree uses (m uniformly spaced participants):
def one_stage_subset_wavelengths_ring(m: int) -> int:
    return math.ceil(m * m / 8)


def one_stage_subset_wavelengths_line(m: int) -> int:
    return (m * m) // 4


# --------------------------------------------------------------------------
# OpTree (Theorem 1 + exact per-plan accounting)
# --------------------------------------------------------------------------
def optree_stage_demand(plan: OpTreePlan, stage: int) -> int:
    """Total wavelength demand of ``stage`` (ring-wide concurrent lightpaths).

    Stage 1: ceil(N/m_1) position-subsets share the whole ring, each needs
    ceil(m_1^2/8) wavelengths, one item per node.
    Stage j>=2: parents are link-disjoint segments; within a parent,
    ceil(N/prod_{i<=j} m_i) position-subsets share the segment, each needs
    floor(m_j^2/4) wavelengths *per item*, and every node ships
    prod_{i<j} m_i items.
    """
    if not (1 <= stage <= plan.k):
        raise ValueError("bad stage")
    m = plan.factors[stage - 1]
    items = 1
    for f in plan.factors[: stage - 1]:
        items *= f
    positions = plan.sizes[stage - 1]  # subsets sharing links inside a parent
    if stage == 1:
        return positions * one_stage_subset_wavelengths_ring(m) * items
    return positions * one_stage_subset_wavelengths_line(m) * items


def optree_steps_exact(plan: OpTreePlan, w: int) -> int:
    """Sum over stages of ceil(stage_demand / w) — the schedule's step count."""
    return sum(
        math.ceil(optree_stage_demand(plan, j) / w) for j in range(1, plan.k + 1)
    )


def optree_steps_thm1(n: int, k: int, w: int) -> int:
    """Theorem 1: S = ceil((2k-1) * N^(1+1/k) / (8w))  (real-valued m)."""
    if k < 1:
        raise ValueError("k >= 1")
    if k == 1:
        return one_stage_steps(n, w)
    return math.ceil((2 * k - 1) * n ** (1.0 + 1.0 / k) / (8.0 * w))


def optree_optimal_steps(n: int, w: int) -> Tuple[int, int]:
    """(k_opt, steps) minimizing Theorem 1 over integer k (paper Thm 2/3)."""
    k = optimal_depth_argmin(n, w)
    return k, optree_steps_thm1(n, k, w)


# --------------------------------------------------------------------------
# Baselines (Table I)
# --------------------------------------------------------------------------
def ring_steps(n: int, w: int = 64) -> int:
    """Classic ring all-gather: N-1 steps (one neighbour hop per step)."""
    del w
    return n - 1


def neighbor_exchange_steps(n: int, w: int = 64) -> int:
    """Neighbor-Exchange all-gather: N/2 steps (even/odd pair exchanges)."""
    del w
    return math.ceil(n / 2)


def one_stage_steps(n: int, w: int) -> int:
    """One-stage model on a ring: ceil(N^2 / (8w)) (see module docstring)."""
    return math.ceil(lemma1_wavelengths_ring(n) / w)


def wrht_steps_formula(n: int, w: int) -> int:
    """WRHT extended to all-gather, per the paper's Table-I footnote, read
    literally: p = 2w+1, theta = ceil(log_p N).

    steps = ceil((N-p)/(p-1)) + ceil(2(theta-1)N/p) + 1
    """
    p = 2 * w + 1
    if n <= p:
        return 1
    theta = math.ceil(math.log(n) / math.log(p))
    return math.ceil((n - p) / (p - 1)) + math.ceil(2 * (theta - 1) * n / p) + 1


#: The paper's *printed* Table-I WRHT value(s); see module docstring.
_WRHT_PAPER: dict = {(1024, 64): 259}


def wrht_steps_paper_table(n: int, w: int) -> Optional[int]:
    return _WRHT_PAPER.get((n, w))


# --------------------------------------------------------------------------
# Convenience: the full Table-I row set
# --------------------------------------------------------------------------
def table1(n: int = 1024, w: int = 64) -> dict:
    k, s = optree_optimal_steps(n, w)
    plan = OpTreePlan(n, balanced_factors(n, k))
    return {
        "Ring": ring_steps(n, w),
        "NE": neighbor_exchange_steps(n, w),
        "WRHT(formula)": wrht_steps_formula(n, w),
        "WRHT(paper)": wrht_steps_paper_table(n, w),
        "One-Stage": one_stage_steps(n, w),
        f"OpTree(k*={k})": s,
        f"OpTree-exact(factors={plan.factors})": optree_steps_exact(plan, w),
    }
