"""Communication-time model (paper Eq. 3 / Thm 3) + TeraRack constants (§IV-A).

``T_comm = (d/B + a) * S`` — S communication steps, each transferring one
item of size d per wavelength at per-wavelength bandwidth B, plus a fixed
per-step overhead ``a`` (MRR reconfiguration + O/E/O conversion).

The paper treats ``a`` as a constant; we additionally expose the packet/flit
accounting behind it (128-byte packets, 32-byte flits, one cycle per flit for
O/E/O at the 40 Gbps line rate) for the detailed simulator.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace

__all__ = ["OpticalSystem", "TERARACK", "step_time", "eq3_time", "allgather_time",
           "eq3_overlap_time", "exposed_hidden_bytes"]


@dataclass(frozen=True)
class OpticalSystem:
    """TeraRack-style WDM ring parameters (paper §IV-A defaults)."""

    n_nodes: int = 1024
    wavelengths: int = 64  # w, per fiber direction
    bandwidth_per_wavelength: float = 40e9  # bits/s
    mrr_reconfig_s: float = 25e-6  # MRR reconfiguration delay
    packet_bytes: int = 128
    flit_bytes: int = 32
    oeo_cycles_per_flit: int = 1

    @property
    def flit_time_s(self) -> float:
        """Time to serialize one flit at the line rate = the 'cycle' used for
        O/E/O conversion accounting (one cycle per flit)."""
        return self.flit_bytes * 8 / self.bandwidth_per_wavelength

    def oeo_delay_s(self, chunk_bytes: float) -> float:
        flits = math.ceil(chunk_bytes / self.flit_bytes)
        return flits * self.oeo_cycles_per_flit * self.flit_time_s


TERARACK = OpticalSystem()


def step_time(sys: OpticalSystem, chunk_bytes: float, *, detailed: bool = False) -> float:
    """Duration of one communication step carrying ``chunk_bytes`` (= d).

    paper-style (default):  d/B + a,  a = MRR reconfiguration delay only.
    detailed:               adds flit-level O/E/O conversion latency.
    """
    serial = chunk_bytes * 8 / sys.bandwidth_per_wavelength
    a = sys.mrr_reconfig_s + (sys.oeo_delay_s(chunk_bytes) if detailed else 0.0)
    return serial + a


def eq3_time(sys: OpticalSystem, d_bytes: float, steps: int, *, detailed: bool = False) -> float:
    """Eq. (3): T = (d/B + a) * S."""
    return step_time(sys, d_bytes, detailed=detailed) * steps


def allgather_time(
    sys: OpticalSystem, message_bytes: float, steps: int, *, detailed: bool = False
) -> float:
    """All-gather wall time when every node contributes ``message_bytes``."""
    return eq3_time(sys, message_bytes, steps, detailed=detailed)


def eq3_overlap_time(
    sys: OpticalSystem, d_bytes: float, steps: int, *, detailed: bool = False
) -> float:
    """Per-hop overlapped variant of Eq. (3).

    With double-buffered hops the fixed per-step overhead ``a`` of step t+1
    (MRR reconfiguration / launch) runs while step t's payload is still
    serializing, so only the longer of the two chains is exposed:

        T = max(S·d/B + a,  S·a + d/B)

    Bandwidth-bound steps hide all but one ``a``; latency-bound steps hide
    all but one serialization.  Eq. (3) itself, ``(d/B + a)·S``, is the
    no-overlap upper bound.
    """
    serial = d_bytes * 8 / sys.bandwidth_per_wavelength
    a = sys.mrr_reconfig_s + (sys.oeo_delay_s(d_bytes) if detailed else 0.0)
    return max(steps * serial + a, steps * a + serial)


def exposed_hidden_bytes(
    sys: OpticalSystem, d_bytes: float, steps: int
) -> tuple:
    """(exposed, hidden) byte split for ``steps`` overlapped hops of size d.

    Bandwidth-bound (d/B >= a): every byte's serialization is on the critical
    path — all S·d bytes exposed, the overlap hides the per-step ``a``s.
    Latency-bound: the ``a`` chain paces the pipeline and all but one
    payload's serialization hides under it.
    """
    serial = d_bytes * 8 / sys.bandwidth_per_wavelength
    total = steps * d_bytes
    if serial >= sys.mrr_reconfig_s:
        return float(total), 0.0
    return float(d_bytes), float(total - d_bytes)
