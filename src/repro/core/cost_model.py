"""Communication-time model (paper Eq. 3 / Thm 3) + TeraRack constants (§IV-A).

``T_comm = (d/B + a) * S`` — S communication steps, each transferring one
item of size d per wavelength at per-wavelength bandwidth B, plus a fixed
per-step overhead ``a`` (MRR reconfiguration + O/E/O conversion).

The paper treats ``a`` as a constant; we additionally expose the packet/flit
accounting behind it (128-byte packets, 32-byte flits, one cycle per flit for
O/E/O at the 40 Gbps line rate) for the detailed simulator.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, replace

__all__ = ["OpticalSystem", "TERARACK", "CircuitReconfig", "step_time",
           "eq3_time", "allgather_time", "eq3_overlap_time",
           "exposed_hidden_bytes", "PriceReport", "price",
           "schedule_step_times", "transfer_time", "derive_wavelengths"]


@dataclass(frozen=True)
class OpticalSystem:
    """TeraRack-style WDM ring parameters (paper §IV-A defaults).

    ``mrr_reconfig_s`` is the paper's PER-STEP overhead ``a`` (MRR tuning
    within a fixed circuit configuration).  ``circuit_reconfig_s`` is the
    PER-EVENT topology-reconfiguration delay a circuit-switched photonic
    fabric pays when the lightpath layout itself changes between stages
    (ring -> segmented lines, segment size changes) — zero by default, so
    the fixed-ring world of PRs 3-8 is unchanged.  ``reconfig_overlap``
    enables the SWOT-style overlap: a reconfiguration event starts while
    the previous stage's LAST step is still transmitting, so only
    ``max(0, circuit_reconfig_s - last_step_s)`` is exposed."""

    n_nodes: int = 1024
    wavelengths: int = 64  # w, per fiber direction
    bandwidth_per_wavelength: float = 40e9  # bits/s
    mrr_reconfig_s: float = 25e-6  # MRR reconfiguration delay (per step)
    packet_bytes: int = 128
    flit_bytes: int = 32
    oeo_cycles_per_flit: int = 1
    circuit_reconfig_s: float = 0.0  # per-event circuit/topology change
    reconfig_overlap: bool = True  # hide reconfig behind in-flight last step

    @property
    def flit_time_s(self) -> float:
        """Time to serialize one flit at the line rate = the 'cycle' used for
        O/E/O conversion accounting (one cycle per flit)."""
        return self.flit_bytes * 8 / self.bandwidth_per_wavelength

    def oeo_delay_s(self, chunk_bytes: float) -> float:
        flits = math.ceil(chunk_bytes / self.flit_bytes)
        return flits * self.oeo_cycles_per_flit * self.flit_time_s


TERARACK = OpticalSystem()


@dataclass(frozen=True)
class CircuitReconfig:
    """Circuit-reconfiguration accounting of one priced/simulated schedule.

    ``events`` counts the stage boundaries whose circuit signature changed
    (a topology reconfiguration of the photonic fabric); ``exposed_s`` is
    the wall time those events add after the SWOT overlap — with
    ``reconfig_overlap`` each event hides behind the previous stage's
    in-flight last step, without it the full ``circuit_reconfig_s`` is
    exposed per event.  Events are counted even at zero delay, so planners
    can rank hold-vs-reconfigure candidates independently of the current
    delay calibration."""

    events: int = 0
    exposed_s: float = 0.0


def derive_wavelengths(links, base: "OpticalSystem" = None) -> int:
    """Derive a per-mesh wavelength budget from calibrated LinkSpecs.

    The busiest axis's fitted bandwidth, expressed in per-wavelength WDM
    channels of ``base.bandwidth_per_wavelength`` bits/s and clamped to
    ``[1, base.wavelengths]`` — so ``--calibrate`` output sizes the optical
    pricer's ``w`` instead of hand-picking ``--optical-w``.  ``links`` is
    any iterable/mapping of LinkSpec-shaped objects (``bandwidth_bytes``).
    """
    base = base if base is not None else TERARACK
    specs = links.values() if hasattr(links, "values") else links
    bws = [float(l.bandwidth_bytes) for l in specs
           if getattr(l, "bandwidth_bytes", None)]
    if not bws:
        return base.wavelengths
    per_wl_bytes = base.bandwidth_per_wavelength / 8.0
    return max(1, min(base.wavelengths, math.ceil(max(bws) / per_wl_bytes)))


def transfer_time(model, nbytes: float) -> float:
    """One point-to-point transfer priced under either cost world.

    ``model`` is an :class:`OpticalSystem` (the paper's Eq.-3 step model:
    ``d/B + a``) or a ``LinkSpec``-shaped object (the electrical alpha/
    bandwidth model: ``α + d/B``).  This is the request-transmission
    primitive the cluster simulator (``repro.cluster``) prices client→
    replica hops with, so the serving layer sees the SAME fabric models
    the collectives plan against.
    """
    if isinstance(model, OpticalSystem):
        return step_time(model, nbytes)
    return model.alpha_s + nbytes / model.bandwidth_bytes


def step_time(sys: OpticalSystem, chunk_bytes: float, *, detailed: bool = False) -> float:
    """Duration of one communication step carrying ``chunk_bytes`` (= d).

    paper-style (default):  d/B + a,  a = MRR reconfiguration delay only.
    detailed:               adds flit-level O/E/O conversion latency.
    """
    serial = chunk_bytes * 8 / sys.bandwidth_per_wavelength
    a = sys.mrr_reconfig_s + (sys.oeo_delay_s(chunk_bytes) if detailed else 0.0)
    return serial + a


def eq3_time(sys: OpticalSystem, d_bytes: float, steps: int, *, detailed: bool = False) -> float:
    """Eq. (3): T = (d/B + a) * S."""
    return step_time(sys, d_bytes, detailed=detailed) * steps


def allgather_time(
    sys: OpticalSystem, message_bytes: float, steps: int, *, detailed: bool = False
) -> float:
    """All-gather wall time when every node contributes ``message_bytes``."""
    return eq3_time(sys, message_bytes, steps, detailed=detailed)


def eq3_overlap_time(
    sys: OpticalSystem, d_bytes: float, steps: int, *, detailed: bool = False
) -> float:
    """Per-hop overlapped variant of Eq. (3).

    With double-buffered hops the fixed per-step overhead ``a`` of step t+1
    (MRR reconfiguration / launch) runs while step t's payload is still
    serializing, so only the longer of the two chains is exposed:

        T = max(S·d/B + a,  S·a + d/B)

    Bandwidth-bound steps hide all but one ``a``; latency-bound steps hide
    all but one serialization.  Eq. (3) itself, ``(d/B + a)·S``, is the
    no-overlap upper bound.
    """
    serial = d_bytes * 8 / sys.bandwidth_per_wavelength
    a = sys.mrr_reconfig_s + (sys.oeo_delay_s(d_bytes) if detailed else 0.0)
    return max(steps * serial + a, steps * a + serial)


# --------------------------------------------------------------------------
# unified IR pricing — one entry point for both cost worlds
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PriceReport:
    """What one CollectivePlan costs under one transport model.

    ``stage_times_s`` attributes the total per IR stage; under the chunked
    mode they are the per-chunk pipeline stage costs, so
    ``total_s = sum + (C-1)·max`` (the pipeline makespan).  ``steps`` is
    the optical backend's communication-step count (None for electrical).
    ``reconfigurations``/``reconfig_exposed_s`` report the optical world's
    circuit-reconfiguration events and their exposed (post-overlap) wall
    time — zero for the electrical backend and in the fixed-circuit world
    (``circuit_reconfig_s == 0`` still counts events, exposes nothing).
    """

    backend: str  # "linkspec" | "optical"
    mode: str
    total_s: float
    stage_times_s: tuple
    steps: int = None
    num_chunks: int = 1
    reconfigurations: int = 0
    reconfig_exposed_s: float = 0.0


def _price_linkspec(plan, health=None) -> PriceReport:
    from .planner import perhop_stage_time, pipeline_makespan  # lazy: planner imports us

    for s in plan.stages:
        if s.link is None:
            raise ValueError(
                f"stage {s} has no LinkSpec; the electrical backend needs one")

    if health is not None and not health.is_healthy:
        # derate each stage's link by its axis's best alive direction; a
        # fully dead axis raises DeadAxisError (no staged plan crosses it)
        plan = dataclasses.replace(
            plan,
            stages=tuple(
                dataclasses.replace(s, link=health.degrade_link(s.axis, s.link))
                for s in plan.stages))

    def barrier(s, payload):
        return (s.factor - 1) * (s.link.alpha_s + payload / s.link.bandwidth_bytes)

    if plan.mode in ("chunked", "hybrid") and plan.num_chunks > 1:
        # C-chunk wavefront makespan over per-chunk stage times.  Chunked
        # pipelines blocking whole-stage collectives; hybrid pipelines the
        # SAME wavefront over per-hop ring stages, so a stage whose hop
        # structure is perhop contributes the overlap max-form on the
        # 1/C-payload chunk instead of the barrier time.
        c = plan.num_chunks
        times = tuple(
            perhop_stage_time(s.factor, s.payload_bytes / c, s.link)
            if plan.mode == "hybrid" and s.mode == "perhop"
            else barrier(s, s.payload_bytes / c)
            for s in plan.stages
        )
        return PriceReport("linkspec", plan.mode,
                           pipeline_makespan(times, c), times, num_chunks=c)
    times = []
    for s in plan.stages:
        if plan.mode in ("perhop", "hybrid") and s.mode == "perhop":
            times.append(perhop_stage_time(s.factor, s.payload_bytes, s.link))
        else:
            times.append(barrier(s, s.payload_bytes))
    return PriceReport("linkspec", plan.mode, sum(times), tuple(times),
                       num_chunks=plan.num_chunks)


def _circuit_reconfigurations(sched, sys: "OpticalSystem", per_step):
    """Circuit-reconfiguration events of a lowered schedule and their
    exposed delays, attributed per execution-order stage.

    ``sched.meta["circuits"]`` (written by ``schedule_from_ir`` alongside
    ``stage_ranges``) carries one circuit signature per lowered stage —
    ``("ring", n)`` for whole-ring stages, ``("line", seg)`` for
    segmented-line stages.  Walking the NON-EMPTY stages in schedule-step
    order, every boundary whose signature changes is one reconfiguration
    event; the initial circuit setup is free.  With ``reconfig_overlap``
    the event hides behind the previous stage's in-flight last step
    (``max(0, circuit_reconfig_s - last_step_s)`` exposed), otherwise the
    full delay is exposed.  Each event's exposure is charged to the
    FOLLOWING stage (execution-order index), so stage times still sum to
    the total.  Returns ``(events, exposed_s, per_stage_extra)``;
    hand-built schedules without circuit metadata charge nothing.
    """
    circuits = sched.meta.get("circuits")
    ranges = sched.meta.get("stage_ranges")
    if not circuits or ranges is None or len(circuits) != len(ranges):
        return 0, 0.0, None
    # recover schedule order: ranges/circuits are execution-order, but the
    # (start_step, n_steps) tuples carry the true schedule positions
    order = sorted((i for i in range(len(ranges)) if ranges[i][1] > 0),
                   key=lambda i: ranges[i][0])
    extras = [0.0] * len(ranges)
    events, exposed = 0, 0.0
    for prev, cur in zip(order, order[1:]):
        if circuits[prev] == circuits[cur]:
            continue
        events += 1
        delay = sys.circuit_reconfig_s
        if delay > 0.0:
            if sys.reconfig_overlap:
                last = ranges[prev][0] + ranges[prev][1] - 1
                delay = max(0.0, delay - per_step[last])
            extras[cur] += delay
            exposed += delay
    return events, exposed, extras


def schedule_step_times(sched, sys: "OpticalSystem", message_bytes: float,
                        *, detailed: bool = False):
    """Eq.-3 timing of a lowered schedule, burst- and reconfiguration-aware.

    Returns ``(per_step_times, stage_times, total_s, reconfig)`` where
    ``reconfig`` is a :class:`CircuitReconfig`.  A step's duration is
    ``step_time(sys, burst · d)`` where ``burst`` is the largest number
    of items any single lightpath — one ``(wavelength, direction, src,
    dst)`` slot — carries that step.  Ordinary stages put one item per
    lightpath (burst 1 everywhere), and then the arithmetic is EXACTLY the
    historical ``per_step · steps`` products (no summation drift); only
    exchange stages, whose pairwise rounds serialize a pair's whole buffer
    over one lightpath, produce bursts > 1 and per-step summation.  Stage
    attribution uses ``sched.meta["stage_ranges"]`` (execution-order
    ``(start_step, n_steps)`` from ``schedule_from_ir``) and falls back to
    a sequential ``stage_steps`` split for hand-built schedules.

    When ``sys.circuit_reconfig_s > 0`` every circuit-signature change
    between consecutive non-empty stages (``sched.meta["circuits"]``)
    additionally exposes its post-overlap reconfiguration delay, charged
    to the following stage — the single accounting both ``price`` and
    ``optics.simulator.simulate`` consume, so price == simulate stays
    literal in the reconfiguring world.
    """
    bursts = [1] * sched.num_steps
    counts = {}
    for tx in sched.txs:
        key = (tx.step, tx.wavelength, tx.direction, tx.src, tx.dst)
        c = counts.get(key, 0) + 1
        counts[key] = c
        if c > bursts[tx.step]:
            bursts[tx.step] = c
    if all(b == 1 for b in bursts):
        per = step_time(sys, message_bytes, detailed=detailed)
        per_step = [per] * sched.num_steps
        stage_times = tuple(per * s for s in sched.stage_steps)
        total = per * sched.num_steps
    else:
        per_step = [step_time(sys, b * message_bytes, detailed=detailed)
                    for b in bursts]
        ranges = sched.meta.get("stage_ranges")
        if ranges is None:
            ranges = []
            start = 0
            for s in sched.stage_steps:
                ranges.append((start, s))
                start += s
        stage_times = tuple(sum(per_step[a:a + c]) for a, c in ranges)
        total = sum(per_step)
    events, exposed, extras = _circuit_reconfigurations(sched, sys, per_step)
    if exposed > 0.0:
        stage_times = tuple(t + e for t, e in zip(stage_times, extras))
        total += exposed
    return per_step, stage_times, total, CircuitReconfig(events, exposed)


def _price_optical(plan, sys: "OpticalSystem", *, detailed: bool = False,
                   health=None) -> PriceReport:
    from .plan_ir import optical_message_bytes  # lazy: avoid a cycle
    from .schedule import schedule_from_ir  # lazy: avoid a cycle

    sched = schedule_from_ir(plan, sys.wavelengths, health=health)
    # one step moves ONE schedule item per lightpath: the whole shard for
    # gather traffic, a 1/n (origin, destination) block for exchange (a2a)
    # traffic; exchange-stage bursts scale each step's duration
    _, times, total, reconf = schedule_step_times(
        sched, sys, optical_message_bytes(plan), detailed=detailed)
    return PriceReport("optical", plan.mode, total,
                       times, steps=sched.num_steps,
                       num_chunks=plan.num_chunks,
                       reconfigurations=reconf.events,
                       reconfig_exposed_s=reconf.exposed_s)


def plan_exposure(plan) -> tuple:
    """Per-stage (exposed, hidden) byte tuples of a CollectivePlan under
    per-hop execution — same accounting as
    ``HopSchedule.stage_exposed_bytes``/``stage_hidden_bytes``: ring stages
    split by the overlap model, blocking stages expose every moved byte."""
    from .planner import _stage_exposure  # lazy: planner imports us

    exposed, hidden = [], []
    for s in plan.stages:
        if s.mode == "perhop" and s.link is not None:
            e, h = _stage_exposure(s.factor, s.payload_bytes, s.link)
        else:
            e, h = float((s.factor - 1) * s.payload_bytes), 0.0
        exposed.append(e)
        hidden.append(h)
    return tuple(exposed), tuple(hidden)


def price(plan, model=None, *, detailed: bool = False,
          health=None) -> PriceReport:
    """Price one :class:`~repro.core.plan_ir.CollectivePlan` under a model.

    * ``model=None`` (or ``"electrical"``/``"linkspec"``) — the TPU-mesh
      alpha/bandwidth model from each stage's ``LinkSpec``: barrier stages
      cost ``(f-1)·(α + p/B)``, per-hop stages the overlap max-form, the
      chunked mode prices the C-chunk wavefront makespan, and the hybrid
      mode the same makespan over overlapped ring stage times — numerically
      identical to ``core.planner.choose_hop_schedule``'s modeled times for
      the same chain, so planner and pricer cannot drift.
    * ``model=OpticalSystem`` — the paper's Eq.-3 model on the RWA-lowered
      schedule: ``T = (d/B + a) · S`` with S counted by
      ``schedule_from_ir`` — byte-identical to what
      ``optics.simulator.simulate`` reports for the same plan (chunking is
      an executor concept and does not change the optical step structure).

    ``health`` prices the DEGRADED world: the electrical backend scales
    each stage link's bandwidth by the axis's best alive direction (a dead
    axis raises :class:`~repro.core.health.DeadAxisError`), and the optical
    backend lowers with the lost-wavelength union removed from ``w``, so
    its price stays byte-identical to
    ``simulate(schedule_from_ir(plan, w, health=h), ..., health=h)``.
    Degraded prices are monotone: never below the healthy price.
    """
    if model is None or model in ("electrical", "linkspec"):
        return _price_linkspec(plan, health=health)
    if isinstance(model, OpticalSystem):
        return _price_optical(plan, model, detailed=detailed, health=health)
    raise TypeError(f"model must be None, 'electrical' or OpticalSystem, "
                    f"got {model!r}")


def exposed_hidden_bytes(
    sys: OpticalSystem, d_bytes: float, steps: int
) -> tuple:
    """(exposed, hidden) byte split for ``steps`` overlapped hops of size d.

    Bandwidth-bound (d/B >= a): every byte's serialization is on the critical
    path — all S·d bytes exposed, the overlap hides the per-step ``a``s.
    Latency-bound: the ``a`` chain paces the pipeline and all but one
    payload's serialization hides under it.
    """
    serial = d_bytes * 8 / sys.bandwidth_per_wavelength
    total = steps * d_bytes
    if serial >= sys.mrr_reconfig_s:
        return float(total), 0.0
    return float(d_bytes), float(total - d_bytes)
