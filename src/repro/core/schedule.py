"""Transmission-level schedules with routing and wavelength assignment (RWA).

A *schedule* is the object the paper's simulator consumes: for every
communication step (time slot), a set of lightpaths
``(direction, wavelength, src, dst, item)``, where a lightpath occupies every
fiber link along its route for the whole step and carries exactly one data
item of size ``d`` (the paper's load-balance rule).

Ring model: ``n`` nodes; clockwise (CW) link ``i`` joins node ``i -> i+1 mod
n``; counter-clockwise (CCW) link ``i`` joins ``i+1 -> i``.  The two
directions are separate fibers (TeraRack has two fiber rings per direction;
we model one per direction and let ``w`` describe its wavelength count, which
matches the paper's step accounting).

Wavelength assignment is greedy first-fit over a conflict structure (two
lightpaths conflict iff they share a directed link); colors are packed into
steps of ``w`` wavelengths: ``step = color // w``, ``wavelength = color % w``.
For line segments first-fit in left-endpoint order is *optimal* (interval
graphs); for rings it is near-optimal and validated against the closed forms
in tests.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .tree import OpTreePlan, mixed_radix_sizes

__all__ = [
    "Tx",
    "Schedule",
    "route_ring",
    "route_line",
    "build_optree_schedule",
    "build_one_stage_schedule",
    "build_ring_schedule",
    "build_ne_schedule",
    "schedule_from_ir",
]

CW, CCW = 0, 1


@dataclass(frozen=True)
class Tx:
    """One scheduled lightpath transmission."""

    step: int
    wavelength: int
    direction: int  # CW | CCW
    src: int
    dst: int
    item: int  # original owner of the data block
    links: Tuple[int, ...]  # link ids occupied (orientation per `direction`)


@dataclass
class Schedule:
    n: int
    w: int
    txs: List[Tx] = field(default_factory=list)
    stage_steps: List[int] = field(default_factory=list)  # steps per stage
    meta: Dict = field(default_factory=dict)

    @property
    def num_steps(self) -> int:
        return 1 + max((t.step for t in self.txs), default=-1)

    def by_step(self) -> List[List[Tx]]:
        out: List[List[Tx]] = [[] for _ in range(self.num_steps)]
        for t in self.txs:
            out[t.step].append(t)
        return out


# --------------------------------------------------------------------------
# Routing
# --------------------------------------------------------------------------
def route_ring(n: int, s: int, t: int) -> Tuple[int, Tuple[int, ...]]:
    """Shortest-direction route on the full ring (ties balanced by parity)."""
    d_cw = (t - s) % n
    d_ccw = (s - t) % n
    if d_cw < d_ccw or (d_cw == d_ccw and s % 2 == 0):
        return CW, tuple((s + i) % n for i in range(d_cw))
    return CCW, tuple((s - 1 - i) % n for i in range(d_ccw))


def route_line(
    n: int, seg_start: int, seg_len: int, s: int, t: int
) -> Tuple[int, Tuple[int, ...]]:
    """Route within a contiguous ring segment (no wrap-around): stages >= 2.

    Positions are absolute node ids; both must lie inside the segment.
    """
    ps = (s - seg_start) % n
    pt = (t - seg_start) % n
    if not (ps < seg_len and pt < seg_len):
        raise ValueError("endpoints outside segment")
    if pt > ps:  # forward along the segment = CW
        return CW, tuple((s + i) % n for i in range(pt - ps))
    return CCW, tuple((s - 1 - i) % n for i in range(ps - pt))


# --------------------------------------------------------------------------
# Wavelength/step coloring
#
# A "color" is a (step, wavelength) slot: step = color // w, wl = color % w.
# The two fiber directions are independent resources, so a color may be used
# once per direction per link — colors are assigned per direction and the
# stage's step count is ceil(max(colors_cw, colors_ccw) / w).
# --------------------------------------------------------------------------
RawTx = Tuple[int, int, int, int, Tuple[int, ...]]  # (src, dst, item, dir, links)


class _Colorer:
    """Greedy first-fit coloring on per-direction link resources.

    Optimal for line stages when transmissions are processed in
    left-endpoint order (interval-graph coloring)."""

    def __init__(self, n: int, init_colors: int = 64):
        self.n = n
        self.occ = np.zeros((2, n, init_colors), dtype=bool)

    def _grow(self):
        self.occ = np.concatenate([self.occ, np.zeros_like(self.occ)], axis=2)

    def assign(self, direction: int, links: Sequence[int]) -> int:
        if not links:
            return 0  # src == dst (degenerate); never happens in practice
        l = np.fromiter(links, dtype=np.int64)
        while True:
            used = self.occ[direction, l, :].any(axis=0)
            free = np.flatnonzero(~used)
            if free.size:
                c = int(free[0])
                self.occ[direction, l, c] = True
                return c
            self._grow()


def _interval_color(raw: List[RawTx], n: int) -> np.ndarray:
    """Line stages: first-fit in left-endpoint order (optimal per direction)."""
    order = sorted(range(len(raw)), key=lambda i: (min(raw[i][4]), -len(raw[i][4])))
    colorer = _Colorer(n)
    colors = np.empty(len(raw), dtype=np.int64)
    for i in order:
        _, _, _, direction, links = raw[i]
        colors[i] = colorer.assign(direction, links)
    return colors


def _tiling_color(raw: List[RawTx], n: int) -> np.ndarray:
    """Ring stages: partition arcs into non-overlapping ring tilings.

    Each color is built by walking the ring once from a start position,
    greedily placing the longest remaining arc that fits before the walk
    wraps.  Achieves the ceil(m^2/8) clique bound exactly for the paper's
    example sizes and stays within ~1% above it for large m (validated in
    tests); strictly better than plain first-fit on circular arcs.
    """
    colors = np.empty(len(raw), dtype=np.int64)
    for direction in (CW, CCW):
        idxs = [i for i, r in enumerate(raw) if r[3] == direction]
        # arcs keyed by start link; CW arcs run ascending from links[0],
        # CCW arcs run descending from links[0] — normalize to a walk
        # direction by mirroring CCW starts.
        by_start: Dict[int, List[Tuple[int, int]]] = {}
        for i in idxs:
            links = raw[i][4]
            start = links[0] if direction == CW else (n - 1 - links[0]) % n
            by_start.setdefault(start, []).append((len(links), i))
        for v in by_start.values():
            v.sort()  # ascending length; pop from the back for "longest"
        remaining = sum(len(v) for v in by_start.values())
        color = 0
        while remaining:
            start = max(by_start, key=lambda s: len(by_start[s]))
            if not by_start[start]:
                by_start.pop(start)
                continue
            p, used = start, 0
            while used < n:
                room = n - used
                bucket = by_start.get(p)
                placed = False
                if bucket:
                    for bi in range(len(bucket) - 1, -1, -1):
                        if bucket[bi][0] <= room:
                            length, i = bucket.pop(bi)
                            colors[i] = color
                            remaining -= 1
                            p = (p + length) % n
                            used += length
                            placed = True
                            break
                if not placed:
                    p = (p + 1) % n
                    used += 1
            color += 1
    return colors


def _color_stage(
    raw: List[RawTx],
    n: int,
    w: int,
    step_offset: int,
    *,
    ring_mode: bool,
    coalesce: bool = False,
) -> Tuple[List[Tx], int]:
    """Color one synchronized stage; returns (txs, steps_used).

    ``coalesce`` is the exchange-stage (pairwise round) rule: every item
    flowing between one ``(src, dst)`` pair shares a SINGLE lightpath as a
    serialized burst — one color per (src, dst, direction) group instead
    of one per item.  The group's items all land on the same (step,
    wavelength); the step's duration accounting (burst × d in Eq. 3) lives
    in the cost model and simulator, which treat same-pair same-slot
    transmissions as one long transfer rather than a conflict.
    """
    if not raw:
        return [], 0
    if coalesce:
        groups: Dict[Tuple[int, int, int], List[int]] = {}
        for i, r in enumerate(raw):
            groups.setdefault((r[0], r[1], r[3]), []).append(i)
        reps = [raw[v[0]] for v in groups.values()]
        rep_colors = (_tiling_color(reps, n) if ring_mode
                      else _interval_color(reps, n))
        colors = np.empty(len(raw), dtype=np.int64)
        for v, c in zip(groups.values(), rep_colors):
            colors[np.fromiter(v, dtype=np.int64)] = int(c)
    else:
        colors = _tiling_color(raw, n) if ring_mode else _interval_color(raw, n)
    # per-direction color spaces are independent; step count is driven by the
    # busier direction
    ncolors = 0
    for direction in (CW, CCW):
        cs = [int(colors[i]) for i, r in enumerate(raw) if r[3] == direction]
        if cs:
            ncolors = max(ncolors, max(cs) + 1)
    txs = [
        Tx(
            step=step_offset + int(c) // w,
            wavelength=int(c) % w,
            direction=d,
            src=s,
            dst=t,
            item=it,
            links=lk,
        )
        for (s, t, it, d, lk), c in zip(raw, colors)
    ]
    return txs, math.ceil(ncolors / w)


def _one_stage_raw(
    participants: Sequence[int],
    items_of: Callable[[int], Sequence[int]],
    n: int,
    segment: Optional[Tuple[int, int]],
) -> List[Tuple[int, int, int, int, Tuple[int, ...]]]:
    """All-to-all broadcast lightpaths for one subset (one per (src,dst,item))."""
    raw = []
    for s in participants:
        items = items_of(s)
        for t in participants:
            if t == s:
                continue
            if segment is None:
                d, links = route_ring(n, s, t)
            else:
                d, links = route_line(n, segment[0], segment[1], s, t)
            for it in items:
                raw.append((s, t, it, d, links))
    return raw


# --------------------------------------------------------------------------
# Schedule builders
# --------------------------------------------------------------------------
def build_optree_schedule(plan: OpTreePlan, w: int) -> Schedule:
    """The paper's OpTree schedule for a concrete plan (§III-D.1)."""
    sched = Schedule(n=plan.n, w=w, meta={"algorithm": "optree", "factors": plan.factors})
    offset = 0
    for stage in range(1, plan.k + 1):
        raw: List[Tuple[int, int, int, int, Tuple[int, ...]]] = []
        send_cache: Dict[int, Tuple[int, ...]] = {}
        for subset in plan.subsets(stage):
            for s in subset.members:
                if s not in send_cache:
                    send_cache[s] = plan.items_to_send(stage, s)
            raw.extend(
                _one_stage_raw(
                    subset.members, lambda p: send_cache[p], plan.n, subset.segment
                )
            )
        txs, steps = _color_stage(raw, plan.n, w, offset, ring_mode=(stage == 1))
        sched.txs.extend(txs)
        sched.stage_steps.append(steps)
        offset += steps
    return sched


def _lower_gather_chain(
    sched: Schedule,
    factors: Sequence[int],
    modes: Sequence[str],
    w: int,
    offset: int,
    *,
    collective: str = "ag",
) -> int:
    """Lower one stage chain (execution-order ``factors`` with per-stage hop
    ``modes``) into ``sched``, starting at step ``offset``.

    The transfers come straight from ``plan_ir.stage_hops`` — the IR's own
    hop expansion is the single source of truth; this function only adds
    routing and RWA coloring.  The IR places participants in
    execution-major mixed-radix ring order, so stage-1 transfers route on
    the whole ring and stage-j>=2 transfers inside their contiguous parent
    segment of size ``prod(factors[j-1:])`` — exactly like
    ``build_optree_schedule``.  This holds for exchange (a2a) traffic too:
    a digit-transpose stage moves blocks only within the same stage-j
    subsets the gather broadcast uses, so the identical routing geometry
    applies (the items are the n² (origin, destination) blocks instead of
    the n origin shards).  A ``oneshot`` stage is one synchronized round; a
    ``perhop`` stage is ``m-1`` causally ordered hops, each colored into
    its own step block; an ``exchange`` stage (factor-2 pairwise round) is
    one synchronized round with BURST coalescing — each pair's items share
    one lightpath.  Returns the new step offset; appends one
    ``stage_steps`` entry per stage.
    """
    from .plan_ir import stage_hops  # local import: avoid a cycle
    from .tree import mixed_radix_sizes

    n = math.prod(factors)
    child_sizes = mixed_radix_sizes(factors)
    for j, (m, mode) in enumerate(zip(factors, modes)):
        parent_sz = child_sizes[j] * m
        stage_steps = 0
        for hop in stage_hops(factors, modes, j, 0.0, collective=collective):
            raw: List[RawTx] = []
            for t in hop.transfers:
                if j == 0:
                    d, links = route_ring(n, t.src, t.dst)
                else:
                    seg_start = (t.src // parent_sz) * parent_sz
                    d, links = route_line(n, seg_start, parent_sz, t.src, t.dst)
                raw.append((t.src, t.dst, t.item, d, links))
            txs, steps = _color_stage(raw, n, w, offset, ring_mode=(j == 0),
                                      coalesce=(mode == "exchange"))
            sched.txs.extend(txs)
            offset += steps
            stage_steps += steps
        sched.stage_steps.append(stage_steps)
    return offset


def schedule_from_ir(plan, w: int, *, health=None) -> Schedule:
    """Lower a :class:`~repro.core.plan_ir.CollectivePlan` to a Tx-level
    :class:`Schedule` the optical simulator can execute and conflict-check.

    ``health`` (a :class:`~repro.core.health.LinkHealth`) restricts the RWA
    to the *healthy* wavelengths: the lost set is the union of the plan
    axes' lost-wavelength masks (the WDM ring is a shared medium), the
    coloring runs with the shrunken effective ``w``, and the color slots are
    then remapped onto the surviving wavelength indices — an injective
    remap, so conflict-freedom is preserved and no transmission ever lands
    on a failed wavelength.  ``num_steps`` grows accordingly, which is
    exactly how lost wavelengths surface in the Eq.-3 price
    (``price(plan, system, health=...)`` uses this same lowering).

    * ``ag`` — lowered directly: the plan's execution-order stages become
      OpTree stages (oneshot = all-to-all broadcast round, perhop = m-1 ring
      hops).  For an all-oneshot plan this reproduces
      ``build_optree_schedule(OpTreePlan(n, factors), w)`` transmission for
      transmission.
    * ``rs`` — lowered as the time-reversed mirror all-gather (reversed
      stage order): a reduce-scatter runs exactly those lightpaths backwards
      carrying partial sums, so step and transmission counts are identical
      (the duality ``optics/comparison.py`` prices).  Items flow in gather
      direction so the simulator's causality/completeness checks apply.
      ``stage_steps`` is re-reversed to the plan's EXECUTION order, so
      per-stage attribution (``SimReport.stage_times_s``,
      ``PriceReport.stage_times_s``) pairs with ``plan.factors`` — stage i
      of the plan occupies the time-reversed i-th block of the schedule.
    * ``ar`` — the RS mirror chain followed by the AG chain (2k stages);
      the RS half's ``stage_steps`` are execution-ordered the same way.
    * ``a2a`` — lowered forward like ``ag`` but with exchange traffic: the
      items are the n² (origin, destination) blocks (labels ``u·n + v``,
      each ``shard/n`` bytes) and stage j transposes one mixed-radix digit
      within the same subsets/segments the gather stages use.
      ``meta["semantics"] = "exchange"`` tells the simulator to start node
      u holding ``{u·n + v}`` and check node v ends holding ``{u·n + v}``.

    Chunking (``plan.mode == "chunked"``) is an executor-side wavefront over
    whole-stage collectives; the optical step structure is unchanged, so the
    lowering ignores ``num_chunks``.  The ``hybrid`` mode (chunk wavefront
    OVER per-hop ring stages) lowers like ``perhop`` — each ring-preference
    stage becomes its m-1 causally ordered hop step blocks
    (``effective_stage_mode`` materializes stage ``perhop`` under both plan
    modes) and the wavefront stays executor-side, so
    ``price(plan, OpticalSystem)`` for a hybrid plan equals the simulator's
    wall time on this lowering exactly as for every other mode.
    """
    from .plan_ir import collective_kind, effective_stage_mode  # local import: avoid a cycle

    lost: frozenset = frozenset()
    if health is not None:
        lost = frozenset(wl for wl in health.lost_for(plan.axes) if wl < w)
    healthy_slots = [wl for wl in range(w) if wl not in lost]
    if not healthy_slots:
        from .health import HealthError  # local import: avoid a cycle
        raise HealthError(
            f"all {w} wavelengths lost for axes {plan.axes}: "
            "no healthy wavelength to schedule on")
    w_eff = len(healthy_slots)
    kind = collective_kind(plan.collective)
    sched = Schedule(
        n=plan.n, w=w_eff,
        meta={"algorithm": f"ir-{plan.collective}",
              "factors": plan.factors,
              "modes": plan.stage_modes,
              "mode": plan.mode,
              "semantics": kind.traffic,
              "axes": plan.axes,
              "source": plan.meta.get("source")},
    )
    # factor-1 stages are lowered too (zero transfers, zero steps) so
    # ``stage_steps`` always has one entry per plan stage and per-stage
    # attribution pairs with ``plan.factors`` index for index
    offset = 0
    if kind.two_phase:
        k = len(plan.stages) // 2
        halves = ((plan.stages[:k], True), (plan.stages[k:], False))
    else:
        halves = ((plan.stages, kind.chain == "reversed"),)
    stage_ranges: List[Tuple[int, int]] = []
    stage_circuits: List[Tuple] = []
    for half, flip in halves:
        # scatter halves lower as their time-reversed mirror all-gather
        stages = tuple(reversed(half)) if flip else half
        if not stages:
            continue
        mark = len(sched.stage_steps)
        start = offset
        factors = [s.factor for s in stages]
        offset = _lower_gather_chain(
            sched,
            factors,
            [effective_stage_mode(plan, s) for s in stages],
            w_eff, offset,
            collective=plan.collective,
        )
        # (start_step, n_steps) per lowered stage of this half, so pricing
        # can attribute per-step times to stages even when steps within a
        # stage differ in duration (exchange bursts)
        ranges: List[Tuple[int, int]] = []
        for steps in sched.stage_steps[mark:]:
            ranges.append((start, steps))
            start += steps
        # circuit signature per lowered stage — the lightpath layout the
        # photonic fabric must be configured for: the whole ring for the
        # first chain stage, contiguous parent segments of shrinking size
        # for deeper stages (mirrors _lower_gather_chain's routing).  A
        # boundary between differing signatures is a circuit
        # reconfiguration event in the Eq.-3 accounting.
        child_sizes = mixed_radix_sizes(factors)
        circuits: List[Tuple] = [
            ("ring", plan.n) if j == 0
            else ("line", child_sizes[j] * m)
            for j, m in enumerate(factors)
        ]
        if flip:  # attribution back to execution order
            sched.stage_steps[mark:] = sched.stage_steps[mark:][::-1]
            ranges.reverse()
            circuits.reverse()
        stage_ranges.extend(ranges)
        stage_circuits.extend(circuits)
    sched.meta["stage_ranges"] = tuple(stage_ranges)
    sched.meta["circuits"] = tuple(stage_circuits)
    if lost:
        # remap color slots 0..w_eff-1 onto the surviving wavelength
        # indices (injective, so the conflict structure is untouched) and
        # restore the physical ring width for range checks / telemetry
        sched.txs[:] = [
            dataclasses.replace(tx, wavelength=healthy_slots[tx.wavelength])
            for tx in sched.txs
        ]
        sched.w = w
        sched.meta["lost_wavelengths"] = tuple(sorted(lost))
        sched.meta["w_effective"] = w_eff
    return sched


def build_one_stage_schedule(n: int, w: int) -> Schedule:
    """One-stage model: direct all-to-all broadcast on the ring (k=1)."""
    sched = Schedule(n=n, w=w, meta={"algorithm": "one-stage"})
    raw = _one_stage_raw(list(range(n)), lambda p: (p,), n, None)
    txs, steps = _color_stage(raw, n, w, 0, ring_mode=True)
    sched.txs.extend(txs)
    sched.stage_steps.append(steps)
    return sched


def build_ring_schedule(n: int, w: int) -> Schedule:
    """Classic ring all-gather: step t, node i forwards item (i - t) mod n CW."""
    sched = Schedule(n=n, w=w, meta={"algorithm": "ring"})
    for step in range(n - 1):
        for i in range(n):
            item = (i - step) % n
            sched.txs.append(
                Tx(step=step, wavelength=0, direction=CW, src=i,
                   dst=(i + 1) % n, item=item, links=(i,))
            )
    sched.stage_steps = [n - 1]
    return sched


def build_ne_schedule(n: int, w: int) -> Schedule:
    """Neighbor-Exchange all-gather (Chen et al. 2005): N/2 steps, n even.

    Step 1: even pairs (2i, 2i+1) swap their own items.  Step t>=2: pairing
    parity alternates and each node forwards the two items it received in
    step t-1.
    """
    if n % 2:
        raise ValueError("neighbor-exchange needs even n")
    sched = Schedule(n=n, w=w, meta={"algorithm": "neighbor-exchange"})
    last_recv: List[List[int]] = [[i] for i in range(n)]
    for step in range(n // 2):
        pairs = (
            [((2 * i) % n, (2 * i + 1) % n) for i in range(n // 2)]
            if step % 2 == 0
            else [((2 * i + 1) % n, (2 * i + 2) % n) for i in range(n // 2)]
        )
        new_recv: List[List[int]] = [[] for _ in range(n)]
        for a, b in pairs:
            link_cw, link_ccw = a, a  # link between a and b=(a+1)%n
            for wl, item in enumerate(last_recv[a]):
                sched.txs.append(Tx(step=step, wavelength=wl, direction=CW,
                                    src=a, dst=b, item=item, links=(link_cw,)))
                new_recv[b].append(item)
            for wl, item in enumerate(last_recv[b]):
                sched.txs.append(Tx(step=step, wavelength=wl, direction=CCW,
                                    src=b, dst=a, item=item, links=(link_ccw,)))
                new_recv[a].append(item)
        if step == 0:
            # after the first exchange each node forwards the pair
            # {own item, partner's item}, not just the single receipt
            last_recv = [[i] + new_recv[i] for i in range(n)]
        else:
            last_recv = new_recv
    sched.stage_steps = [n // 2]
    return sched
