"""m-ary tree / stage-group algebra for OpTree (paper §III-D).

An OpTree plan factorizes the N ring nodes into ``factors = (m_1, ..., m_k)``
with ``prod(factors) == N``.  Stage ``j`` (1-indexed) partitions every
level-(j-1) group (a contiguous ring segment) into ``m_j`` children and runs
one-stage all-to-all broadcast inside the "same position across siblings"
subsets.  The paper's perfect-power case is ``factors == (m,)*k``; the mixed
radix generalization is what the JAX mesh-axis adaptation needs (a device axis
is factorized, not necessarily into equal factors).

Node coordinates are mixed-radix, *major first*:

    p = c_1 * sz_1 + c_2 * sz_2 + ... + c_k * sz_k,   sz_j = prod_{i>j} m_i

After stage j a node holds exactly the items of all peers that agree with it
on coordinates c_{j+1} .. c_k  (proof: induction, see DESIGN.md §2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Iterator, Optional, Sequence, Tuple

__all__ = [
    "optimal_depth_thm2",
    "optimal_depth_argmin",
    "balanced_factors",
    "OpTreePlan",
]


def optimal_depth_thm2(n: int, *, rounding: str = "round") -> int:
    """Theorem 2: k* = [ (ln N + sqrt(ln N (ln N - 2))) / 2 ].

    The paper writes the ceiling operator but calls it "integer rounding"; its
    own Fig. 4 optima (6/6/7/8 for N=512/1024/2048/4096) match *round*, while
    Table I's k*=7 for N=1024 matches *ceil* (both give 70 steps there).  We
    default to round and expose both.
    """
    if n <= 1:
        return 1
    ln = math.log(n)
    if ln <= 2.0:
        return 1
    x = (ln + math.sqrt(ln * (ln - 2.0))) / 2.0
    if rounding == "ceil":
        return max(1, math.ceil(x))
    if rounding == "round":
        return max(1, round(x))
    raise ValueError(f"rounding must be 'round' or 'ceil', got {rounding!r}")


def optimal_depth_argmin(n: int, w: int, *, steps_fn=None) -> int:
    """Integer argmin over k of the Theorem-1 step count (ties -> smaller k).

    This is the operationally correct optimum (what Fig. 4 sweeps); Theorem 2
    is its continuous approximation.
    """
    from . import steps as _steps  # local import to avoid a cycle

    fn = steps_fn or (lambda k: _steps.optree_steps_thm1(n, k, w))
    kmax = max(1, math.ceil(math.log2(max(n, 2))))
    best_k, best_s = 1, fn(1)
    for k in range(2, kmax + 1):
        s = fn(k)
        if s < best_s:
            best_k, best_s = k, s
    return best_k


@lru_cache(maxsize=4096)
def _divisors(n: int) -> Tuple[int, ...]:
    out = [d for d in range(1, int(math.isqrt(n)) + 1) if n % d == 0]
    out += [n // d for d in reversed(out) if d * d != n]
    return tuple(out)


def balanced_factors(n: int, k: int) -> Tuple[int, ...]:
    """Factor ``n`` into ``k`` integer factors with product exactly ``n``,
    as close to n^(1/k) as possible (minimizing max factor, then spread).

    Factors of 1 are dropped, so the returned tuple may be shorter than k
    (e.g. prime n always returns (n,)).
    """
    if n <= 0:
        raise ValueError("n must be positive")
    if n == 1:
        return (1,)
    if k <= 1:
        return (n,)

    best: Optional[Tuple[int, ...]] = None

    def key(fs: Tuple[int, ...]):
        return (max(fs), sum(f * f for f in fs))

    def rec(rem: int, slots: int, cur: Tuple[int, ...]):
        nonlocal best
        if slots == 1 or rem == 1:
            cand = tuple(sorted(cur + ((rem,) if rem > 1 else ()), reverse=True))
            if not cand:
                cand = (1,)
            if best is None or key(cand) < key(best):
                best = cand
            return
        target = rem ** (1.0 / slots)
        divs = [d for d in _divisors(rem) if d > 1]
        # try divisors closest to the balanced target first; bound the branch
        divs.sort(key=lambda d: abs(d - target))
        for d in divs[:6]:
            rec(rem // d, slots - 1, cur + (d,))

    rec(n, k, ())
    assert best is not None
    out = tuple(f for f in best if f > 1)
    return out if out else (1,)


def mixed_radix_sizes(factors: Sequence[int]) -> Tuple[int, ...]:
    """sz_j = prod_{i>j} m_i  (size of a level-j group), j = 1..k."""
    sizes = []
    acc = 1
    for m in reversed(factors):
        sizes.append(acc)
        acc *= m
    return tuple(reversed(sizes))


@dataclass(frozen=True)
class Subset:
    """One all-to-all subset in one stage."""

    members: Tuple[int, ...]  # node ids, ascending ring position
    segment: Optional[Tuple[int, int]]  # (start, length) of the parent ring
    # segment for stage >= 2 (line routing); None => whole ring (stage 1)


@dataclass(frozen=True)
class OpTreePlan:
    """A concrete k-stage factorization of an N-node ring."""

    n: int
    factors: Tuple[int, ...]

    def __post_init__(self):
        prod = 1
        for m in self.factors:
            if m < 1:
                raise ValueError("factors must be >= 1")
            prod *= m
        if prod != self.n:
            raise ValueError(
                f"prod(factors)={prod} != n={self.n}; pick an exact factorization"
            )

    # -- basic algebra ------------------------------------------------------
    @property
    def k(self) -> int:
        return len(self.factors)

    @property
    def sizes(self) -> Tuple[int, ...]:
        """Group size *below* each stage: sizes[j-1] = nodes per level-j group."""
        return mixed_radix_sizes(self.factors)

    def coords(self, p: int) -> Tuple[int, ...]:
        cs = []
        for sz, m in zip(self.sizes, self.factors):
            cs.append((p // sz) % m)
        return tuple(cs)

    def node(self, coords: Sequence[int]) -> int:
        return sum(c * sz for c, sz in zip(coords, self.sizes))

    # -- stage structure ----------------------------------------------------
    def subsets(self, stage: int) -> Iterator[Subset]:
        """All all-to-all subsets of ``stage`` (1-indexed)."""
        if not (1 <= stage <= self.k):
            raise ValueError(f"stage must be in [1, {self.k}]")
        m = self.factors[stage - 1]
        child_sz = self.sizes[stage - 1]
        parent_sz = child_sz * m
        n_parents = self.n // parent_sz
        for parent in range(n_parents):
            start = parent * parent_sz
            for pos in range(child_sz):
                members = tuple(start + g * child_sz + pos for g in range(m))
                seg = None if stage == 1 else (start, parent_sz)
                yield Subset(members=members, segment=seg)

    def items_held_after(self, stage: int, p: int) -> Tuple[int, ...]:
        """Item ids node p holds after completing ``stage`` (0 = initial)."""
        cs = self.coords(p)
        held = []
        for q in range(self.n):
            cq = self.coords(q)
            if cq[stage:] == cs[stage:]:
                held.append(q)
        return tuple(held)

    def items_to_send(self, stage: int, p: int) -> Tuple[int, ...]:
        """Items node p broadcasts during ``stage`` = holdings after stage-1."""
        return self.items_held_after(stage - 1, p)

    # -- convenience --------------------------------------------------------
    @staticmethod
    def balanced(n: int, k: Optional[int] = None, w: int = 64) -> "OpTreePlan":
        """The paper's plan: optimal depth (argmin of Thm 1) + balanced factors."""
        if k is None:
            k = optimal_depth_argmin(n, w)
        return OpTreePlan(n=n, factors=balanced_factors(n, k))

    def to_ir(
        self,
        *,
        shard_bytes: float = 1.0,
        link=None,
        stage_modes: Optional[Sequence[str]] = None,
    ):
        """Lift this paper plan into the unified :class:`CollectivePlan` IR.

        Stages default to ``oneshot`` (the paper's all-to-all broadcast
        rounds); ``stage_modes`` overrides per stage (``"perhop"`` turns a
        stage into m-1 ring hops).  ``link`` optionally attaches one
        LinkSpec to every stage so the electrical backend of
        ``cost_model.price`` can price it too.
        """
        from .plan_ir import CollectivePlan, PlanStage  # local: avoid a cycle

        modes = tuple(stage_modes) if stage_modes is not None else ("oneshot",) * self.k
        if len(modes) != self.k:
            raise ValueError(f"stage_modes must have {self.k} entries, got {modes}")
        stages = []
        payload = float(shard_bytes)
        for m, mode in zip(self.factors, modes):
            stages.append(PlanStage(factor=m, mode=mode, payload_bytes=payload,
                                    link=link))
            payload *= m
        return CollectivePlan(
            collective="ag",
            n=self.n,
            shard_bytes=float(shard_bytes),
            stages=tuple(stages),
            meta={"source": "optree", "factors": self.factors},
        )
