"""Generalized Theorem 2: stage planning for TPU mesh collectives.

The paper minimizes  S(k) = ceil((2k-1) N^{1+1/k} / 8w)  over the tree depth
k — trading per-stage channel demand against stage count.  On a TPU mesh the
"channel" is a torus-axis link and the analogue is:

    T(m_1..m_k; order) = sum_j (m_j - 1) * (alpha_j + payload_j / B_j)
    payload_j          = shard_bytes * prod_{i<j} m_i

i.e. each stage is a ring all-gather over m_j participants whose per-hop
payload has grown by the factors already gathered.  Total moved volume is
invariant (telescopes to (N-1)*shard); what the plan controls is
  * the latency term   sum_j (m_j - 1) * alpha_j   (Thm 2's trade-off), and
  * *which axis carries which payload* — on heterogeneous axes
    (pod/DCN vs. ICI) gathering the slow axis first moves the un-multiplied
    payload over the slow links: the direct analogue of OpTree's stage-1
    strided subsets running while each node holds a single item.

``plan_staged_allgather`` covers the homogeneous single-axis case (factorize
an axis, pick k) and the heterogeneous multi-axis case (order given axes).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from .tree import balanced_factors

__all__ = ["LinkSpec", "StagePlan", "AllGatherPlan", "plan_staged_allgather",
           "plan_axis_order", "ICI_LINK", "DCN_LINK"]


@dataclass(frozen=True)
class LinkSpec:
    """Per-stage transport characteristics."""

    name: str
    bandwidth_bytes: float  # per-device injection bandwidth over this link
    alpha_s: float  # fixed per-hop cost (launch + hop latency)


# TPU v5e-flavoured defaults (see roofline constants in launch/roofline.py):
ICI_LINK = LinkSpec("ici", 50e9, 1e-6)
DCN_LINK = LinkSpec("dcn", 6.25e9, 1e-5)  # ~50 Gbit/s/host-link class transport


@dataclass(frozen=True)
class StagePlan:
    factor: int
    link: LinkSpec
    payload_bytes: float  # per-device payload entering this stage
    time_s: float


@dataclass(frozen=True)
class AllGatherPlan:
    stages: Tuple[StagePlan, ...]
    total_time_s: float

    @property
    def factors(self) -> Tuple[int, ...]:
        return tuple(s.factor for s in self.stages)


def _stage_time(factor: int, payload: float, link: LinkSpec) -> float:
    # ring all-gather over `factor` participants: factor-1 hops, each moving
    # the current accumulated payload.
    return (factor - 1) * (link.alpha_s + payload / link.bandwidth_bytes)


def _plan_for_factors(
    factors: Sequence[int], links: Sequence[LinkSpec], shard_bytes: float
) -> AllGatherPlan:
    stages: List[StagePlan] = []
    payload = float(shard_bytes)
    total = 0.0
    for f, link in zip(factors, links):
        t = _stage_time(f, payload, link)
        stages.append(StagePlan(factor=f, link=link, payload_bytes=payload, time_s=t))
        total += t
        payload *= f
    return AllGatherPlan(stages=tuple(stages), total_time_s=total)


def plan_staged_allgather(
    axis_size: int,
    shard_bytes: float,
    link: LinkSpec = ICI_LINK,
    max_k: Optional[int] = None,
) -> AllGatherPlan:
    """Homogeneous case: factorize one device axis into the time-optimal
    k-stage plan (generalized Thm 2: integer argmin instead of the continuous
    closed form).
    """
    if axis_size < 1:
        raise ValueError("axis_size >= 1")
    kmax = max_k or max(1, math.ceil(math.log2(max(axis_size, 2))))
    best: Optional[AllGatherPlan] = None
    for k in range(1, kmax + 1):
        factors = balanced_factors(axis_size, k)
        for perm in set(itertools.permutations(factors)):
            plan = _plan_for_factors(perm, [link] * len(perm), shard_bytes)
            if best is None or plan.total_time_s < best.total_time_s:
                best = plan
    assert best is not None
    return best


def plan_axis_order(
    axes: Sequence[Tuple[int, LinkSpec]], shard_bytes: float
) -> AllGatherPlan:
    """Heterogeneous case: given physical mesh axes (size, link), choose the
    stage *order*.  Provably: sort by ascending bandwidth (slow first) when
    alphas are equal; we brute-force the permutation (k is tiny) so latency
    asymmetries are honoured too.
    """
    best: Optional[AllGatherPlan] = None
    for perm in itertools.permutations(axes):
        plan = _plan_for_factors(
            [a[0] for a in perm], [a[1] for a in perm], shard_bytes
        )
        if best is None or plan.total_time_s < best.total_time_s:
            best = plan
    assert best is not None
    return best
