"""Generalized Theorem 2: stage planning for TPU mesh collectives.

The paper minimizes  S(k) = ceil((2k-1) N^{1+1/k} / 8w)  over the tree depth
k — trading per-stage channel demand against stage count.  On a TPU mesh the
"channel" is a torus-axis link and the analogue is:

    T(m_1..m_k; order) = sum_j (m_j - 1) * (alpha_j + payload_j / B_j)
    payload_j          = shard_bytes * prod_{i<j} m_i

i.e. each stage is a ring all-gather over m_j participants whose per-hop
payload has grown by the factors already gathered.  Total moved volume is
invariant (telescopes to (N-1)*shard); what the plan controls is
  * the latency term   sum_j (m_j - 1) * alpha_j   (Thm 2's trade-off), and
  * *which axis carries which payload* — on heterogeneous axes
    (pod/DCN vs. ICI) gathering the slow axis first moves the un-multiplied
    payload over the slow links: the direct analogue of OpTree's stage-1
    strided subsets running while each node holds a single item.

``plan_staged_allgather`` covers the homogeneous single-axis case (factorize
an axis, pick k) and the heterogeneous multi-axis case (order given axes).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from .cost_model import TERARACK
from .plan_ir import collective_kind
from .tree import balanced_factors

__all__ = ["LinkSpec", "StagePlan", "AllGatherPlan", "AllReducePlan",
           "HopSchedule", "FusedMatmulPlan", "load_links",
           "plan_staged_allgather", "plan_axis_order",
           "plan_reduce_scatter_order", "plan_all_reduce",
           "pipeline_makespan", "choose_num_chunks",
           "perhop_stage_time", "choose_hop_schedule",
           "plan_latency_collective", "latency_crossover_bytes",
           "OrderCandidate", "OrderSearch", "search_stage_orders",
           "plan_collective_matmul", "matmul_block_time",
           "ICI_LINK", "DCN_LINK", "MXU_PEAK_FLOPS"]


@dataclass(frozen=True)
class LinkSpec:
    """Per-stage transport characteristics."""

    name: str
    bandwidth_bytes: float  # per-device injection bandwidth over this link
    alpha_s: float  # fixed per-hop cost (launch + hop latency)

    def to_json(self) -> dict:
        return {"name": self.name, "bandwidth_bytes": self.bandwidth_bytes,
                "alpha_s": self.alpha_s}

    @staticmethod
    def from_json(d: dict, fallback: Optional["LinkSpec"] = None) -> "LinkSpec":
        """Build a LinkSpec from a dict — the ``to_json`` form or one entry
        of ``launch/perf.py --calibrate``'s ``fitted_links`` output.

        A calibration sweep on alpha-dominated transport reports
        ``bandwidth_bytes: null`` (unidentifiable); those fall back to
        ``fallback`` (or the entry's own ``hardcoded`` record) so a fitted
        file always round-trips into a usable spec.
        """
        bw = d.get("bandwidth_bytes")
        alpha = d.get("alpha_s")
        hard = d.get("hardcoded") or {}
        if bw is None:
            bw = (fallback.bandwidth_bytes if fallback is not None
                  else hard.get("bandwidth_bytes"))
        if alpha is None:
            alpha = (fallback.alpha_s if fallback is not None
                     else hard.get("alpha_s"))
        if bw is None or alpha is None:
            raise ValueError(f"cannot build LinkSpec from {d!r}: missing "
                             f"bandwidth/alpha and no fallback")
        bw, alpha = float(bw), float(alpha)
        if bw <= 0.0 or alpha < 0.0:
            raise ValueError(
                f"invalid LinkSpec values in {d!r}: bandwidth_bytes must be "
                f"> 0 (got {bw}) and alpha_s >= 0 (got {alpha})")
        return LinkSpec(name=str(d.get("name", "link")),
                        bandwidth_bytes=bw, alpha_s=alpha)


def load_links(
    path,
    fallbacks: Optional[dict] = None,
    *,
    expect_axes: Optional[Sequence[str]] = None,
    allow_missing: bool = False,
) -> dict:
    """Load an axis-name -> LinkSpec map from a JSON file.

    Accepts either a plain ``{axis: LinkSpec.to_json()}`` map or the full
    ``launch/perf.py --calibrate`` output (``{"fitted_links": {...}}``) —
    the calibration loop's feedback path into the comms context
    (``comms.api.CommContext.update_links``) / engine ``links=``.

    ``expect_axes`` validates the file against a mesh's axis set instead of
    silently ignoring typos: entries for axes NOT in ``expect_axes`` raise
    ``ValueError`` naming them, and (unless ``allow_missing``, for callers
    that merge onto a default table) so do expected axes the file lacks.
    """
    import json
    from pathlib import Path

    doc = json.loads(Path(path).read_text())
    entries = doc.get("fitted_links", doc)
    if expect_axes is not None:
        expect = set(expect_axes)
        unknown = sorted(set(entries) - expect)
        missing = sorted(expect - set(entries))
        if unknown or (missing and not allow_missing):
            raise ValueError(
                f"links file {path} does not match axes {sorted(expect)}: "
                f"unknown axes {unknown}, missing axes {missing}")
    out = {}
    for axis, d in entries.items():
        fb = (fallbacks or {}).get(axis)
        out[axis] = LinkSpec.from_json(d, fallback=fb)
    return out


# TPU v5e-flavoured defaults (see roofline constants in launch/roofline.py):
ICI_LINK = LinkSpec("ici", 50e9, 1e-6)
DCN_LINK = LinkSpec("dcn", 6.25e9, 1e-5)  # ~50 Gbit/s/host-link class transport

MXU_PEAK_FLOPS = 197e12  # v5e bf16 peak (launch/roofline.py HW model)


@dataclass(frozen=True)
class StagePlan:
    factor: int
    link: LinkSpec
    payload_bytes: float  # per-device payload entering this stage
    time_s: float


@dataclass(frozen=True)
class AllGatherPlan:
    """A staged collective plan (all-gather or its reduce-scatter dual).

    ``num_chunks`` / ``pipelined_time_s`` carry the chunking decision: split
    the shard into C chunks and software-pipeline stage j of chunk i with
    stage j+1 of chunk i-1.  C=1 means chunking does not pay (alpha-bound).
    """

    stages: Tuple[StagePlan, ...]
    total_time_s: float
    num_chunks: int = 1
    pipelined_time_s: Optional[float] = None

    @property
    def factors(self) -> Tuple[int, ...]:
        return tuple(s.factor for s in self.stages)


@dataclass(frozen=True)
class AllReducePlan:
    """Staged all-reduce = reduce-scatter + all-gather sharing one axis plan
    (AG stage order is the exact reverse of the RS order).

    ``num_chunks``/``pipelined_time_s`` model what ``staged_all_reduce``
    actually executes: ONE 2k-stage RS+AG pipeline with a single shared
    chunk count, not two independently chunked halves.
    """

    reduce_scatter: AllGatherPlan
    all_gather: AllGatherPlan
    num_chunks: int = 1
    pipelined_time_s: Optional[float] = None

    @property
    def total_time_s(self) -> float:
        return self.reduce_scatter.total_time_s + self.all_gather.total_time_s


def _stage_time(factor: int, payload: float, link: LinkSpec) -> float:
    # ring all-gather over `factor` participants: factor-1 hops, each moving
    # the current accumulated payload.
    return (factor - 1) * (link.alpha_s + payload / link.bandwidth_bytes)


def _plan_from_law(
    collective: str, factors: Sequence[int], links: Sequence[LinkSpec],
    shard_bytes: float,
) -> AllGatherPlan:
    """Stage chain priced by the registry's payload-per-stage law
    (``plan_ir.CollectiveKind.stage_payloads``): gather grows, scatter
    shrinks, exchange moves a constant ``shard / f_j`` per peer."""
    payloads = collective_kind(collective).stage_payloads(shard_bytes, factors)
    stages = tuple(
        StagePlan(factor=f, link=link, payload_bytes=p,
                  time_s=_stage_time(f, p, link))
        for f, link, p in zip(factors, links, payloads)
    )
    return AllGatherPlan(stages=stages,
                         total_time_s=sum(s.time_s for s in stages))


def _plan_for_factors(
    factors: Sequence[int], links: Sequence[LinkSpec], shard_bytes: float
) -> AllGatherPlan:
    return _plan_from_law("ag", factors, links, shard_bytes)


def plan_staged_allgather(
    axis_size: int,
    shard_bytes: float,
    link: LinkSpec = ICI_LINK,
    max_k: Optional[int] = None,
) -> AllGatherPlan:
    """Homogeneous case: factorize one device axis into the time-optimal
    k-stage plan (generalized Thm 2: integer argmin instead of the continuous
    closed form).
    """
    if axis_size < 1:
        raise ValueError("axis_size >= 1")
    kmax = max_k or max(1, math.ceil(math.log2(max(axis_size, 2))))
    best: Optional[AllGatherPlan] = None
    for k in range(1, kmax + 1):
        factors = balanced_factors(axis_size, k)
        for perm in set(itertools.permutations(factors)):
            plan = _plan_for_factors(perm, [link] * len(perm), shard_bytes)
            if best is None or plan.total_time_s < best.total_time_s:
                best = plan
    assert best is not None
    return best


def _rs_plan_for_factors(
    factors: Sequence[int], links: Sequence[LinkSpec], shard_bytes: float
) -> AllGatherPlan:
    """Reduce-scatter dual: payload *shrinks* stage by stage.  A ring
    reduce-scatter over ``f`` participants with input payload P makes f-1
    hops each moving P/f, leaving P/f per device.  ``shard_bytes`` is the
    *output* shard (input = shard * prod(factors)) so the duality with the
    all-gather plan is literal: reversed factors give mirrored stage costs.
    """
    return _plan_from_law("rs", factors, links, shard_bytes)


def _chunked_stage_times(
    factors: Sequence[int],
    links: Sequence[LinkSpec],
    shard_bytes: float,
    num_chunks: int,
    collective: str,
) -> List[float]:
    """Per-chunk stage times with the shard split into ``num_chunks``:
    bandwidth terms shrink by C, alpha terms are paid per chunk per stage."""
    plan = _plan_from_law(collective, factors, links, shard_bytes / num_chunks)
    return [s.time_s for s in plan.stages]


def pipeline_makespan(stage_times: Sequence[float], num_chunks: int) -> float:
    """Makespan of C chunks flowing through a linear k-stage pipeline where
    each stage is a serially-reused link: fill the pipe once, then the
    slowest stage paces the remaining C-1 chunks."""
    return sum(stage_times) + (num_chunks - 1) * max(stage_times)


# small-message chunking floor (in packets): a shard below this many packets
# is latency-regime traffic — the chunk wavefront's extra per-chunk alphas
# can never be repaid by pipelining bandwidth that small, and the packet-
# quantized wire would not deliver the modeled sub-packet wins anyway.
# ``_best_chunks`` clamps straight to C=1 below ``packet_bytes * FLOOR``.
SMALL_MESSAGE_FLOOR_PACKETS = 32


def _best_chunks(
    times_for_c, max_chunks: int, *, shard_bytes: Optional[float] = None,
    packet_bytes: int = TERARACK.packet_bytes,
) -> Tuple[int, float]:
    """Scan power-of-two chunk counts, minimizing the pipelined makespan of
    whatever stage chain ``times_for_c(c)`` describes.

    Shards under the small-message floor (``packet_bytes *
    SMALL_MESSAGE_FLOOR_PACKETS``) clamp to C=1 outright: KiB-scale
    payloads never pay chunk-wavefront overhead.  Above the floor, chunk
    counts whose per-chunk payload would drop below one packet
    (``packet_bytes``) are never considered: below that the linear d/B model
    is a lie — transfers are packet-quantized, so the modeled win would not
    materialize and chunking can only add launch overhead.  C=1 is always a
    candidate, so the returned makespan never exceeds the unchunked time.
    """
    if (shard_bytes is not None
            and shard_bytes < packet_bytes * SMALL_MESSAGE_FLOOR_PACKETS):
        return 1, pipeline_makespan(times_for_c(1), 1)
    best_c, best_t = 1, math.inf
    c = 1
    while c <= max_chunks:
        if c > 1 and shard_bytes is not None and shard_bytes / c < packet_bytes:
            break  # payload per chunk under one packet; larger C only worse
        t = pipeline_makespan(times_for_c(c), c)
        if t < best_t:
            best_c, best_t = c, t
        c *= 2
    return best_c, best_t


def choose_num_chunks(
    factors: Sequence[int],
    links: Sequence[LinkSpec],
    shard_bytes: float,
    *,
    max_chunks: int = 8,
    collective: str = "ag",
    packet_bytes: int = TERARACK.packet_bytes,
) -> Tuple[int, float]:
    """Pick C minimizing the pipelined makespan (alpha/bandwidth trade-off:
    chunking amortizes bandwidth across stages but multiplies alpha).  C is
    clamped so one chunk never carries less than ``packet_bytes``."""
    return _best_chunks(
        lambda c: _chunked_stage_times(factors, links, shard_bytes, c, collective),
        max_chunks,
        shard_bytes=shard_bytes,
        packet_bytes=packet_bytes,
    )


def _best_permutation(
    axes: Sequence[Tuple[int, LinkSpec]], shard_bytes: float, builder
) -> AllGatherPlan:
    best: Optional[AllGatherPlan] = None
    for perm in itertools.permutations(axes):
        plan = builder([a[0] for a in perm], [a[1] for a in perm], shard_bytes)
        if best is None or plan.total_time_s < best.total_time_s:
            best = plan
    assert best is not None
    return best


def _with_chunking(
    plan: AllGatherPlan, shard_bytes: float, max_chunks: int, collective: str
) -> AllGatherPlan:
    links = [s.link for s in plan.stages]
    c, t = choose_num_chunks(
        plan.factors, links, shard_bytes, max_chunks=max_chunks,
        collective=collective,
    )
    return dataclasses.replace(plan, num_chunks=c, pipelined_time_s=t)


def plan_axis_order(
    axes: Sequence[Tuple[int, LinkSpec]],
    shard_bytes: float,
    *,
    max_chunks: int = 8,
) -> AllGatherPlan:
    """Heterogeneous case: given physical mesh axes (size, link), choose the
    stage *order*.  Provably: sort by ascending bandwidth (slow first) when
    alphas are equal; we brute-force the permutation (k is tiny) so latency
    asymmetries are honoured too.  The returned plan also carries the
    chunking decision (``num_chunks``/``pipelined_time_s``).
    """
    best = _best_permutation(axes, shard_bytes, _plan_for_factors)
    return _with_chunking(best, shard_bytes, max_chunks, "ag")


def plan_reduce_scatter_order(
    axes: Sequence[Tuple[int, LinkSpec]],
    shard_bytes: float,
    *,
    max_chunks: int = 8,
) -> AllGatherPlan:
    """Stage order for the reduce-scatter dual.  ``shard_bytes`` is the
    *output* shard per device (same parameterization as the all-gather
    planner's input shard, so rs.total == ag.total for mirrored orders).

    The optimum is the exact reverse of the all-gather order: the payload
    shrinks stage by stage, so the slow links run *last*, when the payload
    is smallest.
    """
    best = _best_permutation(axes, shard_bytes, _rs_plan_for_factors)
    return _with_chunking(best, shard_bytes, max_chunks, "rs")


def plan_all_reduce(
    axes: Sequence[Tuple[int, LinkSpec]],
    shard_bytes: float,
    *,
    max_chunks: int = 8,
) -> AllReducePlan:
    """Staged all-reduce = RS then AG over one shared axis plan: the AG
    stage order is the exact reverse of the planned RS order (duality), not
    a second independent optimization.  ``shard_bytes`` is the scattered
    (1/N) shard — the payload at the RS/AG boundary.

    The chunk decision is made over the *combined* 2k-stage chain with one
    shared C — matching ``staged_all_reduce``'s wavefront, which flows each
    chunk through RS then AG as a single pipeline.
    """
    rs = plan_reduce_scatter_order(axes, shard_bytes, max_chunks=1)
    ag_factors = [s.factor for s in reversed(rs.stages)]
    ag_links = [s.link for s in reversed(rs.stages)]
    ag = _plan_for_factors(ag_factors, ag_links, shard_bytes)

    rs_links = [s.link for s in rs.stages]
    best_c, best_t = _best_chunks(
        lambda c: (
            _chunked_stage_times(rs.factors, rs_links, shard_bytes, c, "rs")
            + _chunked_stage_times(ag_factors, ag_links, shard_bytes, c, "ag")
        ),
        max_chunks,
        shard_bytes=shard_bytes,
    )
    return AllReducePlan(
        reduce_scatter=rs, all_gather=ag, num_chunks=best_c,
        pipelined_time_s=best_t,
    )


# --------------------------------------------------------------------------
# per-hop overlapped execution (double-buffered ppermute rings)
# --------------------------------------------------------------------------

def perhop_stage_time(factor: int, payload: float, link: LinkSpec) -> float:
    """Exposed time of a double-buffered ring stage over ``factor``
    participants with per-hop payload ``payload``.

    The ring executor forwards the block received at hop t while its local
    copy/reduce (and the next hop's launch) run concurrently, so per hop only
    the longer of {serialization chain, launch chain} is exposed:

        T = max((f-1)·p/B + α,  (f-1)·α + p/B)

    This is the TPU-mesh analogue of ``cost_model.eq3_overlap_time`` — α is
    amortized across in-flight hops when the stage is bandwidth-bound.  The
    barrier model ``_stage_time`` = (f-1)·(α + p/B) is its upper bound.
    """
    if factor <= 1:
        return 0.0
    hops = factor - 1
    serial = payload / link.bandwidth_bytes
    return max(hops * serial + link.alpha_s, hops * link.alpha_s + serial)


def _stage_exposure(factor: int, payload: float, link: LinkSpec) -> Tuple[float, float]:
    """(exposed, hidden) bytes for one overlapped ring stage (see
    ``cost_model.exposed_hidden_bytes``): bandwidth-bound stages expose every
    moved byte and hide the αs; latency-bound stages hide all but one hop's
    payload under the α chain."""
    if factor <= 1:
        return 0.0, 0.0
    moved = (factor - 1) * payload
    if payload / link.bandwidth_bytes >= link.alpha_s:
        return float(moved), 0.0
    return float(payload), float(moved - payload)


@dataclass(frozen=True)
class HopSchedule:
    """Planner decision for HOW a staged collective executes.

      * ``oneshot``  — one blocking XLA collective per stage (PR-1 engine);
      * ``chunked``  — C-chunk wavefront over whole-stage collectives;
      * ``perhop``   — double-buffered ppermute rings (comms/ring_executor),
                       per-stage selectable via ``stage_modes`` ("ring" where
                       the overlap model wins, "oneshot" where a stage is too
                       small for hop pipelining to matter, e.g. factor 2);
      * ``hybrid``   — the chunk wavefront OVER the per-hop ring stages:
                       ``hybrid_chunks`` chunks pipeline through the same
                       ``stage_modes`` chain, each stage costing the overlap
                       max-form (ring) or barrier (oneshot) on a 1/C chunk.
                       Elementwise ≤ the chunked stage times and equal to
                       perhop at C=1, so it is never modeled worse than
                       either pure mode; ties prefer the simpler modes.

    All four modeled times come from the same ``LinkSpec``s;
    ``stage_exposed_bytes``/``stage_hidden_bytes`` carry the per-stage
    exposed-vs-hidden byte accounting of the per-hop mode.
    """

    mode: str
    stage_modes: Tuple[str, ...]
    num_chunks: int
    oneshot_time_s: float
    chunked_time_s: float
    perhop_time_s: float
    stage_exposed_bytes: Tuple[float, ...]
    stage_hidden_bytes: Tuple[float, ...]
    # the priced stage chain (for "ar": the full 2k-stage RS+AG sequence),
    # carried so the schedule lowers losslessly into the CollectivePlan IR
    stages: Tuple[StagePlan, ...] = ()
    collective: str = "ag"
    shard_bytes: float = 0.0
    hybrid_time_s: float = math.inf
    hybrid_chunks: int = 1

    @property
    def time_s(self) -> float:
        return {"oneshot": self.oneshot_time_s, "chunked": self.chunked_time_s,
                "perhop": self.perhop_time_s,
                "hybrid": self.hybrid_time_s}[self.mode]

    @property
    def exposed_bytes(self) -> float:
        return sum(self.stage_exposed_bytes)

    @property
    def hidden_bytes(self) -> float:
        return sum(self.stage_hidden_bytes)

    def to_ir(self, axis_names: Optional[Sequence[str]] = None, *,
              mode: Optional[str] = None):
        """Lower this planner decision into the unified CollectivePlan IR.

        ``axis_names`` labels each stage with the mesh axis the engine
        executes it over (execution order — for ``ar`` the 2k-long RS+AG
        name sequence).  Per-stage hop structure maps ``"ring"`` →
        ``"perhop"``; the plan-level ``mode`` (overridable) selects which
        modeled execution the plan carries — a ``hybrid`` plan carries the
        hybrid wavefront's own chunk count, every other mode the chunked
        decision.
        """
        from .plan_ir import CollectivePlan, PlanStage  # local: avoid a cycle

        if not self.stages:
            raise ValueError("HopSchedule built without its stage chain "
                             "cannot lower to IR")
        names: Sequence[Optional[str]]
        names = tuple(axis_names) if axis_names is not None else (None,) * len(self.stages)
        if len(names) != len(self.stages):
            raise ValueError(
                f"axis_names must have {len(self.stages)} entries, got {names}"
            )
        ir_stages = tuple(
            PlanStage(
                factor=s.factor,
                mode="perhop" if m == "ring" else "oneshot",
                payload_bytes=s.payload_bytes,  # per-hop payload, both duals
                axis=name,
                link=s.link,
            )
            for s, m, name in zip(self.stages, self.stage_modes, names)
        )
        n = math.prod(
            s.factor for s in (self.stages[: len(self.stages) // 2]
                               if collective_kind(self.collective).two_phase
                               else self.stages)
        )
        eff_mode = mode or self.mode
        return CollectivePlan(
            collective=self.collective,
            n=n,
            shard_bytes=self.shard_bytes,
            stages=ir_stages,
            mode=eff_mode,
            num_chunks=(self.hybrid_chunks if eff_mode == "hybrid"
                        else self.num_chunks),
            meta={"source": "hop_schedule",
                  "modeled": {"oneshot": self.oneshot_time_s,
                              "chunked": self.chunked_time_s,
                              "perhop": self.perhop_time_s,
                              "hybrid": self.hybrid_time_s},
                  # per-mode chunk decisions: with_mode restores the right
                  # count when flipping between chunked and hybrid
                  "mode_chunks": {"chunked": self.num_chunks,
                                  "hybrid": self.hybrid_chunks}},
        )


def _stage_chain(
    factors: Sequence[int], links: Sequence[LinkSpec], shard_bytes: float,
    collective: str,
) -> List[StagePlan]:
    """The (factor, link, payload) chain a collective actually executes —
    the registry's payload-per-stage law over the execution order.  For a
    two-phase kind (AR) ``factors`` is the first (RS) half's order and the
    second half mirrors it; single-chain kinds (AG/RS/A2A) execute the
    given order directly."""
    if collective_kind(collective).two_phase:
        rs = _rs_plan_for_factors(factors, links, shard_bytes).stages
        ag = _plan_for_factors(
            [s.factor for s in reversed(rs)], [s.link for s in reversed(rs)],
            shard_bytes,
        ).stages
        return list(rs) + list(ag)
    return list(_plan_from_law(collective, factors, links, shard_bytes).stages)


def choose_hop_schedule(
    factors: Sequence[int],
    links: Sequence[LinkSpec],
    shard_bytes: float,
    *,
    max_chunks: int = 8,
    collective: str = "ag",
    packet_bytes: int = TERARACK.packet_bytes,
    health=None,
    axis_names: Optional[Sequence[Optional[str]]] = None,
) -> HopSchedule:
    """Pick one-shot vs chunked-wavefront vs per-hop vs hybrid execution
    for a staged collective, all from the same ``LinkSpec``s.

    ``health`` (with ``axis_names`` naming each stage's mesh axis) plans
    under the DEGRADED world: every stage link's bandwidth is scaled by its
    axis's best alive direction before any mode decision, so the chosen
    mode/chunking is the one that wins on the hardware as it actually is.
    An axis dead in both directions raises
    :class:`~repro.core.health.DeadAxisError` — callers fall back to the
    one-shot XLA collective.

    ``factors``/``links`` are the planned *stage order* (``plan_axis_order``
    / ``plan_reduce_scatter_order`` output); ``shard_bytes`` is the
    scattered-end payload, as everywhere in this module.  For ``ar`` the
    modeled chain is the full 2k-stage RS+AG pipeline.  The hybrid
    candidate (chunk wavefront over per-hop ring stages) reuses the perhop
    ``stage_modes`` and the chunked candidate's power-of-two/packet-clamped
    chunk scan, so it degenerates exactly to perhop at C=1 and to chunked
    when no stage runs as a ring — ties resolve to the simpler mode.
    """
    if health is not None and not health.is_healthy:
        names = (tuple(axis_names) if axis_names is not None
                 else (None,) * len(links))
        if len(names) != len(links):
            raise ValueError(
                f"axis_names length {len(names)} != links length {len(links)}")
        links = [health.degrade_link(nm, l) for nm, l in zip(names, links)]
    stages = _stage_chain(factors, links, shard_bytes, collective)

    oneshot = sum(s.time_s for s in stages)

    if collective_kind(collective).two_phase:
        num_chunks, chunked = _best_chunks(
            lambda c: [
                t.time_s
                for t in _stage_chain(factors, links, shard_bytes / c, collective)
            ],
            max_chunks, shard_bytes=shard_bytes, packet_bytes=packet_bytes,
        )
    else:
        num_chunks, chunked = choose_num_chunks(
            factors, links, shard_bytes, max_chunks=max_chunks,
            collective=collective, packet_bytes=packet_bytes,
        )

    perhop = 0.0
    stage_modes: List[str] = []
    exposed: List[float] = []
    hidden: List[float] = []
    for s in stages:
        t_barrier = s.time_s
        t_ring = perhop_stage_time(s.factor, s.payload_bytes, s.link)
        # a 2-participant stage has a single hop — nothing to pipeline; keep
        # the XLA collective (stage_mode "oneshot") and its barrier cost
        if s.factor > 2 and t_ring < t_barrier:
            stage_modes.append("ring")
            perhop += t_ring
            e, h = _stage_exposure(s.factor, s.payload_bytes, s.link)
        else:
            stage_modes.append("oneshot")
            perhop += t_barrier
            e, h = (s.factor - 1) * s.payload_bytes, 0.0
        exposed.append(e)
        hidden.append(h)

    # hybrid: the chunk wavefront over the per-hop stage chain — per chunk,
    # ring stages cost the overlap max-form and oneshot stages the barrier,
    # each on a 1/C payload (stage payloads are linear in the shard)
    def hybrid_stage_times(c: int) -> List[float]:
        return [
            perhop_stage_time(s.factor, s.payload_bytes / c, s.link)
            if m == "ring"
            else (s.factor - 1) * (s.link.alpha_s
                                   + (s.payload_bytes / c) / s.link.bandwidth_bytes)
            for s, m in zip(stages, stage_modes)
        ]

    hybrid_chunks, hybrid = _best_chunks(
        hybrid_stage_times, max_chunks,
        shard_bytes=shard_bytes, packet_bytes=packet_bytes,
    )

    mode = min(
        (("oneshot", oneshot), ("chunked", chunked), ("perhop", perhop),
         ("hybrid", hybrid)),
        key=lambda kv: kv[1],
    )[0]
    if mode == "chunked" and num_chunks == 1:
        mode = "oneshot"
    if mode == "hybrid" and hybrid_chunks == 1:
        mode = "perhop"  # one-chunk hybrid IS the per-hop schedule
    return HopSchedule(
        mode=mode,
        stage_modes=tuple(stage_modes),
        num_chunks=num_chunks,
        oneshot_time_s=oneshot,
        chunked_time_s=chunked,
        perhop_time_s=perhop,
        stage_exposed_bytes=tuple(exposed),
        stage_hidden_bytes=tuple(hidden),
        stages=tuple(stages),
        collective=collective,
        shard_bytes=float(shard_bytes),
        hybrid_time_s=hybrid,
        hybrid_chunks=hybrid_chunks,
    )


# --------------------------------------------------------------------------
# latency-regime plans (recursive-doubling pairwise exchange)
# --------------------------------------------------------------------------

# collectives the pairwise-exchange structure covers: a2a's exchange traffic
# already moves a constant payload per stage and gains nothing from it.
_LATENCY_COLLECTIVES = ("ag", "rs", "ar")


def _pow2_exponent(n: int) -> Optional[int]:
    """log2(n) when n is a power of two, else None."""
    if n >= 1 and (n & (n - 1)) == 0:
        return n.bit_length() - 1
    return None


def _latency_plan_for_order(
    chain: Sequence[Tuple[Optional[str], int, LinkSpec]],
    shard_bytes: float,
    collective: str,
    *,
    canonical_names: Optional[Sequence[Optional[str]]] = None,
):
    """Build the CollectivePlan for one expanded factor-2 chain.

    ``chain`` is the all-gather-order stage list, every entry ``(name, 2,
    link)`` — one bidirectional pairwise-exchange round per stage
    (recursive doubling: k = log2(n) rounds instead of an m-ary ring's
    m-1 hops per stage).  Execution-order derivation per collective
    mirrors ``search_stage_orders``: RS executes the reverse, AR the
    reverse (its RS half) plus that half's mirror.  Returns ``(plan,
    total_electrical_s)`` — the closed-form alpha-dominated cost
    ``sum_j (alpha_j + payload_j / B_j)`` (the barrier stage time at
    factor 2), which for a homogeneous AG telescopes to
    ``k*alpha + (n-1)*shard/B``.
    """
    from .plan_ir import CollectivePlan, PlanStage  # local: avoid a cycle

    kind = collective_kind(collective)
    ag_names = tuple(a[0] for a in chain)
    if kind.two_phase:
        exec_chain = tuple(reversed(chain))  # the RS half's order
        rs_names = tuple(reversed(ag_names))
        plan_names = rs_names + tuple(reversed(rs_names))
    elif kind.chain == "reversed":
        exec_chain = tuple(reversed(chain))
        plan_names = tuple(reversed(ag_names))
    else:  # forward: ag executes the chain directly
        exec_chain = tuple(chain)
        plan_names = ag_names
    stages = _stage_chain(
        [a[1] for a in exec_chain], [a[2] for a in exec_chain],
        shard_bytes, collective,
    )
    ir_stages = tuple(
        PlanStage(factor=s.factor, mode="exchange",
                  payload_bytes=s.payload_bytes, axis=name, link=s.link)
        for s, name in zip(stages, plan_names)
    )
    total = sum(s.time_s for s in stages)
    meta = {"source": "latency", "regime": "latency",
            "modeled": {"latency": total}}
    if canonical_names is not None and all(
            nm is not None for nm in canonical_names):
        meta["axis_names"] = tuple(canonical_names)
    plan = CollectivePlan(
        collective=collective,
        n=math.prod(a[1] for a in chain),
        shard_bytes=float(shard_bytes),
        stages=ir_stages,
        mode="oneshot",
        num_chunks=1,
        meta=meta,
    )
    return plan, total


def plan_latency_collective(
    axes: Sequence[Tuple[Optional[str], int, LinkSpec]],
    shard_bytes: float,
    *,
    collective: str = "ag",
    health=None,
):
    """Latency-optimal small-message plan: every stage a factor-2
    bidirectional pairwise-exchange round (recursive doubling /
    short-circuit style), picked over axis permutations by the closed-form
    alpha-dominated electrical cost.

    Each axis of size ``2^m`` expands into ``m`` contiguous exchange
    rounds over that axis's link; the permutation search orders whole axes
    (rounds of one axis stay contiguous — the executor relies on it).
    ``shard_bytes`` is the scattered-end payload, as everywhere in this
    module.  ``health`` plans in the degraded world (per-axis link
    derating) — but any DEAD ring direction disqualifies the whole
    family, because every exchange round moves payload both ways.

    Returns the best CollectivePlan (stages carry ``mode="exchange"``,
    ``meta["regime"] == "latency"``), or ``None`` when the structure does
    not apply: a collective outside ag/rs/ar, a non-power-of-two axis
    size, a degenerate n < 2, or a dead direction.
    """
    if collective not in _LATENCY_COLLECTIVES:
        return None
    norm: List[Tuple[Optional[str], int, LinkSpec, int]] = []
    for name, size, link in axes:
        m = _pow2_exponent(int(size))
        if m is None:
            return None
        if health is not None and not health.is_healthy:
            link = health.degrade_link(name, link)
        norm.append((name, int(size), link, m))
    if math.prod(a[1] for a in norm) < 2:
        return None
    if health is not None and health.dead_directions([a[0] for a in norm]):
        return None  # exchange rounds need both ring directions alive
    canonical = tuple(a[0] for a in norm)
    best = None
    best_key = None
    for perm in itertools.permutations(norm):
        chain = tuple(
            (name, 2, link)
            for name, _size, link, m in perm
            for _ in range(m)
        )
        plan, total = _latency_plan_for_order(
            chain, shard_bytes, collective, canonical_names=canonical)
        key = (total, tuple(str(a[0]) for a in chain))
        if best_key is None or key < best_key:
            best, best_key = plan, key
    return best


def latency_crossover_bytes(
    axes: Sequence[Tuple[Optional[str], int, LinkSpec]],
    *,
    collective: str = "ar",
    backend: str = "electrical",
    system=None,
    health=None,
    lo_bytes: float = 64.0,
    hi_bytes: float = float(1 << 26),
) -> Optional[float]:
    """Modeled alpha/bandwidth crossover: the shard size (bytes) where the
    best ring-family plan catches up with the latency plan.

    For shards strictly below the returned size the latency plan is
    modeled cheaper than every ring-mode plan; at or above it the ring
    family wins.  ``backend`` picks the cost world ("electrical" LinkSpec
    alpha+beta, or "optical" Eq. 3 on the RWA lowering under ``system``).
    Returns ``None`` when the latency structure does not apply to
    ``axes``/``collective``; ``0.0`` when the ring family already wins at
    ``lo_bytes`` (latency never pays); ``inf`` when latency still wins at
    ``hi_bytes``.
    """
    from .cost_model import price  # lazy: cost_model imports us

    if backend not in ("electrical", "optical"):
        raise ValueError(f"backend must be electrical|optical, got {backend!r}")
    if plan_latency_collective(
            axes, lo_bytes, collective=collective, health=health) is None:
        return None

    def latency_time(s: float) -> float:
        plan = plan_latency_collective(
            axes, s, collective=collective, health=health)
        if backend == "electrical":
            return price(plan).total_s
        return price(plan, system, health=health).total_s

    def ring_time(s: float) -> float:
        if backend == "optical":
            return search_stage_orders(
                axes, s, collective=collective, backend="optical",
                system=system, health=health, include_latency=False,
            ).best.optical_s
        best = math.inf
        for perm in itertools.permutations(axes):
            sched = choose_hop_schedule(
                [a[1] for a in perm], [a[2] for a in perm], s,
                collective=collective, health=health,
                axis_names=[a[0] for a in perm],
            )
            best = min(best, sched.time_s)
        return best

    def margin(s: float) -> float:
        # > 0 where the latency plan is strictly cheaper
        return ring_time(s) - latency_time(s)

    if margin(lo_bytes) <= 0.0:
        return 0.0
    lo = lo_bytes
    while lo < hi_bytes:
        nxt = min(lo * 2.0, hi_bytes)
        if margin(nxt) <= 0.0:
            break
        lo = nxt
        if lo >= hi_bytes:
            return math.inf
    hi = min(lo * 2.0, hi_bytes)
    # log-space bisection down to ~1-byte resolution on [lo, hi]
    for _ in range(64):
        if hi - lo <= 1.0:
            break
        mid = math.sqrt(lo * hi)
        if margin(mid) > 0.0:
            lo = mid
        else:
            hi = mid
    return hi


# --------------------------------------------------------------------------
# cross-world stage-order search (electrical AND optical pricing)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class OrderCandidate:
    """One searched stage order, priced under BOTH cost worlds.

    ``order`` is the all-gather-order axis naming of the candidate (the RS
    execution order is its reverse, the AR chain RS-order + reversed — one
    AG permutation determines all three); ``plan`` is the full
    CollectivePlan ``choose_hop_schedule`` emitted for it, the very object
    the executor would interpret.  ``electrical_s`` is ``price(plan)`` (the
    LinkSpec model of the plan's chosen mode), ``optical_s``/
    ``optical_steps`` are Eq. 3 on the RWA-lowered schedule
    (``price(plan, system)`` == ``simulate(schedule_from_ir(plan, w))``).

    ``regime`` names the candidate family: ``"bandwidth"`` for the ring
    chains, ``"latency"`` for the recursive-doubling exchange plans (whose
    ``order`` is the EXPANDED per-round axis naming, e.g. ``("b","b","a")``
    for a 4×2 mesh gathered b-first).

    ``reconfigurations`` counts the circuit/topology changes the lowered
    schedule needs on a reconfigurable photonic fabric (0 = the candidate
    holds one circuit for the whole collective).  The count is structural
    — it is reported even when ``system.circuit_reconfig_s == 0`` — so
    the hold-vs-reconfigure decision can be ranked independently of the
    delay calibration; the delay itself is already inside ``optical_s``.
    """

    order: Tuple[str, ...]
    plan: object  # CollectivePlan (kept untyped: plan_ir imports us lazily)
    electrical_s: float
    optical_s: float
    optical_steps: int
    regime: str = "bandwidth"
    reconfigurations: int = 0


def _order_rank_key(backend: str):
    """Deterministic ranking key: backend time, then regime ("bandwidth"
    sorts first — equal-cost ties resolve to the simpler ring plan), then
    the (stringified — names may be None) order tuple."""
    time_of = {"electrical": lambda c: c.electrical_s,
               "optical": lambda c: c.optical_s}[backend]
    return lambda c: (time_of(c), c.regime, tuple(str(n) for n in c.order))


@dataclass(frozen=True)
class OrderSearch:
    """Result of ``search_stage_orders``: candidates ranked by ``backend``."""

    collective: str
    backend: str
    candidates: Tuple[OrderCandidate, ...]
    capped: bool = False  # True when max_candidates truncated the space
    # AG orders excluded because their lowered schedule would cross a ring
    # direction the health table marks dead (empty when searched healthy)
    pruned: Tuple[Tuple, ...] = ()

    @property
    def best(self) -> OrderCandidate:
        return self.candidates[0]

    def best_by(self, backend: str) -> OrderCandidate:
        """The winner under one backend regardless of the search backend
        (deterministic: time, then order, breaks ties)."""
        return min(self.candidates, key=_order_rank_key(backend))

    @property
    def flipped(self) -> bool:
        """True iff the two worlds GENUINELY disagree: the optical winner
        is a different order than the electrical winner AND strictly
        cheaper under Eq. 3.  Equal-cost candidates rank by the
        deterministic order tie-break, so differing order tuples alone
        (e.g. every stage fits one step at large w) are a tie, not a
        flip."""
        eb = self.best_by("electrical")
        ob = self.best_by("optical")
        return (eb.order != ob.order
                and ob.optical_s < eb.optical_s * (1.0 - 1e-9))

    @property
    def regime_flipped(self) -> bool:
        """True iff the two worlds disagree about the plan FAMILY — one
        backend's winner is a latency (exchange) plan and the other's a
        ring chain, with the optical choice strictly cheaper under Eq. 3
        (same strictness as ``flipped``)."""
        eb = self.best_by("electrical")
        ob = self.best_by("optical")
        return (eb.regime != ob.regime
                and ob.optical_s < eb.optical_s * (1.0 - 1e-9))


def _candidate_factorizations(
    axes: Sequence[Tuple[Optional[str], int, LinkSpec]], max_k: Optional[int]
) -> List[Tuple[Tuple[Optional[str], int, LinkSpec], ...]]:
    """Stage chains to search: every permutation of the given axes; for a
    SINGLE unnamed axis additionally its balanced k-stage factorizations
    (the paper world, where sub-axis stages are executable) — named mesh
    axes are atomic, the engine cannot split a shard_map axis.

    Asking for ``max_k > 1`` sub-axis factorization anywhere else is a
    hard error rather than a silent no-op: a factored stage over a NAMED
    mesh axis (or a multi-axis chain) would name sub-groups no
    ``shard_map`` axis exists for, producing an order the executor cannot
    lower to ppermutes."""
    if max_k is not None and max_k > 1 and not (
            len(axes) == 1 and axes[0][0] is None):
        raise ValueError(
            f"max_k={max_k} sub-axis factorization only applies to a "
            f"single unnamed paper-world axis; got "
            f"{[(a[0], a[1]) for a in axes]} — named mesh axes are atomic "
            "(shard_map cannot split a physical axis into ppermute "
            "sub-stages); drop max_k or search the unnamed single-axis "
            "world")
    base: List[Tuple] = [tuple(p) for p in itertools.permutations(axes)]
    if len(axes) == 1 and axes[0][0] is None and axes[0][1] > 1:
        _, n, link = axes[0]
        kmax = max_k or max(1, math.ceil(math.log2(max(n, 2))))
        seen = {(n,)}
        for k in range(2, kmax + 1):
            factors = tuple(balanced_factors(n, k))
            for perm in set(itertools.permutations(factors)):
                if perm in seen:
                    continue
                seen.add(perm)
                base.append(tuple((None, f, link) for f in perm))
    return base


def search_stage_orders(
    axes: Sequence,
    shard_bytes: float,
    *,
    collective: str = "ag",
    backend: str = "electrical",
    system=None,
    max_chunks: int = 8,
    max_candidates: int = 24,
    max_k: Optional[int] = None,
    packet_bytes: int = TERARACK.packet_bytes,
    health=None,
    include_latency: bool = True,
    reconfig: str = "auto",
) -> OrderSearch:
    """Cross-world stage-order search: enumerate candidate stage
    factorizations/permutations, price each full CollectivePlan through
    BOTH cost backends, rank by ``backend``.

    ``include_latency`` additionally enumerates the recursive-doubling
    exchange family (``plan_latency_collective``'s candidates, one per
    axis permutation, when the collective and sizes admit them) so the
    ranking — and ``meta["order_search"]`` downstream — records REGIME
    flips, not just order flips.  Latency candidates ride outside the
    ``max_candidates`` cap (the family adds at most axes! entries) and
    are all pruned whenever any ring direction is dead: exchange rounds
    are bidirectional.

    ``axes`` entries are ``(name, size, link)`` (name may be None for
    paper-world plans, which then also search balanced factorizations of a
    single axis).  Candidates are AG orders; every registered collective
    derives its execution order from each AG permutation via its chain
    descriptor (RS = reverse, AR = RS order + its reverse, A2A = the order
    itself), so one enumeration covers them all.

    The electrical backend prices each candidate's chosen-mode LinkSpec
    time (== ``choose_hop_schedule``'s decision signal).  The optical
    backend lowers the same plan through ``schedule_from_ir`` and prices
    Eq. 3 on the RWA step count — the stage ORDER changes the step count
    (stage 1 routes on the whole ring, deeper stages inside shrinking
    segments), which is why the two worlds can disagree; on asymmetric
    LinkSpec tables the optical winner is often NOT slow-axis-first.
    ``max_candidates`` caps the enumeration (``OrderSearch.capped`` reports
    truncation); ranking ties break on the order tuple, so results are
    deterministic.

    ``health`` searches the DEGRADED world: axis links are derated by their
    best alive direction before enumeration (a fully dead axis raises
    :class:`~repro.core.health.DeadAxisError`), the optical backend prices
    with the lost-wavelength union removed from ``w``, and any candidate
    whose RWA-lowered schedule crosses a dead ring direction is pruned
    (``OrderSearch.pruned`` lists the excluded orders).  If every candidate
    is pruned, :class:`~repro.core.health.DeadDirectionError` is raised —
    callers fall back to the one-shot collective.

    ``reconfig`` constrains the hold-vs-reconfigure decision on a
    reconfigurable photonic fabric.  ``"auto"`` (default) ranks the full
    space — the per-event ``system.circuit_reconfig_s`` delay (minus any
    SWOT overlap behind the previous stage's in-flight last step) is part
    of each candidate's ``optical_s``, so the ranking itself decides
    whether fewer-steps-plus-delay beats hold-the-circuit.  ``"hold"``
    keeps only candidates with ``reconfigurations == 0`` (one circuit for
    the whole collective); ``"reconfigure"`` keeps only candidates that
    pay at least one topology change.  A constraint that empties a
    non-empty space raises ``ValueError`` (e.g. ``"hold"`` on a
    multi-stage named mesh, where every chain must re-circuit between
    axes).
    """
    from .cost_model import OpticalSystem, price  # lazy: cost_model imports us
    from .schedule import schedule_from_ir  # lazy: avoid a cycle

    if backend not in ("electrical", "optical"):
        raise ValueError(
            f"backend must be electrical|optical, got {backend!r}")
    if reconfig not in ("auto", "hold", "reconfigure"):
        raise ValueError(
            f"reconfig must be auto|hold|reconfigure, got {reconfig!r}")
    norm: List[Tuple[Optional[str], int, LinkSpec]] = []
    for a in axes:
        name, size, link = a
        if health is not None and not health.is_healthy:
            link = health.degrade_link(name, link)
        norm.append((name, int(size), link))
    dead_dirs = (health.dead_directions([a[0] for a in norm])
                 if health is not None else frozenset())
    chains = _candidate_factorizations(norm, max_k)
    capped = len(chains) > max_candidates
    chains = chains[:max_candidates]

    sys = system if system is not None else TERARACK
    if not isinstance(sys, OpticalSystem):
        raise TypeError(f"system must be an OpticalSystem, got {sys!r}")

    cands: List[OrderCandidate] = []
    pruned: List[Tuple] = []
    for chain in chains:
        ag_names = tuple(a[0] for a in chain)
        kind = collective_kind(collective)
        if kind.two_phase:
            exec_chain = tuple(reversed(chain))  # the RS half's order
            rs_names = tuple(reversed(ag_names))
            plan_names = rs_names + tuple(reversed(rs_names))
        elif kind.chain == "reversed":
            exec_chain = tuple(reversed(chain))
            plan_names = tuple(reversed(ag_names))
        else:  # forward: ag, a2a execute the candidate order directly
            exec_chain = chain
            plan_names = ag_names
        sched = choose_hop_schedule(
            [a[1] for a in exec_chain], [a[2] for a in exec_chain],
            shard_bytes, max_chunks=max_chunks, collective=collective,
            packet_bytes=packet_bytes,
        )
        names = plan_names if all(n is not None for n in ag_names) else None
        plan = sched.to_ir(names)
        if dead_dirs:
            lowered = schedule_from_ir(plan, sys.wavelengths, health=health)
            if any(tx.direction in dead_dirs for tx in lowered.txs):
                pruned.append(ag_names)
                continue
        opt = price(plan, sys, health=health)
        cands.append(OrderCandidate(
            order=ag_names,
            plan=plan,
            electrical_s=price(plan).total_s,
            optical_s=opt.total_s,
            optical_steps=opt.steps,
            reconfigurations=opt.reconfigurations,
        ))
    if (include_latency and collective in _LATENCY_COLLECTIVES
            and all(_pow2_exponent(a[1]) is not None for a in norm)
            and math.prod(a[1] for a in norm) >= 2):
        seen_lat = set()
        for perm in itertools.permutations(norm):
            chain = tuple(
                (name, 2, link)
                for name, size, link in perm
                for _ in range(_pow2_exponent(size))
            )
            if chain in seen_lat:
                continue
            seen_lat.add(chain)
            lat_names = tuple(a[0] for a in chain)
            if dead_dirs:
                # every exchange round moves payload both ways around the
                # ring — any dead direction kills the whole family
                pruned.append(lat_names)
                continue
            plan, _ = _latency_plan_for_order(
                chain, shard_bytes, collective,
                canonical_names=[a[0] for a in norm])
            opt = price(plan, sys, health=health)
            cands.append(OrderCandidate(
                order=lat_names,
                plan=plan,
                electrical_s=price(plan).total_s,
                optical_s=opt.total_s,
                optical_steps=opt.steps,
                regime="latency",
                reconfigurations=opt.reconfigurations,
            ))
    if reconfig != "auto" and cands:
        keep = [c for c in cands
                if (c.reconfigurations == 0) == (reconfig == "hold")]
        if not keep:
            counts = sorted({c.reconfigurations for c in cands})
            raise ValueError(
                f"reconfig={reconfig!r} excludes every {collective} "
                f"candidate: the searched space has reconfiguration "
                f"counts {counts} only (a multi-stage named mesh must "
                "re-circuit between axes, so 'hold' needs a single-stage "
                "or single-axis world); use reconfig='auto'")
        cands = keep
    if not cands:
        from .health import DeadDirectionError  # lazy: avoid a cycle
        raise DeadDirectionError(
            f"every {collective} stage-order candidate crosses a dead ring "
            f"direction {sorted(dead_dirs)} "
            f"(pruned {len(pruned)} orders: {pruned[:4]}...); fall back to "
            "the one-shot collective")
    cands.sort(key=_order_rank_key(backend))
    return OrderSearch(collective=collective, backend=backend,
                       candidates=tuple(cands), capped=capped,
                       pruned=tuple(pruned))


# --------------------------------------------------------------------------
# collective-matmul fusion (gather/compute overlap)
# --------------------------------------------------------------------------

def matmul_block_time(
    rows: int, inner: int, cols: int, *, peak_flops: float = MXU_PEAK_FLOPS
) -> float:
    """Roofline time for one (rows × inner) @ (inner × cols) block matmul."""
    return 2.0 * rows * inner * cols / peak_flops


@dataclass(frozen=True)
class FusedMatmulPlan:
    """Fuse-or-not decision for all-gather→matmul / matmul→reduce-scatter.

    ``fused_time_s`` models the per-hop schedule where each gathered (or
    about-to-be-scattered) block's matmul runs while the next hop is in
    flight; ``unfused_time_s`` is the blocking collective followed (or
    preceded) by one full matmul.  ``hidden_comm_s`` is the transfer time the
    fused schedule hides behind compute.
    """

    fuse: bool
    fused_time_s: float
    unfused_time_s: float
    hidden_comm_s: float


def plan_collective_matmul(
    factors: Sequence[int],
    links: Sequence[LinkSpec],
    shard_bytes: float,
    block_compute_s: float,
    *,
    kernel_alpha_s: float = 2e-6,
) -> FusedMatmulPlan:
    """Decide whether to decompose a gather-adjacent matmul per hop.

    ``block_compute_s`` is the matmul time for ONE device block (the
    scattered shard's worth of rows); ``kernel_alpha_s`` is the per-block
    launch/efficiency penalty of running N skinny matmuls instead of one wide
    one — the only force that can make fusion lose under this model.

    Fused schedule over the AG stage chain (payload and blocks-per-hop grow
    stage by stage): each hop's transfer runs concurrently with the matmul of
    the blocks the *previous* hop delivered, so a stage costs
    ``(f-1)·max(hop, blocks·t_blk)`` and only the final delivery's matmul is
    exposed.  Applies symmetrically to the reduce-scatter dual (just-in-time
    block matmuls feeding the ring).
    """
    t_blk = block_compute_s + kernel_alpha_s
    n = math.prod(factors)

    payload = float(shard_bytes)
    blocks = 1  # device blocks carried per hop at this stage
    fused = block_compute_s  # local block's matmul (overlaps the first send)
    comm = 0.0
    exposed_comm = 0.0
    trailing_blocks = 0  # per-hop block count of the last stage with hops
    for f, link in zip(factors, links):
        if f <= 1:
            continue
        hop = link.alpha_s + payload / link.bandwidth_bytes
        fused += (f - 1) * max(hop, blocks * t_blk)
        comm += (f - 1) * hop
        exposed_comm += (f - 1) * max(0.0, hop - blocks * t_blk)
        trailing_blocks = blocks
        payload *= f
        blocks *= f
    # the last hop's delivery is multiplied after the wire goes quiet
    fused += trailing_blocks * t_blk

    unfused = comm + n * block_compute_s
    return FusedMatmulPlan(
        fuse=fused < unfused,
        fused_time_s=fused,
        unfused_time_s=unfused,
        hidden_comm_s=comm - exposed_comm,
    )
