"""Schedule validators: the correctness oracle for every schedule builder.

A schedule is *valid* iff:
  1. conflict-freedom — within a step, no two lightpaths share a
     (direction, link) on the same wavelength, and wavelength < w;
  2. causality — a node only transmits items it holds when the step begins;
  3. completeness — afterwards every node holds its collective's target set.

``sched.meta["semantics"]`` selects the item model, exactly as in
``optics.simulator``: ``"gather"`` (the default) starts node i holding
item i and requires every node to end with all n items; ``"exchange"``
(a2a) uses the n² (origin, destination) item space ``u·n + v`` — node u
starts holding ``{u·n + v : v}`` and node v must end holding
``{u·n + v : u}``.

These checks are what the hypothesis property tests sweep.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Set, Tuple

from .schedule import Schedule, Tx

__all__ = [
    "validate_conflict_free",
    "validate_causality_completeness",
    "validate_schedule",
]


class ScheduleError(AssertionError):
    pass


def validate_conflict_free(sched: Schedule) -> None:
    for step_txs in sched.by_step():
        seen: Set[Tuple[int, int, int]] = set()
        for tx in step_txs:
            if not (0 <= tx.wavelength < sched.w):
                raise ScheduleError(
                    f"wavelength {tx.wavelength} out of range w={sched.w}: {tx}"
                )
            for link in tx.links:
                key = (tx.direction, link, tx.wavelength)
                if key in seen:
                    raise ScheduleError(
                        f"wavelength conflict at step {tx.step}: "
                        f"(dir={tx.direction}, link={link}, wl={tx.wavelength})"
                    )
                seen.add(key)


def validate_causality_completeness(sched: Schedule) -> None:
    exchange = sched.meta.get("semantics") == "exchange"
    if exchange:
        holdings: List[Set[int]] = [
            {u * sched.n + v for v in range(sched.n)} for u in range(sched.n)
        ]
    else:
        holdings = [{i} for i in range(sched.n)]
    for step_txs in sched.by_step():
        arrivals: Dict[int, Set[int]] = defaultdict(set)
        for tx in step_txs:
            if tx.item not in holdings[tx.src]:
                raise ScheduleError(
                    f"causality violation: node {tx.src} sends item {tx.item} "
                    f"it does not hold at step {tx.step}"
                )
            arrivals[tx.dst].add(tx.item)
        for dst, items in arrivals.items():
            holdings[dst] |= items
    for p, h in enumerate(holdings):
        need = ({u * sched.n + p for u in range(sched.n)} if exchange
                else set(range(sched.n)))
        missing = sorted(need - h)
        if missing:
            raise ScheduleError(
                f"incomplete {'all-to-all' if exchange else 'all-gather'}: "
                f"node {p} missing items {missing[:8]}"
                f"{'...' if len(missing) > 8 else ''}"
            )


def validate_schedule(sched: Schedule) -> None:
    validate_conflict_free(sched)
    validate_causality_completeness(sched)
