"""Schedule validators: the correctness oracle for every schedule builder.

A schedule is a *valid all-gather* iff:
  1. conflict-freedom — within a step, no two lightpaths share a
     (direction, link) on the same wavelength, and wavelength < w;
  2. causality — a node only transmits items it holds when the step begins;
  3. completeness — afterwards every node holds all n items.

These three checks are what the hypothesis property tests sweep.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Set, Tuple

from .schedule import Schedule, Tx

__all__ = [
    "validate_conflict_free",
    "validate_causality_completeness",
    "validate_schedule",
]


class ScheduleError(AssertionError):
    pass


def validate_conflict_free(sched: Schedule) -> None:
    for step_txs in sched.by_step():
        seen: Set[Tuple[int, int, int]] = set()
        for tx in step_txs:
            if not (0 <= tx.wavelength < sched.w):
                raise ScheduleError(
                    f"wavelength {tx.wavelength} out of range w={sched.w}: {tx}"
                )
            for link in tx.links:
                key = (tx.direction, link, tx.wavelength)
                if key in seen:
                    raise ScheduleError(
                        f"wavelength conflict at step {tx.step}: "
                        f"(dir={tx.direction}, link={link}, wl={tx.wavelength})"
                    )
                seen.add(key)


def validate_causality_completeness(sched: Schedule) -> None:
    holdings: List[Set[int]] = [{i} for i in range(sched.n)]
    for step_txs in sched.by_step():
        arrivals: Dict[int, Set[int]] = defaultdict(set)
        for tx in step_txs:
            if tx.item not in holdings[tx.src]:
                raise ScheduleError(
                    f"causality violation: node {tx.src} sends item {tx.item} "
                    f"it does not hold at step {tx.step}"
                )
            arrivals[tx.dst].add(tx.item)
        for dst, items in arrivals.items():
            holdings[dst] |= items
    for p, h in enumerate(holdings):
        if len(h) != sched.n:
            missing = sorted(set(range(sched.n)) - h)
            raise ScheduleError(
                f"incomplete all-gather: node {p} missing items {missing[:8]}"
                f"{'...' if len(missing) > 8 else ''}"
            )


def validate_schedule(sched: Schedule) -> None:
    validate_conflict_free(sched)
    validate_causality_completeness(sched)
