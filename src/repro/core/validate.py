"""Schedule validators: the correctness oracle for every schedule builder.

A schedule is *valid* iff:
  1. conflict-freedom — within a step, no two lightpaths share a
     (direction, link) on the same wavelength, and wavelength < w.  The
     one sanctioned sharing is a same-pair BURST: transmissions between
     the same (src, dst) may ride one wavelength together (exchange
     stages serialize a pair's items over a single lightpath — the cost
     model charges the step for the whole burst);
  2. causality — a node only transmits items it holds when the step begins;
  3. completeness — afterwards every node holds its collective's target set.
  4. health (optional) — no transmission rides a lost wavelength or a dead
     ring direction of the :class:`~repro.core.health.LinkHealth` it is
     checked against (``schedule_from_ir(..., health=...)`` schedules
     *around* faults; this check is the defense in depth that catches a
     builder that does not).

``sched.meta["semantics"]`` selects the item model, exactly as in
``optics.simulator``: ``"gather"`` (the default) starts node i holding
item i and requires every node to end with all n items; ``"exchange"``
(a2a) uses the n² (origin, destination) item space ``u·n + v`` — node u
starts holding ``{u·n + v : v}`` and node v must end holding ``{u·n + v :
u}``.

These checks are what the hypothesis property tests sweep.  Error messages
name the offending (step, link, wavelength, health state) so a failed
chaos run points straight at the bad transmission.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Set, Tuple

from .schedule import Schedule, Tx

__all__ = [
    "validate_conflict_free",
    "validate_causality_completeness",
    "validate_health",
    "validate_schedule",
]

_DIR_NAMES = {0: "cw", 1: "ccw"}


class ScheduleError(AssertionError):
    pass


def _tx_where(tx: Tx) -> str:
    return (f"step {tx.step}, {tx.src}->{tx.dst} "
            f"dir={_DIR_NAMES.get(tx.direction, tx.direction)} "
            f"wl={tx.wavelength} links={list(tx.links)}")


def validate_conflict_free(sched: Schedule) -> None:
    for step_txs in sched.by_step():
        seen: Dict[Tuple[int, int, int], Tx] = {}
        for tx in step_txs:
            if not (0 <= tx.wavelength < sched.w):
                raise ScheduleError(
                    f"wavelength {tx.wavelength} out of range w={sched.w} "
                    f"at {_tx_where(tx)}"
                )
            for link in tx.links:
                key = (tx.direction, link, tx.wavelength)
                if key in seen:
                    other = seen[key]
                    # same-pair burst: one lightpath serializing several
                    # items between one (src, dst) is not a conflict
                    if (other.src, other.dst) == (tx.src, tx.dst):
                        continue
                    raise ScheduleError(
                        f"wavelength conflict at step {tx.step}: link {link} "
                        f"(dir={_DIR_NAMES.get(tx.direction, tx.direction)}, "
                        f"wl={tx.wavelength}) carried by both "
                        f"{other.src}->{other.dst} (item {other.item}) and "
                        f"{tx.src}->{tx.dst} (item {tx.item})"
                    )
                seen[key] = tx


def validate_causality_completeness(sched: Schedule) -> None:
    exchange = sched.meta.get("semantics") == "exchange"
    if exchange:
        holdings: List[Set[int]] = [
            {u * sched.n + v for v in range(sched.n)} for u in range(sched.n)
        ]
    else:
        holdings = [{i} for i in range(sched.n)]
    for step_txs in sched.by_step():
        arrivals: Dict[int, Set[int]] = defaultdict(set)
        for tx in step_txs:
            if tx.item not in holdings[tx.src]:
                raise ScheduleError(
                    f"causality violation at {_tx_where(tx)}: node {tx.src} "
                    f"sends item {tx.item} it does not hold when the step "
                    f"begins (holds {len(holdings[tx.src])} items)"
                )
            arrivals[tx.dst].add(tx.item)
        for dst, items in arrivals.items():
            holdings[dst] |= items
    for p, h in enumerate(holdings):
        need = ({u * sched.n + p for u in range(sched.n)} if exchange
                else set(range(sched.n)))
        missing = sorted(need - h)
        if missing:
            raise ScheduleError(
                f"incomplete {'all-to-all' if exchange else 'all-gather'}: "
                f"node {p} missing items {missing[:8]}"
                f"{'...' if len(missing) > 8 else ''}"
            )


def validate_health(sched: Schedule, health) -> None:
    """Reject any transmission on a lost wavelength or a dead ring
    direction of ``health``.  The axis scope comes from
    ``sched.meta["axes"]`` (stamped by ``schedule_from_ir``); schedules
    without it are checked against the union over the whole health table —
    the conservative reading of a shared ring."""
    if health is None or health.is_healthy:
        return
    axes = sched.meta.get("axes")
    lost = health.lost_for(axes)
    dead = health.dead_directions(axes)
    for tx in sched.txs:
        if tx.wavelength in lost:
            raise ScheduleError(
                f"transmission on LOST wavelength at {_tx_where(tx)}: "
                f"health says wavelengths {sorted(lost)} are down for axes "
                f"{list(axes) if axes else '<all>'} ({health.describe()})"
            )
        if tx.direction in dead:
            raise ScheduleError(
                f"transmission on DEAD ring direction at {_tx_where(tx)}: "
                f"health says direction "
                f"{_DIR_NAMES.get(tx.direction, tx.direction)} is dead for "
                f"axes {list(axes) if axes else '<all>'} "
                f"({health.describe()})"
            )


def validate_schedule(sched: Schedule,
                      health=None) -> None:
    validate_conflict_free(sched)
    validate_causality_completeness(sched)
    if health is not None:
        validate_health(sched, health)
