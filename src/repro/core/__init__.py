"""OpTree core: m-ary tree all-gather scheduling (paper §III) + TPU planner."""
from .tree import (  # noqa: F401
    OpTreePlan,
    balanced_factors,
    optimal_depth_argmin,
    optimal_depth_thm2,
)
from .steps import (  # noqa: F401
    lemma1_wavelengths_line,
    lemma1_wavelengths_ring,
    neighbor_exchange_steps,
    one_stage_steps,
    optree_optimal_steps,
    optree_steps_exact,
    optree_steps_thm1,
    ring_steps,
    table1,
    wrht_steps_formula,
    wrht_steps_paper_table,
)
from .schedule import (  # noqa: F401
    Schedule,
    Tx,
    build_ne_schedule,
    build_one_stage_schedule,
    build_optree_schedule,
    build_ring_schedule,
    schedule_from_ir,
)
from .validate import validate_health, validate_schedule  # noqa: F401
from .health import (  # noqa: F401
    DeadAxisError,
    DeadDirectionError,
    FaultEvent,
    FaultTrace,
    HealthError,
    LinkHealth,
    health_fingerprint,
    load_health,
)
from .cost_model import (  # noqa: F401
    TERARACK,
    CircuitReconfig,
    OpticalSystem,
    PriceReport,
    allgather_time,
    derive_wavelengths,
    eq3_time,
    price,
    step_time,
    transfer_time,
)
from .plan_ir import (  # noqa: F401
    COLLECTIVES,
    CollectiveKind,
    CollectivePlan,
    Hop,
    PlanStage,
    Transfer,
    collective_kind,
    expand_hops,
    optical_message_bytes,
)
from .planner import (  # noqa: F401
    DCN_LINK,
    ICI_LINK,
    AllGatherPlan,
    HopSchedule,
    LinkSpec,
    OrderCandidate,
    OrderSearch,
    choose_hop_schedule,
    load_links,
    plan_axis_order,
    plan_staged_allgather,
    search_stage_orders,
)
