"""Link/wavelength health: the degraded-hardware planning input.

Every other tier assumes the fabric it was priced against: the electrical
pricer assumes each axis link delivers its full ``LinkSpec`` bandwidth, the
Eq.-3/RWA backend assumes all ``w`` wavelengths of the ring are lit, and
the executor assumes every ppermute hop lands.  :class:`LinkHealth` makes
the *actual* hardware state a first-class value that planning, pricing,
lowering, validation, and the plan cache all consume:

  * per-(axis, direction) bandwidth **derating** in ``(0, 1]`` — a flaky
    transceiver at quarter speed is ``derate[("pod", CW)] = 0.25``;
  * **dead** (axis, direction) pairs — a cut fiber.  An axis with both
    directions dead cannot carry a staged collective at all
    (:class:`DeadAxisError`); a single dead direction prunes stage orders
    whose lowered schedule would cross it;
  * per-axis **lost-wavelength masks** — failed ring lasers / MRR columns.
    The WDM ring is a shared medium, so the effective wavelength count for
    a plan is ``w`` minus the union of losses over the plan's axes.

``LinkHealth`` is immutable; fault/recover events produce new tables via
:meth:`LinkHealth.apply`.  :meth:`LinkHealth.fingerprint` gives the short
stable hash the comms-context plan cache keys on (the "health fingerprint"
— a fault therefore *automatically* invalidates every cached plan priced
against the old world).  :class:`FaultTrace` is a deterministic, seeded
sequence of :class:`FaultEvent` for chaos-injection harnesses: the same
seed always reproduces the same fault schedule.

JSON round-trips reuse the ``load_links`` ``expect_axes`` idiom from
:mod:`repro.core.planner`: unknown axes are rejected with the same error
shape, and derates outside ``(0, 1]`` never load.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import random
from dataclasses import dataclass, field
from typing import (Dict, FrozenSet, Iterable, Mapping, Optional, Sequence,
                    Tuple)

__all__ = [
    "CW",
    "CCW",
    "DIRECTIONS",
    "HealthError",
    "DeadAxisError",
    "DeadDirectionError",
    "FaultEvent",
    "FaultTrace",
    "LinkHealth",
    "health_fingerprint",
    "load_health",
]

# mirrors core.schedule: direction 0 is clockwise (+1 neighbor), 1 is ccw
CW, CCW = 0, 1
DIRECTIONS = (CW, CCW)
_DIR_NAMES = {CW: "cw", CCW: "ccw"}


class HealthError(ValueError):
    """A plan cannot be produced under the current :class:`LinkHealth`."""


class DeadAxisError(HealthError):
    """Both directions of a required axis are dead — no staged plan can
    cross it; callers fall back to the one-shot XLA collective."""


class DeadDirectionError(HealthError):
    """Every stage-order candidate was pruned because its lowered schedule
    crosses a dead ring direction."""


def _check_direction(direction: int) -> int:
    if direction not in DIRECTIONS:
        raise ValueError(
            f"direction must be {CW} (cw) or {CCW} (ccw), got {direction!r}")
    return int(direction)


def _check_derate(value: float) -> float:
    value = float(value)
    if not (0.0 < value <= 1.0):
        raise ValueError(
            f"derate must be in (0, 1], got {value!r} "
            "(use kind='dead' for a fully failed direction)")
    return value


@dataclass(frozen=True)
class FaultEvent:
    """One fault or recovery, attributed to a training step.

    ``kind`` is one of:
      * ``"derate"``    — set ``derate`` for ``(axis, direction)``;
      * ``"dead"``      — mark ``(axis, direction)`` dead;
      * ``"lose_wavelength"`` — add ``wavelength`` to the axis's lost mask;
      * ``"recover"``   — clear state: the ``(axis, direction)`` entry when
        ``direction`` is given, the wavelength when ``wavelength`` is
        given, or everything recorded for ``axis`` when neither is.
    """

    step: int
    kind: str
    axis: str
    direction: Optional[int] = None
    derate: Optional[float] = None
    wavelength: Optional[int] = None

    def __post_init__(self) -> None:
        if self.kind not in ("derate", "dead", "lose_wavelength", "recover"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "derate":
            if self.derate is None:
                raise ValueError("kind='derate' requires a derate value")
            _check_derate(self.derate)
            _check_direction(self._dir())
        elif self.kind == "dead":
            _check_direction(self._dir())
        elif self.kind == "lose_wavelength":
            if self.wavelength is None or int(self.wavelength) < 0:
                raise ValueError(
                    "kind='lose_wavelength' requires wavelength >= 0")
        if self.direction is not None:
            _check_direction(self.direction)

    def _dir(self) -> int:
        return CW if self.direction is None else self.direction

    def describe(self) -> str:
        d = "" if self.direction is None else f"/{_DIR_NAMES[self.direction]}"
        extra = ""
        if self.kind == "derate":
            extra = f" x{self.derate:g}"
        elif self.kind == "lose_wavelength":
            extra = f" wl={self.wavelength}"
        return f"step {self.step}: {self.kind} {self.axis}{d}{extra}"


def _freeze_derate(m: Mapping[Tuple[str, int], float]
                   ) -> Tuple[Tuple[Tuple[str, int], float], ...]:
    out = []
    for (axis, direction), val in m.items():
        out.append(((str(axis), _check_direction(direction)),
                    _check_derate(val)))
    return tuple(sorted(out))


def _freeze_dead(s: Iterable[Tuple[str, int]]
                 ) -> Tuple[Tuple[str, int], ...]:
    return tuple(sorted(
        (str(axis), _check_direction(direction)) for axis, direction in s))


def _freeze_lost(m: Mapping[str, Iterable[int]]
                 ) -> Tuple[Tuple[str, Tuple[int, ...]], ...]:
    out = []
    for axis, wls in m.items():
        wl_t = tuple(sorted({int(w) for w in wls}))
        if any(w < 0 for w in wl_t):
            raise ValueError(f"lost wavelength must be >= 0 on axis {axis!r}")
        if wl_t:
            out.append((str(axis), wl_t))
    return tuple(sorted(out))


@dataclass(frozen=True)
class LinkHealth:
    """Immutable health table.  Empty (the default) means fully healthy."""

    derate: Tuple[Tuple[Tuple[str, int], float], ...] = ()
    dead: Tuple[Tuple[str, int], ...] = ()
    lost_wavelengths: Tuple[Tuple[str, Tuple[int, ...]], ...] = ()

    # ---------------------------------------------------------- constructors
    @staticmethod
    def healthy() -> "LinkHealth":
        return LinkHealth()

    @staticmethod
    def make(*,
             derate: Optional[Mapping[Tuple[str, int], float]] = None,
             dead: Optional[Iterable[Tuple[str, int]]] = None,
             lost_wavelengths: Optional[Mapping[str, Iterable[int]]] = None,
             ) -> "LinkHealth":
        return LinkHealth(
            derate=_freeze_derate(derate or {}),
            dead=_freeze_dead(dead or ()),
            lost_wavelengths=_freeze_lost(lost_wavelengths or {}),
        )

    def __post_init__(self) -> None:
        # normalize through the checked freezers so hand-built instances and
        # dataclasses.replace go through the same validation
        object.__setattr__(self, "derate", _freeze_derate(dict(self.derate)))
        object.__setattr__(self, "dead", _freeze_dead(self.dead))
        object.__setattr__(
            self, "lost_wavelengths",
            _freeze_lost({a: wls for a, wls in self.lost_wavelengths}))

    # --------------------------------------------------------------- queries
    @property
    def is_healthy(self) -> bool:
        return not (self.derate or self.dead or self.lost_wavelengths)

    def _derate_map(self) -> Dict[Tuple[str, int], float]:
        return dict(self.derate)

    def _dead_set(self) -> FrozenSet[Tuple[str, int]]:
        return frozenset(self.dead)

    def _lost_map(self) -> Dict[str, FrozenSet[int]]:
        return {a: frozenset(wls) for a, wls in self.lost_wavelengths}

    def axis_dead(self, axis: str) -> bool:
        dead = self._dead_set()
        return all((axis, d) in dead for d in DIRECTIONS)

    def axis_factor(self, axis: Optional[str]) -> float:
        """Best usable bandwidth fraction over the axis's alive directions
        (the planner routes around a single dead direction).  0.0 iff both
        directions are dead.  Unnamed axes (paper-world plans) are assumed
        healthy."""
        if axis is None:
            return 1.0
        dead, derate = self._dead_set(), self._derate_map()
        alive = [derate.get((axis, d), 1.0)
                 for d in DIRECTIONS if (axis, d) not in dead]
        return max(alive) if alive else 0.0

    def direction_factor(self, axis: str, direction: int) -> float:
        if (axis, direction) in self._dead_set():
            return 0.0
        return self._derate_map().get((axis, direction), 1.0)

    def dead_directions(self, axes: Optional[Sequence[Optional[str]]] = None
                        ) -> FrozenSet[int]:
        """Ring directions unusable for a plan spanning ``axes``: the union
        of dead directions over the named axes (the physical ring is
        shared).  ``axes=None`` — or any unnamed axis — unions over every
        axis in the table."""
        dead = self._dead_set()
        if axes is None or any(a is None for a in axes):
            return frozenset(d for _, d in dead)
        wanted = set(axes)
        return frozenset(d for a, d in dead if a in wanted)

    def lost_for(self, axes: Optional[Sequence[Optional[str]]] = None
                 ) -> FrozenSet[int]:
        """Lost-wavelength union for a plan spanning ``axes`` (shared WDM
        ring); ``axes=None`` or an unnamed axis unions everything."""
        lost = self._lost_map()
        if axes is None or any(a is None for a in axes):
            axes_iter: Iterable[str] = lost.keys()
        else:
            axes_iter = [a for a in axes if a in lost]
        out: FrozenSet[int] = frozenset()
        for a in axes_iter:
            out |= lost.get(a, frozenset())
        return out

    def degrade_link(self, axis: Optional[str], link):
        """LinkSpec with bandwidth scaled by :meth:`axis_factor`.  Raises
        :class:`DeadAxisError` when the axis has no alive direction."""
        f = self.axis_factor(axis)
        if f <= 0.0:
            raise DeadAxisError(
                f"axis {axis!r} is dead in both ring directions; no staged "
                "plan can cross it (fall back to the one-shot collective)")
        if f >= 1.0:
            return link
        return dataclasses.replace(
            link, bandwidth_bytes=link.bandwidth_bytes * f)

    def degrade_links(self, links: Mapping[str, object]) -> Dict[str, object]:
        return {a: self.degrade_link(a, l) for a, l in links.items()}

    # ---------------------------------------------------------------- events
    def apply(self, event: FaultEvent) -> "LinkHealth":
        derate, dead = self._derate_map(), set(self._dead_set())
        lost = {a: set(wls) for a, wls in self._lost_map().items()}
        key = (event.axis, event._dir())
        if event.kind == "derate":
            derate[key] = float(event.derate)
            dead.discard(key)
        elif event.kind == "dead":
            dead.add(key)
            derate.pop(key, None)
        elif event.kind == "lose_wavelength":
            lost.setdefault(event.axis, set()).add(int(event.wavelength))
        elif event.kind == "recover":
            if event.wavelength is not None:
                lost.get(event.axis, set()).discard(int(event.wavelength))
            elif event.direction is not None:
                derate.pop(key, None)
                dead.discard(key)
            else:
                for d in DIRECTIONS:
                    derate.pop((event.axis, d), None)
                    dead.discard((event.axis, d))
                lost.pop(event.axis, None)
        return LinkHealth.make(derate=derate, dead=dead,
                               lost_wavelengths=lost)

    # ----------------------------------------------------------- fingerprint
    def fingerprint(self) -> str:
        """Short stable id of the health state: ``"healthy"`` for the empty
        table, else 16 hex chars.  Goes into the plan-cache key so a fault
        invalidates every plan priced under the old world."""
        if self.is_healthy:
            return "healthy"
        canon = repr((self.derate, self.dead, self.lost_wavelengths))
        return hashlib.sha1(canon.encode()).hexdigest()[:16]

    def describe(self) -> str:
        if self.is_healthy:
            return "healthy"
        parts = []
        for (a, d), v in self.derate:
            parts.append(f"{a}/{_DIR_NAMES[d]} x{v:g}")
        for a, d in self.dead:
            parts.append(f"{a}/{_DIR_NAMES[d]} dead")
        for a, wls in self.lost_wavelengths:
            parts.append(f"{a} lost wl {list(wls)}")
        return "; ".join(parts)

    # ------------------------------------------------------------------ json
    def to_json(self) -> dict:
        return {
            "derate": [[a, _DIR_NAMES[d], v] for (a, d), v in self.derate],
            "dead": [[a, _DIR_NAMES[d]] for a, d in self.dead],
            "lost_wavelengths": {a: list(wls)
                                 for a, wls in self.lost_wavelengths},
        }

    @staticmethod
    def from_json(d: Mapping, *,
                  expect_axes: Optional[Sequence[str]] = None) -> "LinkHealth":
        """Inverse of :meth:`to_json` with validation.  ``expect_axes``
        follows the ``load_links`` idiom: every axis named by the table must
        be a known mesh axis (health is sparse, so *missing* axes are fine —
        they are simply healthy)."""
        if not isinstance(d, Mapping):
            raise ValueError(f"health table must be a mapping, got {type(d)}")
        unknown_keys = set(d) - {"derate", "dead", "lost_wavelengths"}
        if unknown_keys:
            raise ValueError(
                f"unknown health table keys {sorted(unknown_keys)}")
        dir_ids = {"cw": CW, "ccw": CCW, "0": CW, "1": CCW}

        def as_dir(v) -> int:
            if isinstance(v, str):
                if v not in dir_ids:
                    raise ValueError(
                        f"direction must be 'cw' or 'ccw', got {v!r}")
                return dir_ids[v]
            return _check_direction(int(v))

        derate: Dict[Tuple[str, int], float] = {}
        for entry in d.get("derate", []):
            axis, direction, val = entry
            derate[(str(axis), as_dir(direction))] = _check_derate(val)
        dead = {(str(a), as_dir(dd)) for a, dd in d.get("dead", [])}
        lost = {str(a): [int(w) for w in wls]
                for a, wls in d.get("lost_wavelengths", {}).items()}
        health = LinkHealth.make(derate=derate, dead=dead,
                                 lost_wavelengths=lost)
        if expect_axes is not None:
            expect = set(expect_axes)
            named = ({a for (a, _), _ in health.derate}
                     | {a for a, _ in health.dead}
                     | {a for a, _ in health.lost_wavelengths})
            unknown = sorted(named - expect)
            if unknown:
                raise ValueError(
                    f"health table does not match axes {sorted(expect)}: "
                    f"unknown axes {unknown}")
        return health


def health_fingerprint(health: Optional[LinkHealth]) -> str:
    """Cache-key fingerprint; ``None`` is the healthy world."""
    return "healthy" if health is None else health.fingerprint()


def load_health(path, *,
                expect_axes: Optional[Sequence[str]] = None) -> LinkHealth:
    """Read a :meth:`LinkHealth.to_json` file from disk."""
    with open(path) as f:
        return LinkHealth.from_json(json.load(f), expect_axes=expect_axes)


@dataclass(frozen=True)
class FaultTrace:
    """A deterministic fault schedule: ``events`` ordered by step.

    :meth:`generate` derives the whole trace from a seed via
    ``random.Random(seed)`` — no global RNG, so the same seed reproduces
    the identical fault/recover sequence in every process of a chaos run.
    """

    events: Tuple[FaultEvent, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: e.step)))

    @staticmethod
    def generate(axes: Sequence[str], steps: int, *, seed: int,
                 rate: float = 0.1, wavelengths: int = 64,
                 allow_dead: bool = False,
                 recover_after: int = 2) -> "FaultTrace":
        """Seeded trace: each step faults with probability ``rate``; a
        matching recovery is scheduled ``recover_after`` steps later (so
        traces exercise both directions of the cache-invalidation path).
        ``allow_dead`` adds whole-direction kills to the event mix."""
        rng = random.Random(seed)
        kinds = ["derate", "derate", "lose_wavelength"]
        if allow_dead:
            kinds.append("dead")
        events = []
        for step in range(steps):
            if rng.random() >= rate:
                continue
            axis = rng.choice(list(axes))
            kind = rng.choice(kinds)
            if kind == "derate":
                ev = FaultEvent(step, "derate", axis,
                                direction=rng.choice(DIRECTIONS),
                                derate=rng.choice([0.25, 0.5, 0.75]))
                rec = FaultEvent(step + recover_after, "recover", axis,
                                 direction=ev.direction)
            elif kind == "lose_wavelength":
                wl = rng.randrange(wavelengths)
                ev = FaultEvent(step, "lose_wavelength", axis, wavelength=wl)
                rec = FaultEvent(step + recover_after, "recover", axis,
                                 wavelength=wl)
            else:
                ev = FaultEvent(step, "dead", axis,
                                direction=rng.choice(DIRECTIONS))
                rec = FaultEvent(step + recover_after, "recover", axis,
                                 direction=ev.direction)
            events.append(ev)
            events.append(rec)
        return FaultTrace(events=tuple(events), seed=seed)

    def at(self, step: int) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.step == step)

    def apply_step(self, health: LinkHealth, step: int) -> LinkHealth:
        for ev in self.at(step):
            health = health.apply(ev)
        return health

    def replay(self, step: int) -> LinkHealth:
        """Health table after folding every event with ``event.step <=
        step`` into the healthy world."""
        health = LinkHealth()
        for ev in self.events:
            if ev.step <= step:
                health = health.apply(ev)
        return health
