"""Unified CollectivePlan IR — ONE plan object from scheduler to executor.

The repo used to hold two disjoint plan worlds: the paper side
(``core.tree.OpTreePlan`` → ``core.schedule`` Tx lightpaths → the Eq.-3
optical simulator) and the engine side (``core.planner`` stage plans →
``comms`` shard_map executors), each priced by its own cost model.  This
module is the bridge: a single IR

    CollectivePlan
      └─ PlanStage(factor, axis, link, mode ∈ {oneshot, perhop})
           └─ Hop
                └─ Transfer(src, dst, item, bytes)

with builders from both worlds (``OpTreePlan.to_ir()``,
``HopSchedule.to_ir()``) and consumers in all four layers:

  * ``core.cost_model.price(plan, model)`` — one pricing entry point for
    the LinkSpec alpha/bandwidth model AND the paper's optical Eq.-3 model;
  * ``core.schedule.schedule_from_ir(plan, w)`` — lowers a plan to Tx
    lightpaths for step-accurate, conflict-checked validation in
    ``optics.simulator.simulate``;
  * ``comms.plan_executor.execute_plan`` — the JAX executor interprets the
    plan's stages directly (no re-derivation, no drift);
  * ``launch/perf.py --collectives`` / ``benchmarks/run.py`` — report
    modeled-electrical, modeled-optical and measured time off the same
    plan object.

Semantics.  ``stages`` are in EXECUTION order.  A plan with factors
(f_1..f_k) places participant p at ring/mixed-radix position with the
first-executed factor most significant, which makes the transfer structure
of an all-gather plan literally ``OpTreePlan(n, factors)``: stage j gathers
coordinate c_j inside "same position across siblings" subsets.  The dual
collectives reuse the gather algebra by time reversal: a reduce-scatter's
transfer structure is the mirrored all-gather run backwards (identical hop
and step counts — see ``optics/comparison.py``), an all-reduce is RS then
AG.

``PlanStage.mode`` is the hop structure: ``"oneshot"`` — the stage is one
synchronized all-to-all round (paper §III-D; XLA blocking collective on the
engine side); ``"perhop"`` — the stage runs as ``factor-1`` double-buffered
ring hops (``comms.ring_executor``).  ``CollectivePlan.mode`` is the
plan-level execution decision (``oneshot`` / ``chunked`` / ``perhop`` /
``hybrid``); ``num_chunks`` carries the wavefront chunk count for the
chunked and hybrid modes.  ``hybrid`` is the perhop-chunked combination:
the C-chunk wavefront flows OVER per-hop ring stages, so each pipeline
stage is the overlapped ring (or the blocking collective where the stage's
hop structure says ``oneshot``) on a 1/C-payload chunk — dominated by
neither pure mode, never worse than either (the makespan of elementwise-
smaller stage times over the same chunk candidates).
Hops/transfers are materialized lazily (``expand_hops``) — consumers that
only price or execute a plan never pay the O(N^2) enumeration.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .tree import OpTreePlan

__all__ = [
    "Transfer",
    "Hop",
    "PlanStage",
    "CollectivePlan",
    "expand_hops",
    "stage_hops",
    "gather_chain",
    "effective_stage_mode",
]

STAGE_MODES = ("oneshot", "perhop")
PLAN_MODES = ("oneshot", "chunked", "perhop", "hybrid")


@dataclass(frozen=True)
class Transfer:
    """One logical block movement: ``src`` sends origin-block ``item`` to
    ``dst``.  ``bytes`` is the block size (the scattered shard d)."""

    src: int
    dst: int
    item: int
    bytes: float


@dataclass(frozen=True)
class Hop:
    """One synchronized communication round within a stage.  A ``oneshot``
    stage has exactly one hop (the all-to-all broadcast); a ``perhop``
    stage has ``factor - 1`` ring hops, each causally after the previous."""

    transfers: Tuple[Transfer, ...]


@dataclass(frozen=True)
class PlanStage:
    """One stage of a staged collective.

    ``payload_bytes`` is the PER-HOP per-device payload the stage moves:
    the entering payload for a gather stage (grows by the already-gathered
    factors), the leaving payload for a scatter stage (shrinks) — exactly
    the ``p`` in the ``(f-1)·(α + p/B)`` barrier and
    ``max((f-1)·p/B + α, (f-1)·α + p/B)`` overlap models.  ``axis`` is the
    mesh axis the engine executes this stage over (None for paper-world
    plans); ``link`` is the transport model pricing it (None for pure
    optical plans).
    """

    factor: int
    mode: str  # "oneshot" | "perhop"
    payload_bytes: float
    axis: Optional[str] = None
    link: Optional[object] = None  # core.planner.LinkSpec (kept untyped: no cycle)
    hops: Tuple[Hop, ...] = ()

    def __post_init__(self):
        if self.mode not in STAGE_MODES:
            raise ValueError(f"stage mode must be one of {STAGE_MODES}, got {self.mode!r}")
        if self.factor < 1:
            raise ValueError("stage factor must be >= 1")


@dataclass(frozen=True)
class CollectivePlan:
    """The unified staged-collective plan (see module docstring).

    ``shard_bytes`` is the scattered-end payload — the AG input / RS output
    shard, the paper's item size d.  ``stages`` are in execution order; for
    ``collective == "ar"`` they span the full 2k-stage RS+AG chain.
    """

    collective: str  # "ag" | "rs" | "ar"
    n: int
    shard_bytes: float
    stages: Tuple[PlanStage, ...]
    mode: str = "oneshot"
    num_chunks: int = 1
    meta: Dict = field(default_factory=dict)

    def __post_init__(self):
        if self.collective not in ("ag", "rs", "ar"):
            raise ValueError(f"collective must be ag|rs|ar, got {self.collective!r}")
        if self.mode not in PLAN_MODES:
            raise ValueError(f"plan mode must be one of {PLAN_MODES}, got {self.mode!r}")
        prod = math.prod(s.factor for s in self.stages)
        expect = self.n * self.n if self.collective == "ar" else self.n
        if prod != expect:
            raise ValueError(
                f"stage factors {tuple(s.factor for s in self.stages)} do not "
                f"cover n={self.n} for collective {self.collective!r}"
            )

    # -- convenience ---------------------------------------------------------
    @property
    def factors(self) -> Tuple[int, ...]:
        return tuple(s.factor for s in self.stages)

    @property
    def axes(self) -> Tuple[Optional[str], ...]:
        return tuple(s.axis for s in self.stages)

    @property
    def stage_modes(self) -> Tuple[str, ...]:
        return tuple(s.mode for s in self.stages)

    def with_mode(self, mode: str) -> "CollectivePlan":
        """Same plan, different plan-level execution mode (the per-stage hop
        structure is preserved; it takes effect under ``perhop``/``hybrid``).

        The chunked and hybrid wavefronts carry independent chunk
        decisions; a plan built from a ``HopSchedule`` records both in
        ``meta["mode_chunks"]`` and switching into either mode restores the
        matching count — so ``price(plan.with_mode(m))`` reproduces the
        planner's modeled time for every ``m`` with no explicit
        ``with_chunks`` bookkeeping (an explicit ``with_chunks`` afterwards
        still wins).  A wavefront mode whose restored count is 1 normalizes
        like ``with_chunks(1)`` does (chunked → oneshot, hybrid → perhop):
        the label and the execution never disagree."""
        if mode not in PLAN_MODES:
            raise ValueError(f"plan mode must be one of {PLAN_MODES}, got {mode!r}")
        chunks = self.num_chunks
        mode_chunks = self.meta.get("mode_chunks") if self.meta else None
        if mode_chunks and mode in mode_chunks:
            chunks = mode_chunks[mode]
        if chunks == 1:
            mode = {"chunked": "oneshot", "hybrid": "perhop"}.get(mode, mode)
        return dataclasses.replace(self, mode=mode, num_chunks=chunks)

    def with_chunks(self, num_chunks: int) -> "CollectivePlan":
        """Same plan, different chunk count.  A count that collapses to 1
        (e.g. ``fit_chunks`` on a small shard) normalizes a ``chunked``
        plan back to ``oneshot`` and a ``hybrid`` plan back to ``perhop``
        (its one-chunk degenerate: the ring stages with no wavefront) — the
        label and the execution never disagree, and ``price(plan)`` is
        drift-free either way (a one-chunk wavefront prices exactly as the
        barrier / overlapped stage chain)."""
        if num_chunks < 1:
            raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
        mode = self.mode
        if num_chunks == 1:
            mode = {"chunked": "oneshot", "hybrid": "perhop"}.get(mode, mode)
        return dataclasses.replace(self, num_chunks=num_chunks, mode=mode)

    # -- transfer-structure algebra -----------------------------------------
    def gather_tree(self) -> OpTreePlan:
        """The OpTree plan whose subset algebra generates this plan's
        transfers (gather-order factors; RS/AR reuse it by time reversal)."""
        return OpTreePlan(self.n, gather_chain(self)[0] or (1,))


def gather_chain(plan: CollectivePlan) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """(factors, stage_modes) of the plan's gather-equivalent chain.

    * ``ag`` — the stages as executed.
    * ``rs`` — the time-reversed mirror: an RS with execution factors
      (f_1..f_k) moves exactly the transfers of the mirrored AG with factors
      (f_k..f_1) run backwards, so hop/step counts are identical.
    * ``ar`` — only the gather half is a single gather chain; callers that
      need the full AR structure handle the two halves explicitly (see
      ``schedule_from_ir``).

    Per-stage hop structure is the EFFECTIVE mode: a stage's ``perhop``
    preference only materializes when the plan-level mode is ``perhop`` or
    ``hybrid`` — under ``oneshot``/``chunked`` every stage runs as a
    blocking collective, exactly as the executor would run it.  Factor-1
    stages carry no transfers and are dropped.
    """
    if plan.collective == "ar":
        raise ValueError("ar spans two chains; lower the halves separately")
    stages = plan.stages
    if plan.collective == "rs":
        stages = tuple(reversed(stages))
    pairs = [(s.factor, effective_stage_mode(plan, s)) for s in stages
             if s.factor > 1]
    factors = tuple(f for f, _ in pairs)
    modes = tuple(m for _, m in pairs)
    return factors, modes


def effective_stage_mode(plan: CollectivePlan, stage: PlanStage) -> str:
    """The hop structure a stage actually executes/lowers with under the
    plan-level mode (stage ``perhop`` applies only when the plan is
    ``perhop`` or ``hybrid`` — the hybrid wavefront flows over the same
    ring stages the perhop mode runs)."""
    return stage.mode if plan.mode in ("perhop", "hybrid") else "oneshot"


def _ring_hops(
    tree: OpTreePlan, stage: int, shard_bytes: float
) -> List[Hop]:
    """``m - 1`` double-buffered ring hops for stage ``stage`` (1-indexed).

    Hop t: within every subset (members ascending ring position), the
    member at subset position q forwards to position (q+1) mod m the
    stage-entry items of position (q - t + 1) mod m — the block received at
    hop t-1 (at t=1, its own holding).  After m-1 hops every member has
    every sibling's stage-entry items: the ring all-gather the per-hop
    executor runs (``comms.ring_executor.ring_all_gather_stage``).
    """
    m = tree.factors[stage - 1]
    hops: List[Hop] = []
    subsets = list(tree.subsets(stage))
    entry_items = {
        p: tree.items_to_send(stage, p)
        for sub in subsets
        for p in sub.members
    }
    for t in range(1, m):
        transfers: List[Transfer] = []
        for sub in subsets:
            members = sub.members
            for q, src in enumerate(members):
                dst = members[(q + 1) % m]
                origin = members[(q - t + 1) % m]
                for item in entry_items[origin]:
                    transfers.append(Transfer(src, dst, item, shard_bytes))
        hops.append(Hop(tuple(transfers)))
    return hops


def _oneshot_hop(
    tree: OpTreePlan, stage: int, shard_bytes: float
) -> List[Hop]:
    """The paper's stage: one all-to-all broadcast round per subset — each
    member sends every item it entered the stage with to every sibling."""
    transfers: List[Transfer] = []
    for sub in tree.subsets(stage):
        for src in sub.members:
            items = tree.items_to_send(stage, src)
            for dst in sub.members:
                if dst == src:
                    continue
                for item in items:
                    transfers.append(Transfer(src, dst, item, shard_bytes))
    return [Hop(tuple(transfers))]


def stage_hops(
    factors: Sequence[int],
    modes: Sequence[str],
    stage_idx: int,
    shard_bytes: float,
) -> List[Hop]:
    """Hops of gather-chain stage ``stage_idx`` (0-indexed execution order)."""
    tree = OpTreePlan(int(math.prod(factors)), tuple(factors))
    if modes[stage_idx] == "perhop":
        return _ring_hops(tree, stage_idx + 1, shard_bytes)
    return _oneshot_hop(tree, stage_idx + 1, shard_bytes)


def expand_hops(plan: CollectivePlan) -> CollectivePlan:
    """Materialize ``hops`` on every stage of an ``ag``/``rs`` plan.

    RS stages get the hops of their time-reversed mirror AG (identical
    counts; the executed RS runs them backwards carrying partial sums).
    O(N^2) transfers — validation-sized plans only.
    """
    factors, modes = gather_chain(plan)
    per_stage: List[Tuple[Hop, ...]] = []
    for j in range(len(factors)):
        per_stage.append(tuple(stage_hops(factors, modes, j, plan.shard_bytes)))
    if plan.collective == "rs":
        per_stage = list(reversed(per_stage))
    out: List[PlanStage] = []
    it = iter(per_stage)
    for st in plan.stages:
        hops = next(it) if st.factor > 1 else ()
        out.append(dataclasses.replace(st, hops=hops))
    return dataclasses.replace(plan, stages=tuple(out))
