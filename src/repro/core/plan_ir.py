"""Unified CollectivePlan IR — ONE plan object from scheduler to executor.

The repo used to hold two disjoint plan worlds: the paper side
(``core.tree.OpTreePlan`` → ``core.schedule`` Tx lightpaths → the Eq.-3
optical simulator) and the engine side (``core.planner`` stage plans →
``comms`` shard_map executors), each priced by its own cost model.  This
module is the bridge: a single IR

    CollectivePlan
      └─ PlanStage(factor, axis, link, mode ∈ {oneshot, perhop})
           └─ Hop
                └─ Transfer(src, dst, item, bytes)

with builders from both worlds (``OpTreePlan.to_ir()``,
``HopSchedule.to_ir()``) and consumers in all four layers:

  * ``core.cost_model.price(plan, model)`` — one pricing entry point for
    the LinkSpec alpha/bandwidth model AND the paper's optical Eq.-3 model;
  * ``core.schedule.schedule_from_ir(plan, w)`` — lowers a plan to Tx
    lightpaths for step-accurate, conflict-checked validation in
    ``optics.simulator.simulate``;
  * ``comms.plan_executor.execute_plan`` — the JAX executor interprets the
    plan's stages directly (no re-derivation, no drift);
  * ``launch/perf.py --collectives`` / ``benchmarks/run.py`` — report
    modeled-electrical, modeled-optical and measured time off the same
    plan object.

Semantics.  ``stages`` are in EXECUTION order.  A plan with factors
(f_1..f_k) places participant p at ring/mixed-radix position with the
first-executed factor most significant, which makes the transfer structure
of an all-gather plan literally ``OpTreePlan(n, factors)``: stage j gathers
coordinate c_j inside "same position across siblings" subsets.  The dual
collectives reuse the gather algebra by time reversal: a reduce-scatter's
transfer structure is the mirrored all-gather run backwards (identical hop
and step counts — see ``optics/comparison.py``), an all-reduce is RS then
AG.

``PlanStage.mode`` is the hop structure: ``"oneshot"`` — the stage is one
synchronized all-to-all round (paper §III-D; XLA blocking collective on the
engine side); ``"perhop"`` — the stage runs as ``factor-1`` double-buffered
ring hops (``comms.ring_executor``).  ``CollectivePlan.mode`` is the
plan-level execution decision (``oneshot`` / ``chunked`` / ``perhop`` /
``hybrid``); ``num_chunks`` carries the wavefront chunk count for the
chunked and hybrid modes.  ``hybrid`` is the perhop-chunked combination:
the C-chunk wavefront flows OVER per-hop ring stages, so each pipeline
stage is the overlapped ring (or the blocking collective where the stage's
hop structure says ``oneshot``) on a 1/C-payload chunk — dominated by
neither pure mode, never worse than either (the makespan of elementwise-
smaller stage times over the same chunk candidates).
Hops/transfers are materialized lazily (``expand_hops``) — consumers that
only price or execute a plan never pay the O(N^2) enumeration.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .tree import OpTreePlan

__all__ = [
    "Transfer",
    "Hop",
    "PlanStage",
    "CollectivePlan",
    "CollectiveKind",
    "COLLECTIVES",
    "collective_kind",
    "optical_message_bytes",
    "expand_hops",
    "stage_hops",
    "gather_chain",
    "effective_stage_mode",
]

STAGE_MODES = ("oneshot", "perhop", "exchange")
PLAN_MODES = ("oneshot", "chunked", "perhop", "hybrid")


# --------------------------------------------------------------------------
# collective registry — the stage algebra of each collective kind
# --------------------------------------------------------------------------

def _gather_payloads(shard_bytes: float, factors: Sequence[int]) -> List[float]:
    """Entering payload of each gather stage: grows by the already-gathered
    prefix (stage j moves shard · prod_{i<j} f_i per peer)."""
    out: List[float] = []
    payload = float(shard_bytes)
    for f in factors:
        out.append(payload)
        payload *= f
    return out


def _scatter_payloads(shard_bytes: float, factors: Sequence[int]) -> List[float]:
    """Leaving payload of each scatter stage — the gather law run backwards
    (stage j of an RS with execution factors g_1..g_k moves
    shard · prod_{i>j} g_i per peer)."""
    out: List[float] = []
    payload = float(shard_bytes) * math.prod(factors)
    for f in factors:
        payload /= f
        out.append(payload)
    return out


@dataclass(frozen=True)
class CollectiveKind:
    """Stage-algebra descriptor for one collective kind — the registry entry
    that replaces the string-literal ``ag|rs|ar`` special-casing.

    ``traffic`` — the per-stage hop structure family:

      * ``"gather"`` — stage j broadcasts each member's entering block within
        its "same position across siblings" subset; the payload grows
        (forward) or shrinks (reversed) with the already-covered factors;
      * ``"exchange"`` — stage j transposes ONE mixed-radix digit of the
        (origin, destination) block grid: every member sends a ``1/m`` slice
        of its constant-``n``-block residency to every sibling (the scaled-
        payload all-to-all semantics — nothing accumulates across stages).

    ``chain`` — how execution-order stages map onto the gather-equivalent
    lowering chain: ``"forward"`` (ag, a2a), ``"reversed"`` (rs — the
    time-reversed mirror AG), ``"two_phase"`` (ar — an RS half then an AG
    half; consumers split at ``k = len(stages) // 2``).

    ``dual`` — the kind whose chain is this one's time reversal (rs ↔ ag);
    ``a2a`` is self-dual: an all-to-all run backwards is the inverse
    all-to-all, with identical hop and step structure.
    """

    name: str
    traffic: str  # "gather" | "exchange"
    chain: str  # "forward" | "reversed" | "two_phase"
    dual: Optional[str] = None

    @property
    def two_phase(self) -> bool:
        return self.chain == "two_phase"

    def expected_factor_product(self, n: int) -> int:
        """What the plan's stage factors must multiply to (two-phase kinds
        span both mirrored chains)."""
        return n * n if self.two_phase else n

    def item_count(self, n: int) -> int:
        """Size of the schedule item space: origin shards for gather
        traffic, ``n²`` (origin, destination) blocks for exchange traffic."""
        return n * n if self.traffic == "exchange" else n

    def message_bytes(self, shard_bytes: float, n: int) -> float:
        """Bytes of ONE schedule item — the per-step optical message size
        (a whole shard for gather traffic; a ``1/n`` block for exchange)."""
        return shard_bytes / n if self.traffic == "exchange" else shard_bytes

    def stage_payloads(
        self, shard_bytes: float, factors: Sequence[int]
    ) -> Tuple[float, ...]:
        """The payload-per-stage law: the per-peer ``p`` each EXECUTED stage
        moves, as fed to the ``(f-1)·(α + p/B)`` barrier and
        ``max((f-1)·p/B + α, (f-1)·α + p/B)`` overlap models."""
        factors = tuple(factors)
        if self.traffic == "exchange":
            return tuple(shard_bytes / f for f in factors)
        if self.two_phase:
            k = len(factors) // 2
            return tuple(
                _scatter_payloads(shard_bytes, factors[:k])
                + _gather_payloads(shard_bytes, factors[k:])
            )
        if self.chain == "reversed":
            return tuple(_scatter_payloads(shard_bytes, factors))
        return tuple(_gather_payloads(shard_bytes, factors))


COLLECTIVES: Dict[str, CollectiveKind] = {
    "ag": CollectiveKind("ag", traffic="gather", chain="forward", dual="rs"),
    "rs": CollectiveKind("rs", traffic="gather", chain="reversed", dual="ag"),
    "ar": CollectiveKind("ar", traffic="gather", chain="two_phase"),
    "a2a": CollectiveKind("a2a", traffic="exchange", chain="forward", dual="a2a"),
}


def collective_kind(name: str) -> CollectiveKind:
    """Registry lookup; raises with the registered names on a miss."""
    try:
        return COLLECTIVES[name]
    except KeyError:
        raise ValueError(
            f"unknown collective {name!r}; registered: {sorted(COLLECTIVES)}"
        ) from None


def optical_message_bytes(plan: "CollectivePlan") -> float:
    """Bytes of one schedule item of ``plan`` — the per-step message size
    the optical Eq.-3 model prices AND the size every ``simulate`` call must
    pass: the whole shard for gather traffic, a ``1/n`` (origin,
    destination) block for exchange traffic."""
    return collective_kind(plan.collective).message_bytes(plan.shard_bytes, plan.n)


@dataclass(frozen=True)
class Transfer:
    """One logical block movement: ``src`` sends origin-block ``item`` to
    ``dst``.  ``bytes`` is the block size (the scattered shard d)."""

    src: int
    dst: int
    item: int
    bytes: float


@dataclass(frozen=True)
class Hop:
    """One synchronized communication round within a stage.  A ``oneshot``
    stage has exactly one hop (the all-to-all broadcast); a ``perhop``
    stage has ``factor - 1`` ring hops, each causally after the previous."""

    transfers: Tuple[Transfer, ...]


@dataclass(frozen=True)
class PlanStage:
    """One stage of a staged collective.

    ``payload_bytes`` is the PER-HOP per-device payload the stage moves:
    the entering payload for a gather stage (grows by the already-gathered
    factors), the leaving payload for a scatter stage (shrinks) — exactly
    the ``p`` in the ``(f-1)·(α + p/B)`` barrier and
    ``max((f-1)·p/B + α, (f-1)·α + p/B)`` overlap models.  ``axis`` is the
    mesh axis the engine executes this stage over (None for paper-world
    plans); ``link`` is the transport model pricing it (None for pure
    optical plans).
    """

    factor: int
    mode: str  # "oneshot" | "perhop" | "exchange"
    payload_bytes: float
    axis: Optional[str] = None
    link: Optional[object] = None  # core.planner.LinkSpec (kept untyped: no cycle)
    hops: Tuple[Hop, ...] = ()

    def __post_init__(self):
        if self.mode not in STAGE_MODES:
            raise ValueError(f"stage mode must be one of {STAGE_MODES}, got {self.mode!r}")
        if self.factor < 1:
            raise ValueError("stage factor must be >= 1")
        if self.mode == "exchange" and self.factor != 2:
            raise ValueError(
                f"exchange stages are bidirectional pairwise rounds; factor "
                f"must be 2, got {self.factor}")


@dataclass(frozen=True)
class CollectivePlan:
    """The unified staged-collective plan (see module docstring).

    ``shard_bytes`` is the scattered-end payload — the AG input / RS output
    shard, the paper's item size d.  ``stages`` are in execution order; for
    ``collective == "ar"`` they span the full 2k-stage RS+AG chain.
    """

    collective: str  # a key of COLLECTIVES: "ag" | "rs" | "ar" | "a2a"
    n: int
    shard_bytes: float
    stages: Tuple[PlanStage, ...]
    mode: str = "oneshot"
    num_chunks: int = 1
    meta: Dict = field(default_factory=dict)

    def __post_init__(self):
        kind = collective_kind(self.collective)
        if self.mode not in PLAN_MODES:
            raise ValueError(f"plan mode must be one of {PLAN_MODES}, got {self.mode!r}")
        prod = math.prod(s.factor for s in self.stages)
        expect = kind.expected_factor_product(self.n)
        if prod != expect:
            raise ValueError(
                f"stage factors {tuple(s.factor for s in self.stages)} do not "
                f"cover n={self.n} for collective {self.collective!r}"
            )

    # -- convenience ---------------------------------------------------------
    @property
    def kind(self) -> CollectiveKind:
        """This plan's registry descriptor (stage algebra)."""
        return collective_kind(self.collective)

    @property
    def factors(self) -> Tuple[int, ...]:
        return tuple(s.factor for s in self.stages)

    @property
    def axes(self) -> Tuple[Optional[str], ...]:
        return tuple(s.axis for s in self.stages)

    @property
    def stage_modes(self) -> Tuple[str, ...]:
        return tuple(s.mode for s in self.stages)

    @property
    def is_fallback(self) -> bool:
        """True when planning degraded this collective to the forced
        one-shot plan (``meta["fallback"]`` holds the reason — e.g. an axis
        dead in both ring directions makes every staged order unroutable)."""
        return bool(self.meta.get("fallback"))

    def with_mode(self, mode: str) -> "CollectivePlan":
        """Same plan, different plan-level execution mode (the per-stage hop
        structure is preserved; it takes effect under ``perhop``/``hybrid``).

        The chunked and hybrid wavefronts carry independent chunk
        decisions; a plan built from a ``HopSchedule`` records both in
        ``meta["mode_chunks"]`` and switching into either mode restores the
        matching count — so ``price(plan.with_mode(m))`` reproduces the
        planner's modeled time for every ``m`` with no explicit
        ``with_chunks`` bookkeeping (an explicit ``with_chunks`` afterwards
        still wins).  A wavefront mode whose restored count is 1 normalizes
        like ``with_chunks(1)`` does (chunked → oneshot, hybrid → perhop):
        the label and the execution never disagree."""
        if mode not in PLAN_MODES:
            raise ValueError(f"plan mode must be one of {PLAN_MODES}, got {mode!r}")
        chunks = self.num_chunks
        mode_chunks = self.meta.get("mode_chunks") if self.meta else None
        if mode_chunks and mode in mode_chunks:
            chunks = mode_chunks[mode]
        if chunks == 1:
            mode = {"chunked": "oneshot", "hybrid": "perhop"}.get(mode, mode)
        return dataclasses.replace(self, mode=mode, num_chunks=chunks)

    def with_chunks(self, num_chunks: int) -> "CollectivePlan":
        """Same plan, different chunk count.  A count that collapses to 1
        (e.g. ``fit_chunks`` on a small shard) normalizes a ``chunked``
        plan back to ``oneshot`` and a ``hybrid`` plan back to ``perhop``
        (its one-chunk degenerate: the ring stages with no wavefront) — the
        label and the execution never disagree, and ``price(plan)`` is
        drift-free either way (a one-chunk wavefront prices exactly as the
        barrier / overlapped stage chain)."""
        if num_chunks < 1:
            raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
        mode = self.mode
        if num_chunks == 1:
            mode = {"chunked": "oneshot", "hybrid": "perhop"}.get(mode, mode)
        return dataclasses.replace(self, num_chunks=num_chunks, mode=mode)

    # -- transfer-structure algebra -----------------------------------------
    def gather_tree(self) -> OpTreePlan:
        """The OpTree plan whose subset algebra generates this plan's
        transfers (gather-order factors; RS/AR reuse it by time reversal)."""
        return OpTreePlan(self.n, gather_chain(self)[0] or (1,))


def gather_chain(plan: CollectivePlan) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """(factors, stage_modes) of the plan's lowering-equivalent chain.

    Dispatches on the registry descriptor's ``chain``:

    * ``forward`` (ag, a2a) — the stages as executed.
    * ``reversed`` (rs) — the time-reversed mirror: an RS with execution
      factors (f_1..f_k) moves exactly the transfers of the mirrored AG with
      factors (f_k..f_1) run backwards, so hop/step counts are identical.
    * ``two_phase`` (ar) — only each half is a single chain; callers that
      need the full structure handle the two halves explicitly (see
      ``schedule_from_ir``).

    Per-stage hop structure is the EFFECTIVE mode: a stage's ``perhop``
    preference only materializes when the plan-level mode is ``perhop`` or
    ``hybrid`` — under ``oneshot``/``chunked`` every stage runs as a
    blocking collective, exactly as the executor would run it.  Factor-1
    stages carry no transfers and are dropped.
    """
    kind = collective_kind(plan.collective)
    if kind.two_phase:
        raise ValueError(
            f"{plan.collective} spans two chains; lower the halves separately")
    stages = plan.stages
    if kind.chain == "reversed":
        stages = tuple(reversed(stages))
    pairs = [(s.factor, effective_stage_mode(plan, s)) for s in stages
             if s.factor > 1]
    factors = tuple(f for f, _ in pairs)
    modes = tuple(m for _, m in pairs)
    return factors, modes


def effective_stage_mode(plan: CollectivePlan, stage: PlanStage) -> str:
    """The hop structure a stage actually executes/lowers with under the
    plan-level mode (stage ``perhop`` applies only when the plan is
    ``perhop`` or ``hybrid`` — the hybrid wavefront flows over the same
    ring stages the perhop mode runs).  An ``exchange`` stage IS its
    structure under every plan mode: a latency plan's bidirectional
    pairwise round has no alternative hop decomposition."""
    if stage.mode == "exchange":
        return "exchange"
    return stage.mode if plan.mode in ("perhop", "hybrid") else "oneshot"


def _ring_hops(
    tree: OpTreePlan, stage: int, shard_bytes: float
) -> List[Hop]:
    """``m - 1`` double-buffered ring hops for stage ``stage`` (1-indexed).

    Hop t: within every subset (members ascending ring position), the
    member at subset position q forwards to position (q+1) mod m the
    stage-entry items of position (q - t + 1) mod m — the block received at
    hop t-1 (at t=1, its own holding).  After m-1 hops every member has
    every sibling's stage-entry items: the ring all-gather the per-hop
    executor runs (``comms.ring_executor.ring_all_gather_stage``).
    """
    m = tree.factors[stage - 1]
    hops: List[Hop] = []
    subsets = list(tree.subsets(stage))
    entry_items = {
        p: tree.items_to_send(stage, p)
        for sub in subsets
        for p in sub.members
    }
    for t in range(1, m):
        transfers: List[Transfer] = []
        for sub in subsets:
            members = sub.members
            for q, src in enumerate(members):
                dst = members[(q + 1) % m]
                origin = members[(q - t + 1) % m]
                for item in entry_items[origin]:
                    transfers.append(Transfer(src, dst, item, shard_bytes))
        hops.append(Hop(tuple(transfers)))
    return hops


def _oneshot_hop(
    tree: OpTreePlan, stage: int, shard_bytes: float
) -> List[Hop]:
    """The paper's stage: one all-to-all broadcast round per subset — each
    member sends every item it entered the stage with to every sibling."""
    transfers: List[Transfer] = []
    for sub in tree.subsets(stage):
        for src in sub.members:
            items = tree.items_to_send(stage, src)
            for dst in sub.members:
                if dst == src:
                    continue
                for item in items:
                    transfers.append(Transfer(src, dst, item, shard_bytes))
    return [Hop(tuple(transfers))]


def _a2a_stage_transfers(
    tree: OpTreePlan, stage: int, shard_bytes: float
) -> List[Tuple[int, Transfer]]:
    """(digit shift, Transfer) for every block an exchange stage moves.

    Item space is the n² (origin, destination) blocks, labeled
    ``u * n + v`` with each block ``shard_bytes / n``.  At stage-``j`` entry
    block (u, v) resides at the node whose mixed-radix coords are
    ``(v_1..v_{j-1}, u_j..u_k)``; stage j rewrites digit j from ``u_j`` to
    ``v_j`` — after all k stages the block sits at v: the full all-to-all.
    A block with ``u_j == v_j`` does not move; the rest travel within the
    same stage-``j`` subset the gather traffic uses (same groups, 1/m of
    the resident bytes to each sibling — the scaled-payload semantics)."""
    n = tree.n
    block = shard_bytes / n
    j = stage
    m = tree.factors[j - 1]
    out: List[Tuple[int, Transfer]] = []
    coords = [tree.coords(p) for p in range(n)]
    for u in range(n):
        cu = coords[u]
        for v in range(n):
            cv = coords[v]
            if cu[j - 1] == cv[j - 1]:
                continue
            src = tree.node(cv[: j - 1] + cu[j - 1:])
            dst = tree.node(cv[:j] + cu[j:])
            shift = (cv[j - 1] - cu[j - 1]) % m
            out.append((shift, Transfer(src, dst, u * n + v, block)))
    return out


def _a2a_oneshot_hop(
    tree: OpTreePlan, stage: int, shard_bytes: float
) -> List[Hop]:
    """One synchronized exchange round: every member of every stage subset
    sends its 1/m destination slices to all m-1 siblings at once."""
    return [Hop(tuple(t for _, t in _a2a_stage_transfers(tree, stage, shard_bytes)))]


def _a2a_ring_hops(
    tree: OpTreePlan, stage: int, shard_bytes: float
) -> List[Hop]:
    """``m - 1`` rotation hops: hop t carries exactly the slices whose digit
    shift ``(v_j - u_j) mod m == t`` — every block moves once, in the hop
    matching its shift distance, so the union over hops equals the oneshot
    round and hops are causally independent (no forwarding chains: the
    double-buffered overlap model applies)."""
    m = tree.factors[stage - 1]
    buckets: List[List[Transfer]] = [[] for _ in range(m)]
    for shift, t in _a2a_stage_transfers(tree, stage, shard_bytes):
        buckets[shift].append(t)
    return [Hop(tuple(buckets[t])) for t in range(1, m)]


def stage_hops(
    factors: Sequence[int],
    modes: Sequence[str],
    stage_idx: int,
    shard_bytes: float,
    *,
    collective: str = "ag",
) -> List[Hop]:
    """Hops of lowering-chain stage ``stage_idx`` (0-indexed execution
    order), built by the collective's traffic family (gather broadcast
    subsets vs. exchange digit transposes).  An ``exchange`` stage mode
    (factor 2) builds the oneshot hop: a factor-2 all-to-all broadcast
    round IS the bidirectional pairwise exchange."""
    tree = OpTreePlan(int(math.prod(factors)), tuple(factors))
    if modes[stage_idx] == "exchange" and factors[stage_idx] != 2:
        raise ValueError("exchange stage modes require factor 2")
    perhop = modes[stage_idx] == "perhop"
    if collective_kind(collective).traffic == "exchange":
        builder = _a2a_ring_hops if perhop else _a2a_oneshot_hop
    else:
        builder = _ring_hops if perhop else _oneshot_hop
    return builder(tree, stage_idx + 1, shard_bytes)


def expand_hops(plan: CollectivePlan) -> CollectivePlan:
    """Materialize ``hops`` on every stage of a single-chain plan.

    RS stages get the hops of their time-reversed mirror AG (identical
    counts; the executed RS runs them backwards carrying partial sums);
    exchange (a2a) stages get their digit-transpose hops over the n² block
    items.  O(N^2) transfers — validation-sized plans only.
    """
    kind = collective_kind(plan.collective)
    factors, modes = gather_chain(plan)
    per_stage: List[Tuple[Hop, ...]] = []
    for j in range(len(factors)):
        per_stage.append(tuple(stage_hops(
            factors, modes, j, plan.shard_bytes, collective=plan.collective)))
    if kind.chain == "reversed":
        per_stage = list(reversed(per_stage))
    out: List[PlanStage] = []
    it = iter(per_stage)
    for st in plan.stages:
        hops = next(it) if st.factor > 1 else ()
        out.append(dataclasses.replace(st, hops=hops))
    return dataclasses.replace(plan, stages=tuple(out))
