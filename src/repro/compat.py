"""Version-compat shims for the pinned JAX / Pallas wheels.

The codebase targets the current public API names (``jax.shard_map``,
``jax.sharding.AxisType``, ``pltpu.CompilerParams``); the pinned wheel
predates some of them.  Every call site goes through this module so a
version bump is a one-file fix:

  * ``AxisType`` / ``axis_types=`` on ``jax.make_mesh`` — newer JAX only.
    ``make_mesh`` passes the kwarg when supported and omits it otherwise
    (meshes default to Auto axes on old versions anyway).
  * ``jax.shard_map(..., check_vma=)`` — falls back to
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.
  * ``pltpu.CompilerParams`` — renamed from ``pltpu.TPUCompilerParams``.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax

__all__ = [
    "get_axis_type",
    "auto_axis_types",
    "make_mesh",
    "shard_map",
    "axis_size",
    "cost_analysis",
    "tpu_compiler_params",
]


def axis_size(name: str) -> int:
    """Static size of a named mesh axis inside shard_map.

    ``jax.lax.axis_size`` on new JAX; on old versions ``jax.core.axis_frame``
    resolves the name in the ambient axis env (returning either the size
    itself or a frame carrying it, depending on the exact version).
    """
    lax_size = getattr(jax.lax, "axis_size", None)
    if lax_size is not None:
        return lax_size(name)
    frame = jax.core.axis_frame(name)
    return frame if isinstance(frame, int) else frame.size


def get_axis_type() -> Optional[Any]:
    """``jax.sharding.AxisType.Auto`` where it exists, else ``None``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return None if axis_type is None else axis_type.Auto


def auto_axis_types(n: int) -> Optional[Tuple[Any, ...]]:
    """``(AxisType.Auto,) * n`` on new JAX, ``None`` (omit kwarg) on old."""
    auto = get_axis_type()
    return None if auto is None else (auto,) * n


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices=None,
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types when the kwarg exists."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    kwargs: dict = {}
    types = auto_axis_types(len(axis_shapes))
    if types is not None:
        kwargs["axis_types"] = types
    return jax.make_mesh(axis_shapes, axis_names, devices=devices, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any JAX version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def cost_analysis(compiled) -> dict:
    """Dict form of ``Compiled.cost_analysis()`` on any JAX version (old
    versions return a one-element list of per-program dicts)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def tpu_compiler_params(**kwargs):
    """Build Pallas-TPU compiler params under either class name."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
