from .pipeline import DataConfig, SyntheticLMPipeline  # noqa: F401
