"""Deterministic synthetic LM data pipeline.

Production-shaped: per-host sharding (each host materializes only its slice
of the global batch), background prefetch, and a checkpointable iterator
state (`state()` / `restore()`) so a restarted job resumes mid-epoch on the
exact batch it crashed before.

Tokens are a Zipf-ish mixture with a Markov flavour derived from a counter-
based hash — reproducible from (seed, step) alone, no files needed offline.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLMPipeline"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2


class SyntheticLMPipeline:
    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self._step = 0
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---- deterministic batch synthesis ------------------------------------
    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        local_b = cfg.global_batch // cfg.num_hosts
        rng = np.random.default_rng(
            np.uint64(cfg.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(cfg.num_hosts)
            + np.uint64(cfg.host_id)
        )
        # zipf-flavoured unigram + short repeats to give the LM signal
        base = rng.zipf(1.3, size=(local_b, cfg.seq_len + 1)).astype(np.int64)
        tokens = (base % (cfg.vocab_size - 2)) + 1
        # inject periodic structure: every 7th token repeats the 3rd-previous
        tokens[:, 7::7] = tokens[:, 4:-3:7] if cfg.seq_len >= 8 else tokens[:, 7::7]
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    # ---- iterator protocol with prefetch ----------------------------------
    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._batch_at(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def start(self):
        if self._thread is None:
            self._q = queue.Queue(maxsize=self.cfg.prefetch)
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2)
            self._thread = None
            self._q = None

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._thread is not None:
            while True:
                step, batch = self._q.get()
                if step == self._step:  # drop stale prefetches after restore
                    break
        else:
            batch = self._batch_at(self._step)
        self._step += 1
        return batch

    # ---- checkpointable state ----------------------------------------------
    def state(self) -> Dict:
        return {"step": self._step, "seed": self.cfg.seed}

    def restore(self, state: Dict):
        if state.get("seed") != self.cfg.seed:
            raise ValueError("restoring a pipeline with a different seed")
        was_running = self._thread is not None
        self.stop()
        self._step = int(state["step"])
        if was_running:
            self.start()
