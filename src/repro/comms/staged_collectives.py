"""Staged collective engine: OpTree's k-stage machinery generalized beyond
all-gather.

``staged_all_gather`` (staged_allgather.py) runs the paper's stages
minor-payload-first so the slow links move the *small* payload.  This module
adds the rest of the gather-shaped family:

  * ``staged_reduce_scatter`` — the exact dual.  A reduce-scatter's payload
    *shrinks* stage by stage, so the paper-optimal order is the **reverse**
    of the all-gather order: the slow (pod/DCN) axes run last, when each
    device holds only the final 1/N shard.  Any stage order composes to the
    canonical (major-first) block layout after one *local* block permutation
    before the scatters — layout work, not communication (the mirror of the
    all-gather's post-transpose).
  * ``staged_all_reduce`` — reduce-scatter + all-gather sharing one plan
    (the AG stage order is the reverse of the RS order).
  * **chunked execution** — every primitive takes ``num_chunks=C``: the
    shard is split into C chunks and stage j of chunk i is issued in the
    same wavefront as stage j+1 of chunk i-1 (SWOT-style software
    pipelining; XLA's scheduler overlaps the independent collectives).  The
    planner (``core.planner.choose_num_chunks``) decides C from the
    alpha/bandwidth trade-off.

The user-facing surface is the context-scoped API (``repro.comms.api``:
``comm_context`` + module ops); ``StagedCollectiveEngine`` and
``tp_all_reduce`` remain as deprecation shims routing through it.
"""
from __future__ import annotations

import math
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from ..compat import axis_size
from ..core.plan_ir import CollectivePlan
from ..core.planner import (
    LinkSpec,
    choose_hop_schedule,
    plan_axis_order,
    plan_reduce_scatter_order,
)
from .staged_allgather import link_for_axis, names_for_plan, staged_all_gather

__all__ = [
    "staged_reduce_scatter",
    "staged_all_reduce",
    "staged_all_gather_chunked",
    "staged_all_to_all",
    "tp_all_reduce",
    "fit_chunks",
    "plan_collectives",
    "StagedCollectiveEngine",
]


# --------------------------------------------------------------------------
# inside-shard_map primitives
# --------------------------------------------------------------------------

def _check_order(order, axis_names) -> Tuple[str, ...]:
    order = tuple(order)
    if sorted(order) != sorted(axis_names):
        raise ValueError(f"stage_order {order} must permute {axis_names}")
    return order


def _axis_sizes(axis_names: Sequence[str]) -> Dict[str, int]:
    return {n: axis_size(n) for n in axis_names}


def _permute_blocks_to_order(y, axis_names, order, sizes):
    """Local permutation of the N device blocks along dim 0 from canonical
    (major-first ``axis_names``) layout to ``order`` layout, so tiled
    psum_scatter stages executed in ``order`` land each device on its
    canonical block.  Pure layout work — no communication."""
    k = len(axis_names)
    n_total = math.prod(sizes[n] for n in axis_names)
    block = y.shape[0] // n_total
    shaped = y.reshape(tuple(sizes[n] for n in axis_names) + (block,) + y.shape[1:])
    perm = tuple(axis_names.index(n) for n in order)
    shaped = jnp.transpose(shaped, perm + tuple(range(k, shaped.ndim)))
    return shaped.reshape(y.shape)


def _rs_stage(y, name):
    return lax.psum_scatter(y, name, scatter_dimension=0, tiled=True)


def _ag_stage(y, name):
    # stacking form: composes under any stage order; one local fix-up at the
    # end restores canonical device order (cf. staged_all_gather)
    return lax.all_gather(y, name, axis=0, tiled=False)


def _ag_finalize(y, axis_names, order):
    """Collapse the k stacked stage axes (reversed(order) leading) into one
    canonical (N, ...) device axis."""
    k = len(axis_names)
    stacked = tuple(reversed(order))
    perm = tuple(stacked.index(n) for n in axis_names)
    y = jnp.transpose(y, perm + tuple(range(k, y.ndim)))
    n_total = math.prod(y.shape[:k])
    return y.reshape((n_total,) + y.shape[k:])


def _wavefront(chunks: List, num_stages: int, apply_stage) -> List:
    """Software pipeline: at tick t, chunk c runs stage t-c — stage j of
    chunk i is issued alongside stage j+1 of chunk i-1, so independent
    per-chunk collectives can overlap."""
    num_chunks = len(chunks)
    for t in range(num_chunks + num_stages - 1):
        for c in range(num_chunks):
            j = t - c
            if 0 <= j < num_stages:
                chunks[c] = apply_stage(chunks[c], j)
    return chunks


def _split_rs_chunks(y, axis_names, order, sizes, num_chunks):
    """Split the (moveaxis'd) input into num_chunks RS-ready chunks: chunk c
    holds every device block's c-th slice, pre-permuted to ``order`` layout
    when the stage order is non-canonical.  Raises on indivisibility."""
    n_total = math.prod(sizes.values())
    length = y.shape[0]
    if length % (n_total * num_chunks):
        raise ValueError(
            f"axis length {length} not divisible by devices*chunks "
            f"{n_total}*{num_chunks}"
        )

    def prep(chunk):
        if order != axis_names:
            return _permute_blocks_to_order(chunk, axis_names, order, sizes)
        return chunk

    if num_chunks == 1:
        return [prep(y)]
    per_chunk = length // n_total // num_chunks
    blocks = y.reshape((n_total, num_chunks, per_chunk) + y.shape[1:])
    return [
        prep(blocks[:, c].reshape((n_total * per_chunk,) + y.shape[1:]))
        for c in range(num_chunks)
    ]


def staged_reduce_scatter(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    num_chunks: int = 1,
) -> jax.Array:
    """k-stage reduce-scatter inside shard_map — the dual of
    ``staged_all_gather``.

    Returns the same value as ``jax.lax.psum_scatter(x, tuple(axis_names),
    scatter_dimension=axis, tiled=True)``: device p (canonical major-first
    order) ends with block p of the sum.

    Args:
      axis_names: factorized sub-axes of the logical axis, *major first*.
      stage_order: execution order (default: paper order — major/slow axis
        **last**, i.e. the slow links carry the smallest payload).
      num_chunks: split the output shard into C chunks and pipeline the
        stages across chunks.
    """
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else tuple(reversed(axis_names))
    )
    sizes = _axis_sizes(axis_names)

    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    chunks = _split_rs_chunks(y, axis_names, order, sizes, num_chunks)
    chunks = _wavefront(
        chunks, len(order), lambda ch, j: _rs_stage(ch, order[j])
    )
    out = chunks[0] if num_chunks == 1 else jnp.concatenate(chunks, axis=0)
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def staged_all_gather_chunked(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    num_chunks: int = 2,
) -> jax.Array:
    """Chunked/pipelined ``staged_all_gather``: equals
    ``lax.all_gather(x, tuple(axis_names), axis=axis, tiled=True)``."""
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else axis_names
    )
    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    shard = y.shape[0]
    if shard % num_chunks:
        raise ValueError(f"shard length {shard} not divisible by {num_chunks}")
    per_chunk = shard // num_chunks
    chunks = [y[c * per_chunk:(c + 1) * per_chunk] for c in range(num_chunks)]
    chunks = _wavefront(
        chunks, len(order), lambda ch, j: _ag_stage(ch, order[j])
    )
    gathered = [_ag_finalize(ch, axis_names, order) for ch in chunks]
    # interleave: device p's shard is the concat of its chunks
    out = jnp.stack(gathered, axis=1)  # (N, C, per_chunk, ...)
    n_total = out.shape[0]
    out = out.reshape((n_total * shard,) + out.shape[3:])
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def _a2a_split_digits(y, axis_names, sizes):
    """(n_total·B, ...) → (s₁, ..., s_k, B, ...): expose the N destination
    blocks of an all-to-all buffer as one mixed-radix digit axis per sub-axis
    (canonical major-first order), so each stage can transpose its own
    digit independently."""
    n_total = math.prod(sizes[n] for n in axis_names)
    if y.shape[0] % n_total:
        raise ValueError(
            f"axis length {y.shape[0]} not divisible by devices {n_total}"
        )
    block = y.shape[0] // n_total
    return y.reshape(
        tuple(sizes[n] for n in axis_names) + (block,) + y.shape[1:]
    )


def _a2a_merge_digits(y, k: int):
    """Inverse of ``_a2a_split_digits``: collapse the k digit axes + block
    interior back into one (n_total·B, ...) leading axis."""
    n_total = math.prod(y.shape[:k])
    return y.reshape((n_total * y.shape[k],) + y.shape[k + 1:])


def _a2a_stage(y, name, dim):
    # one digit transpose: exchange the m slices along digit axis ``dim``
    # over sub-axis ``name`` (out[d] = device d's slice for us)
    return lax.all_to_all(y, name, split_axis=dim, concat_axis=dim, tiled=True)


def staged_all_to_all(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    num_chunks: int = 1,
) -> jax.Array:
    """k-stage all-to-all inside shard_map: equals ``lax.all_to_all(x,
    tuple(axis_names), split_axis=axis, concat_axis=axis, tiled=True)`` bit
    for bit.

    The N-block exchange factorizes into k per-sub-axis digit transposes
    that COMMUTE — any ``stage_order`` yields the identical output and only
    the modeled cost differs (each m-ary stage moves 1/m of every peer's
    shard, never a gathered block).  ``num_chunks=C`` splits the block
    *interior* into C slices and pipelines the stage chain across them in
    the same wavefront as the gather family.
    """
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else axis_names
    )
    sizes = _axis_sizes(axis_names)
    k = len(axis_names)

    if axis < 0:
        axis += x.ndim
    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    shaped = _a2a_split_digits(y, axis_names, sizes)
    block = shaped.shape[k]
    if block % num_chunks:
        raise ValueError(
            f"block interior {block} not divisible by {num_chunks} chunks"
        )
    per = block // num_chunks
    chunks = [
        lax.slice_in_dim(shaped, c * per, (c + 1) * per, axis=k)
        for c in range(num_chunks)
    ]
    chunks = _wavefront(
        chunks, k,
        lambda ch, j: _a2a_stage(ch, order[j], axis_names.index(order[j])),
    )
    out = chunks[0] if num_chunks == 1 else jnp.concatenate(chunks, axis=k)
    out = _a2a_merge_digits(out, k)
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def staged_all_reduce(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    rs_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    num_chunks: int = 1,
) -> jax.Array:
    """Staged all-reduce = staged RS + staged AG sharing one plan.

    Equals ``jax.lax.psum(x, tuple(axis_names))``.  The AG stage order is
    the reverse of the RS order, so each payload size crosses each link
    class exactly twice and the slow links only ever carry the scattered
    (smallest) payloads.  With ``num_chunks=C`` the whole 2k-stage RS+AG
    chain is software-pipelined across chunks.
    """
    axis_names = tuple(axis_names)
    order = (
        _check_order(rs_order, axis_names)
        if rs_order is not None
        else tuple(reversed(axis_names))
    )
    ag_order = tuple(reversed(order))
    sizes = _axis_sizes(axis_names)

    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    length = y.shape[0]

    if num_chunks == 1:
        out = staged_reduce_scatter(y, axis_names, stage_order=order)
        out = staged_all_gather(out, axis_names, stage_order=ag_order)
        return jnp.moveaxis(out, 0, axis) if axis != 0 else out

    k = len(axis_names)
    chunks = _split_rs_chunks(y, axis_names, order, sizes, num_chunks)

    def apply_stage(ch, j):
        if j < k:
            return _rs_stage(ch, order[j])
        return _ag_stage(ch, ag_order[j - k])

    chunks = _wavefront(chunks, 2 * k, apply_stage)
    gathered = [_ag_finalize(ch, axis_names, ag_order) for ch in chunks]
    out = jnp.stack(gathered, axis=1)  # (N, C, per_chunk, ...)
    out = out.reshape((length,) + out.shape[3:])
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def tp_all_reduce(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    axis: int = -1,
    num_chunks: int = 1,
) -> jax.Array:
    """DEPRECATED shim: tensor-parallel partial-sum combine.

    Use :func:`repro.comms.api.all_reduce` (context-scoped, plan-cached)
    instead; this shim routes through it with the same contract (staged AR
    when divisible, flat ``lax.psum`` fallback otherwise)."""
    import warnings

    from . import api

    warnings.warn(
        "tp_all_reduce is deprecated; use repro.comms.api.all_reduce "
        "under a comm_context", DeprecationWarning, stacklevel=2)
    return api.all_reduce(
        x, axis=axis, axes=tuple(axis_names),
        num_chunks=api.legacy_chunks(num_chunks))


# --------------------------------------------------------------------------
# planning + user-facing engine
# --------------------------------------------------------------------------

def plan_collectives(
    mesh,
    axis_names: Sequence[str],
    shard_bytes: float,
    *,
    links: Optional[Dict[str, LinkSpec]] = None,
    max_chunks: int = 8,
) -> Dict[str, CollectivePlan]:
    """One :class:`~repro.core.plan_ir.CollectivePlan` per collective
    ("ag" / "rs" / "ar" / "a2a") for this (mesh axes, payload) point.

    ``mesh`` is a :class:`jax.sharding.Mesh` or a plain ``{axis: size}``
    dict (the comms context plans from trace-time axis sizes, meshless).
    Stage orders come from the cost-model planners (slow axis first for AG,
    last for RS; the AR chain is the RS order followed by its reverse), the
    execution mode + per-stage hop structure + chunk count from
    ``core.planner.choose_hop_schedule`` — all carried ON the plan, so the
    executor (``comms.plan_executor.execute_plan``), the pricer
    (``core.cost_model.price``) and the optical validator
    (``core.schedule.schedule_from_ir`` → ``optics.simulator``) consume the
    same object.  ``shard_bytes`` is the per-device payload at the
    scattered end (AG input / RS output); for "a2a" it is the node's full
    local exchange buffer (all N destination blocks), matching the IR's
    scaled-payload law (stage j moves shard/f_j)."""
    axis_names = tuple(axis_names)
    if isinstance(mesh, dict):
        sizes = {n: int(mesh[n]) for n in axis_names}
    else:
        sizes = {n: mesh.shape[n] for n in axis_names}
    axes = [(sizes[n], link_for_axis(n, links)) for n in axis_names]
    ag_plan = plan_axis_order(axes, shard_bytes, max_chunks=max_chunks)
    rs_plan = plan_reduce_scatter_order(axes, shard_bytes, max_chunks=max_chunks)
    ag_order = names_for_plan(ag_plan, axis_names, sizes, links)
    rs_order = names_for_plan(rs_plan, axis_names, sizes, links)
    ag_links = [s.link for s in ag_plan.stages]
    rs_links = [s.link for s in rs_plan.stages]
    scheds = {
        "ag": (choose_hop_schedule(
            ag_plan.factors, ag_links, shard_bytes,
            max_chunks=max_chunks, collective="ag"), ag_order),
        "rs": (choose_hop_schedule(
            rs_plan.factors, rs_links, shard_bytes,
            max_chunks=max_chunks, collective="rs"), rs_order),
        "ar": (choose_hop_schedule(
            rs_plan.factors, rs_links, shard_bytes,
            max_chunks=max_chunks, collective="ar"),
            rs_order + tuple(reversed(rs_order))),
        # electrical a2a cost is stage-order invariant (each stage moves
        # shard·(f-1)/f regardless of position), so reuse the AG order as
        # the deterministic choice; order-sensitive optical planning goes
        # through search_stage_orders / PlanPolicy(order="search") instead
        "a2a": (choose_hop_schedule(
            ag_plan.factors, ag_links, shard_bytes,
            max_chunks=max_chunks, collective="a2a"), ag_order),
    }
    plans: Dict[str, CollectivePlan] = {}
    for coll, (sched, order) in scheds.items():
        plan = sched.to_ir(order)
        plans[coll] = dataclasses.replace(
            plan, meta={**plan.meta, "axis_names": axis_names})
    return plans


def fit_chunks(length: int, granularity: int, chunks: int) -> int:
    """Largest power-of-two <= chunks such that length divides into
    granularity*chunks pieces (planner chunk counts are powers of two)."""
    while chunks > 1 and length % (granularity * chunks):
        chunks //= 2
    return chunks


class StagedCollectiveEngine:
    """DEPRECATED shim over the context-scoped API (``repro.comms.api``).

    The engine predates :class:`~repro.comms.api.CommContext`; it now IS
    one — each method delegates to the module-level ops with an explicit
    ``ctx=`` handle, so legacy call sites share the same plan cache,
    policy machinery and links auto-invalidation as the new surface:

        eng = StagedCollectiveEngine(mesh, ("pod", "data"))
        y = eng.all_reduce(x)          # == api.all_reduce(x, ctx=eng.ctx)

    New code should use ``comm_context(mesh, axis_names)`` + the
    ``repro.comms.api`` ops directly.
    """

    def __init__(
        self,
        mesh: Mesh,
        axis_names: Sequence[str],
        *,
        links: Optional[Dict[str, LinkSpec]] = None,
        max_chunks: int = 8,
    ):
        import warnings

        from .api import CommContext, PlanPolicy

        warnings.warn(
            "StagedCollectiveEngine is deprecated; use "
            "repro.comms.api.comm_context(mesh, axis_names) and the "
            "module-level ops", DeprecationWarning, stacklevel=2)
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.max_chunks = max_chunks
        self.n_devices = math.prod(mesh.shape[n] for n in self.axis_names)
        self.ctx = CommContext(
            mesh, self.axis_names, links=links,
            policy=PlanPolicy(max_chunks=max_chunks),
        )

    @property
    def links(self):
        return self.ctx.links

    def plan(self, x: jax.Array, collective: str = "ag") -> CollectivePlan:
        """The CollectivePlan the context would execute for ``x``.

        ``x`` is the full-length array in every case (sharded for AG,
        replicated for RS/AR); the scattered-end payload is nbytes/N."""
        shard_bytes = x.size * x.dtype.itemsize / self.n_devices
        return self.ctx.plan(collective, shard_bytes,
                             shape=tuple(x.shape), dtype=x.dtype)

    def all_gather(
        self, x: jax.Array, *, axis: int = 0, mode: Optional[str] = None
    ) -> jax.Array:
        """x sharded over ``axis_names`` along ``axis`` -> replicated."""
        from . import api

        return api.all_gather(x, axis=axis, ctx=self.ctx, mode=mode)

    def reduce_scatter(
        self, x: jax.Array, *, axis: int = 0, mode: Optional[str] = None
    ) -> jax.Array:
        """x replicated -> summed and scattered over ``axis_names``."""
        from . import api

        return api.reduce_scatter(x, axis=axis, ctx=self.ctx, mode=mode)

    def all_reduce(
        self, x: jax.Array, *, axis: int = 0, mode: Optional[str] = None
    ) -> jax.Array:
        """x replicated -> psum over ``axis_names`` (device count factor)."""
        from . import api

        return api.all_reduce(x, axis=axis, ctx=self.ctx, mode=mode)
