"""Staged (OpTree) all-gather over factorized mesh axes.

``staged_all_gather`` is the *inside-shard_map* primitive: it runs the
paper's k stages as a sequence of single-sub-axis all-gathers.  Gathering
minor-to-major needs no data movement beyond the collectives themselves;
any other stage order (e.g. the OpTree-optimal "slow/major axis first while
the payload is small") is followed by one local transpose to restore the
canonical order — layout work, not communication.

``optree_all_gather`` is the user-facing wrapper: plans the stage order from
the cost model (core.planner ≙ Theorem 2) and wraps shard_map.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..core.planner import ICI_LINK, DCN_LINK, LinkSpec, plan_axis_order

__all__ = ["staged_all_gather", "canonical_all_gather", "optree_all_gather",
           "link_for_axis", "names_for_plan"]


def link_for_axis(name: str, links: Optional[dict] = None) -> LinkSpec:
    """Link model for a mesh axis: explicit map wins, else 'pod*' names are
    DCN-class and everything else ICI."""
    if links and name in links:
        return links[name]
    return DCN_LINK if name.startswith("pod") else ICI_LINK


def names_for_plan(plan, axis_names, sizes, links=None):
    """Map a planned (size, link) stage sequence back to axis names (stable
    for duplicate (size, link) pairs)."""
    remaining = list(axis_names)
    order = []
    for st in plan.stages:
        for n in remaining:
            if sizes[n] == st.factor and link_for_axis(n, links).name == st.link.name:
                order.append(n)
                remaining.remove(n)
                break
    assert not remaining, (order, remaining)
    return tuple(order)


def staged_all_gather(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
) -> jax.Array:
    """k-stage all-gather inside shard_map.

    Args:
      x: local shard.
      axis_names: the factorized sub-axes of the logical gather axis,
        *major first* (mesh order).  ``prod(sizes) = N``.
      stage_order: the order stages execute (default: paper order — major
        first, i.e. slowest/most-distant links carry the smallest payload).
      axis: array axis to gather along.

    Returns the same value as ``jax.lax.all_gather(x, tuple(axis_names),
    axis=axis, tiled=True)`` — i.e. blocks concatenated in canonical
    (major-first) device order.
    """
    axis_names = tuple(axis_names)
    order = tuple(stage_order) if stage_order is not None else axis_names
    if sorted(order) != sorted(axis_names):
        raise ValueError(f"stage_order {order} must permute {axis_names}")

    if order == tuple(reversed(axis_names)):
        # minor-to-major: tiled gathers compose to canonical order directly
        y = x
        for name in order:
            y = jax.lax.all_gather(y, name, axis=axis, tiled=True)
        return y

    # general order: stack stages as leading axes, then one local fix-up
    y = x
    for name in order:
        y = jax.lax.all_gather(y, name, axis=0, tiled=False)
    # leading stacked axes are reversed(order); want axis_names order
    stacked = tuple(reversed(order))
    perm_named = tuple(stacked.index(n) for n in axis_names)
    rest = tuple(range(len(axis_names), y.ndim))
    y = jnp.transpose(y, perm_named + rest)
    # collapse the k stacked axes into the target axis
    k = len(axis_names)
    gathered = math.prod(y.shape[:k])
    y = y.reshape((gathered,) + y.shape[k:])  # (N, *x.shape)
    # merge into `axis`: (N, ..., s, ...) -> (..., N*s, ...)
    if axis != 0:
        y = jnp.moveaxis(y, 0, axis)
        pre = y.shape[:axis]
        y = y.reshape(pre + (y.shape[axis] * y.shape[axis + 1],) + y.shape[axis + 2 :])
    else:
        y = y.reshape((y.shape[0] * y.shape[1],) + y.shape[2:])
    return y


def canonical_all_gather(x: jax.Array, axis_names: Sequence[str], axis: int = 0) -> jax.Array:
    """XLA's own single-shot all-gather over the product axis (baseline)."""
    return jax.lax.all_gather(x, tuple(axis_names), axis=axis, tiled=True)


def optree_all_gather(
    x: jax.Array,
    mesh: Mesh,
    axis_names: Sequence[str],
    *,
    links: Optional[dict] = None,
    axis: int = 0,
    in_spec: Optional[P] = None,
    out_spec: Optional[P] = None,
) -> jax.Array:
    """User-facing staged all-gather: plans the stage order (Theorem 2
    analogue) and runs it under shard_map.

    Args:
      x: globally-sharded array (sharded along ``axis`` over ``axis_names``).
      links: optional map axis_name -> LinkSpec (defaults: 'pod*' -> DCN,
        else ICI) for the planner.
    """
    axis_names = tuple(axis_names)
    sizes = {n: mesh.shape[n] for n in axis_names}

    shard_bytes = x.size * x.dtype.itemsize / math.prod(sizes.values())
    axes = [(sizes[n], link_for_axis(n, links)) for n in axis_names]
    plan = plan_axis_order(axes, shard_bytes)
    order = names_for_plan(plan, axis_names, sizes, links)

    ispec = in_spec if in_spec is not None else P(axis_names)
    ospec = out_spec if out_spec is not None else P()

    fn = shard_map(
        lambda y: staged_all_gather(y, axis_names, stage_order=order, axis=axis),
        mesh=mesh,
        in_specs=ispec,
        out_specs=ospec,
    )
    return fn(x)
