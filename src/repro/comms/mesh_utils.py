"""Mesh helpers: factorized axes are how OpTree's m-ary tree lands on a mesh.

A paper "k-stage m-ary tree over N ring nodes" becomes a device axis of size
N split into named sub-axes (m_1, ..., m_k), *major first*: the linear device
position along the logical axis is

    p = i_1 * (N/m_1) + i_2 * (N/(m_1 m_2)) + ... + i_k

which is exactly `jax.make_mesh((m_1, ..., m_k), names)` device order.  Stage
j of the paper (subsets = "same position across the m_j siblings") is an
all-gather over sub-axis j.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax

from ..compat import auto_axis_types, make_mesh

__all__ = ["make_factorized_mesh", "auto_axis_types"]


def make_factorized_mesh(
    factors: Sequence[int],
    names: Sequence[str],
    *,
    devices=None,
) -> jax.sharding.Mesh:
    """Mesh whose axes are the stage factors of one logical OpTree axis
    (optionally combined with other parallelism axes by the caller)."""
    if len(factors) != len(names):
        raise ValueError("factors and names must align")
    n = math.prod(factors)
    devs = devices if devices is not None else jax.devices()
    if n > len(devs):
        raise ValueError(f"need {n} devices, have {len(devs)}")
    return make_mesh(tuple(factors), tuple(names), devices=devs[:n])
