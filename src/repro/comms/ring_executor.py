"""Per-hop ring executor: double-buffered ppermute schedules for the staged
engine.

PR 1's staged collectives issue one blocking XLA collective per stage —
Eq. 3's ``(d/B + a)·S`` with every stage a barrier.  This module is the
execution layer below that granularity: each stage runs as an explicit ring
of ``ppermute`` hops, structured so the block received at hop t is
*forwarded* at hop t+1 while its local copy (all-gather) or local
reduce/add (reduce-scatter) runs concurrently — the double-buffering that
``core.planner.perhop_stage_time`` models (α amortized across in-flight
hops, only the longer of the serialization/launch chains exposed).

Every executor composes stage-by-stage exactly like the staged primitives in
``staged_collectives.py`` (stacking form + one local fix-up for AG; one
local block permutation for RS), so any planner stage order is supported and
the results are bit-identical to the XLA one-shot collectives (all-reduce:
identical up to reduction order).  ``stage_modes`` lets the planner pick the
executor per stage: ``"ring"`` (per-hop ppermute) where the overlap model
wins, ``"oneshot"`` (the blocking XLA collective) where a stage is too small
to pipeline — see ``core.planner.choose_hop_schedule``.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from .staged_collectives import (
    _a2a_merge_digits,
    _a2a_split_digits,
    _ag_finalize,
    _axis_sizes,
    _check_order,
    _permute_blocks_to_order,
    _split_rs_chunks,
    _wavefront,
)

__all__ = [
    "FaultInjection",
    "fault_injection",
    "ring_all_gather_stage",
    "ring_reduce_scatter_stage",
    "ring_all_to_all_stage",
    "perhop_all_gather",
    "perhop_reduce_scatter",
    "perhop_all_reduce",
    "perhop_all_to_all",
    "hybrid_all_gather",
    "hybrid_reduce_scatter",
    "hybrid_all_reduce",
    "hybrid_all_to_all",
]


def _ring_perm(m: int) -> List[Tuple[int, int]]:
    return [(i, (i + 1) % m) for i in range(m)]


# --------------------------------------------------------------------------
# fault injection (chaos harness hook)
# --------------------------------------------------------------------------

@dataclass
class FaultInjection:
    """Corrupt a chosen ppermute hop of a ring stage, for chaos tests.

    ``axis`` is the mesh axis whose ring stage to hit, ``hop`` the 1-based
    hop index within the stage, ``mode`` either ``"drop"`` (the received
    block arrives zeroed — a lost lightpath) or ``"corrupt"`` (+1 to every
    element — a payload bit flip).  ``times`` bounds how many matching hop
    *traces* are corrupted: the executor's bounded retry re-traces the
    stage per attempt, so ``times=1`` means only the first attempt sees the
    fault (the retry genuinely recovers) while a large ``times`` keeps
    every attempt faulty (forcing the one-shot fallback).  ``device``
    optionally restricts the fault to one position on the ring.
    """

    axis: str
    hop: int = 1
    mode: str = "drop"
    times: int = 1
    device: Optional[int] = None
    applied: int = 0  # mutable: matching hop traces consumed so far

    def __post_init__(self) -> None:
        if self.mode not in ("drop", "corrupt"):
            raise ValueError(f"mode must be drop|corrupt, got {self.mode!r}")


_INJECTIONS: List[FaultInjection] = []


@contextmanager
def fault_injection(spec: FaultInjection):
    """Activate ``spec`` for every ring stage traced inside the block."""
    _INJECTIONS.append(spec)
    try:
        yield spec
    finally:
        _INJECTIONS.remove(spec)


def _maybe_inject(recv: jax.Array, name: str, hop: int) -> jax.Array:
    """Pass a just-received ppermute block through the active injections."""
    for spec in _INJECTIONS:
        if spec.axis != name or spec.hop != hop or spec.applied >= spec.times:
            continue
        spec.applied += 1
        if spec.mode == "drop":
            bad = jnp.zeros_like(recv)
        else:
            bad = recv + jnp.ones_like(recv)
        if spec.device is None:
            recv = bad
        else:
            recv = jnp.where(lax.axis_index(name) == spec.device, bad, recv)
    return recv


def _store(buf: jax.Array, piece: jax.Array, slot) -> jax.Array:
    return lax.dynamic_update_slice(
        buf, piece[None], (slot,) + (0,) * piece.ndim
    )


def ring_all_gather_stage(x: jax.Array, name: str) -> jax.Array:
    """One ring all-gather stage in stacking form: equals
    ``lax.all_gather(x, name, axis=0, tiled=False)``.

    m-1 ppermute hops, double-buffered: the block received at hop t is
    forwarded at hop t+1 while only being *referenced* locally (pieces are
    collected in arrival order — origin ``idx - t``), so nothing serializes
    against the sends.  One flip+roll at the end rotates arrival order into
    origin order — a single local copy instead of m buffer updates.
    """
    m = axis_size(name)
    if m == 1:
        return x[None]
    idx = lax.axis_index(name)
    perm = _ring_perm(m)
    pieces = [x]  # arrival order: origin idx, idx-1, ..., idx-(m-1)
    for t in range(1, m):
        pieces.append(_maybe_inject(lax.ppermute(pieces[-1], name, perm),
                                    name, t))
    # arrival[t] holds origin (idx - t) mod m; flipping gives origin
    # (idx + 1 + j) mod m at slot j, and rolling by idx+1 lands origin j
    # at slot j — the all_gather stacking order
    stacked = jnp.flip(jnp.stack(pieces, axis=0), axis=0)
    return jnp.roll(stacked, idx + 1, axis=0)


def ring_reduce_scatter_stage(
    y: jax.Array, name: str, *, block_fn=None
) -> jax.Array:
    """One ring reduce-scatter stage: equals ``lax.psum_scatter(y, name,
    scatter_dimension=0, tiled=True)`` up to reduction order (exact for
    exactly-representable sums).

    The accumulator for block b travels the ring b+1 → ... → b, gaining one
    local contribution per hop; the local block's slice+add for hop t runs
    while hop t's ppermute is in flight.

    ``block_fn(b)`` overrides the local-contribution provider (default: the
    b-th of m contiguous slices of ``y``) — the collective-matmul fusion
    plugs in a just-in-time block matmul here.
    """
    m = axis_size(name)
    if m == 1:
        return y if block_fn is None else block_fn(0)
    if block_fn is None:
        if y.shape[0] % m:
            raise ValueError(
                f"length {y.shape[0]} not divisible by ring size {m}"
            )
        blk = y.shape[0] // m

        def block_fn(b):
            return lax.dynamic_slice_in_dim(y, b * blk, blk, axis=0)

    idx = lax.axis_index(name)
    perm = _ring_perm(m)
    acc = block_fn((idx - 1) % m)  # own contribution to the departing block
    for s in range(1, m):
        recv = _maybe_inject(lax.ppermute(acc, name, perm), name, s)
        acc = recv + block_fn((idx - s - 1) % m)
    return acc


def ring_all_to_all_stage(y: jax.Array, name: str) -> jax.Array:
    """One ring all-to-all digit transpose on the leading (m, ...) axis:
    equals ``lax.all_to_all(y, name, split_axis=0, concat_axis=0,
    tiled=True)`` bit for bit.

    m-1 ppermute hops, hop t carrying exactly the slices whose digit shift
    is t: device q ships its resident slice (q+t) mod m along the rotation
    q → (q+t) mod m, and receiver r files the arrival under origin
    (r-t) mod m.  Unlike the gather ring there is NO forwarding chain —
    every hop sends a distinct locally-resident slice, the causal
    independence the per-hop overlap model prices.  Arrival slot t holds
    origin (idx - t) mod m, so the same flip+roll as the all-gather ring
    restores origin order in one local copy.
    """
    m = axis_size(name)
    if m == 1:
        return y
    if y.shape[0] != m:
        raise ValueError(f"digit axis {y.shape[0]} != ring size {m}")
    idx = lax.axis_index(name)
    pieces = [lax.dynamic_index_in_dim(y, idx, axis=0, keepdims=False)]
    for t in range(1, m):
        send = lax.dynamic_index_in_dim(
            y, (idx + t) % m, axis=0, keepdims=False
        )
        perm = [(i, (i + t) % m) for i in range(m)]
        pieces.append(_maybe_inject(lax.ppermute(send, name, perm), name, t))
    stacked = jnp.flip(jnp.stack(pieces, axis=0), axis=0)
    return jnp.roll(stacked, idx + 1, axis=0)


def _resolve_modes(
    stage_modes: Optional[Sequence[str]], k: int
) -> Tuple[str, ...]:
    if stage_modes is None:
        return ("ring",) * k
    modes = tuple(stage_modes)
    if len(modes) != k or any(m not in ("ring", "oneshot") for m in modes):
        raise ValueError(
            f"stage_modes must be {k} of 'ring'|'oneshot', got {modes}"
        )
    return modes


def _merge_device_axis(y: jax.Array, axis: int) -> jax.Array:
    """Fold a leading (N,) device-block axis into local axis ``axis``."""
    if axis == 0:
        return y.reshape((y.shape[0] * y.shape[1],) + y.shape[2:])
    y = jnp.moveaxis(y, 0, axis)
    pre = y.shape[:axis]
    return y.reshape(pre + (y.shape[axis] * y.shape[axis + 1],) + y.shape[axis + 2:])


def perhop_all_gather(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    stage_modes: Optional[Sequence[str]] = None,
    stage_probe: Optional[Callable] = None,
) -> jax.Array:
    """Per-hop staged all-gather inside shard_map: bit-identical to
    ``lax.all_gather(x, tuple(axis_names), axis=axis, tiled=True)``.

    Stages run in ``stage_order`` (default major-first, the paper order),
    each as a double-buffered ppermute ring (or the blocking XLA collective
    where ``stage_modes`` says ``"oneshot"``); the stacked stage axes are
    collapsed to canonical device order by one local transpose at the end.

    ``stage_probe(before, after, name)`` is called once per stage with the
    stage's traced input/output — the hook the verified executor uses for
    per-stage conservation checksums.
    """
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else axis_names
    )
    modes = _resolve_modes(stage_modes, len(order))

    if axis < 0:
        axis += x.ndim
    y = x
    for name, mode in zip(order, modes):
        before = y
        if mode == "ring":
            y = ring_all_gather_stage(y, name)
        else:
            y = lax.all_gather(y, name, axis=0, tiled=False)
        if stage_probe is not None:
            stage_probe(before, y, name)
    y = _ag_finalize(y, axis_names, order)  # (N, *x.shape)
    return _merge_device_axis(y, axis)


def perhop_reduce_scatter(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    stage_modes: Optional[Sequence[str]] = None,
    stage_probe: Optional[Callable] = None,
) -> jax.Array:
    """Per-hop staged reduce-scatter: equals ``lax.psum_scatter(x,
    tuple(axis_names), scatter_dimension=axis, tiled=True)`` (bit-identical
    for exactly-representable sums; ring stages reduce in ring order).

    Default stage order is the paper-optimal reverse (slow axes last, on the
    smallest payload); any order composes via the same local pre-permutation
    ``staged_reduce_scatter`` uses.
    """
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else tuple(reversed(axis_names))
    )
    modes = _resolve_modes(stage_modes, len(order))
    sizes = _axis_sizes(axis_names)

    if axis < 0:
        axis += x.ndim
    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    n_total = math.prod(sizes.values())
    if y.shape[0] % n_total:
        raise ValueError(
            f"axis length {y.shape[0]} not divisible by devices {n_total}"
        )
    if order != axis_names:
        y = _permute_blocks_to_order(y, axis_names, order, sizes)
    for name, mode in zip(order, modes):
        before = y
        if mode == "ring":
            y = ring_reduce_scatter_stage(y, name)
        else:
            y = lax.psum_scatter(y, name, scatter_dimension=0, tiled=True)
        if stage_probe is not None:
            stage_probe(before, y, name)
    return jnp.moveaxis(y, 0, axis) if axis != 0 else y


def _a2a_stage_dispatch(y, name, dim, mode):
    """One a2a digit transpose on digit axis ``dim``: a double-buffered
    ppermute rotation ("ring") or the blocking XLA collective ("oneshot")."""
    if mode == "ring":
        y = jnp.moveaxis(y, dim, 0) if dim != 0 else y
        y = ring_all_to_all_stage(y, name)
        return jnp.moveaxis(y, 0, dim) if dim != 0 else y
    return lax.all_to_all(y, name, split_axis=dim, concat_axis=dim, tiled=True)


def perhop_all_to_all(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    stage_modes: Optional[Sequence[str]] = None,
    stage_probe: Optional[Callable] = None,
) -> jax.Array:
    """Per-hop staged all-to-all inside shard_map: bit-identical to
    ``lax.all_to_all(x, tuple(axis_names), split_axis=axis,
    concat_axis=axis, tiled=True)``.

    The N-block exchange factorizes into k per-sub-axis digit transposes
    that commute — any ``stage_order`` yields the identical output (no
    finalize transpose needed, unlike the gather family); only the modeled
    cost differs.  Each stage runs as a ppermute rotation ring or the
    blocking XLA collective per ``stage_modes``.
    """
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else axis_names
    )
    modes = _resolve_modes(stage_modes, len(order))
    sizes = _axis_sizes(axis_names)
    k = len(axis_names)

    if axis < 0:
        axis += x.ndim
    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    shaped = _a2a_split_digits(y, axis_names, sizes)
    for name, mode in zip(order, modes):
        before = shaped
        shaped = _a2a_stage_dispatch(shaped, name, axis_names.index(name), mode)
        if stage_probe is not None:
            stage_probe(before, shaped, name)
    out = _a2a_merge_digits(shaped, k)
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


# --------------------------------------------------------------------------
# hybrid execution: the chunk wavefront OVER per-hop ring stages
# --------------------------------------------------------------------------
#
# ``staged_collectives`` pipelines C chunks over BLOCKING whole-stage
# collectives; the executors below run the same wavefront with each stage
# dispatched per its planner stage mode — a "ring" stage is the
# double-buffered ppermute ring, an "oneshot" stage the XLA collective — so
# chunk i's stage j overlaps chunk i-1's stage j+1 AND every ring stage's
# hops double-buffer internally.  This is the IR's ``hybrid`` plan mode
# (``core.planner.choose_hop_schedule`` emits it when its modeled makespan
# beats both pure modes); outputs stay bit-identical to the XLA one-shot
# collectives exactly like the pure paths (ring AG == all_gather stacking
# form; ring RS reduces in ring order — exact for exactly-representable
# sums).

def _hyb_ag_stage(ch: jax.Array, name: str, mode: str) -> jax.Array:
    if mode == "ring":
        return ring_all_gather_stage(ch, name)
    return lax.all_gather(ch, name, axis=0, tiled=False)


def _hyb_rs_stage(ch: jax.Array, name: str, mode: str) -> jax.Array:
    if mode == "ring":
        return ring_reduce_scatter_stage(ch, name)
    return lax.psum_scatter(ch, name, scatter_dimension=0, tiled=True)


def hybrid_all_gather(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    num_chunks: int = 2,
    stage_modes: Optional[Sequence[str]] = None,
) -> jax.Array:
    """Chunk-wavefront per-hop staged all-gather: equals
    ``lax.all_gather(x, tuple(axis_names), axis=axis, tiled=True)`` bit for
    bit (same chunk interleave as ``staged_all_gather_chunked``, same ring
    stages as ``perhop_all_gather``)."""
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else axis_names
    )
    modes = _resolve_modes(stage_modes, len(order))

    if axis < 0:
        axis += x.ndim
    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    shard = y.shape[0]
    if shard % num_chunks:
        raise ValueError(f"shard length {shard} not divisible by {num_chunks}")
    per_chunk = shard // num_chunks
    chunks = [y[c * per_chunk:(c + 1) * per_chunk] for c in range(num_chunks)]
    chunks = _wavefront(
        chunks, len(order),
        lambda ch, j: _hyb_ag_stage(ch, order[j], modes[j]),
    )
    gathered = [_ag_finalize(ch, axis_names, order) for ch in chunks]
    out = jnp.stack(gathered, axis=1)  # (N, C, per_chunk, ...)
    n_total = out.shape[0]
    out = out.reshape((n_total * shard,) + out.shape[3:])
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def hybrid_reduce_scatter(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    num_chunks: int = 2,
    stage_modes: Optional[Sequence[str]] = None,
) -> jax.Array:
    """Chunk-wavefront per-hop staged reduce-scatter: equals
    ``lax.psum_scatter(x, tuple(axis_names), scatter_dimension=axis,
    tiled=True)`` (exact for exactly-representable sums)."""
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else tuple(reversed(axis_names))
    )
    modes = _resolve_modes(stage_modes, len(order))
    sizes = _axis_sizes(axis_names)

    if axis < 0:
        axis += x.ndim
    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    chunks = _split_rs_chunks(y, axis_names, order, sizes, num_chunks)
    chunks = _wavefront(
        chunks, len(order),
        lambda ch, j: _hyb_rs_stage(ch, order[j], modes[j]),
    )
    out = chunks[0] if num_chunks == 1 else jnp.concatenate(chunks, axis=0)
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def hybrid_all_to_all(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    num_chunks: int = 2,
    stage_modes: Optional[Sequence[str]] = None,
) -> jax.Array:
    """Chunk-wavefront per-hop staged all-to-all: equals
    ``lax.all_to_all(x, tuple(axis_names), split_axis=axis,
    concat_axis=axis, tiled=True)`` bit for bit (same block-interior chunk
    split as ``staged_all_to_all``, same digit-transpose stages as
    ``perhop_all_to_all``)."""
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else axis_names
    )
    modes = _resolve_modes(stage_modes, len(order))
    sizes = _axis_sizes(axis_names)
    k = len(axis_names)

    if axis < 0:
        axis += x.ndim
    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    shaped = _a2a_split_digits(y, axis_names, sizes)
    block = shaped.shape[k]
    if block % num_chunks:
        raise ValueError(
            f"block interior {block} not divisible by {num_chunks} chunks"
        )
    per = block // num_chunks
    chunks = [
        lax.slice_in_dim(shaped, c * per, (c + 1) * per, axis=k)
        for c in range(num_chunks)
    ]
    chunks = _wavefront(
        chunks, k,
        lambda ch, j: _a2a_stage_dispatch(
            ch, order[j], axis_names.index(order[j]), modes[j]),
    )
    out = chunks[0] if num_chunks == 1 else jnp.concatenate(chunks, axis=k)
    out = _a2a_merge_digits(out, k)
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def hybrid_all_reduce(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    rs_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    num_chunks: int = 2,
    stage_modes: Optional[Sequence[str]] = None,
) -> jax.Array:
    """Chunk-wavefront per-hop staged all-reduce (RS then AG over one plan,
    the 2k-stage chain pipelined across chunks): equals ``lax.psum(x,
    tuple(axis_names))`` up to ring-stage reduction order.  ``stage_modes``
    covers the full 2k-stage chain, matching
    ``choose_hop_schedule(..., collective="ar")``."""
    axis_names = tuple(axis_names)
    order = (
        _check_order(rs_order, axis_names)
        if rs_order is not None
        else tuple(reversed(axis_names))
    )
    ag_order = tuple(reversed(order))
    k = len(axis_names)
    modes = _resolve_modes(stage_modes, 2 * k)
    sizes = _axis_sizes(axis_names)

    if axis < 0:
        axis += x.ndim
    y = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    length = y.shape[0]
    chunks = _split_rs_chunks(y, axis_names, order, sizes, num_chunks)

    def apply_stage(ch, j):
        if j < k:
            return _hyb_rs_stage(ch, order[j], modes[j])
        return _hyb_ag_stage(ch, ag_order[j - k], modes[j])

    chunks = _wavefront(chunks, 2 * k, apply_stage)
    gathered = [_ag_finalize(ch, axis_names, ag_order) for ch in chunks]
    out = jnp.stack(gathered, axis=1)  # (N, C, per_chunk, ...)
    out = out.reshape((length,) + out.shape[3:])
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def perhop_all_reduce(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    rs_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    stage_modes: Optional[Sequence[str]] = None,
) -> jax.Array:
    """Per-hop staged all-reduce: RS then AG sharing one plan (the AG stage
    order is the reverse of the RS order).  Equals ``lax.psum(x,
    tuple(axis_names))`` up to reduction order.

    ``stage_modes`` covers the full 2k-stage chain (RS stages then AG
    stages), matching ``choose_hop_schedule(..., collective="ar")``.
    """
    axis_names = tuple(axis_names)
    order = (
        _check_order(rs_order, axis_names)
        if rs_order is not None
        else tuple(reversed(axis_names))
    )
    k = len(axis_names)
    modes = _resolve_modes(stage_modes, 2 * k)
    y = perhop_reduce_scatter(
        x, axis_names, stage_order=order, axis=axis, stage_modes=modes[:k]
    )
    return perhop_all_gather(
        y, axis_names, stage_order=tuple(reversed(order)), axis=axis,
        stage_modes=modes[k:],
    )
