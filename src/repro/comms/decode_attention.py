"""Sharded-KV decode attention (flash-decoding style two-pass combine).

When a KV cache is *sequence*-sharded (the layout the framework picks when
kv-head count doesn't divide the TP axis — DESIGN.md §6), each model shard
holds a contiguous slice of the keys/values.  Decode attention then runs in
two passes:

  1. locally: partial online-softmax statistics over the shard's slice
     (max m_i, denominator l_i, weighted accumulator o_i);
  2. globally: a log-sum-exp-weighted combine across the axis —
     three tiny collectives (pmax + 2 psum of (B,H[,hd])-sized tensors)
     instead of gathering the full cache.

This is the shard_map primitive behind the pjit layout; its collectives are
what XLA emits for that layout, written explicitly so serving stacks can
call it directly.

The two psum combines route through the context-scoped collectives API
(``repro.comms.api.all_reduce``) — decode collectives plan through the
innermost ``comm_context`` (``launch/serve.py`` installs one) and hit its
plan cache like every other collective in the stack; head counts that
don't divide the axis fall back to the flat ``lax.psum`` inside the api
op, so the old contract is unchanged.  The pmax is a scalar-combine (not
gather-shaped) and stays on ``lax``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["sharded_decode_attention"]


def sharded_decode_attention(
    q: jax.Array,  # (B, H, 1, hd) — replicated across the axis
    k_shard: jax.Array,  # (B, Hkv, T_local, hd) — local KV slice
    v_shard: jax.Array,
    *,
    axis_name: str,
    valid_len: jax.Array,  # () global number of valid cache positions
    scale: Optional[float] = None,
) -> jax.Array:
    """Attention of one query against an axis-sharded KV cache."""
    B, H, _, hd = q.shape
    Hkv, T_local = k_shard.shape[1], k_shard.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)

    idx = lax.axis_index(axis_name)
    start = idx * T_local
    pos = start + jnp.arange(T_local)  # global positions of local keys
    valid = pos < valid_len  # (T_local,)

    kx = jnp.repeat(k_shard, rep, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v_shard, rep, axis=1).astype(jnp.float32)
    q32 = q[:, :, 0].astype(jnp.float32)  # (B, H, hd)

    s = jnp.einsum("bhd,bhtd->bht", q32, kx) * scale  # (B, H, T_local)
    s = jnp.where(valid[None, None, :], s, -jnp.inf)

    m_local = jnp.max(s, axis=-1)  # (B, H)
    # guard all-invalid shards
    m_safe = jnp.where(jnp.isfinite(m_local), m_local, -1e30)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(valid[None, None, :], p, 0.0)
    l_local = jnp.sum(p, axis=-1)  # (B, H)
    o_local = jnp.einsum("bht,bhtd->bhd", p, vx)  # (B, H, hd)

    # two-pass combine across the axis: the psums are context-planned
    # (staged AR when the head dim divides the axis, flat psum otherwise)
    from . import api  # local: comms.api imports this package lazily too

    m_global = lax.pmax(m_safe, axis_name)  # (B, H)
    alpha = jnp.exp(m_safe - m_global)
    l_global = api.all_reduce(l_local * alpha, axes=(axis_name,))
    o_global = api.all_reduce(o_local * alpha[..., None], axes=(axis_name,))
    l_global = jnp.where(l_global == 0.0, 1.0, l_global)
    out = o_global / l_global[..., None]
    return out[:, :, None, :].astype(q.dtype)  # (B, H, 1, hd)
