"""Pairwise-exchange executor: recursive-doubling rounds for latency plans.

The planner's latency regime (``core.planner.plan_latency_collective``)
emits plans whose every stage is a factor-2 bidirectional pairwise exchange
(``PlanStage.mode == "exchange"``): log2(n)-ish round chains instead of the
m-ary ring chains the bandwidth regime uses.  This module executes those
rounds inside shard_map as paired ``ppermute``s — each round, every device
swaps its whole buffer (gather) or half its buffer (scatter) with the
partner whose index differs in one bit of one mesh-axis coordinate.

Digit bookkeeping: a plan's rounds are grouped per axis (the planner emits
each axis's rounds contiguously).  A gather group over an axis of size
``2^k`` runs k rounds MSB-first (round t pairs across bit ``k-1-t``), each
stacking the received buffer as a new LEADING digit axis, so the final digit
order is the reverse of round order; one closing transpose + reshape lands
the blocks in the canonical major-first ``meta["axis_names"]`` layout — the
same output convention as ``ring_executor``/``staged_collectives``, so the
results are bit-identical to the XLA one-shot collectives (AG/RS exactly;
AR up to reduction order).  A scatter group is the time-mirror: the input is
pre-transposed from canonical digit order into round order, then each round
keeps the half matching this device's bit and sends the other half to the
partner, adding what arrives.

``stage_probe(before, after, name)`` fires once per AXIS GROUP (not per
round) with the group's entry/exit buffers — group-level conservation over
the full named axis, the same checksum granularity
``plan_executor.execute_plan_verified`` uses on the ring paths.  Chaos
injection (``ring_executor.fault_injection``) applies per round, with hops
numbered 1..k within each group.
"""
from __future__ import annotations

import math
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from ..core.plan_ir import CollectivePlan, PlanStage
from .ring_executor import _maybe_inject

__all__ = [
    "exchange_all_gather",
    "exchange_reduce_scatter",
    "exchange_all_reduce",
]


def _canonical_names(plan: CollectivePlan) -> Tuple[str, ...]:
    names = plan.meta.get("axis_names")
    if not names:
        raise ValueError(
            "exchange plans need meta['axis_names'] (the canonical mesh "
            "axis order); build them via plan_latency_collective on named "
            "axes or through comms.api")
    return tuple(names)


def _axis_groups(stages: Sequence[PlanStage]) -> List[Tuple[str, int]]:
    """Contiguous runs of same-axis factor-2 exchange stages as
    ``(axis_name, num_rounds)``.  Each axis must form exactly one run —
    the planner builds chains that way and the digit bookkeeping relies
    on it."""
    groups: List[List] = []
    for s in stages:
        if s.mode != "exchange" or s.factor != 2:
            raise ValueError(
                f"exchange executor needs factor-2 exchange stages, got "
                f"factor={s.factor} mode={s.mode!r} on axis {s.axis!r}")
        if s.axis is None:
            raise ValueError("exchange stages need named mesh axes")
        if groups and groups[-1][0] == s.axis:
            groups[-1][1] += 1
        else:
            groups.append([s.axis, 1])
    run_names = [g[0] for g in groups]
    if len(set(run_names)) != len(run_names):
        raise ValueError(
            f"exchange rounds of one axis must be contiguous, got stage "
            f"axes {[s.axis for s in stages]}")
    out = []
    for name, k in groups:
        m = axis_size(name)
        if m != 1 << k:
            raise ValueError(
                f"axis {name!r} has size {m} but the plan carries {k} "
                f"factor-2 exchange rounds (needs size {1 << k})")
        out.append((name, k))
    return out


def _pair_perm(m: int, stride: int) -> List[Tuple[int, int]]:
    return [(i, i ^ stride) for i in range(m)]


def _canonical_digits(
    names: Sequence[str], ks: dict
) -> List[Tuple[str, int]]:
    """Digit labels in canonical output order: axes in ``names`` order
    (major first), each axis's bits MSB-first."""
    return [(n, s) for n in names for s in reversed(range(ks.get(n, 0)))]


def _gather_rounds(
    buf: jax.Array,
    groups: Sequence[Tuple[str, int]],
    probe: Optional[Callable],
) -> Tuple[jax.Array, List[Tuple[str, int]]]:
    """Run every gather group's rounds on ``buf`` (leading-axis block).

    Returns ``(stacked, digits)`` where ``stacked`` has one leading (2,)
    axis per round and ``digits`` labels those axes leading-to-trailing
    (newest round first, since each round stacks a new leading axis).
    """
    digits: List[Tuple[str, int]] = []
    for name, k in groups:
        idx = lax.axis_index(name)
        before = buf
        for t in range(k):
            sig = k - 1 - t  # MSB first
            recv = _maybe_inject(
                lax.ppermute(buf, name, _pair_perm(1 << k, 1 << sig)),
                name, t + 1)
            bit = (idx >> sig) & 1
            # new digit stacks LEADING: slot 0 = the bit-0 half
            buf = jnp.where(bit == 0, jnp.stack([buf, recv]),
                            jnp.stack([recv, buf]))
            digits.insert(0, (name, sig))
        if probe is not None:
            probe(before, buf, name)
    return buf, digits


def _scatter_rounds(
    buf: jax.Array,
    groups: Sequence[Tuple[str, int]],
    probe: Optional[Callable],
) -> jax.Array:
    """Run every scatter group's rounds.  ``buf`` arrives with one leading
    (2,) axis per round in ROUND order (first round's digit leading); each
    round consumes the leading axis — keep my bit's half, swap the other
    with the partner, add what arrives."""
    for name, k in groups:
        idx = lax.axis_index(name)
        before = buf
        for t in range(k):
            sig = t  # LSB first: the time-mirror of the gather rounds
            bit = (idx >> sig) & 1
            mine = jnp.where(bit == 0, buf[0], buf[1])
            other = jnp.where(bit == 0, buf[1], buf[0])
            recv = _maybe_inject(
                lax.ppermute(other, name, _pair_perm(1 << k, 1 << sig)),
                name, t + 1)
            buf = mine + recv
        if probe is not None:
            probe(before, buf, name)
    return buf


def _finalize_gather(
    buf: jax.Array,
    digits: List[Tuple[str, int]],
    names: Sequence[str],
    block_ndim: int,
) -> jax.Array:
    """Transpose the stacked digit axes into canonical order and collapse
    them (plus the local block axis) into one leading device-block axis —
    the tiled all_gather layout."""
    ks: dict = {}
    for n, s in digits:
        ks[n] = max(ks.get(n, 0), s + 1)
    canonical = _canonical_digits(names, ks)
    if sorted(canonical) != sorted(digits):
        raise ValueError(
            f"plan digits {sorted(digits)} do not cover the canonical "
            f"axes {list(names)}")
    K = len(digits)
    perm = tuple(digits.index(d) for d in canonical) + tuple(
        range(K, K + block_ndim))
    buf = jnp.transpose(buf, perm)
    return buf.reshape((-1,) + buf.shape[K + 1:])


def _split_canonical(
    x: jax.Array,
    groups: Sequence[Tuple[str, int]],
    names: Sequence[str],
) -> jax.Array:
    """Reshape a canonical full-length leading axis into per-digit (2,)
    axes and transpose them into the scatter ROUND order (first scatter
    round's digit leading)."""
    ks = {name: k for name, k in groups}
    canonical = _canonical_digits(names, ks)
    round_order = [(name, t) for name, k in groups for t in range(k)]
    K = len(canonical)
    n_total = 1 << K
    if x.shape[0] % n_total:
        raise ValueError(
            f"leading length {x.shape[0]} not divisible by group size "
            f"{n_total}")
    block = x.shape[0] // n_total
    buf = x.reshape((2,) * K + (block,) + x.shape[1:])
    perm = tuple(canonical.index(d) for d in round_order) + tuple(
        range(K, buf.ndim))
    return jnp.transpose(buf, perm)


def exchange_all_gather(
    y: jax.Array, plan: CollectivePlan, *, axis: int = 0,
    stage_probe: Optional[Callable] = None,
) -> jax.Array:
    """Recursive-doubling all-gather: equals ``lax.all_gather(y, names,
    axis=axis, tiled=True)`` bit for bit."""
    names = _canonical_names(plan)
    groups = _axis_groups(plan.stages)
    x = jnp.moveaxis(y, axis, 0)
    buf, digits = _gather_rounds(x, groups, stage_probe)
    out = _finalize_gather(buf, digits, names, x.ndim)
    return jnp.moveaxis(out, 0, axis)


def exchange_reduce_scatter(
    y: jax.Array, plan: CollectivePlan, *, axis: int = 0,
    stage_probe: Optional[Callable] = None,
) -> jax.Array:
    """Recursive-halving reduce-scatter: equals ``lax.psum_scatter(y,
    names, scatter_dimension=axis, tiled=True)`` up to reduction order
    (exact for exactly-representable sums)."""
    names = _canonical_names(plan)
    groups = _axis_groups(plan.stages)
    x = jnp.moveaxis(y, axis, 0)
    buf = _split_canonical(x, groups, names)
    out = _scatter_rounds(buf, groups, stage_probe)
    return jnp.moveaxis(out, 0, axis)


def exchange_all_reduce(
    y: jax.Array, plan: CollectivePlan, *, axis: int = 0,
    rs_probe: Optional[Callable] = None,
    ag_probe: Optional[Callable] = None,
) -> jax.Array:
    """Recursive halving-doubling all-reduce (scatter rounds then gather
    rounds — the plan's 2k exchange stages): equals ``lax.psum(y, names)``
    up to reduction order."""
    names = _canonical_names(plan)
    k = len(plan.stages) // 2
    rs_groups = _axis_groups(plan.stages[:k])
    ag_groups = _axis_groups(plan.stages[k:])
    x = jnp.moveaxis(y, axis, 0)
    buf = _split_canonical(x, rs_groups, names)
    block = _scatter_rounds(buf, rs_groups, rs_probe)
    gathered, digits = _gather_rounds(block, ag_groups, ag_probe)
    out = _finalize_gather(gathered, digits, names, block.ndim)
    return jnp.moveaxis(out, 0, axis)
