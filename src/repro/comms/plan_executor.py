"""IR-interpreting executor: run one CollectivePlan inside shard_map.

This is consumer (3) of the unified IR (``core.plan_ir``): the JAX engine
no longer re-derives stage orders, chunk counts, or per-stage execution
modes at the callsite — ``execute_plan`` reads them off the plan and maps
its stage chain onto the shard_map primitives:

  * plan mode ``chunked`` → the ``num_chunks``-chunk wavefront over
    blocking whole-stage collectives (``staged_collectives``);
  * plan mode ``hybrid`` → the same chunk wavefront run OVER the per-hop
    stage executors (``ring_executor.hybrid_*``): chunk i's stage j
    overlaps chunk i-1's stage j+1 while every ring stage double-buffers
    its own hops — the perhop-chunked combination the planner emits when
    its modeled makespan beats both pure modes;
  * otherwise → the staged executors of ``ring_executor`` with one
    ``stage_modes`` entry per stage: a stage whose effective IR mode is
    ``perhop`` runs as a double-buffered ppermute ring, the rest as the
    blocking XLA collective (under plan mode ``oneshot`` every stage is
    blocking — ``effective_stage_mode``).

Because the same plan object is priced (``core.cost_model.price``), lowered
to lightpaths (``core.schedule.schedule_from_ir``) and executed here,
planner decisions and executor behavior cannot drift.  Outputs are
bit-identical to the XLA one-shot collectives (AG/RS exactly; AR up to
reduction order) — enforced by ``tests/subproc/check_plan_executor.py``.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..core.plan_ir import CollectivePlan, PlanStage, effective_stage_mode
from .exchange_executor import (
    exchange_all_gather,
    exchange_all_reduce,
    exchange_reduce_scatter,
)
from .ring_executor import (
    hybrid_all_gather,
    hybrid_all_reduce,
    hybrid_all_to_all,
    hybrid_reduce_scatter,
    perhop_all_gather,
    perhop_all_to_all,
    perhop_reduce_scatter,
)
from .staged_collectives import (
    staged_all_gather_chunked,
    staged_all_reduce,
    staged_all_to_all,
    staged_reduce_scatter,
)

__all__ = [
    "execute_plan",
    "execute_plan_verified",
    "oneshot_reference",
    "plan_axis_names",
]


def plan_axis_names(plan: CollectivePlan) -> Tuple[str, ...]:
    """Canonical (major-first mesh order) axis names the plan gathers over,
    stamped into ``plan.meta`` by ``comms.staged_collectives.plan_collectives``."""
    names = plan.meta.get("axis_names")
    if not names:
        raise ValueError(
            "plan has no meta['axis_names']; build engine plans via "
            "plan_collectives (paper-world plans lower through "
            "core.schedule.schedule_from_ir instead)"
        )
    return tuple(names)


def _executor_modes(
    plan: CollectivePlan, stages: Sequence[PlanStage]
) -> Tuple[str, ...]:
    """Per-stage ``ring_executor`` stage_modes ("ring"/"oneshot") for the
    stages' EFFECTIVE hop structure under the plan-level mode."""
    return tuple(
        "ring" if effective_stage_mode(plan, s) == "perhop" else "oneshot"
        for s in stages
    )


def execute_plan(y: jax.Array, plan: CollectivePlan, *, axis: int = 0,
                 stage_probe: Optional[Callable] = None) -> jax.Array:
    """Execute ``plan`` on the local shard ``y`` inside shard_map.

    * ``ag`` — ``y`` is the local shard; returns the full gather (equals
      ``lax.all_gather(y, names, axis=axis, tiled=True)`` bit for bit).
    * ``rs`` — ``y`` is the full-length local addend; returns this device's
      canonical block of the sum (equals ``lax.psum_scatter``).
    * ``ar`` — returns ``lax.psum(y, names)`` (up to reduction order for
      per-hop ring stages).
    * ``a2a`` — ``y`` is the full local exchange buffer (N destination
      blocks along ``axis``); returns the block transpose (equals
      ``lax.all_to_all(y, names, split_axis=axis, concat_axis=axis,
      tiled=True)`` bit for bit).

    ``stage_probe(before, after, name, kind)`` is invoked once per stage on
    the per-hop (non-chunked) paths with the stage's traced input/output
    and the stage's traffic kind ("ag"/"rs"/"a2a") — the hook
    :func:`execute_plan_verified` uses for per-stage checksums.  The
    chunked/hybrid wavefronts do not expose stage boundaries; verification
    there happens at collective granularity.
    """
    names = plan_axis_names(plan)
    coll = plan.collective
    chunked = plan.mode == "chunked" and plan.num_chunks > 1
    # a one-chunk hybrid degenerates to the per-hop path (same stages, no
    # wavefront) — matching ``CollectivePlan.with_chunks`` normalization
    hybrid = plan.mode == "hybrid" and plan.num_chunks > 1

    def probe_for(kind: str) -> Optional[Callable]:
        if stage_probe is None:
            return None
        return lambda before, after, name: stage_probe(before, after, name, kind)

    if any(s.mode == "exchange" for s in plan.stages):
        # latency-regime plans: recursive-doubling pairwise rounds.  They
        # are single-shot by construction — the planner never chunks them
        # (KiB payloads are under the chunking floor anyway).
        if plan.num_chunks > 1:
            raise ValueError(
                f"exchange (latency) plans execute single-shot, got "
                f"num_chunks={plan.num_chunks}")
        if coll == "ag":
            return exchange_all_gather(
                y, plan, axis=axis, stage_probe=probe_for("ag"))
        if coll == "rs":
            return exchange_reduce_scatter(
                y, plan, axis=axis, stage_probe=probe_for("rs"))
        if coll == "ar":
            return exchange_all_reduce(
                y, plan, axis=axis, rs_probe=probe_for("rs"),
                ag_probe=probe_for("ag"))
        raise ValueError(
            f"exchange stages unsupported for collective {coll!r}")

    if coll == "ag":
        order = plan.axes
        if chunked:
            return staged_all_gather_chunked(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks)
        if hybrid:
            return hybrid_all_gather(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks,
                stage_modes=_executor_modes(plan, plan.stages))
        return perhop_all_gather(
            y, names, stage_order=order, axis=axis,
            stage_modes=_executor_modes(plan, plan.stages),
            stage_probe=probe_for("ag"))

    if coll == "rs":
        order = plan.axes
        if chunked:
            return staged_reduce_scatter(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks)
        if hybrid:
            return hybrid_reduce_scatter(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks,
                stage_modes=_executor_modes(plan, plan.stages))
        return perhop_reduce_scatter(
            y, names, stage_order=order, axis=axis,
            stage_modes=_executor_modes(plan, plan.stages),
            stage_probe=probe_for("rs"))

    if coll == "a2a":
        order = plan.axes
        if chunked:
            return staged_all_to_all(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks)
        if hybrid:
            return hybrid_all_to_all(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks,
                stage_modes=_executor_modes(plan, plan.stages))
        return perhop_all_to_all(
            y, names, stage_order=order, axis=axis,
            stage_modes=_executor_modes(plan, plan.stages),
            stage_probe=probe_for("a2a"))

    if coll == "ar":
        k = len(plan.stages) // 2
        rs_stages, ag_stages = plan.stages[:k], plan.stages[k:]
        rs_order = tuple(st.axis for st in rs_stages)
        if chunked:
            return staged_all_reduce(
                y, names, rs_order=rs_order, axis=axis,
                num_chunks=plan.num_chunks)
        if hybrid:
            return hybrid_all_reduce(
                y, names, rs_order=rs_order, axis=axis,
                num_chunks=plan.num_chunks,
                stage_modes=_executor_modes(plan, plan.stages))
        y = perhop_reduce_scatter(
            y, names, stage_order=rs_order, axis=axis,
            stage_modes=_executor_modes(plan, rs_stages),
            stage_probe=probe_for("rs"))
        return perhop_all_gather(
            y, names, stage_order=tuple(st.axis for st in ag_stages),
            axis=axis, stage_modes=_executor_modes(plan, ag_stages),
            stage_probe=probe_for("ag"))

    raise ValueError(f"unknown collective {coll!r}")


# --------------------------------------------------------------------------
# verified execution: per-stage checksums, bounded retry, one-shot fallback
# --------------------------------------------------------------------------

def oneshot_reference(y: jax.Array, plan: CollectivePlan, *,
                      axis: int = 0) -> jax.Array:
    """The XLA one-shot collective for ``plan`` — the graceful-degradation
    target: bit-identical to what the staged executor produces on healthy
    hardware (AG/RS/A2A exactly; AR up to reduction order)."""
    names = plan_axis_names(plan)
    coll = plan.collective
    if coll == "ag":
        return lax.all_gather(y, names, axis=axis, tiled=True)
    if coll == "rs":
        return lax.psum_scatter(y, names, scatter_dimension=axis, tiled=True)
    if coll == "ar":
        return lax.psum(y, names)
    if coll == "a2a":
        return lax.all_to_all(y, names, split_axis=axis, concat_axis=axis,
                              tiled=True)
    raise ValueError(f"unknown collective {coll!r}")


def _close(a: jax.Array, b: jax.Array, tol: float) -> jax.Array:
    if tol == 0.0:
        return a == b
    scale = jnp.maximum(jnp.maximum(jnp.abs(a), jnp.abs(b)), 1.0)
    return jnp.abs(a - b) <= tol * scale


def _conservation_ok(y, out, plan, names, tol) -> jax.Array:
    """Whole-collective conservation checksum.  All four collectives
    preserve the group's total mass; AG and AR additionally deliver the
    full total to EVERY device, so their check is per-device (a device
    whose gather lost a block fails locally)."""
    tin = lax.psum(jnp.sum(y), names)
    if plan.collective in ("ag", "ar"):
        return _close(jnp.sum(out), tin, tol)
    return _close(lax.psum(jnp.sum(out), names), tin, tol)


def execute_plan_verified(
    y: jax.Array,
    plan: CollectivePlan,
    *,
    axis: int = 0,
    retries: int = 1,
    tol: float = 0.0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Checksummed execution with bounded retry and graceful degradation.

    Runs ``plan`` up to ``retries + 1`` times; each attempt is verified by
    per-stage conservation checksums (via the ``stage_probe`` hook on the
    per-hop paths — an AG stage must deliver the stage group's full mass to
    every member, RS/A2A stages must preserve the group total) plus the
    whole-collective checksum.  The result is the FIRST attempt whose every
    checksum passes; if none passes, the bit-identical XLA one-shot
    collective (:func:`oneshot_reference`) is selected instead — degraded
    throughput, never corrupted data.

    ``tol`` is the relative checksum tolerance; the default ``0.0`` demands
    exact equality, which holds for exactly-representable sums (the chaos
    harness uses integer-valued payloads).  Float rounding with ``tol=0``
    can only cause a spurious *fallback*, never a wrong result.

    Returns ``(out, diag)``; ``diag["attempt_ok"]`` is the per-attempt
    verdict vector, ``diag["used_fallback"]`` the scalar bool that no
    attempt survived, and ``diag["stage_ok"]`` (per-hop paths only) the
    (attempt, stage) checksum matrix.  All verification is traced — inside
    jit/shard_map the diagnostics are arrays, not Python bools.
    """
    if retries < 0:
        raise ValueError(f"retries must be >= 0, got {retries}")
    attempts = []
    attempt_oks = []
    stage_ok_rows = []
    for _ in range(retries + 1):
        stage_oks: list = []

        def probe(before, after, name, kind, _oks=stage_oks):
            tin = lax.psum(jnp.sum(before), name)
            if kind == "ag":
                ok = _close(jnp.sum(after), tin, tol)
            else:  # rs / a2a: stage-group total conservation
                ok = _close(lax.psum(jnp.sum(after), name), tin, tol)
            _oks.append(ok)

        out = execute_plan(y, plan, axis=axis, stage_probe=probe)
        ok = _conservation_ok(y, out, plan, plan_axis_names(plan), tol)
        for s_ok in stage_oks:
            ok = jnp.logical_and(ok, s_ok)
        attempts.append(out)
        attempt_oks.append(ok)
        stage_ok_rows.append(stage_oks)
    fallback = oneshot_reference(y, plan, axis=axis)
    out = fallback
    for a_out, a_ok in reversed(list(zip(attempts, attempt_oks))):
        out = jnp.where(a_ok, a_out, out)
    any_ok = attempt_oks[0]
    for a_ok in attempt_oks[1:]:
        any_ok = jnp.logical_or(any_ok, a_ok)
    diag: Dict[str, jax.Array] = {
        "attempt_ok": jnp.stack(attempt_oks),
        "used_fallback": jnp.logical_not(any_ok),
    }
    if stage_ok_rows[0]:
        diag["stage_ok"] = jnp.stack(
            [jnp.stack(row) for row in stage_ok_rows])
    return out, diag
