"""IR-interpreting executor: run one CollectivePlan inside shard_map.

This is consumer (3) of the unified IR (``core.plan_ir``): the JAX engine
no longer re-derives stage orders, chunk counts, or per-stage execution
modes at the callsite — ``execute_plan`` reads them off the plan and maps
its stage chain onto the shard_map primitives:

  * plan mode ``chunked`` → the ``num_chunks``-chunk wavefront over
    blocking whole-stage collectives (``staged_collectives``);
  * plan mode ``hybrid`` → the same chunk wavefront run OVER the per-hop
    stage executors (``ring_executor.hybrid_*``): chunk i's stage j
    overlaps chunk i-1's stage j+1 while every ring stage double-buffers
    its own hops — the perhop-chunked combination the planner emits when
    its modeled makespan beats both pure modes;
  * otherwise → the staged executors of ``ring_executor`` with one
    ``stage_modes`` entry per stage: a stage whose effective IR mode is
    ``perhop`` runs as a double-buffered ppermute ring, the rest as the
    blocking XLA collective (under plan mode ``oneshot`` every stage is
    blocking — ``effective_stage_mode``).

Because the same plan object is priced (``core.cost_model.price``), lowered
to lightpaths (``core.schedule.schedule_from_ir``) and executed here,
planner decisions and executor behavior cannot drift.  Outputs are
bit-identical to the XLA one-shot collectives (AG/RS exactly; AR up to
reduction order) — enforced by ``tests/subproc/check_plan_executor.py``.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax

from ..core.plan_ir import CollectivePlan, PlanStage, effective_stage_mode
from .ring_executor import (
    hybrid_all_gather,
    hybrid_all_reduce,
    hybrid_all_to_all,
    hybrid_reduce_scatter,
    perhop_all_gather,
    perhop_all_to_all,
    perhop_reduce_scatter,
)
from .staged_collectives import (
    staged_all_gather_chunked,
    staged_all_reduce,
    staged_all_to_all,
    staged_reduce_scatter,
)

__all__ = ["execute_plan", "plan_axis_names"]


def plan_axis_names(plan: CollectivePlan) -> Tuple[str, ...]:
    """Canonical (major-first mesh order) axis names the plan gathers over,
    stamped into ``plan.meta`` by ``comms.staged_collectives.plan_collectives``."""
    names = plan.meta.get("axis_names")
    if not names:
        raise ValueError(
            "plan has no meta['axis_names']; build engine plans via "
            "plan_collectives (paper-world plans lower through "
            "core.schedule.schedule_from_ir instead)"
        )
    return tuple(names)


def _executor_modes(
    plan: CollectivePlan, stages: Sequence[PlanStage]
) -> Tuple[str, ...]:
    """Per-stage ``ring_executor`` stage_modes ("ring"/"oneshot") for the
    stages' EFFECTIVE hop structure under the plan-level mode."""
    return tuple(
        "ring" if effective_stage_mode(plan, s) == "perhop" else "oneshot"
        for s in stages
    )


def execute_plan(y: jax.Array, plan: CollectivePlan, *, axis: int = 0) -> jax.Array:
    """Execute ``plan`` on the local shard ``y`` inside shard_map.

    * ``ag`` — ``y`` is the local shard; returns the full gather (equals
      ``lax.all_gather(y, names, axis=axis, tiled=True)`` bit for bit).
    * ``rs`` — ``y`` is the full-length local addend; returns this device's
      canonical block of the sum (equals ``lax.psum_scatter``).
    * ``ar`` — returns ``lax.psum(y, names)`` (up to reduction order for
      per-hop ring stages).
    * ``a2a`` — ``y`` is the full local exchange buffer (N destination
      blocks along ``axis``); returns the block transpose (equals
      ``lax.all_to_all(y, names, split_axis=axis, concat_axis=axis,
      tiled=True)`` bit for bit).
    """
    names = plan_axis_names(plan)
    coll = plan.collective
    chunked = plan.mode == "chunked" and plan.num_chunks > 1
    # a one-chunk hybrid degenerates to the per-hop path (same stages, no
    # wavefront) — matching ``CollectivePlan.with_chunks`` normalization
    hybrid = plan.mode == "hybrid" and plan.num_chunks > 1

    if coll == "ag":
        order = plan.axes
        if chunked:
            return staged_all_gather_chunked(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks)
        if hybrid:
            return hybrid_all_gather(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks,
                stage_modes=_executor_modes(plan, plan.stages))
        return perhop_all_gather(
            y, names, stage_order=order, axis=axis,
            stage_modes=_executor_modes(plan, plan.stages))

    if coll == "rs":
        order = plan.axes
        if chunked:
            return staged_reduce_scatter(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks)
        if hybrid:
            return hybrid_reduce_scatter(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks,
                stage_modes=_executor_modes(plan, plan.stages))
        return perhop_reduce_scatter(
            y, names, stage_order=order, axis=axis,
            stage_modes=_executor_modes(plan, plan.stages))

    if coll == "a2a":
        order = plan.axes
        if chunked:
            return staged_all_to_all(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks)
        if hybrid:
            return hybrid_all_to_all(
                y, names, stage_order=order, axis=axis,
                num_chunks=plan.num_chunks,
                stage_modes=_executor_modes(plan, plan.stages))
        return perhop_all_to_all(
            y, names, stage_order=order, axis=axis,
            stage_modes=_executor_modes(plan, plan.stages))

    if coll == "ar":
        k = len(plan.stages) // 2
        rs_stages, ag_stages = plan.stages[:k], plan.stages[k:]
        rs_order = tuple(st.axis for st in rs_stages)
        if chunked:
            return staged_all_reduce(
                y, names, rs_order=rs_order, axis=axis,
                num_chunks=plan.num_chunks)
        if hybrid:
            return hybrid_all_reduce(
                y, names, rs_order=rs_order, axis=axis,
                num_chunks=plan.num_chunks,
                stage_modes=_executor_modes(plan, plan.stages))
        y = perhop_reduce_scatter(
            y, names, stage_order=rs_order, axis=axis,
            stage_modes=_executor_modes(plan, rs_stages))
        return perhop_all_gather(
            y, names, stage_order=tuple(st.axis for st in ag_stages),
            axis=axis, stage_modes=_executor_modes(plan, ag_stages))

    raise ValueError(f"unknown collective {coll!r}")
