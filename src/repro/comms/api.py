"""Context-scoped collectives API — ONE entry surface for every
gather-shaped collective (ISSUE 4).

After three generations of entry points (``staged_*`` primitives, the
``StagedCollectiveEngine`` methods, ``perhop_*``, direct
``allgather_matmul``), callers still threaded mesh, axis names, LinkSpecs,
execution mode and fusion flags by hand at every site.  This module
collapses that surface to the PCCL-style framework shape: install a
:class:`CommContext` once, call the module-level ops anywhere —

    with comm_context(mesh, ("pod", "tp")) as ctx:
        y = api.all_reduce(x)                 # outside shard_map: wraps it
        fn = shard_map(lambda v: api.all_reduce(v), ...)   # or inside one

Every op dispatches through ``plan_collectives`` → the unified
:class:`~repro.core.plan_ir.CollectivePlan` IR → ``execute_plan``; the
POLICY (mode / chunking / fusion / stage-order overrides) lives on the
context (:class:`PlanPolicy`), not at call sites — SWOT's argument that
reconfiguration/overlap decisions belong to the runtime.

Plans are cached per context, keyed
``(collective, shape, dtype, axes, policy, links_fingerprint)``.  The
links fingerprint makes the cache **auto-invalidating**: feeding a fitted
calibration file back (``ctx.update_links("fitted.json")``) drops every
stale entry and the next call re-plans with the fitted specs — closing the
ROADMAP auto-calibration loop without constructing a new engine.
``ctx.cache_stats`` (hits / misses / invalidated) makes the re-plan
observable.

Inside vs outside shard_map is detected at trace time: if the context's
axis names are bound in the ambient axis env, ops run the plan directly on
the local shard; otherwise they wrap themselves in shard_map over the
context's mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import math
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import axis_size, shard_map
from ..core.health import (
    FaultEvent,
    HealthError,
    LinkHealth,
    health_fingerprint,
    load_health,
)
from ..core.plan_ir import CollectivePlan
from ..core.planner import (
    LinkSpec,
    load_links,
    matmul_block_time,
    plan_collective_matmul,
)

__all__ = [
    "PlanPolicy",
    "CacheStats",
    "CommContext",
    "comm_context",
    "current_context",
    "legacy_chunks",
    "legacy_context",
    "links_fingerprint",
    "all_gather",
    "reduce_scatter",
    "all_reduce",
    "all_to_all",
    "allgather_matmul",
    "matmul_reduce_scatter",
]


# --------------------------------------------------------------------------
# policy + context
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PlanPolicy:
    """Per-context planning/execution overrides.

    ``mode``       — force the plan-level execution mode (``oneshot`` /
                     ``chunked`` / ``perhop`` / ``hybrid``); None follows
                     the planner.
    ``num_chunks`` — force the wavefront chunk count (implies ``chunked``
                     when > 1, unless the plan already runs a chunked-
                     family mode — a hybrid plan keeps its ring stages);
                     None follows the planner.
    ``max_chunks`` — planner search bound for the chunk decision.
    ``fuse``       — collective-matmul fusion: True / False / ``"auto"``
                     (the ``plan_collective_matmul`` overlap model decides
                     per (shape, mesh) point).
    ``order``      — the stage-order hook (cross-world planning):
                       * ``None`` — the electrical cost-model planners pick
                         the order directly (slow-axis-first AG, reversed
                         RS), no search;
                       * ``"electrical"`` / ``"optical"`` —
                         ``core.planner.search_stage_orders`` enumerates
                         candidate orders, prices every candidate plan
                         under BOTH backends, and the named backend's
                         winner is cached per context key — ``"optical"``
                         makes the paper's Eq.-3 RWA pricing drive the
                         engine's stage order;
                       * an explicit axis-name tuple — force exactly this
                         all-gather order (RS runs its reverse, AR the
                         RS-order + reversed).
    ``optical``    — the ``OpticalSystem`` the ``"optical"`` search prices
                     with (None = TERARACK defaults); lower wavelength
                     counts sharpen order differences (step counts tie at
                     large w on small meshes).
    ``verify``     — run ops through ``execute_plan_verified``: per-stage
                     conservation checksums, up to ``verify_retries``
                     bounded retries of the staged path, then a graceful
                     degrade to the bit-identical XLA one-shot collective
                     (counted in ``CacheStats.fallbacks``).
    ``verify_retries`` — retry budget for the verified executor (>= 0).
    ``regime``     — the latency/bandwidth plan family (ISSUE 8):
                       * ``"auto"`` (default) — per payload size, price the
                         recursive-doubling exchange chain
                         (``plan_latency_collective``) against the ring
                         plan and cache the electrical winner — decode-size
                         psums get log-round latency plans, training
                         payloads keep their ring/hybrid modes;
                       * ``"bandwidth"`` — rings only (pre-ISSUE-8
                         behaviour);
                       * ``"latency"`` — force the exchange chain; raises
                         when the axis structure has no latency plan
                         (non-power-of-two sizes).
                     Latency plans are single-shot exchange chains, so
                     ``regime="latency"`` is incompatible with ``mode``/
                     ``num_chunks``/``order`` overrides, and any mode or
                     chunk override (policy or per-call) pins the plan to
                     the bandwidth family.
    ``reconfig``   — the hold-vs-reconfigure constraint on a
                     reconfigurable photonic fabric (ISSUE 10):
                       * ``"auto"`` (default) — the order search ranks the
                         full candidate space; the per-event
                         ``OpticalSystem.circuit_reconfig_s`` delay (minus
                         SWOT overlap) is part of every candidate's
                         optical price, so the ranking decides;
                       * ``"hold"`` — only candidates that keep ONE
                         circuit for the whole collective;
                       * ``"reconfigure"`` — only candidates that pay at
                         least one topology change.
                     Only meaningful on the searched-order path, so a
                     non-auto value requires ``order`` to be
                     ``"electrical"`` or ``"optical"``.
    """

    mode: Optional[str] = None
    num_chunks: Optional[int] = None
    max_chunks: int = 8
    fuse: object = "auto"
    order: object = None
    optical: object = None
    verify: bool = False
    verify_retries: int = 1
    regime: str = "auto"
    reconfig: str = "auto"

    def __post_init__(self):
        if self.mode is not None and self.mode not in (
                "oneshot", "chunked", "perhop", "hybrid"):
            raise ValueError(f"policy mode must be oneshot|chunked|perhop|"
                             f"hybrid, got {self.mode!r}")
        if self.regime not in ("auto", "latency", "bandwidth"):
            raise ValueError(f"policy regime must be auto|latency|bandwidth, "
                             f"got {self.regime!r}")
        if self.regime == "latency" and (
                self.mode is not None or self.num_chunks is not None
                or self.order is not None):
            raise ValueError(
                "regime='latency' forces single-shot exchange plans; "
                "mode/num_chunks/order overrides are incompatible with it")
        if self.reconfig not in ("auto", "hold", "reconfigure"):
            raise ValueError(
                f"policy reconfig must be auto|hold|reconfigure, "
                f"got {self.reconfig!r}")
        if self.reconfig != "auto" and self.order not in (
                "electrical", "optical"):
            raise ValueError(
                f"reconfig={self.reconfig!r} only constrains the searched-"
                f"order path; it requires order='electrical' or 'optical', "
                f"got order={self.order!r}")
        if not isinstance(self.verify_retries, int) or self.verify_retries < 0:
            raise ValueError(
                f"verify_retries must be a non-negative int, "
                f"got {self.verify_retries!r}")
        if isinstance(self.order, str):
            if self.order not in ("electrical", "optical"):
                raise ValueError(
                    f"policy order must be 'electrical', 'optical' or an "
                    f"axis-name tuple, got {self.order!r}")
        elif self.order is not None:
            object.__setattr__(self, "order", tuple(self.order))

    def merged(self, **overrides) -> "PlanPolicy":
        """A copy with the given fields replaced (nesting semantics)."""
        return dataclasses.replace(self, **overrides)


@dataclass
class CacheStats:
    """Plan-cache counters.

    ``invalidated`` counts entries dropped by a links-table change
    (``CommContext.update_links``) or a health change;
    ``replans_on_fault`` counts entries re-planned IN PLACE after a
    ``report_fault``/``update_health`` (the self-healing path);
    ``fallbacks`` counts degrades to the one-shot collective — either at
    plan time (a dead axis/direction made every staged candidate illegal)
    or at run time (the verified executor exhausted its retries);
    ``latency_plans`` / ``ring_plans`` split the planned entries by regime
    (exchange chains vs ring/hybrid stages) — the per-size winner cache
    made observable."""

    hits: int = 0
    misses: int = 0
    invalidated: int = 0
    replans_on_fault: int = 0
    fallbacks: int = 0
    latency_plans: int = 0
    ring_plans: int = 0

    def to_json(self) -> Dict[str, int]:
        """The counters as one structured dict — callers (train telemetry,
        the cluster front end's drain report) log this blob instead of
        hand-formatting fields."""
        return dataclasses.asdict(self)


def links_fingerprint(links: Optional[Dict[str, LinkSpec]]) -> str:
    """Stable fingerprint of an axis→LinkSpec table — part of every plan
    cache key, so swapping the table re-keys (invalidates) every plan."""
    if not links:
        return "default"
    items = sorted(
        (a, l.name, float(l.bandwidth_bytes), float(l.alpha_s))
        for a, l in links.items()
    )
    return hashlib.sha1(repr(items).encode()).hexdigest()[:16]


class CommContext:
    """One mesh + axis set + LinkSpec table + policy = one collectives
    scope.  All module-level ops resolve to the innermost installed context
    (or an explicit ``ctx=`` handle) and share its plan cache.

    ``mesh`` may be None for trace-time-only contexts (ops then work only
    inside an existing shard_map, where axis sizes come from the ambient
    axis env).  ``axis_sizes`` overrides size lookup for meshless planning
    (tests / offline planning).
    """

    def __init__(
        self,
        mesh=None,
        axis_names: Optional[Sequence[str]] = None,
        *,
        links: Optional[Dict[str, LinkSpec]] = None,
        policy: Optional[PlanPolicy] = None,
        axis_sizes: Optional[Dict[str, int]] = None,
        health: Optional[LinkHealth] = None,
    ):
        self.mesh = mesh
        self.axis_names = tuple(axis_names) if axis_names is not None else None
        self.links = dict(links) if links else None
        self.policy = policy or PlanPolicy()
        self.axis_sizes = dict(axis_sizes) if axis_sizes else None
        self.health = health
        self._links_fp = links_fingerprint(self.links)
        self._health_fp = health_fingerprint(health)
        self._cache: Dict[tuple, CollectivePlan] = {}
        self._counts: Dict[tuple, int] = {}
        # what each cache entry was planned FOR — lets a health change
        # re-plan every live entry in place instead of just dropping it
        self._requests: Dict[tuple, tuple] = {}
        # memoized latency/bandwidth crossover payloads, keyed
        # (collective, names, links_fp, health_fp) — telemetry only
        self._crossovers: Dict[tuple, Optional[float]] = {}
        self.cache_stats = CacheStats()

    # -- links / auto-calibration -----------------------------------------
    def update_links(self, links: Union[str, Dict[str, LinkSpec]],
                     *, merge: bool = True) -> Dict[str, LinkSpec]:
        """Swap (or merge into) the LinkSpec table and invalidate every
        cached plan — the auto-calibration path: point this at a
        ``launch/perf.py --calibrate`` output and the very next op call
        re-plans with the fitted specs, same context, same cache.
        """
        if isinstance(links, (str,)) or hasattr(links, "read_text"):
            expect = self.axis_names
            links = load_links(links, fallbacks=self.links,
                               expect_axes=expect, allow_missing=True)
        table = dict(self.links) if (merge and self.links) else {}
        table.update(links)
        self.links = table
        new_fp = links_fingerprint(self.links)
        if new_fp != self._links_fp:
            self.cache_stats.invalidated += len(self._cache)
            self._cache.clear()
            self._counts.clear()
            self._requests.clear()
            self._links_fp = new_fp
        return self.links

    @property
    def links_fp(self) -> str:
        return self._links_fp

    # -- health / fault handling -------------------------------------------
    def update_health(self, health: Union[str, LinkHealth, None]) -> Optional[LinkHealth]:
        """Swap the link/wavelength health table (a :class:`LinkHealth`, a
        JSON path, or None = fully healthy) and RE-PLAN every cached entry
        in place under the new degraded world — the self-healing path:
        callers keep calling the same ops, and the very next hit serves a
        plan already priced (and order-searched) for the faulted fabric.
        A planning dead end (dead axis / every order crossing a dead
        direction) degrades that entry to the one-shot fallback plan,
        counted in ``cache_stats.fallbacks``."""
        if isinstance(health, (str,)) or hasattr(health, "read_text"):
            health = load_health(health, expect_axes=self.axis_names)
        new_fp = health_fingerprint(health)
        self.health = health
        if new_fp != self._health_fp:
            self._health_fp = new_fp
            self._replan_cached()
        return self.health

    def report_fault(
        self,
        event: Optional[FaultEvent] = None,
        *,
        axis: Optional[str] = None,
        kind: Optional[str] = None,
        direction: Optional[int] = None,
        derate: Optional[float] = None,
        wavelength: Optional[int] = None,
        step: int = 0,
    ) -> Optional[LinkHealth]:
        """Fold one fault (or recovery) event into the health table and
        re-plan affected cache entries in place.  Pass a
        :class:`~repro.core.health.FaultEvent`, or keyword pieces —
        ``kind`` is inferred when omitted (``wavelength=`` →
        ``lose_wavelength``, ``derate=`` → ``derate``, else ``dead``)."""
        if event is None:
            if axis is None:
                raise ValueError(
                    "report_fault needs a FaultEvent or axis=... pieces")
            if kind is None:
                kind = ("lose_wavelength" if wavelength is not None
                        else "derate" if derate is not None else "dead")
            event = FaultEvent(step=step, kind=kind, axis=axis,
                               direction=direction, derate=derate,
                               wavelength=wavelength)
        base = self.health if self.health is not None else LinkHealth()
        return self.update_health(base.apply(event))

    def _replan_cached(self):
        """Re-key and re-plan every cached entry under the current health
        fingerprint.  Old keys are invalidated (counted), each live request
        is planned afresh — ``cache_stats.replans_on_fault`` counts them —
        and usage counts carry over so telemetry stays meaningful."""
        stale = list(self._cache)
        self.cache_stats.invalidated += len(stale)
        old_counts, old_requests = self._counts, self._requests
        self._cache, self._counts, self._requests = {}, {}, {}
        for old_key in stale:
            req = old_requests.get(old_key)
            if req is None:
                continue
            new_key = old_key[:-1] + (self._health_fp,)
            self._cache[new_key] = self._plan_with_fallback(*req)
            self._requests[new_key] = req
            self._counts[new_key] = old_counts.get(old_key, 0)
            self.cache_stats.replans_on_fault += 1

    @property
    def health_fp(self) -> str:
        return self._health_fp

    def plans(self) -> List[CollectivePlan]:
        """Snapshot of every cached CollectivePlan — the same objects the
        ops execute, priceable (``core.cost_model.price``) and lowerable to
        the optical simulator (``core.schedule.schedule_from_ir``)."""
        return list(self._cache.values())

    def plan_usage(self) -> List[Tuple[CollectivePlan, int]]:
        """(plan, times-requested) pairs — distinguishes the deduplicated
        cache entries from how often each was actually issued (e.g. a TP
        block's two all-reduces share one entry but count twice)."""
        return [(p, self._counts.get(k, 0)) for k, p in self._cache.items()]

    def telemetry_snapshot(self) -> Dict:
        """One structured telemetry blob for this context: cache counters
        (:meth:`CacheStats.to_json`), fingerprints, the regime crossover,
        and a per-cached-plan record (collective, payload, mode/chunks,
        stage order, regime, issue count, order-search verdict, fallback
        reason).  ``launch/train.py`` and the cluster front end
        (``repro.cluster.frontend``) log this dict as JSON instead of
        hand-formatting fields; the line-oriented
        ``launch.train.comm_plan_telemetry`` renders from the same blob."""
        plans = []
        for plan, issued in self.plan_usage():
            rec = {
                "collective": plan.collective,
                "shard_bytes": float(plan.shard_bytes),
                "regime": plan.meta.get("regime", "bandwidth"),
                "mode": plan.mode,
                "num_chunks": plan.num_chunks,
                "order": [str(a) for a in plan.axes],
                "issued": issued,
            }
            srch = plan.meta.get("order_search")
            if srch:
                rec["order_search"] = {
                    "backend": srch["backend"],
                    "flipped": srch["flipped"],
                    "regime_flipped": srch.get("regime_flipped", False),
                    "reconfigurations": srch.get("reconfigurations", 0),
                }
            if plan.meta.get("fallback"):
                rec["fallback"] = plan.meta["fallback"]
            plans.append(rec)
        xover = (self.latency_crossover("ar")
                 if self.axis_names else None)
        return {
            "plans": len(self._cache),
            "cache": self.cache_stats.to_json(),
            "links_fp": self._links_fp,
            "health_fp": self._health_fp,
            "crossover_ar_bytes": xover,
            "per_plan": plans,
        }

    # -- sizes -------------------------------------------------------------
    def _names(self, axes: Optional[Sequence[str]]) -> Tuple[str, ...]:
        names = tuple(axes) if axes is not None else self.axis_names
        if not names:
            raise ValueError(
                "no collective axes: pass axes=... or install a context "
                "with axis_names (comm_context(mesh, names))")
        return names

    def _sizes(self, names: Tuple[str, ...]) -> Dict[str, int]:
        if self.axis_sizes is not None:
            known = {n: self.axis_sizes[n] for n in names if n in self.axis_sizes}
            if len(known) == len(names):
                return known
        if self.mesh is not None:
            return {n: self.mesh.shape[n] for n in names}
        # trace-time: inside shard_map the ambient axis env knows the sizes
        return {n: axis_size(n) for n in names}

    # -- planning (cached) ---------------------------------------------------
    def _effective_regime(self, mode: Optional[str] = None,
                          num_chunks: Optional[int] = None) -> str:
        """The regime one op call actually plans under: any mode/chunk
        override — per-call or policy-level — pins the plan to the
        bandwidth family (latency plans are single-shot exchange chains
        with no chunked/perhop execution to force)."""
        pol = self.policy
        if mode is not None or num_chunks is not None:
            if pol.regime == "latency":
                raise ValueError(
                    "regime='latency' plans are single-shot exchange "
                    "chains; per-call mode/num_chunks overrides do not "
                    "apply — use regime='auto' or 'bandwidth'")
            return "bandwidth"
        if pol.mode is not None or pol.num_chunks is not None:
            return "bandwidth"
        return pol.regime

    def plan(
        self,
        collective: str,
        shard_bytes: float,
        *,
        axes: Optional[Sequence[str]] = None,
        shape: Optional[Tuple[int, ...]] = None,
        dtype=None,
        regime: Optional[str] = None,
    ) -> CollectivePlan:
        """The policy-resolved CollectivePlan for one (collective, payload)
        point.  ``shard_bytes`` is the scattered-end payload, as everywhere
        in the planner (for "a2a": the full local exchange buffer — all N
        destination blocks).  Cached on ``(collective, shape, dtype, axes,
        regime, policy, links_fingerprint)``; a links change re-keys
        everything.  ``regime`` overrides the policy regime for this call
        (the ops pass ``_effective_regime`` so a per-call mode/chunk
        override plans in the bandwidth family).
        """
        if collective not in ("ag", "rs", "ar", "a2a"):
            raise ValueError(
                f"collective must be ag|rs|ar|a2a, got {collective!r}")
        regime = regime if regime is not None else self._effective_regime()
        names = self._names(axes)
        sizes = self._sizes(names)
        # shard_bytes AND the resolved axis sizes are always part of the
        # key: the same (shape, dtype) can mean a local shard inside
        # shard_map or a global array outside it, and the same axis NAME
        # can have a different size on another mesh (the shared default
        # context sees many) — either collision would serve a stale plan
        key = (
            collective,
            float(shard_bytes),
            tuple(sizes[n] for n in names),
            tuple(shape) if shape is not None else None,
            str(dtype) if dtype is not None else None,
            names,
            regime,
            self.policy,
            self._links_fp,
            self._health_fp,  # LAST: _replan_cached re-keys on it
        )
        self._counts[key] = self._counts.get(key, 0) + 1
        cached = self._cache.get(key)
        if cached is not None:
            self.cache_stats.hits += 1
            return cached
        self.cache_stats.misses += 1
        plan = self._plan_with_fallback(
            collective, float(shard_bytes), names, sizes, regime)
        self._cache[key] = plan
        self._requests[key] = (
            collective, float(shard_bytes), names, sizes, regime)
        return plan

    def _plan_with_fallback(
        self, collective: str, shard_bytes: float, names: Tuple[str, ...],
        sizes: Dict[str, int], regime: str = "auto",
    ) -> CollectivePlan:
        """Plan under the current health; when the degraded world makes
        every staged candidate illegal (dead axis, or every stage order
        crossing a dead ring direction), degrade gracefully to the one-shot
        fallback plan instead of failing the op."""
        try:
            plan = self._plan_uncached(
                collective, shard_bytes, names, sizes, regime)
        except HealthError as err:
            plan = self._fallback_plan(
                collective, shard_bytes, names, sizes, str(err))
            self.cache_stats.fallbacks += 1
        if self._health_fp != "healthy":
            plan = dataclasses.replace(
                plan, meta={**plan.meta, "health_fp": self._health_fp})
        if any(s.mode == "exchange" for s in plan.stages):
            self.cache_stats.latency_plans += 1
        else:
            self.cache_stats.ring_plans += 1
        return plan

    def latency_crossover(
        self, collective: str = "ar",
        axes: Optional[Sequence[str]] = None,
    ) -> Optional[float]:
        """The electrical crossover payload (bytes) below which the latency
        (recursive-doubling) plan beats every ring mode on these axes —
        memoized per (collective, axes, links, health); None when the axis
        structure has no latency plan (non-power-of-two sizes or a dead
        ring direction).  Telemetry for the per-size winner cache."""
        names = self._names(axes)
        key = (collective, names, self._links_fp, self._health_fp)
        if key not in self._crossovers:
            from ..core.planner import latency_crossover_bytes
            from .staged_allgather import link_for_axis

            sizes = self._sizes(names)
            health = self.health
            if health is not None and health.is_healthy:
                health = None
            axes_l = [(n, sizes[n], link_for_axis(n, self.links))
                      for n in names]
            self._crossovers[key] = latency_crossover_bytes(
                axes_l, collective=collective, health=health)
        return self._crossovers[key]

    def _fallback_plan(self, collective, shard_bytes, names, sizes, reason):
        """The graceful-degrade plan: every stage one-shot (pure XLA
        collectives — bit-identical results, no staged ring traffic over
        the faulted fabric), with the reason recorded for telemetry."""
        from .staged_collectives import plan_collectives  # lazy: cycle

        plan = plan_collectives(
            sizes, names, shard_bytes, links=self.links,
            max_chunks=self.policy.max_chunks,
        )[collective].with_mode("oneshot")
        return dataclasses.replace(
            plan, meta={**plan.meta, "fallback": reason})

    def _plan_uncached(
        self, collective: str, shard_bytes: float, names: Tuple[str, ...],
        sizes: Dict[str, int], regime: str = "auto",
    ) -> CollectivePlan:
        from .staged_collectives import plan_collectives  # lazy: cycle

        pol = self.policy
        health = self.health
        if health is not None and health.is_healthy:
            health = None
        if regime == "latency":
            # forced family: the exchange-chain permutation is chosen by
            # its own closed-form cost — no ring order search applies
            plan = self._pick_regime(
                None, collective, shard_bytes, names, sizes, health, regime)
        elif pol.order in ("electrical", "optical"):
            plan = self._plan_searched_order(
                collective, shard_bytes, names, sizes, health,
                include_latency=(regime != "bandwidth"))
        elif pol.order is not None:
            plan = self._plan_forced_order(
                collective, shard_bytes, names, sizes, health)
        else:
            links = self.links
            if health is not None:
                from .staged_allgather import link_for_axis
                # plan under the DEGRADED world: each axis's link scaled by
                # its best alive direction (a fully dead axis raises
                # DeadAxisError → _plan_with_fallback builds the one-shot
                # fallback plan)
                links = {
                    n: health.degrade_link(n, link_for_axis(n, self.links))
                    for n in names}
            plan = plan_collectives(
                sizes, names, shard_bytes, links=links,
                max_chunks=pol.max_chunks,
            )[collective]
            plan = self._pick_regime(
                plan, collective, shard_bytes, names, sizes, health, regime)
        plan = _apply_overrides(plan, pol.mode, pol.num_chunks)
        is_latency = any(s.mode == "exchange" for s in plan.stages)
        return dataclasses.replace(
            plan, meta={**plan.meta,
                        "regime": "latency" if is_latency else "bandwidth"})

    def _pick_regime(self, ring_plan, collective, shard_bytes, names, sizes,
                     health, regime):
        """The per-size regime decision on the default (no order search)
        planning path: price the recursive-doubling exchange chain against
        the planner's ring plan under the electrical backend and keep the
        winner (``regime="auto"``), or force the exchange chain
        (``regime="latency"`` — an error when the structure has none)."""
        if collective not in ("ag", "rs", "ar"):
            if regime == "latency":
                raise ValueError(
                    f"regime='latency' has no {collective} plans (exchange "
                    f"chains exist for ag/rs/ar only)")
            return ring_plan
        if regime == "bandwidth":
            return ring_plan
        from ..core.cost_model import price
        from ..core.planner import plan_latency_collective
        from .staged_allgather import link_for_axis

        axes_l = [(n, sizes[n], link_for_axis(n, self.links)) for n in names]
        lat = plan_latency_collective(
            axes_l, shard_bytes, collective=collective, health=health)
        if lat is None:
            if regime == "latency":
                if health is not None and health.dead_directions(names):
                    # a dead ring direction, not a structural mismatch:
                    # degrade to the one-shot fallback like any other
                    # planning dead end under faults
                    raise HealthError(
                        f"latency plan for {collective} needs both ring "
                        f"directions alive on axes {names}")
                raise ValueError(
                    f"regime='latency': no recursive-doubling plan for "
                    f"{collective} on axes {dict(sizes)} (sizes must be "
                    f"powers of two)")
            return ring_plan
        if regime == "latency":
            return lat
        return lat if price(lat).total_s < price(ring_plan).total_s \
            else ring_plan

    def _plan_searched_order(self, collective, shard_bytes, names, sizes,
                             health=None, *, include_latency=True):
        """Cross-world order search (``PlanPolicy.order`` = ``"electrical"``
        or ``"optical"``): enumerate candidate stage orders, price every
        candidate CollectivePlan under BOTH cost backends
        (``core.planner.search_stage_orders``), return the named backend's
        winner.  ``plan`` caches the result per context key, so the search
        runs once per (collective, payload, axes, policy, links) point —
        the same plan object the executor interprets is the one the
        optical pricer certified cheapest.  The search verdicts ride in
        ``meta["order_search"]`` for telemetry."""
        from ..core.planner import search_stage_orders
        from .staged_allgather import link_for_axis

        axes = [(n, sizes[n], link_for_axis(n, self.links)) for n in names]
        kw = {} if self.policy.optical is None else {"system": self.policy.optical}
        # the search derates links / shrinks wavelengths / prunes orders
        # crossing dead directions itself — pass the raw table plus health
        # (DeadDirectionError with zero survivors → fallback upstream)
        search = search_stage_orders(
            axes, shard_bytes, collective=collective,
            backend=self.policy.order, max_chunks=self.policy.max_chunks,
            health=health, include_latency=include_latency,
            reconfig=self.policy.reconfig, **kw,
        )
        best = search.best
        eb = search.best_by("electrical")
        ob = search.best_by("optical")
        plan = best.plan
        return dataclasses.replace(
            plan,
            meta={**plan.meta,
                  "axis_names": tuple(names),
                  "order_search": {
                      "backend": search.backend,
                      "order": best.order,
                      "regime": best.regime,
                      "electrical_s": best.electrical_s,
                      "optical_s": best.optical_s,
                      "optical_steps": best.optical_steps,
                      "electrical_best_order": eb.order,
                      "optical_best_order": ob.order,
                      # circuit/topology changes the winner's lowered
                      # schedule needs on a reconfigurable fabric
                      "reconfigurations": best.reconfigurations,
                      # genuine cross-world disagreement only: a strictly
                      # cheaper optical order, not an equal-cost tie-break
                      "flipped": search.flipped,
                      # the two worlds picked different plan FAMILIES
                      # (one latency, one bandwidth) — strictly cheaper
                      "regime_flipped": search.regime_flipped,
                      # orders a dead ring direction made illegal
                      "pruned": search.pruned,
                  }})

    def _plan_forced_order(self, collective, shard_bytes, names, sizes,
                           health=None):
        """Policy-forced stage order: build the schedule for exactly this
        AG order (RS runs the reverse; AR is RS-order + its reverse; a2a
        runs the given order directly — its digit transposes commute)."""
        from ..core.planner import choose_hop_schedule
        from .staged_allgather import link_for_axis

        ag_order = tuple(self.policy.order)
        if sorted(ag_order) != sorted(names):
            raise ValueError(
                f"policy order {ag_order} must permute the axes {names}")
        rs_order = tuple(reversed(ag_order))
        order = {"ag": ag_order, "rs": rs_order,
                 "ar": rs_order + tuple(reversed(rs_order)),
                 "a2a": ag_order}[collective]
        exec_order = order if collective != "ar" else rs_order
        factors = [sizes[n] for n in exec_order]
        links = [link_for_axis(n, self.links) for n in exec_order]
        sched = choose_hop_schedule(
            factors, links, shard_bytes,
            max_chunks=self.policy.max_chunks, collective=collective,
            health=health, axis_names=exec_order,
        )
        plan = sched.to_ir(order)
        return dataclasses.replace(
            plan, meta={**plan.meta, "axis_names": tuple(names)})

    # -- matmul fusion decision ---------------------------------------------
    def decide_fuse(
        self,
        names: Tuple[str, ...],
        rows: int,
        d_in: int,
        d_out: int,
        itemsize: int,
        *,
        n_matmuls: int = 1,
        fuse: object = None,
    ) -> bool:
        """Collective-matmul fuse decision under this context's policy:
        explicit True/False wins, ``"auto"`` asks the overlap model.
        ``rows`` is the per-block row count (one scattered shard's worth).
        """
        from .staged_allgather import link_for_axis

        fuse = self.policy.fuse if fuse is None else fuse
        if fuse != "auto":
            return bool(fuse)
        sizes = self._sizes(names)
        factors = [sizes[n] for n in names]
        lks = [link_for_axis(n, self.links) for n in names]
        t_blk = n_matmuls * matmul_block_time(rows, d_in, d_out)
        return plan_collective_matmul(
            factors, lks, rows * d_in * itemsize, t_blk).fuse


# --------------------------------------------------------------------------
# context stack
# --------------------------------------------------------------------------

_STATE = threading.local()

# fallback scope for legacy axis_names-only call sites (no installed
# context): meshless, default links — usable inside shard_map only, but its
# cache persists so repeated traces reuse plans
_DEFAULT = CommContext()


def _stack() -> List[CommContext]:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


def current_context(default: object = _DEFAULT) -> Optional[CommContext]:
    """The innermost installed context (the meshless default scope when
    none is installed; pass ``default=None`` to get None instead)."""
    s = _stack()
    return s[-1] if s else default


@contextlib.contextmanager
def comm_context(
    mesh=None,
    axis_names: Optional[Sequence[str]] = None,
    *,
    links: Optional[Dict[str, LinkSpec]] = None,
    policy: Optional[PlanPolicy] = None,
    axis_sizes: Optional[Dict[str, int]] = None,
    health: Optional[LinkHealth] = None,
    **policy_overrides,
):
    """Install a :class:`CommContext` for the dynamic extent of the block.

    Nesting inherits: omitted mesh / axis_names / links / health come from
    the enclosing context, and ``policy_overrides`` (mode=, num_chunks=,
    max_chunks=, fuse=, order=, optical=, verify=, verify_retries=) merge
    into the enclosing policy — so

        with comm_context(mesh, ("pod", "tp")):
            with comm_context(mode="perhop"):       # same scope, forced mode
                ...

    Yields the context handle (usable as an explicit ``ctx=`` argument
    after the block exits, e.g. to keep its plan cache warm).
    """
    parent = current_context(None)
    if parent is not None:
        mesh = mesh if mesh is not None else parent.mesh
        axis_names = axis_names if axis_names is not None else parent.axis_names
        links = links if links is not None else parent.links
        axis_sizes = axis_sizes if axis_sizes is not None else parent.axis_sizes
        health = health if health is not None else parent.health
        base_policy = policy or parent.policy
    else:
        base_policy = policy or PlanPolicy()
    if policy_overrides:
        base_policy = base_policy.merged(**policy_overrides)
    ctx = CommContext(mesh, axis_names, links=links, policy=base_policy,
                      axis_sizes=axis_sizes, health=health)
    _stack().append(ctx)
    try:
        yield ctx
    finally:
        _stack().pop()


def _resolve(ctx: Optional[CommContext], axes) -> Tuple[CommContext, Tuple[str, ...]]:
    c = ctx if ctx is not None else current_context()
    return c, c._names(axes)


def legacy_chunks(num_chunks: Optional[int]) -> Optional[int]:
    """Normalize the legacy entry points' ``num_chunks`` (default 1 meaning
    "no chunking") to the api's override convention (None = follow the
    plan) — one spelling for every shim."""
    return num_chunks if num_chunks is not None and num_chunks > 1 else None


_LEGACY: Dict[tuple, CommContext] = {}


def legacy_context(axes, links) -> Optional[CommContext]:
    """Memoized meshless context for legacy ``links=`` call sites (model
    shims) — one context per (axes, links table), so repeated traces reuse
    its plan cache instead of re-planning from scratch.  Returns None when
    a context is already installed (the installed one wins)."""
    if links is None or current_context(None) is not None:
        return None
    key = (tuple(axes) if axes is not None else None, links_fingerprint(links))
    ctx = _LEGACY.get(key)
    if ctx is None:
        ctx = _LEGACY[key] = CommContext(axis_names=axes, links=links)
    return ctx


def _in_axis_env(names: Sequence[str]) -> bool:
    """True when every name is bound in the ambient axis env — i.e. we are
    tracing inside a shard_map body over these axes."""
    try:
        for n in names:
            axis_size(n)
        return True
    except Exception:
        return False


# --------------------------------------------------------------------------
# plan resolution helpers
# --------------------------------------------------------------------------

def _fit_plan(plan: CollectivePlan, length: int, granularity: int) -> CollectivePlan:
    """Clamp the chunk count to what divides the payload; a fit that
    collapses to one chunk normalizes the mode back to ``oneshot``
    (``CollectivePlan.with_chunks``) so a plan never executes one-shot
    while labeled ``chunked``."""
    from .staged_collectives import fit_chunks  # lazy: cycle

    if plan.num_chunks > 1:
        plan = plan.with_chunks(fit_chunks(length, granularity, plan.num_chunks))
    return plan


def _apply_overrides(
    plan: CollectivePlan, mode: Optional[str], num_chunks: Optional[int]
) -> CollectivePlan:
    """Mode/chunk overrides on top of a planner-resolved plan — ONE
    implementation for the per-call and the policy path.

    * mode alone — ``with_mode`` (restores that mode's own chunk decision;
      a one-chunk wavefront normalizes to its pure mode);
    * chunks > 1 alone — resize the wavefront; a plan not already in a
      chunked-family mode is forced to ``chunked`` (``hybrid`` keeps its
      ring stages, the count just resizes its wavefront);
    * both explicit with a chunked-family mode — honored verbatim, so
      ``mode="hybrid", num_chunks=4`` runs a 4-chunk hybrid even when the
      planner's own hybrid scan collapsed to one chunk.
    """
    if mode in ("chunked", "hybrid") and num_chunks is not None \
            and num_chunks > 1:
        return dataclasses.replace(plan, mode=mode, num_chunks=num_chunks)
    if mode is not None:
        plan = plan.with_mode(mode)
    if num_chunks is not None:
        plan = plan.with_chunks(num_chunks)
        if num_chunks > 1 and plan.mode not in ("chunked", "hybrid"):
            plan = dataclasses.replace(plan, mode="chunked",
                                       num_chunks=num_chunks)
    return plan


def _local_plan(ctx, collective, names, x, axis, *, mode, num_chunks,
                scattered, regime=None):
    """Plan + runtime fit for an inside-shard_map call.  ``scattered`` —
    whether ``x`` is already the scattered shard (AG input) or the
    full-length local array (RS/AR input).  ``regime`` forces a plan
    family; None resolves it from the policy + these per-call overrides
    (a mode/chunk override plans in the bandwidth family)."""
    sizes = {n: axis_size(n) for n in names}
    n_total = math.prod(sizes.values())
    nbytes = x.size * x.dtype.itemsize
    shard_bytes = nbytes if scattered else nbytes / n_total
    if regime is None:
        regime = ctx._effective_regime(mode, num_chunks)
    plan = ctx.plan(collective, shard_bytes, axes=names,
                    shape=tuple(x.shape), dtype=x.dtype, regime=regime)
    plan = _apply_overrides(plan, mode, num_chunks)
    granularity = 1 if scattered else n_total
    return _fit_plan(plan, x.shape[axis], granularity), n_total


def _require_mesh(ctx: CommContext, op: str):
    if ctx.mesh is None:
        raise ValueError(
            f"{op} was called outside shard_map and the active CommContext "
            f"has no mesh; install one via comm_context(mesh, axis_names)")
    return ctx.mesh


def _wrap(ctx, fn, x, in_spec, out_spec):
    mesh = _require_mesh(ctx, "this op")
    return shard_map(fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec)(x)


def _axis_spec(ndim: int, axis: int, names) -> P:
    spec = [None] * ndim
    spec[axis] = names
    return P(*spec)


def _run_local(ctx, y, plan, axis):
    """Execute a plan on a local shard (inside shard_map) — verified when
    the policy says so.  Fallback counting is impossible here (the diag is
    a tracer inside the caller's program); the verified output itself is
    still the checksum-selected one."""
    from .plan_executor import execute_plan, execute_plan_verified  # lazy: cycle

    if ctx.policy.verify:
        out, _ = execute_plan_verified(
            y, plan, axis=axis, retries=ctx.policy.verify_retries)
        return out
    return execute_plan(y, plan, axis=axis)


def _note_fallback(ctx, fell):
    if isinstance(fell, jax.core.Tracer):
        return  # traced (op called under jit): nothing concrete to count
    if int(fell) > 0:
        ctx.cache_stats.fallbacks += 1


def _run_wrapped(ctx, x, plan, axis, names, in_spec, out_spec):
    """shard_map-wrap + execute for the outside-shard_map op paths.  Under
    ``policy.verify`` the plan runs through ``execute_plan_verified``: each
    attempt's per-stage/conservation checksums pick the first clean result,
    exhausted retries degrade to the bit-identical XLA one-shot reference,
    and a concrete degrade is counted into ``ctx.cache_stats.fallbacks``."""
    from .plan_executor import execute_plan, execute_plan_verified  # lazy: cycle

    if not ctx.policy.verify:
        return _wrap(ctx, lambda y: execute_plan(y, plan, axis=axis), x,
                     in_spec, out_spec)

    def fn(y):
        out, diag = execute_plan_verified(
            y, plan, axis=axis, retries=ctx.policy.verify_retries)
        # replicate the flag over the group so P() is a sound out_spec
        fell = lax.psum(diag["used_fallback"].astype(jnp.int32), tuple(names))
        return out, fell

    mesh = _require_mesh(ctx, "this op")
    out, fell = shard_map(fn, mesh=mesh, in_specs=in_spec,
                          out_specs=(out_spec, P()))(x)
    _note_fallback(ctx, fell)
    return out


# --------------------------------------------------------------------------
# module-level ops
# --------------------------------------------------------------------------

def all_gather(
    x: jax.Array,
    *,
    axis: int = 0,
    axes: Optional[Sequence[str]] = None,
    ctx: Optional[CommContext] = None,
    mode: Optional[str] = None,
    num_chunks: Optional[int] = None,
) -> jax.Array:
    """Context-planned staged all-gather over the context axes.

    Inside shard_map ``x`` is the local shard (returns the full gather,
    bit-identical to ``lax.all_gather(tiled=True)``); outside, ``x`` is the
    globally-sharded array and the op wraps itself in shard_map over the
    context's mesh.  ``mode``/``num_chunks`` override the context policy
    for this call."""
    from .plan_executor import execute_plan  # lazy: cycle

    ctx, names = _resolve(ctx, axes)
    if axis < 0:
        axis += x.ndim
    if _in_axis_env(names):
        plan, _ = _local_plan(ctx, "ag", names, x, axis,
                              mode=mode, num_chunks=num_chunks, scattered=True)
        return _run_local(ctx, x, plan, axis)

    n = math.prod(ctx._sizes(names).values())
    shard_bytes = x.size * x.dtype.itemsize / n
    plan = ctx.plan("ag", shard_bytes, axes=names,
                    shape=tuple(x.shape), dtype=x.dtype,
                    regime=ctx._effective_regime(mode, num_chunks))
    plan = _apply_overrides(plan, mode, num_chunks)
    plan = _fit_plan(plan, x.shape[axis] // n, 1)
    return _run_wrapped(ctx, x, plan, axis, names,
                        _axis_spec(x.ndim, axis, names), P())


def reduce_scatter(
    x: jax.Array,
    *,
    axis: int = 0,
    axes: Optional[Sequence[str]] = None,
    ctx: Optional[CommContext] = None,
    mode: Optional[str] = None,
    num_chunks: Optional[int] = None,
) -> jax.Array:
    """Context-planned staged reduce-scatter (equals ``lax.psum_scatter``
    tiled, canonical blocks).  Inside shard_map ``x`` is the full-length
    local addend; outside, replicated input → scattered output."""
    from .plan_executor import execute_plan  # lazy: cycle

    ctx, names = _resolve(ctx, axes)
    if axis < 0:
        axis += x.ndim
    if _in_axis_env(names):
        plan, _ = _local_plan(ctx, "rs", names, x, axis,
                              mode=mode, num_chunks=num_chunks, scattered=False)
        return _run_local(ctx, x, plan, axis)

    n = math.prod(ctx._sizes(names).values())
    shard_bytes = x.size * x.dtype.itemsize / n
    plan = ctx.plan("rs", shard_bytes, axes=names,
                    shape=tuple(x.shape), dtype=x.dtype,
                    regime=ctx._effective_regime(mode, num_chunks))
    plan = _apply_overrides(plan, mode, num_chunks)
    plan = _fit_plan(plan, x.shape[axis], n)
    return _run_wrapped(ctx, x, plan, axis, names,
                        P(), _axis_spec(x.ndim, axis, names))


def all_reduce(
    x: jax.Array,
    *,
    axis: int = -1,
    axes: Optional[Sequence[str]] = None,
    ctx: Optional[CommContext] = None,
    mode: Optional[str] = None,
    num_chunks: Optional[int] = None,
) -> jax.Array:
    """Context-planned staged all-reduce (equals ``lax.psum``).

    ``axis`` only selects which dim the staged RS+AG pipeline scatters
    along.  Inside shard_map, a length not divisible by the device product
    falls back to a flat ``lax.psum`` — model code never has to care about
    divisibility (the old ``tp_all_reduce`` contract)."""
    from .plan_executor import execute_plan  # lazy: cycle

    ctx, names = _resolve(ctx, axes)
    if axis < 0:
        axis += x.ndim
    if _in_axis_env(names):
        n_total = math.prod(axis_size(n) for n in names)
        if x.shape[axis] % n_total:
            return lax.psum(x, names)
        plan, _ = _local_plan(ctx, "ar", names, x, axis,
                              mode=mode, num_chunks=num_chunks, scattered=False)
        return _run_local(ctx, x, plan, axis)

    n = math.prod(ctx._sizes(names).values())
    if x.shape[axis] % n:  # before planning: don't cache a plan never run
        return _wrap(ctx, lambda y: lax.psum(y, names), x, P(), P())
    shard_bytes = x.size * x.dtype.itemsize / n
    plan = ctx.plan("ar", shard_bytes, axes=names,
                    shape=tuple(x.shape), dtype=x.dtype,
                    regime=ctx._effective_regime(mode, num_chunks))
    plan = _apply_overrides(plan, mode, num_chunks)
    plan = _fit_plan(plan, x.shape[axis], n)
    return _run_wrapped(ctx, x, plan, axis, names, P(), P())


def all_to_all(
    x: jax.Array,
    *,
    axis: int = 0,
    axes: Optional[Sequence[str]] = None,
    ctx: Optional[CommContext] = None,
    mode: Optional[str] = None,
    num_chunks: Optional[int] = None,
) -> jax.Array:
    """Context-planned staged all-to-all over the context axes (the
    expert-parallel MoE dispatch/combine primitive).

    The dim ``axis`` holds N equal destination blocks in canonical
    (major-first) device order; the result holds the N received blocks by
    origin — the block transpose, bit-identical to ``lax.all_to_all(x,
    names, split_axis=axis, concat_axis=axis, tiled=True)``.  Inside
    shard_map ``x`` is the full local exchange buffer; outside, ``x`` is
    sharded along ``axis`` over the context's mesh and the op wraps itself
    in shard_map (output sharded the same way).  ``mode``/``num_chunks``
    override the context policy for this call."""
    from .plan_executor import execute_plan  # lazy: cycle

    ctx, names = _resolve(ctx, axes)
    if axis < 0:
        axis += x.ndim
    if _in_axis_env(names):
        n_total = math.prod(axis_size(n) for n in names)
        plan = ctx.plan("a2a", x.size * x.dtype.itemsize, axes=names,
                        shape=tuple(x.shape), dtype=x.dtype)
        plan = _apply_overrides(plan, mode, num_chunks)
        plan = _fit_plan(plan, x.shape[axis], n_total)
        return _run_local(ctx, x, plan, axis)

    n = math.prod(ctx._sizes(names).values())
    shard_bytes = x.size * x.dtype.itemsize / n  # one local exchange buffer
    plan = ctx.plan("a2a", shard_bytes, axes=names,
                    shape=tuple(x.shape), dtype=x.dtype)
    plan = _apply_overrides(plan, mode, num_chunks)
    plan = _fit_plan(plan, x.shape[axis] // n, n)
    spec = _axis_spec(x.ndim, axis, names)
    return _run_wrapped(ctx, x, plan, axis, names, spec, spec)


# --------------------------------------------------------------------------
# fused collective-matmul ops
# --------------------------------------------------------------------------

def _mm(piece, w):
    return jnp.einsum("...d,df->...f", piece, w)


def allgather_matmul(
    x: jax.Array,
    w,
    *,
    axis: int = 0,
    axes: Optional[Sequence[str]] = None,
    ctx: Optional[CommContext] = None,
    fuse: object = None,
):
    """``all_gather(x) @ w`` with the gather planned by the context and —
    when the policy/overlap model says so — overlapped against per-block
    matmuls (``kernels.collective_matmul.allgather_matmul``).

    ``w`` may be one weight or a sequence sharing the gather (SwiGLU
    gate+up).  Returns ``(gathered_x, out)`` with ``out`` matching ``w``'s
    structure.  Inside shard_map ``x`` is the local (scattered) block and
    ``w`` the local column slice; outside, ``x`` is sharded along ``axis``
    and each ``w`` along its last dim over the context axes."""
    from ..kernels.collective_matmul import allgather_matmul as _fused
    from .plan_executor import execute_plan  # lazy: cycle

    ctx, names = _resolve(ctx, axes)
    single = not isinstance(w, (list, tuple))
    ws = (w,) if single else tuple(w)
    if axis < 0:
        axis += x.ndim

    def run_local(xl, wl):
        # always carries a tuple of outputs; callers unwrap per `single`
        plan, _ = _local_plan(ctx, "ag", names, xl, axis,
                              mode=None, num_chunks=None, scattered=True)
        rows = xl.size // xl.shape[-1]
        d_in, d_out = wl[0].shape[-2], wl[0].shape[-1]
        do_fuse = ctx.decide_fuse(
            names, rows, d_in, d_out, xl.dtype.itemsize,
            n_matmuls=len(wl), fuse=fuse,
        )
        if do_fuse:
            # fused rings everywhere: the fusion decision already says the
            # per-hop decomposition wins, so the plain collective's stage
            # modes (a tradeoff with no compute to hide) don't apply.  A
            # latency (exchange) plan has no ring order to fuse against —
            # re-plan in the bandwidth family for the stage order.
            if any(s.mode == "exchange" for s in plan.stages):
                plan, _ = _local_plan(
                    ctx, "ag", names, xl, axis, mode=None, num_chunks=None,
                    scattered=True, regime="bandwidth")
            g, outs = _fused(xl, tuple(wl), names, stage_order=plan.axes,
                             axis=axis)
            return g, tuple(outs)
        g = execute_plan(xl, plan, axis=axis)
        return g, tuple(_mm(g, wi) for wi in wl)

    if _in_axis_env(names):
        g, outs = run_local(x, ws)
        return g, (outs[0] if single else outs)

    mesh = _require_mesh(ctx, "allgather_matmul")
    w_spec = P(*([None] * (ws[0].ndim - 1)), names)  # column-parallel weights
    # each output has x's rank with the projected feature dim LAST — shard
    # that dim, not the weight's layout (x may be rank > 2)
    o_spec = P(*([None] * (x.ndim - 1)), names)
    out_g, outs = shard_map(
        lambda xl, *wl: run_local(xl, wl),
        mesh=mesh,
        in_specs=(_axis_spec(x.ndim, axis, names),) + (w_spec,) * len(ws),
        out_specs=(P(), (o_spec,) * len(ws)),
    )(x, *ws)
    return out_g, (outs[0] if single else outs)


def matmul_reduce_scatter(
    h: jax.Array,
    w: jax.Array,
    *,
    axis: int = 0,
    axes: Optional[Sequence[str]] = None,
    ctx: Optional[CommContext] = None,
    fuse: object = None,
) -> jax.Array:
    """``psum_scatter(h @ w)`` with the combine planned by the context and —
    when fusion wins — the block matmuls feeding the ring just-in-time
    (``kernels.collective_matmul.matmul_reduce_scatter``).

    Inside shard_map ``h`` is the full-length local activation and ``w``
    the local row slice; outside, ``h`` is sharded along its last dim and
    ``w`` along its first dim over the context axes, the output scattered
    along ``axis``."""
    from ..kernels.collective_matmul import matmul_reduce_scatter as _fused
    from .plan_executor import execute_plan  # lazy: cycle

    ctx, names = _resolve(ctx, axes)
    if axis < 0:
        axis += h.ndim

    def run_local(hl, wl):
        sizes = {n: axis_size(n) for n in names}
        n_total = math.prod(sizes.values())
        out_bytes = (hl.size // hl.shape[-1]) * wl.shape[-1] * hl.dtype.itemsize
        plan = ctx.plan("rs", out_bytes / n_total, axes=names,
                        shape=tuple(hl.shape) + tuple(wl.shape), dtype=hl.dtype)
        # the RS runs on the matmul OUTPUT: when the scatter axis is the
        # feature axis, its length is w's d_out, not h's contracted d_in
        out_len = wl.shape[-1] if axis == hl.ndim - 1 else hl.shape[axis]
        plan = _fit_plan(plan, out_len, n_total)
        rows = hl.size // hl.shape[-1]
        do_fuse = ctx.decide_fuse(
            names, max(1, rows // n_total), wl.shape[0], wl.shape[1],
            hl.dtype.itemsize, fuse=fuse,
        )
        if do_fuse:
            if any(s.mode == "exchange" for s in plan.stages):
                # fused rings need a ring stage order, not an exchange chain
                plan = ctx.plan(
                    "rs", out_bytes / n_total, axes=names,
                    shape=tuple(hl.shape) + tuple(wl.shape),
                    dtype=hl.dtype, regime="bandwidth")
            return _fused(hl, wl, names, stage_order=plan.axes, axis=axis)
        return execute_plan(_mm(hl, wl), plan, axis=axis)

    if _in_axis_env(names):
        return run_local(h, w)

    mesh = _require_mesh(ctx, "matmul_reduce_scatter")
    h_spec = P(*([None] * (h.ndim - 1)), names)  # column-parallel activations
    w_spec = P(names, *([None] * (w.ndim - 1)))  # matching row-parallel weight
    return shard_map(
        run_local, mesh=mesh,
        in_specs=(h_spec, w_spec),
        out_specs=_axis_spec(h.ndim, axis, names),
    )(h, w)
