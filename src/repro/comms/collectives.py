"""Baseline collectives (paper §IV competitors) + hierarchical all-reduce.

All primitives here run *inside* shard_map.  ``ring_all_gather`` and
``neighbor_exchange_all_gather`` are TPU-native ports of the paper's Ring and
NE baselines (ppermute wavefronts); ``one_stage_all_gather`` is the paper's
one-stage model — a single flat collective.  ``hierarchical_all_reduce`` is
the OpTree-style staged gradient sync used by the ZeRO-1 optimizer: the slow
(pod/DCN) axis only ever carries the already-scattered shard — the direct
analogue of OpTree stage 1 carrying a single item per node.

Ring/NE unroll their step loops in Python: they are reference baselines for
correctness tests and small axes; the staged/XLA paths are the scale paths.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..compat import axis_size
from .staged_allgather import staged_all_gather
from .staged_collectives import staged_reduce_scatter

__all__ = [
    "ring_all_gather",
    "neighbor_exchange_all_gather",
    "one_stage_all_gather",
    "reduce_scatter",
    "hierarchical_all_reduce",
]


def one_stage_all_gather(x: jax.Array, axis_names, axis: int = 0) -> jax.Array:
    """The paper's one-stage model: a single flat all-gather."""
    names = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    return lax.all_gather(x, names, axis=axis, tiled=True)


def reduce_scatter(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    return lax.psum_scatter(x, axis_name, scatter_dimension=axis, tiled=True)


def ring_all_gather(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    """Classic N-1-step ring all-gather via ppermute (paper's Ring baseline)."""
    n = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    x0 = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    buf = jnp.zeros((n,) + x0.shape, x0.dtype)
    buf = lax.dynamic_update_slice(buf, x0[None], (idx,) + (0,) * x0.ndim)

    def body(t, carry):
        cur, buf = carry
        cur = lax.ppermute(cur, axis_name, perm)
        src = (idx - t) % n  # origin of the block arriving at step t
        buf = lax.dynamic_update_slice(buf, cur[None], (src,) + (0,) * cur.ndim)
        return cur, buf

    _, buf = lax.fori_loop(1, n, body, (x0, buf))
    out = buf.reshape((n * x0.shape[0],) + x0.shape[1:])
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def _ne_tables(n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Pair-index bookkeeping for neighbor exchange.

    h[t, i] = index of the pair (block 2h, 2h+1) node i *received* at step t
    (h[0] = own pair after the first exchange).  partner[t, i] = neighbour
    exchanged with at step t.
    """
    steps = n // 2
    h = np.zeros((steps, n), dtype=np.int64)
    partner = np.zeros((steps, n), dtype=np.int64)
    h[0] = np.arange(n) // 2
    partner[0] = np.arange(n) ^ 1
    for t in range(1, steps):
        if t % 2 == 1:  # odd pairing: (1,2),(3,4),...,(n-1,0)
            p = np.where(np.arange(n) % 2 == 1, (np.arange(n) + 1) % n, (np.arange(n) - 1) % n)
        else:  # even pairing: (0,1),(2,3),...
            p = np.arange(n) ^ 1
        partner[t] = p
        h[t] = h[t - 1][p]
    return h, partner


def neighbor_exchange_all_gather(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    """Neighbor-Exchange all-gather (Chen et al. 2005): N/2 exchange steps."""
    n = axis_size(axis_name)
    if n % 2:
        raise ValueError("neighbor exchange needs an even axis size")
    if n == 2:
        return one_stage_all_gather(x, axis_name, axis=axis)
    idx = lax.axis_index(axis_name)
    h_np, partner_np = _ne_tables(n)
    h = jnp.asarray(h_np)

    x0 = jnp.moveaxis(x, axis, 0) if axis != 0 else x
    buf = jnp.zeros((n,) + x0.shape, x0.dtype)
    buf = lax.dynamic_update_slice(buf, x0[None], (idx,) + (0,) * x0.ndim)

    # step 0: swap own single block with the even-pairing partner
    perm0 = [(i, int(partner_np[0, i])) for i in range(n)]
    recv = lax.ppermute(x0, axis_name, perm0)
    buf = lax.dynamic_update_slice(
        buf, recv[None], (jnp.asarray(partner_np[0])[idx],) + (0,) * x0.ndim
    )

    # steps 1..n/2-1: forward the pair received last step (pair h[t-1])
    for t in range(1, n // 2):
        send_start = 2 * h[t - 1][idx]
        block = lax.dynamic_slice(
            buf, (send_start,) + (0,) * x0.ndim, (2,) + x0.shape
        )
        perm = [(i, int(partner_np[t, i])) for i in range(n)]
        got = lax.ppermute(block, axis_name, perm)
        buf = lax.dynamic_update_slice(
            buf, got, (2 * h[t][idx],) + (0,) * x0.ndim
        )

    out = buf.reshape((n * x0.shape[0],) + x0.shape[1:])
    return jnp.moveaxis(out, 0, axis) if axis != 0 else out


def hierarchical_all_reduce(
    x: jax.Array,
    fast_axes: Sequence[str],
    slow_axes: Sequence[str] = (),
    *,
    gather: bool = True,
    num_chunks: int = 1,
) -> jax.Array:
    """OpTree-staged all-reduce: reduce-scatter over the fast (ICI) axes,
    psum over the slow (pod/DCN) axes on the scattered shard, then staged
    all-gather back (slow axis never sees the full payload).

    With ``gather=False`` the result stays scattered over ``fast_axes`` —
    the ZeRO-1 form (optimizer updates the shard, parameters are gathered
    later by `optree_all_gather`).  The scatter runs in canonical
    (major-first) block order, so the scattered shard is exactly
    ``psum_scatter(x, fast_axes)``'s block for this device.
    """
    fast_axes = tuple(fast_axes)
    slow_axes = tuple(slow_axes)
    y = staged_reduce_scatter(x, fast_axes, num_chunks=num_chunks)
    if slow_axes:
        y = lax.psum(y, slow_axes)
    if gather:
        y = staged_all_gather(y, fast_axes)  # major-first (paper order)
    return y
