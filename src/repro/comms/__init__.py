"""JAX-native staged collectives — the OpTree technique on a TPU mesh.

User-facing surface: ``comm_context`` + the ``api`` module ops (one
context-scoped entry point over the CollectivePlan IR); everything else
here is internals or deprecation shims.
"""
from .mesh_utils import make_factorized_mesh  # noqa: F401
from .staged_allgather import (  # noqa: F401
    staged_all_gather,
    optree_all_gather,
    canonical_all_gather,
)
from .staged_collectives import (  # noqa: F401
    StagedCollectiveEngine,
    plan_collectives,
    staged_all_gather_chunked,
    staged_all_reduce,
    staged_all_to_all,
    staged_reduce_scatter,
    tp_all_reduce,
)
from .ring_executor import (  # noqa: F401
    hybrid_all_gather,
    hybrid_all_reduce,
    hybrid_all_to_all,
    hybrid_reduce_scatter,
    perhop_all_gather,
    perhop_all_reduce,
    perhop_all_to_all,
    perhop_reduce_scatter,
    ring_all_gather_stage,
    ring_all_to_all_stage,
    ring_reduce_scatter_stage,
)
from .plan_executor import execute_plan  # noqa: F401
from . import api  # noqa: F401
from .api import (  # noqa: F401
    CommContext,
    PlanPolicy,
    comm_context,
    current_context,
)
from .collectives import (  # noqa: F401
    ring_all_gather,
    neighbor_exchange_all_gather,
    one_stage_all_gather,
    hierarchical_all_reduce,
    reduce_scatter,
)
from .decode_attention import sharded_decode_attention  # noqa: F401
