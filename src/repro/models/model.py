"""Config-driven model assembly: init / forward / loss / decode.

One code path covers all ten assigned architectures:

  dense | moe | vlm | audio : [ln -> attention -> ln -> FFN/MoE] x L
  ssm (rwkv6)               : [ln -> time-mix -> ln -> channel-mix] x L
  hybrid (zamba2)           : [ln -> mamba2] x L (+ one *shared* attn+FFN
                              block invoked every cfg.hybrid_attn_every
                              layers, weights reused, per-invocation KV)

Layers are stacked and run under ``lax.scan`` (keeps the HLO O(1) in depth —
essential for 64-layer 32B configs on the dry-run) with optional per-layer
remat.  MoE aux losses are accumulated through the scan carry.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (
    attention,
    attention_heads,
    attention_tp_out,
    attention_tp_out_sp,
    attn_init,
)
from .layers import dense, rmsnorm, rmsnorm_init
from .mamba2 import mamba2_block, mamba2_init, mamba2_state_init
from .mlp import ffn_apply, ffn_apply_tp, ffn_apply_tp_sp, mlp, mlp_init
from .moe import moe_block, moe_init
from .rwkv6 import (
    rwkv6_channel_mix,
    rwkv6_init,
    rwkv6_state_init,
    rwkv6_time_mix,
)
from .sharding import constrain

__all__ = ["init_params", "forward", "loss_fn", "init_decode_state",
           "decode_step", "transformer_block_tp", "transformer_block_ref",
           "tp_block_specs"]

ZERO_AUX = lambda: {"load_balance": jnp.zeros((), jnp.float32),
                    "router_z": jnp.zeros((), jnp.float32)}


def _maybe_checkpoint(cfg: ModelConfig, body):
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(body)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _layer_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":  # rwkv6
        p = rwkv6_init(ks[0], cfg, dtype=dtype)
        return {
            "ln1": rmsnorm_init(cfg.d_model, dtype=dtype),
            "tmix": p["tmix"],
            "ln2": rmsnorm_init(cfg.d_model, dtype=dtype),
            "cmix": p["cmix"],
        }
    if cfg.family == "hybrid":  # zamba2 backbone layer
        return {
            "ln1": rmsnorm_init(cfg.d_model, dtype=dtype),
            "mamba": mamba2_init(ks[0], cfg, dtype=dtype),
        }
    layer = {
        "ln1": rmsnorm_init(cfg.d_model, dtype=dtype),
        "attn": attn_init(ks[0], cfg, dtype=dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype=dtype),
    }
    if cfg.moe is not None:
        layer["moe"] = moe_init(ks[1], cfg, dtype=dtype)
    else:
        layer["ffn"] = mlp_init(ks[1], cfg, dtype=dtype)
    return layer


def init_params(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_head, k_shared = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    if cfg.frontend != "audio":
        # vocab rows are padded to cfg.padded_vocab so the vocab dim shards
        # evenly; the pad region is zero and masked out of loss/decode
        params["embed"] = (jax.random.normal(k_embed, (cfg.padded_vocab, cfg.d_model))
                           * 0.02).astype(dtype)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params["layers"] = jax.vmap(
        functools.partial(_layer_init, cfg=cfg, dtype=dtype)
    )(layer_keys)
    if cfg.hybrid_attn_every:
        params["shared_block"] = {
            "ln1": rmsnorm_init(cfg.d_model, dtype=dtype),
            "attn": attn_init(k_shared, cfg, dtype=dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype=dtype),
            "ffn": mlp_init(jax.random.fold_in(k_shared, 1), cfg, dtype=dtype),
        }
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype=dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": (jax.random.normal(k_head, (cfg.d_model, cfg.padded_vocab)) * 0.02
                  ).astype(dtype)
        }
    return params


# --------------------------------------------------------------------------
# decode state
# --------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.num_layers

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), tree)

    if cfg.family == "ssm":
        return {"rwkv": stack(rwkv6_state_init(cfg, batch, dtype=dtype))}
    if cfg.family == "hybrid":
        n_shared = L // cfg.hybrid_attn_every
        kv_shape = (n_shared, batch, cfg.num_kv_heads, max_seq, cfg.head_dim)
        return {
            "mamba": stack(mamba2_state_init(cfg, batch, dtype=dtype)),
            "shared_k": jnp.zeros(kv_shape, dtype),
            "shared_v": jnp.zeros(kv_shape, dtype),
        }
    kv_shape = (L, batch, cfg.num_kv_heads, max_seq, cfg.head_dim)
    return {"k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype)}


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def _embed_inputs(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    if cfg.frontend == "audio":
        return batch["embeds"].astype(jnp.dtype(cfg.dtype))
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.frontend == "vision" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, img, (0, 0, 0))
    return x


def _attn_layer_body(cfg, layer, x, positions, kv, cache_pos):
    h, new_kv = attention(
        layer["attn"], cfg, rmsnorm(layer["ln1"], x, cfg.norm_eps),
        positions=positions, kv_cache=kv, cache_pos=cache_pos,
    )
    x = x + h
    aux = ZERO_AUX()
    if cfg.moe is not None:
        h, aux = moe_block(layer["moe"], cfg, rmsnorm(layer["ln2"], x, cfg.norm_eps))
    else:
        h = mlp(layer["ffn"], cfg, rmsnorm(layer["ln2"], x, cfg.norm_eps))
    x = constrain(x + h, "hidden")
    return x, new_kv, aux


def _rwkv_layer_body(cfg, layer, x, state):
    st = state or {}
    h, last_t, wkv = rwkv6_time_mix(
        layer["tmix"], cfg, rmsnorm(layer["ln1"], x, cfg.norm_eps),
        last_x=st.get("tmix_x"), wkv_state=st.get("wkv"),
    )
    x = x + h
    h, last_c = rwkv6_channel_mix(
        layer["cmix"], cfg, rmsnorm(layer["ln2"], x, cfg.norm_eps),
        last_x=st.get("cmix_x"),
    )
    x = constrain(x + h, "hidden")
    new_state = {"tmix_x": last_t, "cmix_x": last_c, "wkv": wkv}
    return x, new_state


def _scan_or_loop(body, carry, xs, length: int, use_scan: bool):
    """lax.scan or an unrolled python loop (scan_layers=False: used by the
    roofline flops calibration, where while-loop trip counts hide cost)."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    ys_list = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys_list.append(y)
    ys = jax.tree.map(lambda *a: jnp.stack(a), *ys_list)
    return carry, ys


def apply_head(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    """Final-norm'd hidden -> (padded-)vocab logits in f32."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = dense(params["lm_head"], x)
    return logits.astype(jnp.float32)


def forward(
    cfg: ModelConfig,
    params: Dict,
    batch: Dict,
    *,
    cache: Optional[Dict] = None,
    cache_pos: Optional[jax.Array] = None,
    head_mode: str = "full",  # 'full' | 'last' | 'none'
) -> Tuple[jax.Array, Optional[Dict], Dict]:
    """Returns (logits-or-hidden, new_cache (if cache given), aux losses)."""
    x = _embed_inputs(cfg, params, batch)
    x = constrain(x, "hidden")
    B, S, _ = x.shape
    pos0 = jnp.zeros((), jnp.int32) if cache_pos is None else cache_pos
    positions = (pos0 + jnp.arange(S))[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (B, S))
    L = cfg.num_layers

    new_cache: Optional[Dict] = None

    if cfg.family == "ssm":
        use_cache = cache is not None

        def body(carry, layer_and_st):
            h, aux_acc = carry
            if use_cache:
                layer, st = layer_and_st
            else:
                layer, st = layer_and_st, None
            h, new_st = _rwkv_layer_body(cfg, layer, h, st)
            return (h, aux_acc), (new_st if use_cache else 0)

        if cfg.remat:
            body = _maybe_checkpoint(cfg, body)
        xs = (params["layers"], cache["rwkv"]) if use_cache else params["layers"]
        (x, _), new_sts = _scan_or_loop(body, (x, ZERO_AUX()), xs, L, cfg.scan_layers)
        if use_cache:
            new_cache = {"rwkv": new_sts}
        aux = ZERO_AUX()

    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        shared = params["shared_block"]
        use_cache = cache is not None
        sk = cache["shared_k"] if use_cache else None
        sv = cache["shared_v"] if use_cache else None

        def body(carry, xs):
            h, aux_acc, sk, sv = carry
            layer, st, idx = xs
            m, new_st = mamba2_block(
                layer["mamba"], cfg, rmsnorm(layer["ln1"], h, cfg.norm_eps),
                state=st if use_cache else None,
            )
            h = h + m

            def run_shared(h, sk, sv):
                slot = idx // every
                if use_cache:
                    kv = (
                        jax.lax.dynamic_index_in_dim(sk, slot, 0, keepdims=False),
                        jax.lax.dynamic_index_in_dim(sv, slot, 0, keepdims=False),
                    )
                else:
                    kv = None
                a, new_kv = attention(
                    shared["attn"], cfg, rmsnorm(shared["ln1"], h, cfg.norm_eps),
                    positions=positions, kv_cache=kv, cache_pos=pos0,
                )
                h2 = h + a
                h2 = h2 + mlp(shared["ffn"], cfg,
                              rmsnorm(shared["ln2"], h2, cfg.norm_eps))
                if use_cache:
                    sk = jax.lax.dynamic_update_index_in_dim(sk, new_kv[0], slot, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, new_kv[1], slot, 0)
                return h2, sk, sv

            is_shared = (idx % every) == (every - 1)
            h, sk, sv = jax.lax.cond(
                is_shared, run_shared, lambda h, a, b: (h, a, b), h, sk, sv
            )
            h = constrain(h, "hidden")
            return (h, aux_acc, sk, sv), (new_st if use_cache else 0)

        if cfg.remat:
            body = _maybe_checkpoint(cfg, body)
        if use_cache:
            sts = cache["mamba"]
        else:
            sts = jnp.zeros((L,), x.dtype)  # per-layer placeholder
            sk = jnp.zeros((1,), x.dtype)  # placeholders threaded through carry
            sv = jnp.zeros((1,), x.dtype)
        (x, _, sk, sv), new_sts = _scan_or_loop(
            body, (x, ZERO_AUX(), sk, sv),
            (params["layers"], sts, jnp.arange(L)), L, cfg.scan_layers,
        )
        if use_cache:
            new_cache = {"mamba": new_sts, "shared_k": sk, "shared_v": sv}
        aux = ZERO_AUX()

    else:  # attention families: dense / moe / vlm / audio
        use_cache = cache is not None

        def body(carry, xs):
            h, aux_acc = carry
            layer, kv = xs
            h, new_kv, aux = _attn_layer_body(
                cfg, layer, h, positions, kv if use_cache else None, pos0
            )
            aux_acc = jax.tree.map(lambda a, b: a + b, aux_acc, aux)
            return (h, aux_acc), (new_kv if use_cache else 0)

        if cfg.remat:
            body = _maybe_checkpoint(cfg, body)
        kvs = (cache["k"], cache["v"]) if use_cache else _dummy_kv(cfg, B, L, x.dtype)
        (x, aux), new_kvs = _scan_or_loop(
            body, (x, ZERO_AUX()), (params["layers"], kvs), L, cfg.scan_layers
        )
        if use_cache:
            new_cache = {"k": new_kvs[0], "v": new_kvs[1]}

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    x = constrain(x, "hidden")
    if head_mode == "none":
        # chunked-loss / prefill paths apply the head themselves
        return x, new_cache, aux
    if head_mode == "last":
        x = x[:, -1:]
    logits = constrain(apply_head(cfg, params, x), "logits")
    logits = logits[..., : cfg.vocab_size]  # drop vocab padding
    if head_mode == "last":
        logits = logits[:, 0]
    return logits, new_cache, aux


def _dummy_kv(cfg, B, L, dtype):
    # zero-length KV slots so train/prefill scans have uniform xs structure
    shape = (L, B, cfg.num_kv_heads, 0, cfg.head_dim)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _dummy_rwkv_states(cfg, B, dtype, L):
    hd = cfg.ssm.head_dim
    H = cfg.d_model // hd
    return {
        "tmix_x": jnp.zeros((L, B, 0), dtype),
        "cmix_x": jnp.zeros((L, B, 0), dtype),
        "wkv": jnp.zeros((L, B, 0, hd, hd), jnp.float32),
    }


def _dummy_mamba_states(cfg, B, dtype, L):
    return {
        "conv": jnp.zeros((L, B, 0, 1), dtype),
        "ssm": jnp.zeros((L, B, 0, 1, 1), jnp.float32),
    }


# --------------------------------------------------------------------------
# training loss / decode step
# --------------------------------------------------------------------------
def _chunked_xent(cfg: ModelConfig, params: Dict, hidden: jax.Array,
                  labels: jax.Array) -> jax.Array:
    """Sequence-chunked cross entropy: the (B, S, V) logits tensor is never
    materialized — each scan step computes a (B, chunk, V_padded) slab,
    reduces it to per-token log-likelihoods, and drops it."""
    B, S, d = hidden.shape
    chunk = min(cfg.loss_chunk, S)
    while S % chunk:
        chunk -= 1  # largest divisor <= loss_chunk
    n = S // chunk
    hs = jnp.moveaxis(hidden.reshape(B, n, chunk, d), 1, 0)  # (n,B,chunk,d)
    ls = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    Vp, V = cfg.padded_vocab, cfg.vocab_size

    @jax.checkpoint  # recompute the logits slab in bwd: O(B*chunk*V) -> O(1)
    def step(acc, inp):
        h, lab = inp
        logits = apply_head(cfg, params, h)  # (B, chunk, Vp) f32
        if Vp != V:
            col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            logits = jnp.where(col < V, logits, -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(ll), 0

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hs, ls))
    return -total / (B * S)


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
    hidden, _, aux = forward(cfg, params, batch, head_mode="none")
    labels = batch["labels"]
    ce = _chunked_xent(cfg, params, hidden, labels)
    total = ce
    metrics = {"ce": ce}
    if cfg.moe is not None:
        lb = aux["load_balance"] / cfg.num_layers
        rz = aux["router_z"] / cfg.num_layers
        total = total + 0.01 * lb + cfg.moe.router_z_loss * rz
        metrics.update(load_balance=lb, router_z=rz)
    metrics["loss"] = total
    return total, metrics


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    state: Dict,
    tokens: jax.Array,  # (B, 1)
    cache_pos: jax.Array,  # ()
) -> Tuple[jax.Array, Dict]:
    """One token of autoregressive decode against the serve state."""
    logits, new_cache, _ = forward(
        cfg, params, {"tokens": tokens}, cache=state, cache_pos=cache_pos
    )
    return logits[:, -1], new_cache


# --------------------------------------------------------------------------
# explicit-TP transformer block (context collectives)
# --------------------------------------------------------------------------

_TP_COL = frozenset({"wq", "wk", "wv", "gate", "up"})   # column-parallel
_TP_ROW = frozenset({"wo", "down"})                     # row-parallel


def _tp_local_cfg(cfg: ModelConfig, n: int) -> ModelConfig:
    if cfg.num_heads % n or cfg.num_kv_heads % n:
        raise ValueError(
            f"TP over {n} devices needs num_heads ({cfg.num_heads}) and "
            f"num_kv_heads ({cfg.num_kv_heads}) divisible by it")
    import dataclasses

    return dataclasses.replace(
        cfg, num_heads=cfg.num_heads // n, num_kv_heads=cfg.num_kv_heads // n)


def transformer_block_tp(
    layer: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d) replicated; SP: (B, S_local, d) seq shards
    *,
    positions: jax.Array,  # (B, S) — full sequence in both variants
    ctx=None,
    sequence_parallel: bool = False,
    seq_axis: int = 1,
) -> jax.Array:
    """The full explicit-TP transformer block (inside shard_map), running
    entirely on context collectives (``repro.comms.api``) — the shard_map
    counterpart of the GSPMD block (``transformer_block_ref``).

    ``layer`` holds this shard's TP slices (``tp_block_specs`` gives the
    matching shard_map in_specs): QKV and gate/up column-parallel, wo/down
    row-parallel, norms replicated.

    * **TP** (default): activations replicated; attention runs on the
      local heads, and both combine points are context-planned staged
      all-reduces.
    * **SP** (``sequence_parallel=True``): activations arrive
      sequence-sharded; the QKV projections share ONE context-planned
      all-gather (``api.allgather_matmul`` — each gathered block projected
      the hop it lands), and both combines return to sequence shards via
      just-in-time ``api.matmul_reduce_scatter``.

    All mode/chunking/fusion/stage-order decisions come from the active
    :func:`repro.comms.api.comm_context` (or the explicit ``ctx``) — no
    per-call comms plumbing.
    """
    from ..comms import api
    from ..compat import axis_size

    c = ctx if ctx is not None else api.current_context()
    names = c._names(None)
    n = math.prod(axis_size(a) for a in names)
    lcfg = _tp_local_cfg(cfg, n)
    ap = layer["attn"]

    h = rmsnorm(layer["ln1"], x, cfg.norm_eps)
    if sequence_parallel:
        hg, (q, k, v) = api.allgather_matmul(
            h, (ap["wq"]["w"], ap["wk"]["w"], ap["wv"]["w"]),
            axis=seq_axis, ctx=c,
        )
        # biases stay out of the fused ring: added once to the projections
        if "b" in ap["wq"]:
            q, k, v = q + ap["wq"]["b"], k + ap["wk"]["b"], v + ap["wv"]["b"]
        heads, _ = attention_heads(
            ap, lcfg, hg, positions=positions, qkv=(q, k, v))
        x = x + attention_tp_out_sp(ap, heads, seq_axis=seq_axis, ctx=c)
        h2 = rmsnorm(layer["ln2"], x, cfg.norm_eps)
        return x + ffn_apply_tp_sp(layer["ffn"], h2, seq_axis=seq_axis, ctx=c)

    heads, _ = attention_heads(ap, lcfg, h, positions=positions)
    x = x + attention_tp_out(ap, heads, ctx=c)
    h2 = rmsnorm(layer["ln2"], x, cfg.norm_eps)
    return x + ffn_apply_tp(layer["ffn"], h2, ctx=c)


def transformer_block_ref(
    layer: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    *,
    positions: jax.Array,
) -> jax.Array:
    """The same block on full (unsharded) parameters — the GSPMD path:
    under jit with TP shardings the partitioner emits the collectives this
    module's explicit form issues by hand."""
    h, _ = attention(
        layer["attn"], cfg, rmsnorm(layer["ln1"], x, cfg.norm_eps),
        positions=positions,
    )
    x = x + h
    return x + ffn_apply(layer["ffn"], rmsnorm(layer["ln2"], x, cfg.norm_eps))


def tp_block_specs(layer: Dict, axis_names, *, sequence_parallel: bool = False):
    """(x_spec, layer_specs) PartitionSpecs for running
    ``transformer_block_tp`` under shard_map (or as GSPMD in_shardings for
    the reference block): QKV/gate/up column-parallel over ``axis_names``,
    wo/down row-parallel, everything else replicated; ``x`` replicated (TP)
    or sequence-sharded (SP)."""
    from jax.sharding import PartitionSpec as P

    names = tuple(axis_names)

    def leaf_spec(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        proj = next((k for k in keys if k in _TP_COL | _TP_ROW), None)
        if proj in _TP_COL:
            return P(None, names) if keys[-1] == "w" else P(names)
        if proj in _TP_ROW:
            return P(names, None) if keys[-1] == "w" else P()
        return P()

    specs = jax.tree_util.tree_map_with_path(leaf_spec, layer)
    x_spec = P(None, names, None) if sequence_parallel else P()
    return x_spec, specs
