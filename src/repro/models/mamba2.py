"""Mamba2 (SSD) block for the zamba2 hybrid backbone.

State-space recurrence per head (P = head_dim, N = state_dim):

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * (x_t  B_t^T)     h: (P, N)
    y_t = h_t C_t + D * x_t

with a width-4 causal depthwise conv on (x, B, C) and a silu(z) gate.
Sequential lax.scan over time (chunked SSD left to the kernel layer);
decode carries {conv: (B, w-1, ch), ssm: (B, H, P, N)} — O(1) per token.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, group_norm

__all__ = ["mamba2_init", "mamba2_block", "mamba2_state_init"]


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm.expand * cfg.d_model
    P = cfg.ssm.head_dim
    H = d_in // P
    N = cfg.ssm.state_dim
    conv_ch = d_in + 2 * N
    return d_in, P, H, N, conv_ch


def mamba2_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    d = cfg.d_model
    d_in, P, H, N, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 5)
    out_scale = 0.02 / (2 * cfg.num_layers) ** 0.5
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * N + H, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.conv_dim, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((H,), jnp.float32),  # A = -exp(a_log) = -1
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], d_in, d, dtype=dtype, scale=out_scale),
    }


def mamba2_state_init(cfg: ModelConfig, batch: int, *, dtype) -> Dict:
    d_in, P, H, N, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm.conv_dim - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def _causal_conv(
    xBC: jax.Array, w: jax.Array, b: jax.Array, conv_state: Optional[jax.Array]
) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time. xBC: (B,S,ch); w: (K,ch)."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros_like(xBC[:, : K - 1])
    else:
        pad = conv_state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)  # (B, S+K-1, ch)
    out = sum(xp[:, i : i + xBC.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1) :]
    return out, new_state


def mamba2_block(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B,S,d)
    *,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, d = x.shape
    d_in, P, H, N, conv_ch = _dims(cfg)

    zxbcdt = jnp.einsum("bsd,df->bsf", x, p["in_proj"]["w"])
    z, xBC, dt_raw = jnp.split(zxbcdt, [d_in, d_in + conv_ch], axis=-1)

    conv_state = state["conv"] if state is not None else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs, Bmat, Cmat = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, S, H, P)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,)
    decay = jnp.exp(dt * a)  # (B,S,H)

    s0 = (
        state["ssm"]
        if state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )

    def step(h, inp):
        xt, Bt, Ct, dct, dtt = inp  # (B,H,P), (B,N), (B,N), (B,H), (B,H)
        upd = dtt[..., None, None] * (
            xt.astype(jnp.float32)[..., :, None] * Bt.astype(jnp.float32)[:, None, None, :]
        )  # (B,H,P,N)
        h = dct[..., None, None] * h + upd
        yt = jnp.einsum("bhpn,bn->bhp", h, Ct.astype(jnp.float32))
        return h, yt

    xs_t = jnp.moveaxis(xs, 1, 0)
    B_t = jnp.moveaxis(Bmat, 1, 0)
    C_t = jnp.moveaxis(Cmat, 1, 0)
    dc_t = jnp.moveaxis(decay, 1, 0)
    dt_t = jnp.moveaxis(dt, 1, 0)
    chunk = cfg.ssm.scan_chunk
    if chunk and S % chunk == 0 and S > chunk:
        # time-chunked remat: the backward pass only keeps the recurrent
        # state at chunk boundaries and recomputes inside each chunk —
        # O(S/chunk) residuals instead of O(S) (the zamba2 train_4k memory
        # fix, EXPERIMENTS.md §Perf)
        def chunk_body(h, inp):
            h, ys = jax.lax.scan(step, h, inp)
            return h, ys

        chunk_body = jax.checkpoint(chunk_body)
        resh = lambda a: a.reshape((S // chunk, chunk) + a.shape[1:])
        h_final, ys = jax.lax.scan(
            chunk_body, s0, tuple(resh(a) for a in (xs_t, B_t, C_t, dc_t, dt_t))
        )
        ys = ys.reshape((S,) + ys.shape[2:])
    else:
        h_final, ys = jax.lax.scan(step, s0, (xs_t, B_t, C_t, dc_t, dt_t))
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,P) f32
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = group_norm(y, H) * p["norm_scale"]
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"]["w"])

    new_state = {"conv": new_conv, "ssm": h_final} if state is not None else None
    return out, new_state
