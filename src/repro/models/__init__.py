"""Config-driven model zoo (all ten assigned architectures)."""
from .model import (  # noqa: F401
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
)
from . import sharding  # noqa: F401
