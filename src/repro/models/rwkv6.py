"""RWKV6 "Finch" block (arXiv:2404.05892): data-dependent-decay linear attention.

Time-mix: token-shift with data-dependent lerp (low-rank), WKV6 recurrence
(kernels.ops.rwkv6_scan — Pallas on TPU, scan oracle elsewhere), per-head
group-norm, silu gate.  Channel-mix: shifted squared-relu FFN.

Decode state per layer: {"tmix_x": (B,d), "cmix_x": (B,d),
"wkv": (B,H,hd,hd)} — O(1) per token, which is why rwkv6 runs the
``long_500k`` shape.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import dense, dense_init, group_norm

__all__ = ["rwkv6_init", "rwkv6_time_mix", "rwkv6_channel_mix", "rwkv6_state_init"]

TOKEN_SHIFT_RANK = 32
DECAY_RANK = 64


def rwkv6_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    ks = jax.random.split(key, 12)
    out_scale = 0.02 / (2 * cfg.num_layers) ** 0.5
    tmix = {
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(dtype),
        "lora_a": (jax.random.normal(ks[1], (d, 5 * TOKEN_SHIFT_RANK)) * 0.01).astype(dtype),
        "lora_b": (jax.random.normal(ks[2], (5, TOKEN_SHIFT_RANK, d)) * 0.01).astype(dtype),
        "w0": (jax.random.normal(ks[3], (d,)) * 0.1 - 6.0).astype(jnp.float32),
        "w_lora_a": (jax.random.normal(ks[4], (d, DECAY_RANK)) * 0.01).astype(dtype),
        "w_lora_b": (jax.random.normal(ks[5], (DECAY_RANK, d)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[6], (H, hd)) * 0.1).astype(jnp.float32),
        "wr": dense_init(ks[7], d, d, dtype=dtype),
        "wk": dense_init(ks[8], d, d, dtype=dtype),
        "wv": dense_init(ks[9], d, d, dtype=dtype),
        "wg": dense_init(ks[10], d, d, dtype=dtype),
        "wo": dense_init(ks[11], d, d, dtype=dtype, scale=out_scale),
    }
    kc = jax.random.split(jax.random.fold_in(key, 1), 3)
    cmix = {
        "mu_k": (jax.random.uniform(kc[0], (d,)) * 0.5 + 0.25).astype(dtype),
        "mu_r": (jax.random.uniform(kc[0], (d,)) * 0.5 + 0.25).astype(dtype),
        "wk": dense_init(kc[1], d, cfg.d_ff, dtype=dtype),
        "wv": dense_init(kc[2], cfg.d_ff, d, dtype=dtype, scale=out_scale),
        "wr": dense_init(jax.random.fold_in(kc[2], 7), d, d, dtype=dtype),
    }
    return {"tmix": tmix, "cmix": cmix}


def rwkv6_state_init(cfg: ModelConfig, batch: int, *, dtype) -> Dict:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    return {
        "tmix_x": jnp.zeros((batch, d), dtype),
        "cmix_x": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }


def _token_shift(x: jax.Array, last_x: Optional[jax.Array]) -> jax.Array:
    """Previous-token values: (B,S,d) -> (B,S,d); position 0 uses `last_x`."""
    prev = jnp.zeros_like(x[:, :1]) if last_x is None else last_x[:, None].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv6_time_mix(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B,S,d)
    *,
    last_x: Optional[jax.Array] = None,
    wkv_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, new_last_x, new_wkv_state)."""
    B, S, d = x.shape
    hd = cfg.ssm.head_dim
    H = d // hd

    shifted = _token_shift(x, last_x)
    xx = shifted - x
    # data-dependent lerp (Finch "ddlerp"): 5 channels r,k,v,g,w
    base = x + xx * p["mu"][0]
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, p["lora_a"]))
    lora = lora.reshape(B, S, 5, TOKEN_SHIFT_RANK)
    deltas = jnp.einsum("bscr,crd->bscd", lora, p["lora_b"])  # (B,S,5,d)
    mixed = x[:, :, None] + xx[:, :, None] * (p["mu"][None, None] + deltas)
    xr, xk, xv, xg, xw = (mixed[:, :, i] for i in range(5))

    r = dense(p["wr"], xr).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = dense(p["wk"], xk).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = dense(p["wv"], xv).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    g = dense(p["wg"], xg)

    # data-dependent decay in (0,1): w = exp(-exp(w0 + lora(xw)))
    w_log = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["w_lora_a"])).astype(jnp.float32),
        p["w_lora_b"].astype(jnp.float32),
    )
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, S, H, hd).transpose(0, 2, 1, 3)

    y, new_state = ops.rwkv6_scan(r, k, v, w.astype(r.dtype), p["u"], wkv_state)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d)
    y = group_norm(y, H, eps=64e-5)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    out = dense(p["wo"], y)
    return out, x[:, -1], new_state


def rwkv6_channel_mix(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    last_x: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    shifted = _token_shift(x, last_x)
    xx = shifted - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    k = dense(p["wk"], xk)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = dense(p["wv"], k)
    r = jax.nn.sigmoid(dense(p["wr"], xr).astype(jnp.float32)).astype(x.dtype)
    return r * kv, x[:, -1]
