"""Feed-forward blocks: SwiGLU (default) and GELU (hubert/w2v2).

``ffn_apply`` is the pjit/GSPMD form (sharding via PartitionSpecs);
``ffn_apply_tp`` / ``ffn_apply_tp_sp`` are the explicit tensor-parallel
forms for shard_map execution.  All collective decisions (stage order,
mode, chunking, collective-matmul fusion) come from the active
:class:`repro.comms.api.CommContext` — model code no longer threads
engines, links or fuse flags per call.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from ..comms import api
from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import dense, dense_init

__all__ = ["mlp_init", "mlp", "ffn_init", "ffn_apply", "ffn_apply_tp",
           "ffn_apply_tp_sp", "plan_tp_fusion"]


def ffn_init(key, d_model: int, d_ff: int, num_layers: int, *, dtype,
             kind: str = "swiglu") -> Dict:
    ks = jax.random.split(key, 3)
    down_scale = 0.02 / (2 * num_layers) ** 0.5
    if kind == "swiglu":
        return {
            "gate": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "up": dense_init(ks[1], d_model, d_ff, dtype=dtype),
            "down": dense_init(ks[2], d_ff, d_model, dtype=dtype, scale=down_scale),
        }
    if kind == "gelu":
        return {
            "up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "down": dense_init(ks[1], d_ff, d_model, dtype=dtype, scale=down_scale),
        }
    raise ValueError(kind)


def ffn_apply(p: Dict, x: jax.Array) -> jax.Array:
    if "gate" in p:
        h = ops.swiglu(dense(p["gate"], x), dense(p["up"], x))
    else:
        h = jax.nn.gelu(dense(p["up"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], h)


def ffn_apply_tp(
    p: Dict,
    x: jax.Array,
    axis_names: Optional[Sequence[str]] = None,
    *,
    num_chunks: Optional[int] = None,
    ctx=None,
) -> jax.Array:
    """Explicit tensor-parallel FFN body (inside shard_map).

    ``p`` holds this shard's slice of the hidden dim: gate/up are
    column-parallel (local d_ff columns), down is row-parallel (matching
    d_ff rows).  The down-projection therefore yields a *partial* sum over
    hidden shards; the context-planned all-reduce combines it — on
    factorized meshes the slow axes only ever carry the scattered payload.
    ``axis_names``/``num_chunks`` are legacy overrides; by default the
    active :func:`repro.comms.api.comm_context` supplies axes and policy.
    """
    partial = ffn_apply(p, x)
    return api.all_reduce(partial, axis=-1, ctx=ctx, axes=axis_names,
                          num_chunks=api.legacy_chunks(num_chunks))


def plan_tp_fusion(
    axis_names: Sequence[str],
    rows: int,
    d_in: int,
    d_out: int,
    itemsize: int,
    *,
    links: Optional[Dict] = None,
    n_matmuls: int = 1,
) -> bool:
    """Collective-matmul fuse decision for one gather-adjacent projection.

    ``rows`` is the per-block row count (the scattered shard's worth),
    ``d_in @ d_out`` the projection, ``n_matmuls`` how many projections share
    one gather (SwiGLU gate+up = 2).  Static per trace — shapes and mesh axis
    sizes are known at trace time, so the planner runs inside shard_map.
    One implementation with the context ops: delegates to
    :meth:`repro.comms.api.CommContext.decide_fuse`.
    """
    axis_names = tuple(axis_names)
    # decide_fuse is a pure computation, so a throwaway context carrying
    # the caller's links is fine; without links the active scope decides
    ctx = (api.current_context() if links is None
           else api.CommContext(axis_names=axis_names, links=links))
    return ctx.decide_fuse(
        axis_names, rows, d_in, d_out, itemsize,
        n_matmuls=n_matmuls, fuse="auto",
    )


def ffn_apply_tp_sp(
    p: Dict,
    x: jax.Array,
    axis_names: Optional[Sequence[str]] = None,
    *,
    seq_axis: int = 1,
    fuse: object = None,
    links: Optional[Dict] = None,
    ctx=None,
) -> jax.Array:
    """Sequence-parallel explicit-TP FFN body (inside shard_map).

    ``x`` arrives *sequence-sharded* over the context axes (the usual SP
    residual-stream layout); ``p`` holds this shard's d_ff slice as in
    ``ffn_apply_tp``.  The TP all-gather of ``x`` and the gate/up matmuls
    share one context-planned gather (fused per hop when the overlap model
    wins — ``api.allgather_matmul``) and the down-projection feeds the
    reduce-scatter back to sequence shards just-in-time
    (``api.matmul_reduce_scatter``).  Returns this shard's sequence slice
    of the combined FFN output.

    ``fuse``: None (context policy, default ``"auto"``) / True / False /
    ``"auto"``.  ``links`` is a legacy override consulted only when no
    context is installed.
    """
    if ctx is None:
        ctx = api.legacy_context(axis_names, links)
    up_w = p["up"]["w"]

    if "gate" in p:
        _, (g, u) = api.allgather_matmul(
            x, (p["gate"]["w"], up_w), axis=seq_axis, axes=axis_names,
            ctx=ctx, fuse=fuse,
        )
        h = ops.swiglu(g, u)
    else:
        _, u = api.allgather_matmul(
            x, up_w, axis=seq_axis, axes=axis_names, ctx=ctx, fuse=fuse)
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return api.matmul_reduce_scatter(
        h, p["down"]["w"], axis=seq_axis, axes=axis_names, ctx=ctx, fuse=fuse)


def mlp_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    kind = "gelu" if cfg.family == "audio" else "swiglu"
    return ffn_init(key, cfg.d_model, cfg.d_ff, cfg.num_layers, dtype=dtype, kind=kind)


def mlp(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return ffn_apply(p, x)
