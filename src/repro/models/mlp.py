"""Feed-forward blocks: SwiGLU (default) and GELU (hubert/w2v2).

``ffn_apply`` is the pjit/GSPMD form (sharding via PartitionSpecs);
``ffn_apply_tp`` is the explicit tensor-parallel form for shard_map
execution, combining the row-parallel partial sums with the staged
(OpTree-ordered) all-reduce.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from ..comms.staged_collectives import tp_all_reduce
from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import dense, dense_init

__all__ = ["mlp_init", "mlp", "ffn_init", "ffn_apply", "ffn_apply_tp"]


def ffn_init(key, d_model: int, d_ff: int, num_layers: int, *, dtype,
             kind: str = "swiglu") -> Dict:
    ks = jax.random.split(key, 3)
    down_scale = 0.02 / (2 * num_layers) ** 0.5
    if kind == "swiglu":
        return {
            "gate": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "up": dense_init(ks[1], d_model, d_ff, dtype=dtype),
            "down": dense_init(ks[2], d_ff, d_model, dtype=dtype, scale=down_scale),
        }
    if kind == "gelu":
        return {
            "up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "down": dense_init(ks[1], d_ff, d_model, dtype=dtype, scale=down_scale),
        }
    raise ValueError(kind)


def ffn_apply(p: Dict, x: jax.Array) -> jax.Array:
    if "gate" in p:
        h = ops.swiglu(dense(p["gate"], x), dense(p["up"], x))
    else:
        h = jax.nn.gelu(dense(p["up"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], h)


def ffn_apply_tp(
    p: Dict,
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    num_chunks: int = 1,
) -> jax.Array:
    """Explicit tensor-parallel FFN body (inside shard_map).

    ``p`` holds this shard's slice of the hidden dim: gate/up are
    column-parallel (local d_ff columns), down is row-parallel (matching
    d_ff rows).  The down-projection therefore yields a *partial* sum over
    hidden shards; the staged all-reduce combines it — on factorized meshes
    the slow axes only ever carry the scattered payload, and ``num_chunks``
    pipelines the reduction against nothing-yet (it overlaps RS/AG stages
    across chunks).
    """
    partial = ffn_apply(p, x)
    return tp_all_reduce(partial, axis_names, num_chunks=num_chunks)


def mlp_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    kind = "gelu" if cfg.family == "audio" else "swiglu"
    return ffn_init(key, cfg.d_model, cfg.d_ff, cfg.num_layers, dtype=dtype, kind=kind)


def mlp(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return ffn_apply(p, x)
