"""Feed-forward blocks: SwiGLU (default) and GELU (hubert/w2v2)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import dense, dense_init

__all__ = ["mlp_init", "mlp", "ffn_init", "ffn_apply"]


def ffn_init(key, d_model: int, d_ff: int, num_layers: int, *, dtype,
             kind: str = "swiglu") -> Dict:
    ks = jax.random.split(key, 3)
    down_scale = 0.02 / (2 * num_layers) ** 0.5
    if kind == "swiglu":
        return {
            "gate": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "up": dense_init(ks[1], d_model, d_ff, dtype=dtype),
            "down": dense_init(ks[2], d_ff, d_model, dtype=dtype, scale=down_scale),
        }
    if kind == "gelu":
        return {
            "up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "down": dense_init(ks[1], d_ff, d_model, dtype=dtype, scale=down_scale),
        }
    raise ValueError(kind)


def ffn_apply(p: Dict, x: jax.Array) -> jax.Array:
    if "gate" in p:
        h = ops.swiglu(dense(p["gate"], x), dense(p["up"], x))
    else:
        h = jax.nn.gelu(dense(p["up"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], h)


def mlp_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    kind = "gelu" if cfg.family == "audio" else "swiglu"
    return ffn_init(key, cfg.d_model, cfg.d_ff, cfg.num_layers, dtype=dtype, kind=kind)


def mlp(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return ffn_apply(p, x)
