"""Feed-forward blocks: SwiGLU (default) and GELU (hubert/w2v2).

``ffn_apply`` is the pjit/GSPMD form (sharding via PartitionSpecs);
``ffn_apply_tp`` is the explicit tensor-parallel form for shard_map
execution, combining the row-parallel partial sums with the staged
(OpTree-ordered) all-reduce.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from ..compat import axis_size
from ..comms.staged_allgather import link_for_axis, staged_all_gather
from ..comms.staged_collectives import staged_reduce_scatter, tp_all_reduce
from ..configs.base import ModelConfig
from ..core.planner import matmul_block_time, plan_collective_matmul
from ..kernels import ops
from ..kernels.collective_matmul import allgather_matmul, matmul_reduce_scatter
from .layers import dense, dense_init

__all__ = ["mlp_init", "mlp", "ffn_init", "ffn_apply", "ffn_apply_tp",
           "ffn_apply_tp_sp", "plan_tp_fusion"]


def ffn_init(key, d_model: int, d_ff: int, num_layers: int, *, dtype,
             kind: str = "swiglu") -> Dict:
    ks = jax.random.split(key, 3)
    down_scale = 0.02 / (2 * num_layers) ** 0.5
    if kind == "swiglu":
        return {
            "gate": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "up": dense_init(ks[1], d_model, d_ff, dtype=dtype),
            "down": dense_init(ks[2], d_ff, d_model, dtype=dtype, scale=down_scale),
        }
    if kind == "gelu":
        return {
            "up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "down": dense_init(ks[1], d_ff, d_model, dtype=dtype, scale=down_scale),
        }
    raise ValueError(kind)


def ffn_apply(p: Dict, x: jax.Array) -> jax.Array:
    if "gate" in p:
        h = ops.swiglu(dense(p["gate"], x), dense(p["up"], x))
    else:
        h = jax.nn.gelu(dense(p["up"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], h)


def ffn_apply_tp(
    p: Dict,
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    num_chunks: int = 1,
) -> jax.Array:
    """Explicit tensor-parallel FFN body (inside shard_map).

    ``p`` holds this shard's slice of the hidden dim: gate/up are
    column-parallel (local d_ff columns), down is row-parallel (matching
    d_ff rows).  The down-projection therefore yields a *partial* sum over
    hidden shards; the staged all-reduce combines it — on factorized meshes
    the slow axes only ever carry the scattered payload, and ``num_chunks``
    pipelines the reduction against nothing-yet (it overlaps RS/AG stages
    across chunks).
    """
    partial = ffn_apply(p, x)
    return tp_all_reduce(partial, axis_names, num_chunks=num_chunks)


def plan_tp_fusion(
    axis_names: Sequence[str],
    rows: int,
    d_in: int,
    d_out: int,
    itemsize: int,
    *,
    links: Optional[Dict] = None,
    n_matmuls: int = 1,
) -> bool:
    """Collective-matmul fuse decision for one gather-adjacent projection.

    ``rows`` is the per-block row count (the scattered shard's worth),
    ``d_in @ d_out`` the projection, ``n_matmuls`` how many projections share
    one gather (SwiGLU gate+up = 2).  Static per trace — shapes and mesh axis
    sizes are known at trace time, so the planner runs inside shard_map.
    """
    axis_names = tuple(axis_names)
    factors = [axis_size(n) for n in axis_names]
    lks = [link_for_axis(n, links) for n in axis_names]
    shard_bytes = rows * d_in * itemsize
    t_blk = n_matmuls * matmul_block_time(rows, d_in, d_out)
    return plan_collective_matmul(factors, lks, shard_bytes, t_blk).fuse


def ffn_apply_tp_sp(
    p: Dict,
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    seq_axis: int = 1,
    fuse: object = "auto",
    links: Optional[Dict] = None,
) -> jax.Array:
    """Sequence-parallel explicit-TP FFN body (inside shard_map).

    ``x`` arrives *sequence-sharded* over ``axis_names`` (the usual SP
    residual-stream layout); ``p`` holds this shard's d_ff slice as in
    ``ffn_apply_tp``.  The TP all-gather of ``x`` and the gate/up matmuls are
    fused — each gathered sequence block is projected the hop it lands — and
    the down-projection is decomposed per output block so it feeds the
    reduce-scatter back to sequence shards just-in-time
    (``kernels.collective_matmul``).  Returns this shard's sequence slice of
    the combined FFN output.

    ``fuse``: True / False / ``"auto"`` — auto asks
    ``core.planner.plan_collective_matmul`` whether the overlap model
    predicts a win for this (shape, mesh) point.
    """
    axis_names = tuple(axis_names)
    up_w = p["up"]["w"]
    d_model, d_ff_local = up_w.shape
    rows = x.size // x.shape[-1]  # per-block rows = local batch*seq product

    if fuse == "auto":
        fuse = plan_tp_fusion(
            axis_names, rows, d_model, d_ff_local, x.dtype.itemsize,
            links=links, n_matmuls=2 if "gate" in p else 1,
        )

    if not fuse:
        xg = staged_all_gather(x, axis_names, axis=seq_axis)
        partial = ffn_apply(p, xg)
        return staged_reduce_scatter(partial, axis_names, axis=seq_axis)

    if "gate" in p:
        _, (g, u) = allgather_matmul(
            x, (p["gate"]["w"], up_w), axis_names, axis=seq_axis
        )
        h = ops.swiglu(g, u)
    else:
        _, u = allgather_matmul(x, up_w, axis_names, axis=seq_axis)
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return matmul_reduce_scatter(h, p["down"]["w"], axis_names, axis=seq_axis)


def mlp_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    kind = "gelu" if cfg.family == "audio" else "swiglu"
    return ffn_init(key, cfg.d_model, cfg.d_ff, cfg.num_layers, dtype=dtype, kind=kind)


def mlp(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return ffn_apply(p, x)
