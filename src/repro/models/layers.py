"""Shared neural-net building blocks (pure-functional, dict params).

Compute dtype follows the config (bf16 on the TPU target); normalization,
softmax and logits run in float32.  The rmsnorm/swiglu/attention entry points
route through `repro.kernels.ops` so the Pallas kernels are first-class
(interpret-mode on CPU, ref oracle for gradients).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "rope_freqs",
    "apply_rope",
    "group_norm",
]


def dense_init(key, d_in: int, d_out: int, *, dtype, scale: Optional[float] = None,
               bias: bool = False) -> Dict:
    scale = 0.02 if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Dict, x: jax.Array) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d: int, *, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    from ..kernels import ops

    return ops.rmsnorm(x, p["scale"], eps=eps)


def group_norm(x: jax.Array, num_groups: int, eps: float = 1e-5) -> jax.Array:
    """Per-group (e.g. per-head) normalization, no affine."""
    *lead, d = x.shape
    g = x.reshape(*lead, num_groups, d // num_groups)
    g32 = g.astype(jnp.float32)
    mean = g32.mean(axis=-1, keepdims=True)
    var = g32.var(axis=-1, keepdims=True)
    out = (g32 - mean) * jax.lax.rsqrt(var + eps)
    return out.astype(x.dtype).reshape(*lead, d)


# --------------------------------------------------------------------------
# RoPE (GPT-NeoX half-rotation)
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
