"""GQA attention block: RoPE, optional qk-norm / QKV bias, KV cache.

Prefill/train run the flash path (`kernels.ops.flash_attention`); decode
attends one query against the full padded cache with a position mask —
when the KV cache is sequence-sharded the caller wraps this in the
sharded-KV combine (`serving.sharded_decode_attention`).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..comms import api
from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init

__all__ = ["attn_init", "attention", "attention_heads", "attention_tp_out",
           "attention_tp_out_sp"]


def attn_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    out_scale = 0.02 / (2 * cfg.num_layers) ** 0.5
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.q_dim, dtype=dtype, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, dtype=dtype, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, dtype=dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model, dtype=dtype, scale=out_scale),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim, dtype=dtype)
        p["k_norm"] = rmsnorm_init(cfg.head_dim, dtype=dtype)
    return p


def attention_heads(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    *,
    positions: jax.Array,  # (B, S) absolute positions
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B,Hkv,T,hd) x2
    cache_pos: Optional[jax.Array] = None,  # () position being written
    qkv: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Everything up to (but not including) the output projection: QKV,
    RoPE, flash/decode attention.  Returns the (B, S, H*hd) head outputs —
    the explicit-TP block projects + combines them through the context
    (``attention_tp_out``/``_sp``), the GSPMD path via ``p["wo"]``.

    ``qkv`` optionally supplies precomputed (pre-reshape) projections —
    the SP path computes them fused with the sequence all-gather
    (``api.allgather_matmul``) and hands them in here.
    """
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    if qkv is None:
        q, k, v = dense(p["wq"], x), dense(p["wk"], x), dense(p["wv"], x)
    else:
        q, k, v = qkv
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    qh = q.transpose(0, 2, 1, 3)  # (B,H,S,hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    if kv_cache is None:
        out = ops.flash_attention(qh, kh, vh, causal=cfg.causal)
        new_cache = None
    else:
        ck, cv = kv_cache  # (B, Hkv, T, hd)
        ck = jax.lax.dynamic_update_slice(ck, kh.astype(ck.dtype), (0, 0, cache_pos, 0))
        cv = jax.lax.dynamic_update_slice(cv, vh.astype(cv.dtype), (0, 0, cache_pos, 0))
        new_cache = (ck, cv)
        if S > 1:
            # prefill: the new block is the whole context — attend causally
            # within it; the cache write above is just state installation
            out = ops.flash_attention(qh, kh, vh, causal=cfg.causal)
        else:
            # decode: one query against the valid prefix of the cache
            T = ck.shape[2]
            valid = jnp.arange(T)[None, :] <= cache_pos  # (1, T)
            valid = jnp.broadcast_to(valid, (B, T))
            out = ops.flash_attention(qh, ck, cv, causal=False, kv_mask=valid)

    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return out, new_cache


def attention(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    *,
    positions: jax.Array,  # (B, S) absolute positions
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B,Hkv,T,hd) x2
    cache_pos: Optional[jax.Array] = None,  # () position being written
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    out, new_cache = attention_heads(
        p, cfg, x, positions=positions, kv_cache=kv_cache, cache_pos=cache_pos
    )
    return dense(p["wo"], out), new_cache


def attention_tp_out(
    p: Dict,
    out_local: jax.Array,  # (B, S, local_q_dim) — this shard's heads
    axis_names: Optional[Sequence[str]] = None,
    *,
    num_chunks: Optional[int] = None,
    ctx=None,
) -> jax.Array:
    """Explicit tensor-parallel output projection (inside shard_map).

    Heads are sharded over the context axes; ``p["wo"]`` holds the matching
    rows, so the local matmul is a partial sum over head shards.  The
    context-planned all-reduce combines the partials — the TP-reduction
    analogue of the OpTree all-gather, with the slow axes carrying only the
    scattered payload.  ``axis_names``/``num_chunks`` are legacy overrides.
    """
    partial = dense(p["wo"], out_local)
    return api.all_reduce(partial, axis=-1, ctx=ctx, axes=axis_names,
                          num_chunks=api.legacy_chunks(num_chunks))


def attention_tp_out_sp(
    p: Dict,
    out_local: jax.Array,  # (B, S, local_q_dim) — this shard's heads
    axis_names: Optional[Sequence[str]] = None,
    *,
    seq_axis: int = 1,
    fuse: object = None,
    links: Optional[Dict] = None,
    ctx=None,
) -> jax.Array:
    """Sequence-parallel TP output projection (inside shard_map).

    Like ``attention_tp_out`` but combining back to *sequence shards* (the
    SP residual-stream layout): ``psum_scatter(out_local @ wo)`` along
    ``seq_axis``, planned and (when the overlap model wins) fused per block
    by the context (``api.matmul_reduce_scatter`` — the wo block matmuls
    feed the ring just-in-time).  A wo bias, if present, is added once to
    the scattered output (never into the partial sums).
    """
    if ctx is None:
        ctx = api.legacy_context(axis_names, links)
    out = api.matmul_reduce_scatter(
        out_local, p["wo"]["w"], axis=seq_axis, axes=axis_names,
        ctx=ctx, fuse=fuse,
    )
    if "b" in p["wo"]:
        out = out + p["wo"]["b"]
    return out
