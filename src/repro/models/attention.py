"""GQA attention block: RoPE, optional qk-norm / QKV bias, KV cache.

Prefill/train run the flash path (`kernels.ops.flash_attention`); decode
attends one query against the full padded cache with a position mask —
when the KV cache is sequence-sharded the caller wraps this in the
sharded-KV combine (`serving.sharded_decode_attention`).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..comms.staged_collectives import staged_reduce_scatter, tp_all_reduce
from ..configs.base import ModelConfig
from ..kernels import ops
from ..kernels.collective_matmul import matmul_reduce_scatter
from .layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init

__all__ = ["attn_init", "attention", "attention_tp_out", "attention_tp_out_sp"]


def attn_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    out_scale = 0.02 / (2 * cfg.num_layers) ** 0.5
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.q_dim, dtype=dtype, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, dtype=dtype, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, dtype=dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model, dtype=dtype, scale=out_scale),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim, dtype=dtype)
        p["k_norm"] = rmsnorm_init(cfg.head_dim, dtype=dtype)
    return p


def attention(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    *,
    positions: jax.Array,  # (B, S) absolute positions
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B,Hkv,T,hd) x2
    cache_pos: Optional[jax.Array] = None,  # () position being written
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = dense(p["wq"], x).reshape(B, S, H, hd)
    k = dense(p["wk"], x).reshape(B, S, Hkv, hd)
    v = dense(p["wv"], x).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    qh = q.transpose(0, 2, 1, 3)  # (B,H,S,hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    if kv_cache is None:
        out = ops.flash_attention(qh, kh, vh, causal=cfg.causal)
        new_cache = None
    else:
        ck, cv = kv_cache  # (B, Hkv, T, hd)
        ck = jax.lax.dynamic_update_slice(ck, kh.astype(ck.dtype), (0, 0, cache_pos, 0))
        cv = jax.lax.dynamic_update_slice(cv, vh.astype(cv.dtype), (0, 0, cache_pos, 0))
        new_cache = (ck, cv)
        if S > 1:
            # prefill: the new block is the whole context — attend causally
            # within it; the cache write above is just state installation
            out = ops.flash_attention(qh, kh, vh, causal=cfg.causal)
        else:
            # decode: one query against the valid prefix of the cache
            T = ck.shape[2]
            valid = jnp.arange(T)[None, :] <= cache_pos  # (1, T)
            valid = jnp.broadcast_to(valid, (B, T))
            out = ops.flash_attention(qh, ck, cv, causal=False, kv_mask=valid)

    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return dense(p["wo"], out), new_cache


def attention_tp_out(
    p: Dict,
    out_local: jax.Array,  # (B, S, local_q_dim) — this shard's heads
    axis_names: Sequence[str],
    *,
    num_chunks: int = 1,
) -> jax.Array:
    """Explicit tensor-parallel output projection (inside shard_map).

    Heads are sharded over ``axis_names``; ``p["wo"]`` holds the matching
    rows, so the local matmul is a partial sum over head shards.  The
    staged all-reduce combines the partials — the TP-reduction analogue of
    the OpTree all-gather, with the slow axes carrying only the scattered
    payload and ``num_chunks`` pipelining the RS/AG stages.
    """
    partial = dense(p["wo"], out_local)
    return tp_all_reduce(partial, axis_names, num_chunks=num_chunks)


def attention_tp_out_sp(
    p: Dict,
    out_local: jax.Array,  # (B, S, local_q_dim) — this shard's heads
    axis_names: Sequence[str],
    *,
    seq_axis: int = 1,
    fuse: object = "auto",
    links: Optional[Dict] = None,
) -> jax.Array:
    """Sequence-parallel TP output projection (inside shard_map).

    Like ``attention_tp_out`` but combining back to *sequence shards* (the
    SP residual-stream layout): ``psum_scatter(out_local @ wo)`` over
    ``axis_names`` along ``seq_axis``.  When ``fuse`` (default: the planner's
    overlap model), the wo matmul is decomposed per sequence block so each
    block feeds its reduce-scatter hop just-in-time — the combine's transfer
    time hides behind the MXU.  A wo bias, if present, is added once to the
    scattered output (never into the partial sums).
    """
    import math

    from ..compat import axis_size
    from .mlp import plan_tp_fusion

    axis_names = tuple(axis_names)
    w = p["wo"]["w"]
    rows = out_local.size // out_local.shape[-1]
    n_total = math.prod(axis_size(n) for n in axis_names)

    if fuse == "auto":
        fuse = plan_tp_fusion(
            axis_names, max(1, rows // n_total), w.shape[0], w.shape[1],
            out_local.dtype.itemsize, links=links,
        )

    if fuse:
        out = matmul_reduce_scatter(out_local, w, axis_names, axis=seq_axis)
    else:
        partial = jnp.einsum("...d,df->...f", out_local, w)
        out = staged_reduce_scatter(partial, axis_names, axis=seq_axis)
    if "b" in p["wo"]:
        out = out + p["wo"]["b"]
    return out
