"""Mixture-of-Experts block: top-k routing with sort-based capacity dispatch.

Dispatch is scatter/gather based (Megablocks-style), not the GShard one-hot
einsum: the (tokens, experts, capacity) one-hot tensor is O(T*E*C) and
explodes at arctic scale (1M tokens x 128 experts); the sort path stays
O(T*K*d + E*C*d) and shards cleanly with experts on the 'model' axis
(expert parallelism) and capacity on the 'data' axis.

Supports:
  * top-1 + always-on shared expert (llama4-scout),
  * top-2 + parallel dense residual FFN (arctic),
  * load-balance + router-z auxiliary losses.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense, dense_init
from .mlp import ffn_apply, ffn_init
from .sharding import constrain

__all__ = ["moe_init", "moe_block"]


def moe_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    e = cfg.moe
    assert e is not None
    ks = jax.random.split(key, 4)
    d = cfg.d_model

    def expert_init(k):
        kk = jax.random.split(k, 3)
        scale = 0.02 / (2 * cfg.num_layers) ** 0.5
        return {
            "gate": (jax.random.normal(kk[0], (d, e.d_ff_expert)) * 0.02).astype(dtype),
            "up": (jax.random.normal(kk[1], (d, e.d_ff_expert)) * 0.02).astype(dtype),
            "down": (jax.random.normal(kk[2], (e.d_ff_expert, d)) * scale).astype(dtype),
        }

    p = {
        "router": dense_init(ks[0], d, e.num_experts, dtype=jnp.float32, scale=0.01),
        "experts": jax.vmap(expert_init)(jax.random.split(ks[1], e.num_experts)),
    }
    if e.shared_expert:
        p["shared"] = ffn_init(ks[2], d, e.d_ff_expert, cfg.num_layers, dtype=dtype)
    if e.dense_residual:
        p["dense"] = ffn_init(ks[3], d, cfg.d_ff, cfg.num_layers, dtype=dtype)
    return p


def _expert_ffn(experts: Dict, xe: jax.Array) -> jax.Array:
    """xe: (E, C, d) -> (E, C, d); batched over experts (EP-shardable)."""
    g = jnp.einsum("ecd,edf->ecf", xe, experts["gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, experts["up"])
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(xe.dtype)
    return jnp.einsum("ecf,efd->ecd", h, experts["down"])


def _num_groups(T: int, want: int = 32) -> int:
    g = min(want, T)
    while T % g:
        g -= 1
    return g


def moe_block(
    p: Dict, cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (out, aux_losses).

    Group-local dispatch: tokens are split into G data-parallel groups; the
    argsort / rank / scatter bookkeeping never crosses a group boundary, so
    under pjit those ops stay shard-local and the only cross-device movement
    is the (G, E, C, d) <-> expert-weights contraction — the EP all-to-all.
    (A global argsort permutes tokens across the whole data axis every layer;
    that cost arctic-480b 16 TB/step of all-reduce — EXPERIMENTS.md §Perf.)
    """
    e = cfg.moe
    B, S, d = x.shape
    T = B * S
    K, E = e.top_k, e.num_experts
    G = _num_groups(T)
    Tg = T // G
    C = max(1, math.ceil(K * Tg / E * e.capacity_factor))

    xt = x.reshape(T, d)
    xg = x.reshape(G, Tg, d)
    router_logits = dense(p["router"], xg.astype(jnp.float32))  # (G, Tg, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (G, Tg, K)
    if K > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- aux losses (Switch-style, over all tokens) ----
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )
    aux = {
        "load_balance": E * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2),
    }

    # ---- group-local sort-based dispatch ----
    flat_ids = expert_ids.reshape(G, Tg * K)
    order = jnp.argsort(flat_ids, axis=-1)  # (G, TgK), stable per group
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    run_start = jax.vmap(lambda s: jnp.searchsorted(s, s, side="left"))(sorted_ids)
    pos_in_expert = jnp.arange(Tg * K)[None, :] - run_start
    keep = pos_in_expert < C
    pos_c = jnp.where(keep, pos_in_expert, C)  # C is OOB -> mode='drop'

    src_token = order // K  # (G, TgK) indices into the group's tokens

    def scatter_group(xg_g, ids_g, pos_g, src_g):
        gathered = xg_g[src_g]  # (TgK, d)
        return jnp.zeros((E, C, d), x.dtype).at[ids_g, pos_g].set(
            gathered, mode="drop"
        )

    buf = jax.vmap(scatter_group)(xg, sorted_ids, pos_c, src_token)  # (G,E,C,d)
    buf = constrain(buf, "moe_buffer")

    g_ = jnp.einsum("gecd,edf->gecf", buf, p["experts"]["gate"])
    u_ = jnp.einsum("gecd,edf->gecf", buf, p["experts"]["up"])
    h_ = (jax.nn.silu(g_.astype(jnp.float32)) * u_.astype(jnp.float32)).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", h_, p["experts"]["down"])  # (G,E,C,d)
    ye = constrain(ye, "moe_buffer")

    pos_clip = jnp.minimum(pos_c, C - 1)

    def gather_group(ye_g, ids_g, pos_g, keep_g, src_g, gates_g):
        rows = ye_g[ids_g, pos_g]  # (TgK, d)
        rows = jnp.where(keep_g[:, None], rows, 0.0)
        contrib = rows * gates_g[:, None].astype(rows.dtype)
        return jnp.zeros((Tg, d), x.dtype).at[src_g].add(contrib)

    gates_sorted = jnp.take_along_axis(
        gate_vals.reshape(G, Tg * K), order, axis=-1
    )
    out = jax.vmap(gather_group)(
        ye, sorted_ids, pos_clip, keep, src_token, gates_sorted
    )  # (G, Tg, d)
    out = out.reshape(T, d)

    if e.shared_expert:
        out = out + ffn_apply(p["shared"], xt)
    if e.dense_residual:
        out = out + ffn_apply(p["dense"], xt)
    return out.reshape(B, S, d), aux
