"""Mixture-of-Experts block: top-k routing with sort-based capacity dispatch.

Dispatch is scatter/gather based (Megablocks-style), not the GShard one-hot
einsum: the (tokens, experts, capacity) one-hot tensor is O(T*E*C) and
explodes at arctic scale (1M tokens x 128 experts); the sort path stays
O(T*K*d + E*C*d) and shards cleanly with experts on the 'model' axis
(expert parallelism) and capacity on the 'data' axis.

Supports:
  * top-1 + always-on shared expert (llama4-scout),
  * top-2 + parallel dense residual FFN (arctic),
  * load-balance + router-z auxiliary losses.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from ..configs.base import ModelConfig
from .layers import dense, dense_init
from .mlp import ffn_apply, ffn_init
from .sharding import constrain

__all__ = ["moe_init", "moe_block"]


def _ep_active(axis_name: str) -> bool:
    """True when ``axis_name`` is bound in the ambient axis env — i.e. we
    are tracing inside a shard_map body that carries the expert axis."""
    try:
        axis_size(axis_name)
        return True
    except Exception:
        return False


def moe_init(key, cfg: ModelConfig, *, dtype) -> Dict:
    e = cfg.moe
    assert e is not None
    ks = jax.random.split(key, 4)
    d = cfg.d_model

    def expert_init(k):
        kk = jax.random.split(k, 3)
        scale = 0.02 / (2 * cfg.num_layers) ** 0.5
        return {
            "gate": (jax.random.normal(kk[0], (d, e.d_ff_expert)) * 0.02).astype(dtype),
            "up": (jax.random.normal(kk[1], (d, e.d_ff_expert)) * 0.02).astype(dtype),
            "down": (jax.random.normal(kk[2], (e.d_ff_expert, d)) * scale).astype(dtype),
        }

    p = {
        "router": dense_init(ks[0], d, e.num_experts, dtype=jnp.float32, scale=0.01),
        "experts": jax.vmap(expert_init)(jax.random.split(ks[1], e.num_experts)),
    }
    if e.shared_expert:
        p["shared"] = ffn_init(ks[2], d, e.d_ff_expert, cfg.num_layers, dtype=dtype)
    if e.dense_residual:
        p["dense"] = ffn_init(ks[3], d, cfg.d_ff, cfg.num_layers, dtype=dtype)
    return p


def _expert_ffn(experts: Dict, xe: jax.Array) -> jax.Array:
    """xe: (E, C, d) -> (E, C, d); batched over experts (EP-shardable)."""
    g = jnp.einsum("ecd,edf->ecf", xe, experts["gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, experts["up"])
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(xe.dtype)
    return jnp.einsum("ecf,efd->ecd", h, experts["down"])


def _ep_expert_ffn(experts: Dict, buf: jax.Array, axis_name: str) -> jax.Array:
    """Expert-parallel (G, E, C, d) -> (G, E, C, d): each device owns
    E/m contiguous experts along mesh axis ``axis_name``.

    The dispatch buffer's expert dim is owner-major (experts contiguous per
    owner device), so one context-planned ``api.all_to_all`` ships every
    device's per-expert slices to the expert owners, the local expert shard
    runs on the concatenated arrivals, and the inverse all-to-all returns
    the results to the token owners — the only cross-device movement, and
    it flows through the same CollectivePlan IR the pricer and the optical
    simulator consume.

    ``experts`` may hold the full (E, ...) stacked weights (replicated
    params, e.g. the explicit-ZeRO1 trainer: this device's shard is sliced
    out locally, so gradients land in the right slice) or an already-local
    (E/m, ...) shard."""
    from ..comms import api  # lazy: models must stay importable without comms

    m = axis_size(axis_name)
    G, E, C, d = buf.shape
    if E % m:
        raise ValueError(
            f"num_experts {E} not divisible by expert axis "
            f"{axis_name!r} size {m}")
    e_loc = E // m
    w_gate, w_up, w_down = experts["gate"], experts["up"], experts["down"]
    if w_gate.shape[0] == E and m > 1:
        idx = lax.axis_index(axis_name)

        def sl(w):
            return lax.dynamic_slice_in_dim(w, idx * e_loc, e_loc, axis=0)

        w_gate, w_up, w_down = sl(w_gate), sl(w_up), sl(w_down)
    elif w_gate.shape[0] != e_loc:
        raise ValueError(
            f"expert weights have leading dim {w_gate.shape[0]}; expected "
            f"{E} (replicated) or {e_loc} (local shard) for "
            f"{m}-way expert parallelism")

    # (G,E,C,d) -> (E,G,C,d) -> (E·G·C, d): destination block v = the
    # slices for experts [v·e_loc, (v+1)·e_loc) — owner-major by experts
    z = jnp.swapaxes(buf, 0, 1).reshape(E * G * C, d)
    z = api.all_to_all(z, axes=(axis_name,))
    # received block u = device u's slices for MY experts
    z = jnp.swapaxes(z.reshape(m, e_loc, G, C, d), 0, 1)
    y = _expert_ffn(
        {"gate": w_gate, "up": w_up, "down": w_down},
        z.reshape(e_loc, m * G * C, d),
    )
    # inverse exchange: results back to the token owners, expert-major
    y = jnp.swapaxes(y.reshape(e_loc, m, G, C, d), 0, 1).reshape(E * G * C, d)
    y = api.all_to_all(y, axes=(axis_name,))
    return jnp.swapaxes(y.reshape(E, G, C, d), 0, 1)


def _num_groups(T: int, want: int = 32) -> int:
    g = min(want, T)
    while T % g:
        g -= 1
    return g


def moe_block(
    p: Dict, cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (out, aux_losses).

    Group-local dispatch: tokens are split into G data-parallel groups; the
    argsort / rank / scatter bookkeeping never crosses a group boundary, so
    under pjit those ops stay shard-local and the only cross-device movement
    is the (G, E, C, d) <-> expert-weights contraction — the EP all-to-all.
    (A global argsort permutes tokens across the whole data axis every layer;
    that cost arctic-480b 16 TB/step of all-reduce — EXPERIMENTS.md §Perf.)

    With ``cfg.moe.expert_axis`` set AND that axis bound in the ambient axis
    env (tracing inside shard_map), the EP all-to-all is EXPLICIT: experts
    shard over the axis and ``_ep_expert_ffn`` routes dispatch/combine
    through ``repro.comms.api.all_to_all`` — context-planned, plan-cached,
    and numerically identical to running this block per device shard with
    all experts local.
    """
    e = cfg.moe
    B, S, d = x.shape
    T = B * S
    K, E = e.top_k, e.num_experts
    G = _num_groups(T)
    Tg = T // G
    C = max(1, math.ceil(K * Tg / E * e.capacity_factor))

    xt = x.reshape(T, d)
    xg = x.reshape(G, Tg, d)
    router_logits = dense(p["router"], xg.astype(jnp.float32))  # (G, Tg, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (G, Tg, K)
    if K > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- aux losses (Switch-style, over all tokens) ----
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )
    aux = {
        "load_balance": E * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2),
    }

    # ---- group-local sort-based dispatch ----
    flat_ids = expert_ids.reshape(G, Tg * K)
    order = jnp.argsort(flat_ids, axis=-1)  # (G, TgK), stable per group
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    run_start = jax.vmap(lambda s: jnp.searchsorted(s, s, side="left"))(sorted_ids)
    pos_in_expert = jnp.arange(Tg * K)[None, :] - run_start
    keep = pos_in_expert < C
    pos_c = jnp.where(keep, pos_in_expert, C)  # C is OOB -> mode='drop'

    src_token = order // K  # (G, TgK) indices into the group's tokens

    def scatter_group(xg_g, ids_g, pos_g, src_g):
        gathered = xg_g[src_g]  # (TgK, d)
        return jnp.zeros((E, C, d), x.dtype).at[ids_g, pos_g].set(
            gathered, mode="drop"
        )

    buf = jax.vmap(scatter_group)(xg, sorted_ids, pos_c, src_token)  # (G,E,C,d)
    buf = constrain(buf, "moe_buffer")

    ep = e.expert_axis is not None and _ep_active(e.expert_axis)
    if ep:
        # experts live on the mesh: dispatch/combine cross it through the
        # context-planned all-to-all (comms.api); aux means become global
        # below.  Routing/capacity above is group-local per device, exactly
        # the math of the non-EP block on this device's tokens.
        ye = _ep_expert_ffn(p["experts"], buf, e.expert_axis)  # (G,E,C,d)
        aux = {k: lax.pmean(v, e.expert_axis) for k, v in aux.items()}
    else:
        g_ = jnp.einsum("gecd,edf->gecf", buf, p["experts"]["gate"])
        u_ = jnp.einsum("gecd,edf->gecf", buf, p["experts"]["up"])
        h_ = (jax.nn.silu(g_.astype(jnp.float32)) * u_.astype(jnp.float32)).astype(x.dtype)
        ye = jnp.einsum("gecf,efd->gecd", h_, p["experts"]["down"])  # (G,E,C,d)
    ye = constrain(ye, "moe_buffer")

    pos_clip = jnp.minimum(pos_c, C - 1)

    def gather_group(ye_g, ids_g, pos_g, keep_g, src_g, gates_g):
        rows = ye_g[ids_g, pos_g]  # (TgK, d)
        rows = jnp.where(keep_g[:, None], rows, 0.0)
        contrib = rows * gates_g[:, None].astype(rows.dtype)
        return jnp.zeros((Tg, d), x.dtype).at[src_g].add(contrib)

    gates_sorted = jnp.take_along_axis(
        gate_vals.reshape(G, Tg * K), order, axis=-1
    )
    out = jax.vmap(gather_group)(
        ye, sorted_ids, pos_clip, keep, src_token, gates_sorted
    )  # (G, Tg, d)
    out = out.reshape(T, d)

    if e.shared_expert:
        out = out + ffn_apply(p["shared"], xt)
    if e.dense_residual:
        out = out + ffn_apply(p["dense"], xt)
    return out.reshape(B, S, d), aux
