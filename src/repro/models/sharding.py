"""Sharding rules: parameter/activation/cache PartitionSpecs.

Baseline layout (documented in DESIGN.md §6):
  * TP ('model'): attention QKV/O on heads-dim, FFN on the hidden dim,
    experts on the expert dim (EP), vocab/embed on the vocab dim.
  * DP ('data' [+ 'pod']): batch dim of activations; ZeRO-1 shards optimizer
    state over 'data' (see optim/).
Non-divisible dims (40 heads / 16-way model etc.) rely on GSPMD uneven
sharding; hillclimbed cells override these rules (launch/dryrun.py
--overrides).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig

__all__ = [
    "dp_axes",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "logits_spec",
    "named",
    "set_activation_policy",
    "constrain",
]

TP = "model"

#: module-level activation-sharding policy, installed by the launcher
#: (None => no constraints; models run un-annotated, e.g. CPU smoke tests)
_ACT_POLICY: Optional[Dict] = None


def set_activation_policy(policy: Optional[Dict]) -> None:
    """policy: {"dp": (..axis names..), "tp": "model", "sequence_parallel": bool}"""
    global _ACT_POLICY
    _ACT_POLICY = policy


def constrain(x, kind: str):
    """Annotate an activation tensor.

    kinds: 'hidden' (B,S,d) | 'logits' (B,S,V) | 'tokens_flat' (T,d) |
           'moe_buffer' (E,C,d) — expert-parallel over 'model'."""
    if _ACT_POLICY is None:
        return x
    dp = _ACT_POLICY["dp"]
    tp = _ACT_POLICY.get("tp", TP)
    if kind == "hidden":
        if _ACT_POLICY.get("sequence_parallel"):
            spec = P(dp, tp, None)
        else:
            spec = P(dp, None, None)
    elif kind == "logits":
        spec = P(dp, None, tp)
    elif kind == "tokens_flat":
        spec = P(dp, None)
    elif kind == "moe_buffer":  # (G, E, C, d): groups on dp, experts on tp
        spec = P(dp, tp, None, None)
    else:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _leaf_spec(path: Tuple[str, ...], ndim: int) -> P:
    """Spec for one parameter leaf, path = tuple of dict keys (no layer dim)."""
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    gparent = path[-3] if len(path) >= 3 else ""

    # embeddings / head
    if name == "embed":
        return P(TP, None)
    if parent == "lm_head":
        return P(None, TP)

    # attention projections
    if parent in ("wq", "wk", "wv") and gparent in ("attn", "tmix", "cmix"):
        return P(None, TP) if name == "w" else P(TP)
    if parent == "wo" and name in ("w", "b"):
        return P(TP, None) if name == "w" else P(None)
    if parent in ("wg", "wr") and name == "w":
        return P(None, TP)
    if parent in ("wg", "wr") and name == "b":
        return P(TP)

    # dense FFN (also shared/dense branches of MoE)
    if parent in ("gate", "up") and name == "w":
        return P(None, TP)
    if parent == "down" and name == "w":
        return P(TP, None)
    # moe expert tensors are stacked (E, d, f)/(E, f, d): EP over experts
    if parent == "experts":
        return P(TP, None, None)
    if parent == "router":
        return P(None, None)

    # rwkv specifics
    if name == "u":
        return P(TP, None)
    if name in ("mu", "lora_a", "lora_b", "w0", "w_lora_a", "w_lora_b",
                "mu_k", "mu_r"):
        return P(*([None] * ndim))

    # mamba2
    if parent == "in_proj" and name == "w":
        return P(None, TP)
    if parent == "out_proj" and name == "w":
        return P(TP, None)
    if name == "conv_w":
        return P(None, TP)
    if name == "conv_b":
        return P(TP)
    if name in ("a_log", "d_skip", "dt_bias", "norm_scale"):
        return P(*([None] * ndim))

    # norms and anything residual: replicate
    return P(*([None] * ndim))


def param_specs(cfg: ModelConfig, params) -> Dict:
    """Pytree of PartitionSpec matching ``params``."""

    def spec(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        ndim = leaf.ndim
        if keys and keys[0] == "layers":
            # scanned leaves carry a leading layer dim
            inner = _leaf_spec(("layers",) + keys[1:], ndim - 1)
            return P(None, *inner)
        if keys and keys[0] == "embed":
            return P(TP, None)
        return _leaf_spec(keys, ndim)

    return jax.tree_util.tree_map_with_path(spec, params)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Dict:
    dp = dp_axes(mesh)
    specs: Dict[str, P] = {}
    if cfg.frontend == "audio":
        specs["embeds"] = P(dp, None, None)
    else:
        specs["tokens"] = P(dp, None)
    if cfg.frontend == "vision" and shape.kind != "decode":
        specs["image_embeds"] = P(dp, None, None)
    if shape.kind == "train":
        specs["labels"] = P(dp, None)
    if shape.kind == "decode":
        specs["cache_pos"] = P()
    return specs


def cache_specs(cfg: ModelConfig, mesh: Mesh) -> Dict:
    dp = dp_axes(mesh)
    tp_size = mesh.shape.get(TP, 1)
    # KV heads shard over 'model' when divisible; otherwise shard the
    # sequence dim (flash-decoding-style sharded-KV attention — GSPMD
    # inserts the softmax combine collectives).  cfg.kv_shard overrides.
    if cfg.kv_shard == "heads" or (
        cfg.kv_shard == "auto" and cfg.num_kv_heads % tp_size == 0
    ):
        kv = P(None, dp, TP, None, None)
    else:
        kv = P(None, dp, None, TP, None)
    if cfg.family == "ssm":
        return {
            "rwkv": {
                "tmix_x": P(None, dp, None),
                "cmix_x": P(None, dp, None),
                "wkv": P(None, dp, TP, None, None),
            }
        }
    if cfg.family == "hybrid":
        return {
            "mamba": {
                "conv": P(None, dp, None, TP),
                "ssm": P(None, dp, TP, None, None),
            },
            "shared_k": kv,
            "shared_v": kv,
        }
    return {"k": kv, "v": kv}


def logits_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh), None, TP)


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharded dims whose size is not divisible by the mesh axes
    (pjit requires exact divisibility for explicit in/out shardings).
    GSPMD-internal ops may still shard unevenly; top-level args cannot."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for entry, dim in zip(parts, shape):
        size = _axes_size(mesh, entry)
        out.append(entry if (size > 1 and dim % size == 0) or size == 1 else None)
    return P(*out)


def sanitize_tree(specs, shapes, mesh: Mesh):
    """Sanitize a pytree of PartitionSpec against ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, sds: sanitize_spec(s, sds.shape, mesh),
        specs, shapes, is_leaf=lambda x: isinstance(x, P),
    )


def fsdp_tree(specs, shapes, mesh: Mesh):
    """ZeRO-3/FSDP: additionally shard every param over 'data' on its first
    unsharded divisible dim.  Per-layer all-gathers are emitted by GSPMD
    inside the layer scan — the OpTree-staged gather pattern on the
    multi-pod mesh (pod axis carries only the 1/data shard)."""
    data = mesh.shape.get("data", 1)

    def f(spec, sds):
        parts = list(spec) + [None] * (len(sds.shape) - len(spec))
        used = {a for p in parts if p is not None
                for a in (p if isinstance(p, tuple) else (p,))}
        if "data" in used:
            return P(*parts)
        for i, (p, dim) in enumerate(zip(parts, sds.shape)):
            if p is None and dim % data == 0 and dim >= data:
                parts[i] = "data"
                break
        return P(*parts)

    return jax.tree.map(f, specs, shapes, is_leaf=lambda x: isinstance(x, P))


def named(mesh: Mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
