"""Explicit ZeRO-1 gradient sharding via the context-planned reduce-scatter.

The pjit path (``opt_state_specs``) expresses ZeRO-1 as sharding specs and
lets GSPMD emit the collectives.  This module is the shard_map form used by
explicit-DP training loops: gradients are reduce-scattered over the data
axes through the active :class:`repro.comms.api.CommContext` (OpTree stage
order — slow axes last, carrying only the final 1/N shard), each rank
updates its optimizer shard, and parameters are re-gathered with the
context all-gather.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
from jax import lax

from ..comms import api
from ..compat import axis_size

__all__ = ["zero1_shard_grads", "zero1_unshard_params"]


def _dp_size(fast_axes: Sequence[str]) -> int:
    return math.prod(axis_size(n) for n in fast_axes)


def zero1_shard_grads(
    grads,
    fast_axes: Sequence[str],
    slow_axes: Sequence[str] = (),
    *,
    num_chunks: int = 1,
):
    """Reduce-scatter every gradient leaf over the data axes (ZeRO-1).

    Each DP rank ends with the leading-dim shard it owns for the optimizer
    update; slow (pod/DCN) axes are reduced on the already-scattered shard
    so they never carry the full gradient.  Leaves whose leading dim is not
    divisible by the DP size fall back to a full psum (replicated update) —
    same contract as the spec-based ``opt_state_specs`` path.
    """
    fast_axes = tuple(fast_axes)
    slow_axes = tuple(slow_axes)
    n = _dp_size(fast_axes)

    def shard(g):
        if g.ndim and g.shape[0] % n == 0:
            y = api.reduce_scatter(
                g, axes=fast_axes, num_chunks=api.legacy_chunks(num_chunks))
            return lax.psum(y, slow_axes) if slow_axes else y
        return lax.psum(g, fast_axes + slow_axes)

    return jax.tree.map(shard, grads)


def zero1_unshard_params(
    params,
    fast_axes: Sequence[str],
    *,
    reference=None,
):
    """Staged all-gather of updated parameter shards back to replicated.

    ``reference`` (the matching pre-scatter pytree, e.g. the full params)
    tells which leaves ``zero1_shard_grads`` actually scattered — leaves
    that fell back to a replicated psum are returned unchanged.  Without a
    reference every leaf is gathered (caller guarantees a uniform tree).
    """
    fast_axes = tuple(fast_axes)

    if reference is None:
        return jax.tree.map(
            lambda p: api.all_gather(p, axes=fast_axes), params)

    def gather(p, full):
        if p.ndim and p.shape[0] != full.shape[0]:
            return api.all_gather(p, axes=fast_axes)
        return p

    return jax.tree.map(gather, params, reference)
