"""AdamW with f32 master weights, global-norm clipping, cosine schedule,
and ZeRO-1 state sharding.

ZeRO-1 here is expressed as *sharding specs*, the pjit way: optimizer
moments + master weights get a 'data'-axis sharding on their first
unsharded divisible dim (``opt_state_specs``).  The gradient reduce-scatter
/ parameter all-gather this induces is exactly the OpTree staged pattern —
the explicit shard_map variant lives in ``repro.comms`` and is used by the
examples; under pjit XLA emits the equivalent collectives from the specs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "OptimizerConfig",
    "cosine_lr",
    "adamw_init",
    "adamw_update",
    "opt_state_specs",
]


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # state compression (gradient-compression class tricks for scale):
    # bf16 moments + no separate master copy drop AdamW from 12 to 4
    # bytes/param — the difference between arctic-480b fitting 256 chips
    # or not (EXPERIMENTS.md §Perf). Math still runs in f32.
    state_dtype: str = "float32"
    use_master: bool = True


def cosine_lr(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params, cfg: Optional[OptimizerConfig] = None) -> Dict[str, Any]:
    cfg = cfg or OptimizerConfig()
    sdt = jnp.dtype(cfg.state_dtype)
    zeros = lambda t: jax.tree.map(lambda a: jnp.zeros(a.shape, sdt), t)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": zeros(params),
        "v": zeros(params),
    }
    if cfg.use_master:
        # copy=True: an f32 param's .astype(f32) would alias the param buffer
        # and break donation (same buffer donated twice in the train step)
        state["master"] = jax.tree.map(
            lambda a: jnp.array(a, dtype=jnp.float32, copy=True), params
        )
    return state


def adamw_update(
    grads, opt_state: Dict[str, Any], params, cfg: OptimizerConfig
) -> Tuple[Any, Dict[str, Any]]:
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step)

    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        jax.tree.reduce(
            lambda a, b: a + b, jax.tree.map(lambda g: jnp.sum(g * g), g32)
        )
    )
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    g32 = jax.tree.map(lambda g: g * scale, g32)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    new_m = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g).astype(sdt),
        opt_state["m"], g32)
    new_v = jax.tree.map(
        lambda v, g: (cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g).astype(sdt),
        opt_state["v"], g32)

    def upd(w, m, v):
        mh = m.astype(jnp.float32) / b1c
        vh = v.astype(jnp.float32) / b2c
        w32 = w.astype(jnp.float32)
        return w32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * w32)

    if cfg.use_master:
        new_master = jax.tree.map(upd, opt_state["master"], new_m, new_v)
        new_params = jax.tree.map(
            lambda mp, p: mp.astype(p.dtype), new_master, params
        )
        return new_params, {
            "step": step, "m": new_m, "v": new_v, "master": new_master,
        }
    # master-free: update the (possibly bf16) params directly; f32 math
    new_params = jax.tree.map(
        lambda p, m, v: upd(p, m, v).astype(p.dtype), params, new_m, new_v
    )
    return new_params, {"step": step, "m": new_m, "v": new_v}


def _zero1_spec(spec: P, shape: Tuple[int, ...], data_size: int) -> P:
    """Add a 'data' sharding on the first unsharded dim divisible by the
    data-axis size (ZeRO-1); fall back to the param spec."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for p in parts if p is not None
            for a in (p if isinstance(p, tuple) else (p,))}
    if "data" in used:  # already data-sharded (e.g. FSDP params)
        return P(*parts)
    for i, (p, dim) in enumerate(zip(parts, shape)):
        if p is None and dim % data_size == 0 and dim > 0:
            parts[i] = "data"
            return P(*parts)
    return P(*parts)


def opt_state_specs(param_specs, param_shapes, mesh: Mesh, *,
                    with_master: bool = True):
    """Sharding specs for the optimizer state (ZeRO-1 over 'data')."""
    data_size = mesh.shape.get("data", 1)

    def zspec(spec, sds):
        return _zero1_spec(spec, sds.shape, data_size)

    zero1 = jax.tree.map(
        zspec, param_specs, param_shapes, is_leaf=lambda x: isinstance(x, P)
    )
    out = {"step": P(), "m": zero1, "v": zero1}
    if with_master:
        out["master"] = zero1
    return out
