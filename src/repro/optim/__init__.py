from .adamw import (  # noqa: F401
    OptimizerConfig,
    adamw_init,
    adamw_update,
    cosine_lr,
    opt_state_specs,
)
from .zero1 import (  # noqa: F401
    zero1_shard_grads,
    zero1_unshard_params,
)
