from .adamw import (  # noqa: F401
    OptimizerConfig,
    adamw_init,
    adamw_update,
    cosine_lr,
    opt_state_specs,
)
