"""Pallas-TPU blocked (flash) attention kernel, causal or full, with GQA.

Grid: (B*H, S/bq, T/bk) — the kv dimension is the innermost (sequential)
axis; online-softmax running max/denominator/accumulator live in VMEM
scratch that persists across kv steps.  Causal q-blocks skip kv-blocks
entirely above the diagonal (the pl.when guard), which is where the 2x
flop win over naive masking comes from.

Block sizes default to 128x128 (MXU-aligned); q/k/v tiles + f32 accumulator
for (bq=128, bk=128, hd<=128) stay well under 2 MB of VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, bq: int, bk: int,
):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: kv block j only contributes if its first key position is not
    # strictly below the q block's last query position
    live = (j * bk <= (i + 1) * bq - 1) if causal else (j >= 0)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )

    @pl.when(j == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, Hkv, T, hd)
    v: jax.Array,  # (B, Hkv, T, hd)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)

    bq, bk = min(block_q, S), min(block_k, T)
    pad_q, pad_k = (-S) % bq, (-T) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else v
    Sp, Tp = S + pad_q, T + pad_k
    # padded keys must never win the softmax: causal masking covers q-pads;
    # for key pads rely on causal structure (Tp-pads are masked for all real
    # queries when causal). For non-causal, mask via scores: handled by
    # padding k with +0 but masking in-kernel needs kpos<T — fold into causal
    # path or accept only T % bk == 0 for non-causal:
    if not causal and pad_k:
        raise ValueError("non-causal flash kernel requires T % block_k == 0")

    qf = qp.reshape(B * H, Sp, hd)
    kf = kp.reshape(B * Hkv, Tp, hd)
    vf = vp.reshape(B * Hkv, Tp, hd)

    def q_map(b, i, j):
        return (b, i, 0)

    def kv_map(b, i, j):
        return ((b // H) * Hkv + (b % H) // rep, j, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk
        ),
        grid=(B * H, Sp // bq, Tp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, H, Sp, hd)
    return out[:, :, :S] if pad_q else out
