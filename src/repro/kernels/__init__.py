"""Pallas-TPU kernels for the framework's compute hot spots.

The paper's contribution is a communication schedule (no kernel-level
contribution of its own — see DESIGN.md §3); these kernels cover the model
stack's hot spots: rmsnorm, fused swiglu, blocked flash attention, and the
WKV6 recurrence.  Each has a pure-jnp oracle in ``ref.py`` and is validated
in interpret mode over shape/dtype sweeps in tests/test_kernels.py.
"""
from . import ops, ref  # noqa: F401
from .collective_matmul import allgather_matmul, matmul_reduce_scatter  # noqa: F401
from .flash_attention import flash_attention_pallas  # noqa: F401
from .rmsnorm import rmsnorm_pallas  # noqa: F401
from .rwkv6_scan import rwkv6_scan_pallas  # noqa: F401
from .mamba2_scan import mamba2_ssd_pallas  # noqa: F401
from .swiglu import swiglu_pallas  # noqa: F401
