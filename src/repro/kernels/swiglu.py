"""Pallas-TPU fused SwiGLU kernel: out = silu(gate) * up.

2-D blocked elementwise kernel: (block_rows, block_cols) VMEM tiles, f32
silu, output in the input dtype.  Fusing the two reads + activation into one
pass halves HBM traffic vs. separate silu/mul ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["swiglu_pallas"]


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.lax.logistic(g) * u).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def swiglu_pallas(
    gate: jax.Array,
    up: jax.Array,
    *,
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    assert gate.shape == up.shape, (gate.shape, up.shape)
    orig_shape = gate.shape
    d = gate.shape[-1]
    rows = gate.size // d
    g2, u2 = gate.reshape(rows, d), up.reshape(rows, d)

    bc = min(block_cols, d)
    br = min(block_rows, rows) or 1
    pad_r, pad_c = (-rows) % br, (-d) % bc
    if pad_r or pad_c:
        g2 = jnp.pad(g2, ((0, pad_r), (0, pad_c)))
        u2 = jnp.pad(u2, ((0, pad_r), (0, pad_c)))
    grid = (g2.shape[0] // br, g2.shape[1] // bc)

    out = pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(g2.shape, gate.dtype),
        interpret=interpret,
    )(g2, u2)
    if pad_r or pad_c:
        out = out[:rows, :d]
    return out.reshape(orig_shape)
