"""Pallas-TPU Mamba2 SSD recurrence kernel (zamba2's hot inner loop).

Grid: (B*H, S/chunk) — time is the sequential axis; the (P x N) f32
recurrent state lives in VMEM scratch and persists across chunks (same
structure as the WKV6 kernel: HBM reads each input element exactly once,
the state never leaves VMEM).

Per-(b,h) inputs are (S, P) x-tiles and (S, N) B/C tiles; B/C are shared
across heads, expressed via the BlockSpec index maps (b -> b // H) rather
than materializing the repeat.  P=64, N=64 state tiles align with the
8x128 VPU lanes; the outer grid parallelizes B*H across cores.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

__all__ = ["mamba2_ssd_pallas"]


def _ssd_kernel(x_ref, b_ref, c_ref, dc_ref, dt_ref, s0_ref, y_ref, sT_ref,
                state_scr, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)  # (chunk, P)
    bm = b_ref[0].astype(jnp.float32)  # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)  # (chunk, N)
    dc = dc_ref[0].astype(jnp.float32)  # (chunk,)
    dt = dt_ref[0].astype(jnp.float32)  # (chunk,)

    def step(t, carry):
        h, y = carry
        upd = dt[t] * (x[t][:, None] * bm[t][None, :])  # (P, N)
        h = dc[t] * h + upd
        yt = h @ cm[t]  # (P,)
        y = y.at[t].set(yt)
        return h, y

    y0 = jnp.zeros_like(x)
    h_final, y = jax.lax.fori_loop(0, chunk, step, (state_scr[...], y0))
    state_scr[...] = h_final
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit():
        sT_ref[0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd_pallas(
    x: jax.Array,  # (B, S, H, P)
    Bmat: jax.Array,  # (B, S, N)
    Cmat: jax.Array,  # (B, S, N)
    decay: jax.Array,  # (B, S, H)
    dt: jax.Array,  # (B, S, H)
    state: Optional[jax.Array] = None,  # (B, H, P, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    B, S, H, P = x.shape
    N = Bmat.shape[-1]
    ch = min(chunk, S)
    if S % ch:
        raise ValueError(f"S={S} must be a multiple of chunk={ch}")
    s0 = (state if state is not None
          else jnp.zeros((B, H, P, N), jnp.float32)).astype(jnp.float32)

    # flatten (B, H) into the parallel grid dim; B/C index-map back to b
    xf = jnp.moveaxis(x, 2, 1).reshape(B * H, S, P)
    dcf = jnp.moveaxis(decay, 2, 1).reshape(B * H, S)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(B * H, S)
    s0f = s0.reshape(B * H, P, N)

    t_map = lambda g, c: (g, c, 0)
    bc_map = lambda g, c: (g // H, c, 0)
    v_map = lambda g, c: (g, c)
    s_map = lambda g, c: (g, 0, 0)

    y, sT = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=ch),
        grid=(B * H, S // ch),
        in_specs=[
            pl.BlockSpec((1, ch, P), t_map),
            pl.BlockSpec((1, ch, N), bc_map),
            pl.BlockSpec((1, ch, N), bc_map),
            pl.BlockSpec((1, ch), v_map),
            pl.BlockSpec((1, ch), v_map),
            pl.BlockSpec((1, P, N), s_map),
        ],
        out_specs=[
            pl.BlockSpec((1, ch, P), t_map),
            pl.BlockSpec((1, P, N), s_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((B * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xf, Bmat, Cmat, dcf, dtf, s0f)
    y = y.reshape(B, H, S, P)
    return jnp.moveaxis(y, 1, 2), sT.reshape(B, H, P, N)
