"""Pallas-TPU RMSNorm kernel.

Row-blocked: each grid step normalizes ``block_rows`` rows of the flattened
(rows, d) input entirely in VMEM.  d is padded by the wrapper to a multiple
of 128 (lane width); accumulation in f32.

VMEM budget: block_rows * d * (in + out + f32 temp) — with the default
block_rows=256 and d=8192 that is ~12 MB < 16 MB v5e VMEM; the wrapper
shrinks block_rows for wider models.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_pallas"]


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    scale = scale_ref[...].astype(jnp.float32)
    o_ref[...] = (y * scale[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(
    x: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)

    # shrink the row block until the VMEM working set is comfortable (~12MB)
    while block_rows > 8 and block_rows * d * 12 > 12 * 2**20:
        block_rows //= 2
    pad_rows = (-rows) % block_rows
    if pad_rows:
        x2 = jnp.pad(x2, ((0, pad_rows), (0, 0)))
    grid = (x2.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad_rows:
        out = out[:rows]
    return out.reshape(orig_shape)
