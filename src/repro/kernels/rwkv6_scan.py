"""Pallas-TPU WKV6 recurrence kernel (RWKV6 time-mix inner loop).

Grid: (B*H, S/chunk) — the time dimension is the sequential axis; the
(hd x hd) f32 recurrent state lives in VMEM scratch and persists across
chunks.  Within a chunk the recurrence is a fori_loop over time steps on
VMEM-resident (chunk, hd) tiles: HBM sees each element exactly once.

This is the TPU-native replacement for the CUDA wkv kernel the RWKV project
ships: the hd=64 head fits a (64, 64) state tile; the per-step outer
products k_t v_t^T map to (64x64) VPU/MXU ops.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

__all__ = ["rwkv6_scan_pallas"]


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, state_scr,
                 *, chunk: int):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (chunk, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (hd,)

    def step(t, carry):
        s, y = carry
        rt, kt, vt, wt = r[t], k[t], v[t], w[t]  # (hd,)
        kv = kt[:, None] * vt[None, :]  # (hd, hd)
        yt = jnp.sum(rt[:, None] * (s + u[:, None] * kv), axis=0)  # (hd,)
        y = y.at[t].set(yt)
        s = wt[:, None] * s + kv
        return s, y

    y0 = jnp.zeros_like(r)
    s_final, y = jax.lax.fori_loop(0, chunk, step, (state_scr[...], y0))
    state_scr[...] = s_final
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c == nc - 1)
    def _emit_state():
        sT_ref[0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_pallas(
    r: jax.Array,  # (B, H, S, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,  # (H, hd)
    state: Optional[jax.Array] = None,  # (B, H, hd, hd)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    B, H, S, hd = r.shape
    ch = min(chunk, S)
    if S % ch:
        raise ValueError(f"S={S} must be a multiple of chunk={ch}")
    s0 = state if state is not None else jnp.zeros((B, H, hd, hd), jnp.float32)

    rf, kf, vf, wf = (a.reshape(B * H, S, hd) for a in (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    s0f = s0.reshape(B * H, hd, hd).astype(jnp.float32)

    def t_map(b, c):
        return (b, c, 0)

    def b_map(b, c):
        return (b, 0)

    def s_map(b, c):
        return (b, 0, 0)

    y, sT = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=ch),
        grid=(B * H, S // ch),
        in_specs=[
            pl.BlockSpec((1, ch, hd), t_map),
            pl.BlockSpec((1, ch, hd), t_map),
            pl.BlockSpec((1, ch, hd), t_map),
            pl.BlockSpec((1, ch, hd), t_map),
            pl.BlockSpec((1, hd), b_map),
            pl.BlockSpec((1, hd, hd), s_map),
        ],
        out_specs=[
            pl.BlockSpec((1, ch, hd), t_map),
            pl.BlockSpec((1, hd, hd), s_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), r.dtype),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0f)
    return y.reshape(B, H, S, hd), sT.reshape(B, H, hd, hd)
