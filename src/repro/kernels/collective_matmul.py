"""Collective-matmul fusion: overlap a gather-adjacent matmul with its hops.

The two shapes that dominate explicit-TP transformer blocks:

  * **all-gather → matmul** (sequence-parallel FFN entry / QKV): the
    activations are sequence-sharded; the TP all-gather must finish before
    the projection can start — unless the matmul is decomposed per device
    block.  ``allgather_matmul`` runs the staged gather as double-buffered
    ppermute rings (``comms.ring_executor``) and multiplies each block the
    hop it lands, so the whole gather hides behind the MXU.
  * **matmul → reduce-scatter** (TP combine back to sequence shards):
    ``matmul_reduce_scatter`` slices the matmul per output block
    *just-in-time* — the block feeding ring hop t is multiplied while hop
    t-1's partial accumulator is still on the wire.

Both are value-equivalent to the unfused ``collective ∘ matmul`` composition
(each output block is produced by the same block matmul, so AG-side results
are bit-comparable; the RS ring reduces in ring order, hence allclose).  The
fuse-or-not decision lives in ``core.planner.plan_collective_matmul``.

**Backward pass** (custom_vjp): the two shapes are each other's duals, so
the backward collectives reuse the fused rings instead of falling back to
XLA's transpose:

  * ``allgather_matmul``:  dx = matmul_reduce_scatter(Σ-cat(dout), catᵀ(w))
    — the dgrad's ``@ wᵀ`` feeds the RS ring just-in-time, plus the
    gathered-activation cotangent reduce-scattered; dw = gatheredᵀ @ dout
    is local (residuals carry the gathered activations, so no re-gather).
  * ``matmul_reduce_scatter``: (AG(dy), dh) come from ONE fused
    ``allgather_matmul(dy, wᵀ)`` ring — the gather that dgrad needs also
    delivers the gathered cotangent dw = hᵀ @ AG(dy) contracts against.

Stage orders transpose with the collective (the vjp of a stage order is its
reverse — payload duality), and per-stage ``stage_modes`` follow along
reversed.
"""
from __future__ import annotations

import math
from functools import partial
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from ..comms.ring_executor import (
    _merge_device_axis,
    _resolve_modes,
    _ring_perm,
    _store,
    ring_reduce_scatter_stage,
)
from ..comms.staged_collectives import (
    _ag_finalize,
    _axis_sizes,
    _check_order,
    _permute_blocks_to_order,
)

__all__ = ["allgather_matmul", "matmul_reduce_scatter"]


def _mm(piece: jax.Array, w: jax.Array) -> jax.Array:
    """Contract the trailing feature dim of ``piece`` (any leading/stacked
    dims) with weight ``w`` (d_in, d_out)."""
    return jnp.einsum("...d,df->...f", piece, w)


def _fused_ring_ag_stage(
    cur: jax.Array, outs: List[jax.Array], name: str, ws: Sequence[jax.Array]
) -> Tuple[jax.Array, List[jax.Array]]:
    """One ring all-gather stage that also multiplies every arriving payload.

    ``cur`` is the gathered-so-far data (stacked stage axes leading); ``outs``
    mirror it with the feature dim already projected through each weight.
    Returns the stacked (m, ...) data and outputs — same layout as
    ``lax.all_gather(axis=0, tiled=False)``, so the standard finalize
    transpose applies to both.  The matmul of the block received at hop t
    runs while hop t+1 forwards it: the gather hides behind the MXU.
    """
    m = axis_size(name)
    if m == 1:
        return cur[None], [o[None] for o in outs]
    idx = lax.axis_index(name)
    perm = _ring_perm(m)
    buf = jnp.zeros((m,) + cur.shape, cur.dtype)
    buf = _store(buf, cur, idx)
    obufs = [
        jnp.zeros((m,) + o.shape, o.dtype) for o in outs
    ]
    obufs = [_store(ob, o, idx) for ob, o in zip(obufs, outs)]

    def land(bufs, piece, slot):
        buf, obufs = bufs
        buf = _store(buf, piece, slot)
        obufs = [
            _store(ob, _mm(piece, w), slot) for ob, w in zip(obufs, ws)
        ]
        return buf, obufs

    piece = cur
    for t in range(1, m):
        nxt = lax.ppermute(piece, name, perm)  # forward hop t ...
        if t > 1:
            # ... while the previous delivery is copied AND multiplied
            buf, obufs = land((buf, obufs), piece, (idx - (t - 1)) % m)
        piece = nxt
    buf, obufs = land((buf, obufs), piece, (idx - (m - 1)) % m)
    return buf, obufs


def _oneshot_ag_stage_with_matmul(
    cur: jax.Array, name: str, ws: Sequence[jax.Array]
) -> Tuple[jax.Array, List[jax.Array]]:
    """Blocking-collective fallback for a stage the planner left unfused:
    gather the stacked payloads, then project all of them.  Every block's
    output is still the same block matmul, so values match the fused path."""
    buf = lax.all_gather(cur, name, axis=0, tiled=False)
    return buf, [_mm(buf, w) for w in ws]


def _allgather_matmul_impl(
    x: jax.Array,
    ws: Sequence[jax.Array],
    axis_names: Tuple[str, ...],
    stage_order: Optional[Tuple[str, ...]],
    axis: int,
    stage_modes: Optional[Tuple[str, ...]],
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else axis_names
    )
    modes = _resolve_modes(stage_modes, len(order))
    ws = list(ws)
    if axis < 0:
        axis += x.ndim

    cur = x
    outs = [_mm(x, wi) for wi in ws]  # local block (overlaps the first send)
    for name, mode in zip(order, modes):
        if mode == "ring":
            cur, outs = _fused_ring_ag_stage(cur, outs, name, ws)
        else:
            cur, outs = _oneshot_ag_stage_with_matmul(cur, name, ws)

    gathered = _merge_device_axis(_ag_finalize(cur, axis_names, order), axis)
    outs = tuple(
        _merge_device_axis(_ag_finalize(o, axis_names, order), axis)
        for o in outs
    )
    return gathered, outs


def _rev(seq: Optional[Tuple[str, ...]]) -> Optional[Tuple[str, ...]]:
    return tuple(reversed(seq)) if seq is not None else None


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _ag_matmul_vjp(axis_names, stage_order, axis, stage_modes, x, ws):
    return _allgather_matmul_impl(x, ws, axis_names, stage_order, axis,
                                  stage_modes)


def _ag_matmul_fwd(axis_names, stage_order, axis, stage_modes, x, ws):
    gathered, outs = _allgather_matmul_impl(
        x, ws, axis_names, stage_order, axis, stage_modes)
    # residuals: the gathered activations double as the dw contraction input
    # (no re-gather in the backward pass) + the weights for dgrad
    return (gathered, outs), (gathered, tuple(ws))


def _ag_matmul_bwd(axis_names, stage_order, axis, stage_modes, res, ct):
    gathered, ws = res
    d_gathered, d_outs = ct
    order = stage_order  # resolved (never None) by the public wrapper
    # dgrad reuses the fused ring as its DUAL: the reversed stage order runs
    # matmul→reduce-scatter with the ``@ wᵀ`` block matmuls feeding the ring
    # just-in-time; multiple weights share one ring via feature concat
    douts_cat = (jnp.concatenate(d_outs, axis=-1) if len(d_outs) > 1
                 else d_outs[0])
    w_cat = (jnp.concatenate(list(ws), axis=-1) if len(ws) > 1 else ws[0])
    dx = _matmul_reduce_scatter_impl(
        douts_cat, jnp.swapaxes(w_cat, 0, 1), axis_names,
        _rev(order), axis, _rev(stage_modes))
    # the gathered-activation output's own cotangent: AG's transpose
    dx = dx + lax.psum_scatter(
        d_gathered, axis_names, scatter_dimension=axis, tiled=True)
    dws = tuple(
        jnp.einsum("...d,...f->df", gathered, do) for do in d_outs
    )
    return dx, dws


_ag_matmul_vjp.defvjp(_ag_matmul_fwd, _ag_matmul_bwd)


def _order_from_plan(plan, axis_names, stage_order):
    """Stage order off a :class:`~repro.core.plan_ir.CollectivePlan` —
    the plan's execution-order axes, validated against ``axis_names``."""
    if plan is None:
        return stage_order
    if stage_order is not None:
        raise ValueError("pass either plan= or stage_order=, not both")
    order = tuple(plan.axes)
    if sorted(order) != sorted(axis_names):
        raise ValueError(
            f"plan axes {order} do not permute the collective axes "
            f"{tuple(axis_names)}")
    return order


def allgather_matmul(
    x: jax.Array,
    w: Union[jax.Array, Sequence[jax.Array]],
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    stage_modes: Optional[Sequence[str]] = None,
    plan=None,
):
    """``all_gather(x, axis_names, axis=axis, tiled=True) @ w`` with the
    gather overlapped against the per-block matmuls (inside shard_map).

    ``w`` may be one (d, f) weight or a sequence sharing the gather (e.g.
    SwiGLU gate+up): every arriving block is multiplied by each weight while
    the next hop is in flight, and the gathered *activations* ride along —
    the return is ``(gathered_x, out)`` with ``out`` matching ``w``'s
    structure, since TP callers usually need both.

    ``stage_modes`` (per stage, ``"ring"``/``"oneshot"``) follows the
    planner's hop schedule; one-shot stages still produce identical values.
    ``plan`` (a :class:`~repro.core.plan_ir.CollectivePlan`, e.g. from
    ``CommContext.plan("ag", ...)``) supplies the stage order instead.

    Differentiable via custom_vjp: dgrad runs as the fused
    ``matmul_reduce_scatter`` dual (reversed stage order), dw contracts the
    saved gathered activations locally — the backward collectives ride the
    same overlapped rings as the forward.
    """
    if axis < 0:
        axis += x.ndim
    single = not isinstance(w, (list, tuple))
    ws = (w,) if single else tuple(w)
    # resolve the default stage order HERE so the forward impl and the
    # backward's dual derive from one concrete order
    axis_names = tuple(axis_names)
    stage_order = _order_from_plan(plan, axis_names, stage_order)
    order = tuple(stage_order) if stage_order is not None else axis_names
    gathered, outs = _ag_matmul_vjp(
        axis_names,
        order,
        axis,
        tuple(stage_modes) if stage_modes is not None else None,
        x, ws,
    )
    return gathered, (outs[0] if single else tuple(outs))


def _matmul_reduce_scatter_impl(
    h: jax.Array,
    w: jax.Array,
    axis_names: Tuple[str, ...],
    stage_order: Optional[Tuple[str, ...]],
    axis: int,
    stage_modes: Optional[Tuple[str, ...]],
) -> jax.Array:
    axis_names = tuple(axis_names)
    order = (
        _check_order(stage_order, axis_names)
        if stage_order is not None
        else tuple(reversed(axis_names))
    )
    modes = _resolve_modes(stage_modes, len(order))
    sizes = _axis_sizes(axis_names)
    n_total = math.prod(sizes.values())
    if axis < 0:
        axis += h.ndim

    h0 = jnp.moveaxis(h, axis, 0) if axis != 0 else h
    if h0.shape[0] % n_total:
        raise ValueError(
            f"scatter axis length {h0.shape[0]} not divisible by {n_total}"
        )
    # the scatter permutes whole rows, and the matmul is row-wise — so the
    # canonical→stage-order block permutation commutes with it and can be
    # applied to the *input* (no full-size output ever materializes)
    if order != axis_names:
        h0 = _permute_blocks_to_order(h0, axis_names, order, sizes)

    name0 = order[0]
    m = sizes[name0]
    if m == 1 or modes[0] != "ring":
        y = _mm(h0, w)
        y = lax.psum_scatter(y, name0, scatter_dimension=0, tiled=True)
    else:
        blk = h0.shape[0] // m

        def part(b):
            hs = lax.dynamic_slice_in_dim(h0, b * blk, blk, axis=0)
            return _mm(hs, w)  # just-in-time block matmul

        y = ring_reduce_scatter_stage(h0, name0, block_fn=part)

    for name, mode in zip(order[1:], modes[1:]):
        if mode == "ring":
            y = ring_reduce_scatter_stage(y, name)
        else:
            y = lax.psum_scatter(y, name, scatter_dimension=0, tiled=True)
    return jnp.moveaxis(y, 0, axis) if axis != 0 else y


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _mm_rs_vjp(axis_names, stage_order, axis, stage_modes, h, w):
    return _matmul_reduce_scatter_impl(h, w, axis_names, stage_order, axis,
                                       stage_modes)


def _mm_rs_fwd(axis_names, stage_order, axis, stage_modes, h, w):
    y = _matmul_reduce_scatter_impl(h, w, axis_names, stage_order, axis,
                                    stage_modes)
    return y, (h, w)


def _mm_rs_bwd(axis_names, stage_order, axis, stage_modes, res, dy):
    h, w = res
    order = stage_order  # resolved (never None) by the public wrapper
    # ONE fused allgather_matmul ring (the RS dual, reversed stage order)
    # yields both the gathered cotangent AND dgrad: g_dy = AG(dy) feeds dw,
    # dh = AG(dy) @ wᵀ is multiplied per block the hop it lands
    g_dy, (dh,) = _allgather_matmul_impl(
        dy, (jnp.swapaxes(w, 0, 1),), axis_names,
        _rev(order), axis, _rev(stage_modes))
    dw = jnp.einsum("...k,...f->kf", h, g_dy)
    return dh, dw


_mm_rs_vjp.defvjp(_mm_rs_fwd, _mm_rs_bwd)


def matmul_reduce_scatter(
    h: jax.Array,
    w: jax.Array,
    axis_names: Sequence[str],
    *,
    stage_order: Optional[Sequence[str]] = None,
    axis: int = 0,
    stage_modes: Optional[Sequence[str]] = None,
    plan=None,
) -> jax.Array:
    """``psum_scatter(h @ w, axis_names, scatter_dimension=axis, tiled=True)``
    with the matmul decomposed per scattered block (inside shard_map).

    The first reduce-scatter stage runs as a ring whose local partial for
    each departing block is computed *just-in-time*: the slice of ``h``
    feeding hop t is multiplied while hop t-1's accumulator is in flight, so
    the combine's communication hides behind the block matmuls.  Remaining
    stages (smaller payloads, no compute left to hide behind) follow the
    planner's ``stage_modes``.  Values are allclose to the unfused
    composition (ring reduction order).

    Differentiable via custom_vjp: the backward pass is one fused
    ``allgather_matmul`` ring (the RS dual) producing dgrad and the
    gathered cotangent for wgrad together.
    """
    if axis < 0:
        axis += h.ndim
    # resolve the default stage order HERE so the forward impl and the
    # backward's dual derive from one concrete order
    axis_names = tuple(axis_names)
    stage_order = _order_from_plan(plan, axis_names, stage_order)
    order = (tuple(stage_order) if stage_order is not None
             else tuple(reversed(axis_names)))
    return _mm_rs_vjp(
        axis_names,
        order,
        axis,
        tuple(stage_modes) if stage_modes is not None else None,
        h, w,
    )
