"""Jit'd dispatch wrappers for the Pallas kernels.

Backend selection:
  * ``ref``     — pure-jnp oracles (default on CPU; fully differentiable)
  * ``pallas``  — pl.pallas_call kernels (TPU target; ``interpret=True``
                  executes the kernel body on CPU for validation)

Kernel forwards are wrapped in ``jax.custom_vjp`` with the ref backward, so
the pallas backend remains trainable without hand-written backward kernels
(the recompute matches the remat policy anyway).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .rmsnorm import rmsnorm_pallas
from .rwkv6_scan import rwkv6_scan_pallas
from .swiglu import swiglu_pallas

__all__ = [
    "set_backend",
    "backend_scope",
    "get_backend",
    "rmsnorm",
    "swiglu",
    "flash_attention",
    "rwkv6_scan",
]

_BACKEND = "ref"
_INTERPRET = True  # no real TPU in this container; kernels run interpreted
#: key-length threshold above which the ref backend switches to the chunked
#: online-softmax attention (never materializes the S x T logits)
FLASH_CHUNK_THRESHOLD = 4096
FLASH_CHUNK = 1024


def set_backend(name: str, *, interpret: Optional[bool] = None) -> None:
    global _BACKEND, _INTERPRET
    if name not in ("ref", "pallas"):
        raise ValueError(name)
    _BACKEND = name
    if interpret is not None:
        _INTERPRET = interpret


def get_backend() -> str:
    return _BACKEND


@contextlib.contextmanager
def backend_scope(name: str):
    prev = _BACKEND
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def _ref_vjp(pallas_fn, ref_fn):
    """Kernel forward + oracle backward."""

    @jax.custom_vjp
    def f(*args):
        return pallas_fn(*args)

    def fwd(*args):
        return pallas_fn(*args), args

    def bwd(args, g):
        _, vjp = jax.vjp(lambda *a: ref_fn(*a), *args)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


# --------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    if _BACKEND == "ref":
        return ref.rmsnorm(x, scale, eps)
    fn = _ref_vjp(
        lambda a, s: rmsnorm_pallas(a, s, eps=eps, interpret=_INTERPRET),
        lambda a, s: ref.rmsnorm(a, s, eps),
    )
    return fn(x, scale)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    if _BACKEND == "ref":
        return ref.swiglu(gate, up)
    fn = _ref_vjp(
        lambda g, u: swiglu_pallas(g, u, interpret=_INTERPRET),
        ref.swiglu,
    )
    return fn(gate, up)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    if _BACKEND == "ref" or kv_mask is not None:
        # the kernel path does not implement arbitrary kv masks (decode uses
        # the ref path / sharded-KV combine instead)
        if k.shape[2] > FLASH_CHUNK_THRESHOLD and q.shape[2] > 1:
            # chunked online softmax for long prefill/train; single-query
            # decode keeps the direct masked path (scan overhead loses there)
            return ref.flash_attention_chunked(
                q, k, v, causal=causal, scale=scale, kv_mask=kv_mask,
                chunk=FLASH_CHUNK,
            )
        return ref.flash_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
    fn = _ref_vjp(
        lambda a, b, c: flash_attention_pallas(
            a, b, c, causal=causal, scale=scale, interpret=_INTERPRET
        ),
        lambda a, b, c: ref.flash_attention(a, b, c, causal=causal, scale=scale),
    )
    return fn(q, k, v)


def rwkv6_scan(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    if _BACKEND == "ref":
        return ref.rwkv6_scan(r, k, v, w, u, state)
    B, H, S, hd = r.shape
    chunk = S if S <= 128 else 128
    if S % chunk:
        return ref.rwkv6_scan(r, k, v, w, u, state)
    s0 = state if state is not None else jnp.zeros((B, H, hd, hd), jnp.float32)
    fn = _ref_vjp(
        lambda *a: rwkv6_scan_pallas(*a, chunk=chunk, interpret=_INTERPRET),
        lambda *a: ref.rwkv6_scan(*a),
    )
    return fn(r, k, v, w, u, s0)
