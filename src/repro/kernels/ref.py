"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are also the default backend on CPU and the VJP bodies for the
custom-vjp kernel wrappers (kernel forward, ref backward).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm", "swiglu", "flash_attention", "flash_attention_chunked",
           "rwkv6_scan", "mamba2_ssd_scan"]


def mamba2_ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    Bmat: jax.Array,  # (B, S, N)
    Cmat: jax.Array,  # (B, S, N)
    decay: jax.Array,  # (B, S, H) = exp(dt * A)
    dt: jax.Array,  # (B, S, H)
    state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Mamba2 SSD recurrence (the inner loop of models.mamba2):

        h_t = decay_t * h_{t-1} + dt_t * (x_t B_t^T)
        y_t = h_t C_t

    Returns (y: (B,S,H,P) f32, final_state: (B,H,P,N) f32).
    """
    B, S, H, P = x.shape
    N = Bmat.shape[-1]
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if state is None
          else state.astype(jnp.float32))

    def step(h, inp):
        xt, Bt, Ct, dct, dtt = inp
        upd = dtt[..., None, None] * (
            xt.astype(jnp.float32)[..., :, None]
            * Bt.astype(jnp.float32)[:, None, None, :]
        )
        h = dct[..., None, None] * h + upd
        yt = jnp.einsum("bhpn,bn->bhp", h, Ct.astype(jnp.float32))
        return h, yt

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(Bmat, 1, 0),
          jnp.moveaxis(Cmat, 1, 0), jnp.moveaxis(decay, 1, 0),
          jnp.moveaxis(dt, 1, 0))
    h_final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms).astype(x.dtype) * scale


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    g32 = gate.astype(jnp.float32)
    return (jax.nn.silu(g32) * up.astype(jnp.float32)).astype(gate.dtype)


def flash_attention(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, Hkv, T, hd)
    v: jax.Array,  # (B, Hkv, T, hd)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_mask: Optional[jax.Array] = None,  # (B, T) valid-key mask
) -> jax.Array:
    B, H, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    rep = H // Hkv
    kx = jnp.repeat(k, rep, axis=1)
    vx = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, kx).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(S)[:, None] + (T - S)  # allow cached prefix
        kpos = jnp.arange(T)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    if kv_mask is not None:
        logits = jnp.where(kv_mask[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs.astype(v.dtype), vx)
    return out


def flash_attention_chunked(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, Hkv, T, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    kv_mask: Optional[jax.Array] = None,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention scanned over key chunks: O(S*chunk) live
    memory instead of the O(S*T) logits tensor.  Pure jnp — this is what the
    Pallas kernel computes, in a form every backend can lower (the dry-run
    and non-TPU training path); numerically identical to `flash_attention`.
    """
    B, H, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    if T % chunk or T <= chunk:
        return flash_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    rep = H // Hkv
    n = T // chunk
    cq = chunk if (S % chunk == 0 and S > chunk) else S  # query chunk
    nq = S // cq

    kc = jnp.moveaxis(k.reshape(B, Hkv, n, chunk, hd), 2, 0)  # (n,B,Hkv,c,hd)
    vc = jnp.moveaxis(v.reshape(B, Hkv, n, chunk, hd), 2, 0)
    mc = (jnp.moveaxis(kv_mask.reshape(B, n, chunk), 1, 0)
          if kv_mask is not None else jnp.zeros((n, 0)))
    qc = jnp.moveaxis(q.reshape(B, H, nq, cq, hd), 2, 0)  # (nq,B,H,cq,hd)

    def q_block(inp):
        qi, i = inp  # (B,H,cq,hd), scalar q-chunk index
        q32 = qi.astype(jnp.float32)
        qpos = i * cq + jnp.arange(cq)[:, None] + (T - S)

        @jax.checkpoint
        def body(carry, kvm):
            m, l, acc, j = carry
            kj, vj, mj = kvm
            kj = jnp.repeat(kj.astype(jnp.float32), rep, axis=1)  # (B,H,c,hd)
            vj = jnp.repeat(vj.astype(jnp.float32), rep, axis=1)
            s = jnp.einsum("bhsd,bhtd->bhst", q32, kj) * scale
            kpos = j * chunk + jnp.arange(chunk)[None, :]
            if causal:
                s = jnp.where((kpos <= qpos)[None, None], s, -jnp.inf)
            if kv_mask is not None:
                s = jnp.where(mj[:, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhst,bhtd->bhsd", p, vj)
            return (m_new, l, acc, j + 1), 0

        m0 = jnp.full((B, H, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        acc0 = jnp.zeros((B, H, cq, hd), jnp.float32)
        (m, l, acc, _), _ = jax.lax.scan(
            body, (m0, l0, acc0, jnp.zeros((), jnp.int32)), (kc, vc, mc)
        )
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l[..., None]).astype(q.dtype)

    out = jax.lax.map(q_block, (qc, jnp.arange(nq)))  # (nq,B,H,cq,hd)
    return jnp.moveaxis(out, 0, 2).reshape(B, H, S, hd)


def rwkv6_scan(
    r: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, H, S, hd)
    v: jax.Array,  # (B, H, S, hd)
    w: jax.Array,  # (B, H, S, hd) decay in (0,1), data-dependent
    u: jax.Array,  # (H, hd) bonus for the current token
    state: Optional[jax.Array] = None,  # (B, H, hd, hd)
) -> Tuple[jax.Array, jax.Array]:
    """WKV6 linear-attention recurrence (Finch, arXiv:2404.05892).

        y_t = r_t @ (S_t + diag(u) k_t v_t^T)
        S_{t+1} = diag(w_t) S_t + k_t v_t^T

    Returns (y: (B,H,S,hd), final_state: (B,H,hd,hd)); math in f32.
    """
    B, H, S, hd = r.shape
    r32, k32, v32, w32 = (a.astype(jnp.float32) for a in (r, k, v, w))
    u32 = u.astype(jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32) if state is None else state.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd_k,hd_v)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u32[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (r32, k32, v32, w32))
    s_final, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 2)  # (B,H,S,hd)
    return y.astype(r.dtype), s_final
