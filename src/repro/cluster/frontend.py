"""Multi-replica serving front end: N ``BatchedServer``s behind a policy.

:class:`ClusterServer` is the measured twin of ``cluster.sim.ClusterSim``:
the same :class:`~repro.cluster.scheduler.Policy` objects route real
requests onto real ``BatchedServer`` replicas, every request keeps its
measured phase timestamps (``runtime.server.RequestTiming``), and
:meth:`ClusterServer.drain_report` assembles them into the same
:class:`~repro.cluster.sim.ClusterStats` shape the simulator emits — so
simulated and measured latency distributions compare field-for-field.

Replicas step round-robin on the host (one process, serialized compute),
which preserves the *ordering* of policies — a policy that balances load
better drains sooner and shows a lower measured p99 — even though
absolute times differ from parallel hardware.  That ordering match is
the validation criterion (``docs/serving.md``).

:func:`measure_replica_times` calibrates a replica's per-prompt-token
prefill and per-step decode seconds from a real warm run, feeding
``ReplicaSpec.from_times`` so the simulator predicts with the measured
constants.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cost_model import OpticalSystem, transfer_time
from ..runtime.server import BatchedServer, ServerConfig
from .scheduler import Policy, ReplicaView
from .sim import BYTES_PER_TOKEN, ClusterStats, ReplicaSpec, RequestRecord
from .traces import Request

__all__ = ["ClusterServer", "measure_replica_times"]


def measure_replica_times(cfg, params, scfg: ServerConfig, *,
                          prompt_tokens: int = 8,
                          warmup: int = 1) -> Tuple[float, float]:
    """Measure (prefill seconds per prompt token, decode seconds per
    engine step) on a throwaway server — warm runs only, so jit compiles
    don't pollute the constants."""
    srv = BatchedServer(cfg, params, scfg)
    prompt = np.arange(prompt_tokens, dtype=np.int32) % cfg.vocab_size
    for _ in range(warmup + 1):
        srv.submit(prompt)
        srv.run_until_drained()
    rec = srv.records[max(srv.records)]
    prefill_token_s = (rec.prefill_done_s - rec.prefill_start_s) / prompt_tokens
    if rec.decode_start_s is not None and rec.generated > 1:
        decode_step_s = ((rec.finish_s - rec.decode_start_s)
                         / (rec.generated - 1))
    else:
        decode_step_s = prefill_token_s * prompt_tokens
    return prefill_token_s, decode_step_s


class ClusterServer:
    """Route requests across ``BatchedServer`` replicas via a policy.

    ``servers[i]`` is described by ``specs[i]`` (calibrated via
    :func:`measure_replica_times` + ``ReplicaSpec.from_times`` when the
    simulator should predict this cluster).  All servers must share this
    front end's ``clock`` so cross-replica timestamps are comparable.
    """

    def __init__(self, servers: Sequence[BatchedServer],
                 specs: Sequence[ReplicaSpec], policy: Policy, *,
                 world: str = "electrical",
                 optical: Optional[OpticalSystem] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if len(servers) != len(specs):
            raise ValueError("need one ReplicaSpec per server")
        if world not in ("electrical", "optical"):
            raise ValueError(f"world must be electrical|optical, got {world!r}")
        self.servers = list(servers)
        self.specs = list(specs)
        self.policy = policy
        self.world = world
        self.optical = optical
        self.clock = clock
        self._t0 = clock()
        self._route: Dict[int, Tuple[int, int]] = {}  # gid -> (replica, local rid)
        self._requests: Dict[int, Request] = {}       # gid -> routed Request
        self._next_gid = 0
        self.routed = {s.name: 0 for s in self.specs}
        self.busy_s = {s.name: 0.0 for s in self.specs}

    # -- pricing (same two cost worlds as the simulator) -------------------
    def _tx_time_s(self, spec: ReplicaSpec, nbytes: float) -> float:
        if self.world == "optical":
            from ..core.cost_model import TERARACK
            model = self.optical if self.optical is not None else TERARACK
        else:
            model = spec.link
        return transfer_time(model, nbytes)

    # -- routing snapshot --------------------------------------------------
    def _views(self) -> List[ReplicaView]:
        out = []
        for i, (srv, spec) in enumerate(zip(self.servers, self.specs)):
            backlog = 0.0
            for _, prompt in srv.queue:
                backlog += spec.request_service_s(Request(
                    rid=-1, arrival_s=0.0, prompt_tokens=len(prompt),
                    new_tokens=srv.scfg.max_new_tokens))
            active = srv.active_count()
            if active:
                remaining = max(
                    srv.scfg.max_new_tokens - len(s.generated)
                    for s in srv.slots if s.request_id is not None)
                backlog += max(0, remaining) * spec.decode_step_time_s(active)
            out.append(ReplicaView(
                index=i, spec=spec, queue_len=len(srv.queue), active=active,
                backlog_s=backlog, link_free_in_s=0.0,
                tx_time_s=lambda nb, s=spec: self._tx_time_s(s, nb)))
        return out

    # -- API ---------------------------------------------------------------
    def submit(self, prompt: np.ndarray) -> int:
        return self.submit_batch([prompt])[0]

    def submit_batch(self, prompts: Sequence[np.ndarray]) -> List[int]:
        """Route a batch of simultaneous arrivals jointly (the max-flow
        policy's placement window) and enqueue each on its replica."""
        now = self.clock() - self._t0
        batch = []
        for p in prompts:
            gid = self._next_gid
            self._next_gid += 1
            batch.append(Request(rid=gid, arrival_s=now,
                                 prompt_tokens=len(p), new_tokens=0))
        picks = self.policy.route_batch(batch, self._views(), now)
        gids = []
        for req, p, ridx in zip(batch, prompts, picks):
            srv, spec = self.servers[ridx], self.specs[ridx]
            local = srv.submit(p)
            self._route[req.rid] = (ridx, local)
            self._requests[req.rid] = Request(
                rid=req.rid, arrival_s=req.arrival_s,
                prompt_tokens=req.prompt_tokens,
                new_tokens=srv.scfg.max_new_tokens)
            self.routed[spec.name] += 1
            gids.append(req.rid)
        return gids

    def reset(self) -> None:
        """Reset the whole front end for a fresh trace on the same warm
        replicas: each ``BatchedServer`` drains and clears via its public
        :meth:`~repro.runtime.server.BatchedServer.reset` (compiled jits
        kept), routing state and per-replica counters rebuild, and the
        epoch moves to now — the standard way to re-run a trace under a
        different policy without re-paying compilation."""
        for srv in self.servers:
            srv.reset()
        self._t0 = self.clock()
        self._route.clear()
        self._requests.clear()
        self._next_gid = 0
        self.routed = {s.name: 0 for s in self.specs}
        self.busy_s = {s.name: 0.0 for s in self.specs}

    def pending_work(self) -> bool:
        return any(s.pending_work() for s in self.servers)

    def engine_step(self):
        """One stepping round: every replica with pending work runs one
        engine step (host-serialized; see module docstring)."""
        for srv, spec in zip(self.servers, self.specs):
            if srv.pending_work():
                t0 = self.clock()
                srv.engine_step()
                self.busy_s[spec.name] += self.clock() - t0

    def run_trace(self, trace: Sequence["Request"], *,
                  prompts: Optional[Sequence[np.ndarray]] = None,
                  max_steps: int = 100_000) -> ClusterStats:
        """Replay a trace against the live cluster, pacing arrivals on the
        wall clock: submit each request when its ``arrival_s`` elapses
        (same-instant arrivals submit as one routed batch, matching the
        simulator's placement window), stepping the replicas in between.
        Returns the measured :meth:`drain_report`."""
        if prompts is None:
            prompts = [np.arange(r.prompt_tokens, dtype=np.int32)
                       for r in trace]
        t0 = self.clock()
        self._t0 = t0
        i, n, steps = 0, len(trace), 0
        while i < n or self.pending_work():
            now = self.clock() - t0
            if i < n and trace[i].arrival_s <= now:
                j = i + 1
                while j < n and trace[j].arrival_s == trace[i].arrival_s \
                        and trace[j].arrival_s <= now:
                    j += 1
                self.submit_batch(list(prompts[i:j]))
                i = j
                continue
            if self.pending_work():
                self.engine_step()
                steps += 1
                if steps > max_steps:
                    raise RuntimeError("cluster did not drain")
            # idle-wait for the next arrival (spin; traces are short)
        return self.drain_report()

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while self.pending_work():
            self.engine_step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("cluster did not drain")
        return self.results()

    def results(self) -> Dict[int, List[int]]:
        out = {}
        for gid, (ridx, local) in self._route.items():
            if local in self.servers[ridx].results:
                out[gid] = self.servers[ridx].results[local]
        return out

    def drain_report(self) -> ClusterStats:
        """Measured :class:`ClusterStats` — same shape as the simulator's,
        timestamps rebased to this front end's epoch."""
        records = []
        for gid in sorted(self._route):
            ridx, local = self._route[gid]
            srv, spec = self.servers[ridx], self.specs[ridx]
            t = srv.records[local]
            req = self._requests[gid]

            def reb(x):
                return None if x is None else x - self._t0

            records.append(RequestRecord(
                rid=gid, replica=spec.name,
                prompt_tokens=t.prompt_tokens, new_tokens=t.generated,
                arrival_s=req.arrival_s, enqueue_s=reb(t.enqueue_s),
                prefill_start_s=reb(t.prefill_start_s),
                prefill_done_s=reb(t.prefill_done_s),
                decode_start_s=reb(t.decode_start_s),
                finish_s=reb(t.finish_s)))
        done = [r for r in records if r.finish_s is not None]
        makespan = (max(r.finish_s for r in done)
                    - min(r.arrival_s for r in done)) if done else 0.0
        return ClusterStats(
            records=records, makespan_s=makespan,
            busy_s=dict(self.busy_s), tx_busy_s={},
            routed=dict(self.routed))
