"""Routing policies for the cluster serving layer (ISSUE 9).

Every policy sees the same :class:`ReplicaView` snapshot per decision —
queue depth, active slots, the replica's committed backlog in seconds,
link availability, and a transmission pricer closed over the run's cost
world (electrical ``LinkSpec`` or optical Eq. 3) — and returns replica
indices.  Four families, in increasing use of the cost model:

* :class:`RoundRobin` — arrival-order striping; the cost-blind baseline
  every benchmark compares against;
* :class:`JoinShortestQueue` — classic JSQ on in-flight request count;
* :class:`GreedyCost` — picks the replica minimizing the request's
  estimated finish time (link wait + tx + backlog + solo service), i.e.
  the same α–β / Eq.-3 + roofline arithmetic the collective planner uses;
* :class:`MaxFlowPolicy` — Helix-style joint placement for simultaneous
  arrival batches: a max-flow round over a request→replica bipartite
  graph capacitated by free slots routes as many requests as possible to
  non-overfull replicas at once, then a greedy-cost pass places the
  overflow.

Policies are pure given their inputs (ties broken by replica index), so
a seeded trace routes identically run-to-run — the determinism contract
of ``cluster.sim``.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from .sim import BYTES_PER_TOKEN, ReplicaSpec
from .traces import Request

__all__ = ["ReplicaView", "Policy", "RoundRobin", "JoinShortestQueue",
           "GreedyCost", "MaxFlowPolicy", "POLICIES", "make_policy"]


@dataclass(frozen=True)
class ReplicaView:
    """Point-in-time snapshot of one replica, as a policy sees it."""

    index: int
    spec: ReplicaSpec
    queue_len: int          # requests queued, not yet in a slot
    active: int             # occupied decode slots
    backlog_s: float        # committed seconds of work ahead of a new arrival
    link_free_in_s: float   # seconds until the ingress link is free
    tx_time_s: Callable[[float], float]  # nbytes -> seconds, cost-world priced

    @property
    def in_flight(self) -> int:
        return self.queue_len + self.active

    @property
    def free_slots(self) -> int:
        return max(0, self.spec.batch_size - self.active)

    def est_finish_s(self, req: Request) -> float:
        """Estimated completion delay for routing ``req`` here now: wait
        for the link, transmit the prompt, wait out the backlog, then the
        request's solo service time."""
        tx = self.tx_time_s(req.prompt_tokens * BYTES_PER_TOKEN)
        return (self.link_free_in_s + tx + self.backlog_s
                + self.spec.request_service_s(req))


class Policy:
    """Base: implement :meth:`route`; :meth:`route_batch` defaults to
    independent per-request routing against the same snapshot."""

    name = "policy"

    def route(self, req: Request, views: Sequence[ReplicaView],
              now: float) -> int:
        raise NotImplementedError

    def route_batch(self, batch: Sequence[Request],
                    views: Sequence[ReplicaView], now: float) -> List[int]:
        return [self.route(r, views, now) for r in batch]


class RoundRobin(Policy):
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def route(self, req: Request, views: Sequence[ReplicaView],
              now: float) -> int:
        pick = self._next % len(views)
        self._next += 1
        return pick


class JoinShortestQueue(Policy):
    name = "jsq"

    def route(self, req: Request, views: Sequence[ReplicaView],
              now: float) -> int:
        return min(views, key=lambda v: (v.in_flight, v.index)).index

    def route_batch(self, batch: Sequence[Request],
                    views: Sequence[ReplicaView], now: float) -> List[int]:
        # account for our own picks within the batch, else a burst of k
        # simultaneous arrivals all join the momentarily-shortest queue
        load = {v.index: v.in_flight for v in views}
        out = []
        for _ in batch:
            pick = min(views, key=lambda v: (load[v.index], v.index)).index
            load[pick] += 1
            out.append(pick)
        return out


class GreedyCost(Policy):
    name = "greedy"

    def route(self, req: Request, views: Sequence[ReplicaView],
              now: float) -> int:
        return min(views, key=lambda v: (v.est_finish_s(req), v.index)).index

    def route_batch(self, batch: Sequence[Request],
                    views: Sequence[ReplicaView], now: float) -> List[int]:
        # fold each pick's service into a running backlog estimate so a
        # simultaneous burst spreads by cost instead of piling onto the
        # single momentarily-cheapest replica
        extra = collections.defaultdict(float)
        out = []
        for req in batch:
            pick = min(views, key=lambda v: (
                v.est_finish_s(req) + extra[v.index], v.index))
            extra[pick.index] += pick.spec.request_service_s(req)
            out.append(pick.index)
        return out


def _max_flow(capacity: Dict[int, Dict[int, int]], src: int,
              sink: int) -> Dict[int, Dict[int, int]]:
    """Edmonds–Karp on an integer-capacity adjacency dict; returns the
    flow assignment.  Graphs here are tiny (requests + replicas + 2
    nodes), so BFS augmentation is plenty."""
    flow: Dict[int, Dict[int, int]] = collections.defaultdict(
        lambda: collections.defaultdict(int))

    while True:
        # BFS for an augmenting path in the residual graph
        parent = {src: None}
        frontier = collections.deque([src])
        while frontier and sink not in parent:
            u = frontier.popleft()
            nbrs = set(capacity.get(u, {})) | {w for w in flow if flow[w][u] > 0}
            for v in sorted(nbrs):
                if v in parent:
                    continue
                if capacity.get(u, {}).get(v, 0) - flow[u][v] > 0 \
                        or flow[v][u] > 0:
                    parent[v] = u
                    frontier.append(v)
        if sink not in parent:
            return flow
        # bottleneck along the path
        path, v = [], sink
        while parent[v] is not None:
            u = parent[v]
            path.append((u, v))
            v = u
        bott = min(
            (capacity.get(u, {}).get(v, 0) - flow[u][v]) + flow[v][u]
            for u, v in path)
        for u, v in path:
            fwd = capacity.get(u, {}).get(v, 0) - flow[u][v]
            use = min(bott, fwd)
            flow[u][v] += use
            if bott > use:           # rest cancels reverse flow
                flow[v][u] -= bott - use


class MaxFlowPolicy(Policy):
    """Joint placement for simultaneous arrivals via max flow.

    Build source→request (cap 1) →replica (cap 1 per edge, cheapest-first
    edge order) →sink (cap = free slots); the max-flow round admits as
    many requests as slot capacity allows without overfilling any
    replica, and a greedy-cost pass places whatever the flow could not
    (batch larger than total free slots).  Singleton arrivals reduce to
    greedy-cost — the flow formulation only bites on bursts.
    """

    name = "max-flow"

    def __init__(self):
        self._greedy = GreedyCost()

    def route(self, req: Request, views: Sequence[ReplicaView],
              now: float) -> int:
        return self._greedy.route(req, views, now)

    def route_batch(self, batch: Sequence[Request],
                    views: Sequence[ReplicaView], now: float) -> List[int]:
        if len(batch) <= 1:
            return self._greedy.route_batch(batch, views, now)
        R, V = len(batch), len(views)
        SRC, SINK = R + V, R + V + 1
        cap: Dict[int, Dict[int, int]] = {SRC: {}, SINK: {}}
        for i in range(R):
            cap[SRC][i] = 1
            cap[i] = {R + v.index: 1 for v in views}
        for v in views:
            cap[R + v.index] = {SINK: v.free_slots}
        flow = _max_flow(cap, SRC, SINK)
        picks: List[Optional[int]] = [None] * R
        for i in range(R):
            for v in views:
                if flow[i][R + v.index] > 0:
                    picks[i] = v.index
                    break
        # flow says WHERE capacity exists, not which pairing is cheapest:
        # reassign admitted requests to their flow-selected replica set
        # cheapest-first, then greedy-place the unadmitted overflow
        admitted = [i for i in range(R) if picks[i] is not None]
        slots = collections.Counter(picks[i] for i in admitted)
        by_view = {v.index: v for v in views}
        for i in admitted:
            req = batch[i]
            best = min((r for r in slots if slots[r] > 0),
                       key=lambda r: (by_view[r].est_finish_s(req), r))
            picks[i] = best
            slots[best] -= 1
        extra = collections.defaultdict(float)
        for i in range(R):
            if picks[i] is None:
                req = batch[i]
                pick = min(views, key=lambda v: (
                    v.est_finish_s(req) + extra[v.index], v.index))
                extra[pick.index] += pick.spec.request_service_s(req)
                picks[i] = pick.index
        return [int(p) for p in picks]


POLICIES = {
    "round-robin": RoundRobin,
    "jsq": JoinShortestQueue,
    "greedy": GreedyCost,
    "max-flow": MaxFlowPolicy,
}


def make_policy(name: str) -> Policy:
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; choose from {sorted(POLICIES)}")
