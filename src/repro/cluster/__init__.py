"""Cluster-scale serving: seeded traces, an event-driven simulator, and a
multi-replica front end sharing one routing-policy and cost-model stack.

* :mod:`repro.cluster.traces` — seeded Poisson/bursty/replay arrival traces;
* :mod:`repro.cluster.sim` — event-driven simulator pricing transmission
  with the electrical/optical cost backends and compute with the roofline
  phase model;
* :mod:`repro.cluster.scheduler` — round-robin / JSQ / greedy-cost /
  max-flow routing policies over :class:`ReplicaView` snapshots;
* :mod:`repro.cluster.frontend` — :class:`ClusterServer` wrapping N real
  ``BatchedServer`` replicas behind the same policies, emitting the same
  :class:`ClusterStats` for simulated-vs-measured validation.
"""
from .frontend import ClusterServer, measure_replica_times
from .scheduler import (GreedyCost, JoinShortestQueue, MaxFlowPolicy,
                        POLICIES, Policy, ReplicaView, RoundRobin,
                        make_policy)
from .sim import (BYTES_PER_TOKEN, ClusterSim, ClusterStats, ReplicaSpec,
                  RequestRecord)
from .traces import (Request, bursty_trace, make_trace, poisson_trace,
                     replay_trace, save_trace, trace_to_json)

__all__ = [
    "Request", "poisson_trace", "bursty_trace", "replay_trace",
    "trace_to_json", "save_trace", "make_trace",
    "ReplicaSpec", "RequestRecord", "ClusterStats", "ClusterSim",
    "BYTES_PER_TOKEN",
    "ReplicaView", "Policy", "RoundRobin", "JoinShortestQueue",
    "GreedyCost", "MaxFlowPolicy", "POLICIES", "make_policy",
    "ClusterServer", "measure_replica_times",
]
