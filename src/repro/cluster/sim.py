"""Event-driven cluster serving simulator (ISSUE 9).

Models N model replicas behind a routing policy under an arrival trace,
in the ragx ``Interconnect``/stats idiom: one event heap, per-link FIFO
transmission, per-replica continuous-batching slot pools, and a
:class:`ClusterStats` record mirroring the measured drain report of
``runtime.server.BatchedServer`` field-for-field.

The physics come from the models the stack already has:

* **transmission** — every client→replica prompt transfer is priced by
  ``core.cost_model.transfer_time`` under either the electrical
  ``LinkSpec`` world (``α + d/B``) or the paper's optical Eq.-3 world
  (``d/B + a`` per step), with per-link FIFO contention;
* **compute** — per-request prefill and per-engine-step decode times come
  from the roofline phase queries (``launch.roofline.prefill_time_s`` /
  ``decode_step_time_s``) baked into each :class:`ReplicaSpec`.

Replica engine semantics mirror ``BatchedServer`` exactly: prefill is
per-request and blocking (refill-first), each decode step emits one token
for every active slot, the prefill itself emits token 1, and a finished
slot refills from the queue before the next decode step.  That mirroring
is what lets the simulator's latency distribution be validated against
the measured one on host meshes (``repro.cluster.frontend``).

Determinism: the heap orders events ``(time, seq)`` with ``seq`` a
monotone push counter, and every service/transmission time is a pure
function of the trace and specs — the same seeded trace replays a
bit-identical ``event_log``.
"""
from __future__ import annotations

import heapq
import json
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cost_model import OpticalSystem, transfer_time
from ..core.planner import ICI_LINK, LinkSpec
from .traces import Request

__all__ = ["ReplicaSpec", "RequestRecord", "ClusterStats", "ClusterSim",
           "BYTES_PER_TOKEN"]

BYTES_PER_TOKEN = 4  # int32 token ids on the wire


# --------------------------------------------------------------------------
# replica model
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ReplicaSpec:
    """One model replica's serving physics: slot pool width, the roofline
    terms behind its phase times, and its ingress link.

    ``prefill_time_s``/``decode_step_time_s`` are the two-term roofline
    max (compute against ``peak_flops``, one weight-streaming pass against
    ``hbm_bw``) — :meth:`from_config` fills the terms from a
    ``ModelConfig`` via ``launch.roofline``; :meth:`from_times` pins them
    to measured per-token/per-step seconds (the calibration path the
    front end uses for simulated-vs-measured validation).
    """

    name: str
    batch_size: int
    flops_per_token: float      # 2 · N_active
    weight_bytes: float         # streamed once per engine step / prefill
    peak_flops: float = 197e12  # launch.roofline.PEAK_FLOPS (v5e bf16)
    hbm_bw: float = 819e9       # launch.roofline.HBM_BW
    chips: int = 1
    link: LinkSpec = ICI_LINK

    @staticmethod
    def from_config(name: str, cfg, batch_size: int, *,
                    link: LinkSpec = ICI_LINK, chips: int = 1,
                    peak_flops: Optional[float] = None,
                    hbm_bw: Optional[float] = None) -> "ReplicaSpec":
        from ..configs import active_param_count
        from ..launch.roofline import HBM_BW, PEAK_FLOPS, _weight_bytes

        return ReplicaSpec(
            name=name, batch_size=batch_size,
            flops_per_token=2.0 * active_param_count(cfg),
            weight_bytes=_weight_bytes(cfg),
            peak_flops=peak_flops if peak_flops else PEAK_FLOPS,
            hbm_bw=hbm_bw if hbm_bw else HBM_BW,
            chips=chips, link=link)

    @staticmethod
    def from_times(name: str, batch_size: int, *, prefill_token_s: float,
                   decode_step_s: float,
                   link: LinkSpec = ICI_LINK) -> "ReplicaSpec":
        """Pin the phase times to measured seconds: prefill is linear at
        ``prefill_token_s`` per prompt token (with the decode step as its
        floor), one decode step costs ``decode_step_s`` regardless of the
        active count (the memory-bound regime — exactly what a host-mesh
        calibration observes)."""
        return ReplicaSpec(
            name=name, batch_size=batch_size,
            flops_per_token=prefill_token_s, weight_bytes=decode_step_s,
            peak_flops=1.0, hbm_bw=1.0, chips=1, link=link)

    def prefill_time_s(self, prompt_tokens: int) -> float:
        return max(self.flops_per_token * prompt_tokens / self.chips
                   / self.peak_flops,
                   self.weight_bytes / self.chips / self.hbm_bw)

    def decode_step_time_s(self, active: int = 1) -> float:
        return max(self.flops_per_token * active / self.chips
                   / self.peak_flops,
                   self.weight_bytes / self.chips / self.hbm_bw)

    def request_service_s(self, req: Request) -> float:
        """Single-request service estimate: prefill + its solo decode
        chain (the prefill emits token 1, so ``new_tokens - 1`` steps)."""
        return (self.prefill_time_s(req.prompt_tokens)
                + max(0, req.new_tokens - 1) * self.decode_step_time_s(1))


# --------------------------------------------------------------------------
# records + stats (shared with the measured front end)
# --------------------------------------------------------------------------

@dataclass
class RequestRecord:
    """One request's phase timestamps — the simulator's twin of
    ``runtime.server.RequestTiming`` plus the routing fields."""

    rid: int
    replica: str
    prompt_tokens: int
    new_tokens: int
    arrival_s: float
    enqueue_s: Optional[float] = None   # transmission done, queued at replica
    prefill_start_s: Optional[float] = None
    prefill_done_s: Optional[float] = None
    decode_start_s: Optional[float] = None
    finish_s: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.finish_s is None else self.finish_s - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        if self.prefill_done_s is None:
            return None
        return self.prefill_done_s - self.arrival_s

    @property
    def queue_s(self) -> Optional[float]:
        if self.prefill_start_s is None or self.enqueue_s is None:
            return None
        return self.prefill_start_s - self.enqueue_s

    def to_json(self) -> dict:
        return {
            "rid": self.rid, "replica": self.replica,
            "prompt_tokens": self.prompt_tokens,
            "new_tokens": self.new_tokens,
            "arrival_s": self.arrival_s, "enqueue_s": self.enqueue_s,
            "prefill_start_s": self.prefill_start_s,
            "prefill_done_s": self.prefill_done_s,
            "decode_start_s": self.decode_start_s,
            "finish_s": self.finish_s,
        }


def _percentile(vals: Sequence[float], p: float) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), p)) if vals else 0.0


@dataclass
class ClusterStats:
    """Latency / throughput / utilization breakdowns over one run.

    Built by the simulator AND by the measured front end from the same
    :class:`RequestRecord` rows, so simulated and measured distributions
    compare field-for-field (the validation methodology in
    ``docs/serving.md``)."""

    records: List[RequestRecord] = field(default_factory=list)
    makespan_s: float = 0.0
    busy_s: Dict[str, float] = field(default_factory=dict)      # per replica
    tx_busy_s: Dict[str, float] = field(default_factory=dict)   # per link
    routed: Dict[str, int] = field(default_factory=dict)        # per replica

    @property
    def latencies_s(self) -> List[float]:
        return [r.latency_s for r in self.records if r.finish_s is not None]

    def latency_p50_s(self) -> float:
        return _percentile(self.latencies_s, 50)

    def latency_p99_s(self) -> float:
        return _percentile(self.latencies_s, 99)

    def ttft_p50_s(self) -> float:
        vals = [r.ttft_s for r in self.records if r.ttft_s is not None]
        return _percentile(vals, 50)

    def total_tokens(self) -> int:
        return sum(r.new_tokens for r in self.records
                   if r.finish_s is not None)

    def throughput_tok_s(self) -> float:
        return self.total_tokens() / self.makespan_s if self.makespan_s else 0.0

    def utilization(self) -> Dict[str, float]:
        if not self.makespan_s:
            return {k: 0.0 for k in self.busy_s}
        return {k: v / self.makespan_s for k, v in self.busy_s.items()}

    def to_json(self) -> dict:
        return {
            "requests": len(self.records),
            "tokens": self.total_tokens(),
            "makespan_s": self.makespan_s,
            "throughput_tok_s": self.throughput_tok_s(),
            "latency_p50_s": self.latency_p50_s(),
            "latency_p99_s": self.latency_p99_s(),
            "ttft_p50_s": self.ttft_p50_s(),
            "utilization": self.utilization(),
            "tx_busy_s": dict(self.tx_busy_s),
            "routed": dict(self.routed),
            "per_request": [r.to_json() for r in
                            sorted(self.records, key=lambda r: r.rid)],
        }

    def summary(self) -> str:
        util = " ".join(f"{k}={v:.2f}" for k, v in
                        sorted(self.utilization().items()))
        routed = " ".join(f"{k}={v}" for k, v in sorted(self.routed.items()))
        return (f"req={len(self.records)} tok={self.total_tokens()} "
                f"makespan={self.makespan_s * 1e3:.2f}ms "
                f"p50={self.latency_p50_s() * 1e3:.2f}ms "
                f"p99={self.latency_p99_s() * 1e3:.2f}ms "
                f"tput={self.throughput_tok_s():.0f}tok/s "
                f"util[{util}] routed[{routed}]")


# --------------------------------------------------------------------------
# the simulator
# --------------------------------------------------------------------------

class _Replica:
    """Mutable per-replica simulation state (one BatchedServer analogue)."""

    def __init__(self, index: int, spec: ReplicaSpec):
        self.index = index
        self.spec = spec
        self.queue: List[int] = []          # rids awaiting a slot
        self.active: Dict[int, int] = {}    # rid -> decode steps remaining
        self.busy = False
        self.busy_until = 0.0
        self.busy_s = 0.0
        self.link_free_at = 0.0
        self.tx_busy_s = 0.0

    def backlog_s(self, now: float, reqs: Dict[int, Request]) -> float:
        """Estimated seconds of committed work ahead of a new arrival:
        the in-flight engine phase, every queued request's solo service,
        and the active slots' remaining decode steps."""
        t = max(0.0, self.busy_until - now) if self.busy else 0.0
        for rid in self.queue:
            t += self.spec.request_service_s(reqs[rid])
        if self.active:
            t += max(self.active.values()) * self.spec.decode_step_time_s(
                len(self.active))
        return t


class ClusterSim:
    """Event-driven simulation of N replicas behind one routing policy.

    ``world`` picks the transmission pricing backend: ``"electrical"``
    prices each client→replica hop with the replica's ``LinkSpec``,
    ``"optical"`` with Eq. 3 on ``optical`` (default TERARACK) — the same
    two cost worlds every collective in the stack plans against.
    """

    def __init__(
        self,
        replicas: Sequence[ReplicaSpec],
        policy,
        *,
        world: str = "electrical",
        optical: Optional[OpticalSystem] = None,
        bytes_per_token: int = BYTES_PER_TOKEN,
    ):
        if world not in ("electrical", "optical"):
            raise ValueError(f"world must be electrical|optical, got {world!r}")
        if not replicas:
            raise ValueError("need at least one ReplicaSpec")
        self.specs = list(replicas)
        self.policy = policy
        self.world = world
        self.optical = optical
        self.bytes_per_token = bytes_per_token
        self.event_log: List[tuple] = []

    # -- pricing -----------------------------------------------------------
    def _tx_model(self, spec: ReplicaSpec):
        if self.world == "optical":
            from ..core.cost_model import TERARACK
            return self.optical if self.optical is not None else TERARACK
        return spec.link

    def tx_time_s(self, spec: ReplicaSpec, nbytes: float) -> float:
        return transfer_time(self._tx_model(spec), nbytes)

    # -- run ---------------------------------------------------------------
    def run(self, trace: Sequence[Request]) -> ClusterStats:
        from .scheduler import ReplicaView  # lazy: scheduler imports us

        reqs = {r.rid: r for r in trace}
        recs: Dict[int, RequestRecord] = {}
        reps = [_Replica(i, s) for i, s in enumerate(self.specs)]
        routed = {s.name: 0 for s in self.specs}
        heap: List[tuple] = []
        seq = 0
        self.event_log = []

        def push(t: float, kind: str, *payload):
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, payload))
            seq += 1

        def views(now: float) -> List["ReplicaView"]:
            out = []
            for r in reps:
                spec = r.spec
                out.append(ReplicaView(
                    index=r.index, spec=spec,
                    queue_len=len(r.queue), active=len(r.active),
                    backlog_s=r.backlog_s(now, reqs),
                    link_free_in_s=max(0.0, r.link_free_at - now),
                    tx_time_s=lambda nb, s=spec: self.tx_time_s(s, nb),
                ))
            return out

        def route(batch: List[Request], now: float):
            picks = self.policy.route_batch(batch, views(now), now)
            for req, ridx in zip(batch, picks):
                r = reps[ridx]
                routed[r.spec.name] += 1
                recs[req.rid] = RequestRecord(
                    rid=req.rid, replica=r.spec.name,
                    prompt_tokens=req.prompt_tokens,
                    new_tokens=req.new_tokens, arrival_s=req.arrival_s)
                nbytes = req.prompt_tokens * self.bytes_per_token
                start = max(now, r.link_free_at)
                tx = self.tx_time_s(r.spec, nbytes)
                r.link_free_at = start + tx
                r.tx_busy_s += tx
                push(start + tx, "enqueue", req.rid, r.index)
                self.event_log.append((now, "route", req.rid, r.index))

        def kick(r: _Replica, now: float):
            """Start the replica's next engine phase if it is idle —
            refill-first (prefill) then one decode step, exactly the
            BatchedServer.engine_step order."""
            if r.busy:
                return
            if r.queue and len(r.active) < r.spec.batch_size:
                rid = r.queue.pop(0)
                rec = recs[rid]
                rec.prefill_start_s = now
                dt = r.spec.prefill_time_s(rec.prompt_tokens)
                r.busy, r.busy_until = True, now + dt
                r.busy_s += dt
                push(now + dt, "prefill_done", rid, r.index)
                self.event_log.append((now, "prefill_start", rid, r.index))
                return
            if r.active:
                dt = r.spec.decode_step_time_s(len(r.active))
                r.busy, r.busy_until = True, now + dt
                r.busy_s += dt
                push(now + dt, "step_done", r.index)
                self.event_log.append((now, "decode_step", r.index,
                                       len(r.active)))

        # arrivals sharing one instant route as one batch (the max-flow
        # policy's placement window; singleton batches for everyone else)
        i, n = 0, len(trace)
        while i < n:
            j = i + 1
            while j < n and trace[j].arrival_s == trace[i].arrival_s:
                j += 1
            push(trace[i].arrival_s, "arrivals", tuple(trace[i:j]))
            i = j

        finished = 0
        end = 0.0
        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == "arrivals":
                route(list(payload[0]), now)
            elif kind == "enqueue":
                rid, ridx = payload
                r = reps[ridx]
                recs[rid].enqueue_s = now
                r.queue.append(rid)
                self.event_log.append((now, "enqueue", rid, ridx))
                kick(r, now)
            elif kind == "prefill_done":
                rid, ridx = payload
                r = reps[ridx]
                r.busy = False
                rec = recs[rid]
                rec.prefill_done_s = now
                remaining = reqs[rid].new_tokens - 1  # prefill emits token 1
                if remaining <= 0:
                    rec.finish_s = now
                    finished += 1
                    end = max(end, now)
                    self.event_log.append((now, "finish", rid, ridx))
                else:
                    r.active[rid] = remaining
                self.event_log.append((now, "prefill_done", rid, ridx))
                kick(r, now)
            elif kind == "step_done":
                (ridx,) = payload
                r = reps[ridx]
                r.busy = False
                done_rids = []
                for rid in list(r.active):
                    rec = recs[rid]
                    if rec.decode_start_s is None:
                        rec.decode_start_s = r.busy_until - \
                            r.spec.decode_step_time_s(len(r.active))
                    r.active[rid] -= 1
                    if r.active[rid] <= 0:
                        done_rids.append(rid)
                for rid in done_rids:
                    del r.active[rid]
                    recs[rid].finish_s = now
                    finished += 1
                    end = max(end, now)
                    self.event_log.append((now, "finish", rid, ridx))
                self.event_log.append((now, "step_done", ridx, len(done_rids)))
                kick(r, now)
            else:  # pragma: no cover — no other kinds are pushed
                raise AssertionError(f"unknown event {kind}")

        if finished != len(trace):  # pragma: no cover — invariant
            raise RuntimeError(
                f"simulation drained {finished}/{len(trace)} requests")
        return ClusterStats(
            records=[recs[r.rid] for r in trace],
            makespan_s=end,
            busy_s={r.spec.name: r.busy_s for r in reps},
            tx_busy_s={r.spec.name: r.tx_busy_s for r in reps},
            routed=routed,
        )
