"""Seeded request-arrival traces for the cluster simulator (ISSUE 9).

A trace is a list of :class:`Request` rows sorted by arrival time, rids
assigned in arrival order.  Three generator families:

* :func:`poisson_trace` — memoryless arrivals (exponential gaps at
  ``rate_rps``), the open-loop baseline every queueing result assumes;
* :func:`bursty_trace` — Poisson *burst epochs*, each delivering a whole
  batch of back-to-back requests — the flash-crowd shape that separates
  backlog-aware routing policies from round-robin;
* :func:`replay_trace` — replay a recorded trace (JSON rows), so measured
  production arrivals drive the same simulator.

Everything is driven by one ``numpy`` Generator seeded explicitly: the
same seed produces the bit-identical request sequence (arrival floats
included), which is what makes simulated runs replayable and the
determinism tests meaningful.  Poisson gaps are sampled as
``exp(1) / rate``, so the SAME seed at a different rate yields exactly
time-scaled arrivals — the makespan-monotonicity property tests rely on
this coupling.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = ["Request", "poisson_trace", "bursty_trace", "replay_trace",
           "trace_to_json", "save_trace", "make_trace"]


@dataclass(frozen=True)
class Request:
    """One serving request: arrival time plus its two phase extents."""

    rid: int
    arrival_s: float
    prompt_tokens: int
    new_tokens: int

    def to_json(self) -> dict:
        return {"rid": self.rid, "arrival_s": self.arrival_s,
                "prompt_tokens": self.prompt_tokens,
                "new_tokens": self.new_tokens}


def _lengths(rng: np.random.Generator, n: int,
             bounds: Tuple[int, int]) -> np.ndarray:
    lo, hi = bounds
    if lo > hi:
        raise ValueError(f"bad length bounds {bounds}: lo > hi")
    return rng.integers(lo, hi + 1, size=n)


def _finish(arrivals, prompts, news) -> List[Request]:
    order = np.argsort(arrivals, kind="stable")
    return [
        Request(rid=i, arrival_s=float(arrivals[j]),
                prompt_tokens=int(prompts[j]), new_tokens=int(news[j]))
        for i, j in enumerate(order)
    ]


def poisson_trace(
    n: int,
    *,
    rate_rps: float,
    seed: int,
    prompt_tokens: Tuple[int, int] = (8, 64),
    new_tokens: Tuple[int, int] = (4, 16),
) -> List[Request]:
    """``n`` Poisson arrivals at ``rate_rps`` requests/second.

    Gaps are ``standard exponential / rate``, so the same seed at two
    rates gives exactly time-scaled arrival sequences (same request
    shapes) — higher rate compresses the identical workload.
    """
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng(seed)
    gaps = rng.standard_exponential(n) / rate_rps
    arrivals = np.cumsum(gaps)
    return _finish(arrivals, _lengths(rng, n, prompt_tokens),
                   _lengths(rng, n, new_tokens))


def bursty_trace(
    n: int,
    *,
    rate_rps: float,
    burst: int = 4,
    seed: int = 0,
    prompt_tokens: Tuple[int, int] = (8, 64),
    new_tokens: Tuple[int, int] = (4, 16),
) -> List[Request]:
    """``n`` requests arriving in bursts of ``burst`` at Poisson epochs.

    The aggregate rate stays ``rate_rps`` (burst epochs fire at
    ``rate_rps / burst``); every request in a burst shares the epoch's
    arrival instant, which is exactly the simultaneous-arrival window the
    max-flow placement policy solves jointly.
    """
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    rng = np.random.default_rng(seed)
    n_epochs = (n + burst - 1) // burst
    gaps = rng.standard_exponential(n_epochs) / (rate_rps / burst)
    epochs = np.cumsum(gaps)
    arrivals = np.repeat(epochs, burst)[:n]
    return _finish(arrivals, _lengths(rng, n, prompt_tokens),
                   _lengths(rng, n, new_tokens))


def replay_trace(rows: Union[str, Path, Sequence[dict]]) -> List[Request]:
    """Rebuild a trace from recorded rows (a JSON file path or the parsed
    list) — ``arrival_s``/``prompt_tokens``/``new_tokens`` per row; rids
    are reassigned in arrival order so replays are self-consistent."""
    if isinstance(rows, (str, Path)):
        rows = json.loads(Path(rows).read_text())
    if isinstance(rows, dict):
        rows = rows["requests"]
    arrivals = np.asarray([float(r["arrival_s"]) for r in rows])
    prompts = np.asarray([int(r["prompt_tokens"]) for r in rows])
    news = np.asarray([int(r["new_tokens"]) for r in rows])
    return _finish(arrivals, prompts, news)


def trace_to_json(trace: Sequence[Request]) -> dict:
    return {"requests": [r.to_json() for r in trace]}


def save_trace(trace: Sequence[Request], path: Union[str, Path]) -> None:
    Path(path).write_text(json.dumps(trace_to_json(trace), indent=1))


def make_trace(spec: str, *, n: int, seed: int,
               prompt_tokens: Tuple[int, int] = (8, 64),
               new_tokens: Tuple[int, int] = (4, 16)) -> List[Request]:
    """Parse a CLI trace spec into a trace.

    ``"poisson:RATE"`` / ``"bursty:RATE[,BURST]"`` build the seeded
    generators; anything else is a path to a recorded JSON trace
    (:func:`replay_trace` — ``n``/``seed`` are ignored for replays).
    """
    kw = dict(prompt_tokens=prompt_tokens, new_tokens=new_tokens)
    if spec.startswith("poisson:"):
        return poisson_trace(n, rate_rps=float(spec.split(":", 1)[1]),
                             seed=seed, **kw)
    if spec.startswith("bursty:"):
        parts = spec.split(":", 1)[1].split(",")
        burst = int(parts[1]) if len(parts) > 1 else 4
        return bursty_trace(n, rate_rps=float(parts[0]), burst=burst,
                            seed=seed, **kw)
    return replay_trace(spec)
