from .trainer import Trainer, TrainerConfig, make_train_step  # noqa: F401
from .server import BatchedServer, ServerConfig  # noqa: F401
