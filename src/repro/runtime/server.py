"""Batched serving runtime: continuous batching over a fixed slot pool.

Requests (prompt token arrays) queue up; the server keeps ``batch_size``
decode slots. Each engine step decodes one token for every active slot;
finished slots (EOS or max_new_tokens) are immediately refilled from the
queue — the standard continuous-batching pattern (vLLM-style, cache-slot
granularity) built on ``models.decode_step``.

Prefill is per-request against the slot's cache region (cache layouts are
batched, so prefill runs with batch=1 padding-free and writes into the
slot's lane via index update).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import decode_step, forward, init_decode_state

__all__ = ["ServerConfig", "BatchedServer"]


@dataclass(frozen=True)
class ServerConfig:
    batch_size: int = 4
    max_seq: int = 128
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: disabled (synthetic vocab has no real EOS)


@dataclass
class _Slot:
    request_id: Optional[int] = None
    pos: int = 0
    generated: List[int] = field(default_factory=list)


class BatchedServer:
    def __init__(self, cfg: ModelConfig, params, scfg: ServerConfig):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self.state = init_decode_state(cfg, scfg.batch_size, scfg.max_seq)
        self.slots = [_Slot() for _ in range(scfg.batch_size)]
        self.queue: collections.deque = collections.deque()
        self.results: Dict[int, List[int]] = {}
        self._next_id = 0
        self._tokens = np.zeros((scfg.batch_size, 1), np.int32)

        self._decode = jax.jit(
            lambda p, s, t, pos: decode_step(cfg, p, s, t, pos)
        )

    # ---- API -------------------------------------------------------------
    def submit(self, prompt: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, np.asarray(prompt, np.int32)))
        return rid

    def _prefill_into_slot(self, slot_idx: int, rid: int, prompt: np.ndarray):
        """Run the prompt through the model writing KV/state for this slot."""
        S = len(prompt)
        # batch the prompt across the full slot dim (only slot_idx's lanes
        # are kept — simple and correct; per-slot cache views are a perf
        # optimization on real hardware)
        toks = np.zeros((self.scfg.batch_size, S), np.int32)
        toks[slot_idx] = prompt
        logits, new_state, _ = jax.jit(
            lambda p, b, c: forward(self.cfg, p, b, cache=c,
                                    cache_pos=jnp.zeros((), jnp.int32))
        )(self.params, {"tokens": jnp.asarray(toks)}, self.state)
        self.state = self._merge_slot(self.state, new_state, slot_idx)
        nxt = int(jnp.argmax(logits[slot_idx, -1]))
        slot = self.slots[slot_idx]
        slot.request_id = rid
        slot.pos = S
        slot.generated = [nxt]
        self._tokens[slot_idx, 0] = nxt

    def _merge_slot(self, old, new, slot_idx: int):
        """Keep `new` only on the batch lane of this slot."""

        def pick(o, n):
            # batch dim differs per cache family; all our caches have the
            # batch dim right after the layer dim
            if o.ndim < 2 or o.shape != n.shape:
                return n
            sel = jnp.zeros((o.shape[1],), bool).at[slot_idx].set(True)
            shape = [1, o.shape[1]] + [1] * (o.ndim - 2)
            return jnp.where(sel.reshape(shape), n, o)

        return jax.tree.map(pick, old, new)

    def _refill(self):
        for i, slot in enumerate(self.slots):
            if slot.request_id is None and self.queue:
                rid, prompt = self.queue.popleft()
                self._prefill_into_slot(i, rid, prompt)

    def engine_step(self):
        self._refill()
        active = [i for i, s in enumerate(self.slots) if s.request_id is not None]
        if not active:
            return
        # all active slots decode at their own position; the cache mask uses
        # per-slot positions — we step them at the max position and rely on
        # each slot's own `pos` for emission bookkeeping (positions differ:
        # run per-distinct-position micro-batches)
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(self.slots[i].pos, []).append(i)
        for pos, idxs in sorted(by_pos.items()):
            logits, self.state = self._decode(
                self.params, self.state, jnp.asarray(self._tokens),
                jnp.asarray(pos, jnp.int32),
            )
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for i in idxs:
                slot = self.slots[i]
                tok = int(nxt[i])
                slot.generated.append(tok)
                slot.pos += 1
                self._tokens[i, 0] = tok
                done = (
                    len(slot.generated) >= self.scfg.max_new_tokens
                    or tok == self.scfg.eos_id
                    or slot.pos >= self.scfg.max_seq - 1
                )
                if done:
                    self.results[slot.request_id] = slot.generated
                    self.slots[i] = _Slot()

    def run_until_drained(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or any(s.request_id is not None for s in self.slots)):
            self.engine_step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("server did not drain")
        return self.results
