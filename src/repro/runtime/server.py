"""Batched serving runtime: continuous batching over a fixed slot pool.

Requests (prompt token arrays) queue up; the server keeps ``batch_size``
decode slots. Each engine step decodes one token for every active slot;
finished slots (EOS or max_new_tokens) are immediately refilled from the
queue — the standard continuous-batching pattern (vLLM-style, cache-slot
granularity) built on ``models.decode_step``.

Prefill is per-request against the slot's cache region (cache layouts are
batched, so prefill runs with batch=1 padding-free and writes into the
slot's lane via index update).

Every request carries a :class:`RequestTiming` record (enqueue /
prefill-start / prefill-done / decode-start / finish, on the server's
``clock``), exposed per request in :meth:`BatchedServer.drain_report` —
the measured counterpart of the cluster simulator's event timestamps
(``repro.cluster.sim``), so simulated and measured latency distributions
compare field-for-field.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import decode_step, forward, init_decode_state

__all__ = ["ServerConfig", "BatchedServer", "RequestTiming"]


@dataclass(frozen=True)
class ServerConfig:
    batch_size: int = 4
    max_seq: int = 128
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: disabled (synthetic vocab has no real EOS)


@dataclass
class RequestTiming:
    """Per-request phase timestamps on the server's clock (seconds).

    ``decode_start_s`` stays None for single-token requests (the prefill
    emits token 1, so a ``max_new_tokens=1`` request never decodes)."""

    rid: int
    prompt_tokens: int
    enqueue_s: float
    prefill_start_s: Optional[float] = None
    prefill_done_s: Optional[float] = None
    decode_start_s: Optional[float] = None
    finish_s: Optional[float] = None
    generated: int = 0

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.finish_s is None else self.finish_s - self.enqueue_s

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (the prefill's argmax is token 1)."""
        if self.prefill_done_s is None:
            return None
        return self.prefill_done_s - self.enqueue_s

    @property
    def queue_s(self) -> Optional[float]:
        if self.prefill_start_s is None:
            return None
        return self.prefill_start_s - self.enqueue_s

    def to_json(self) -> Dict[str, Any]:
        return {
            "rid": self.rid, "prompt_tokens": self.prompt_tokens,
            "enqueue_s": self.enqueue_s,
            "prefill_start_s": self.prefill_start_s,
            "prefill_done_s": self.prefill_done_s,
            "decode_start_s": self.decode_start_s,
            "finish_s": self.finish_s, "generated": self.generated,
        }


@dataclass
class _Slot:
    request_id: Optional[int] = None
    pos: int = 0
    generated: List[int] = field(default_factory=list)


def _percentile(vals: List[float], p: float) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), p)) if vals else 0.0


class BatchedServer:
    def __init__(self, cfg: ModelConfig, params, scfg: ServerConfig,
                 *, clock: Callable[[], float] = time.perf_counter):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self.clock = clock
        self.state = init_decode_state(cfg, scfg.batch_size, scfg.max_seq)
        self.slots = [_Slot() for _ in range(scfg.batch_size)]
        self.queue: collections.deque = collections.deque()
        self.results: Dict[int, List[int]] = {}
        self.records: Dict[int, RequestTiming] = {}
        self._next_id = 0
        self._tokens = np.zeros((scfg.batch_size, 1), np.int32)

        self._decode = jax.jit(
            lambda p, s, t, pos: decode_step(cfg, p, s, t, pos)
        )
        # one cached jit for prefill too — a fresh lambda per request would
        # recompile every prefill (retraces only per distinct prompt length)
        self._prefill = jax.jit(
            lambda p, b, c: forward(cfg, p, b, cache=c,
                                    cache_pos=jnp.zeros((), jnp.int32))
        )

    # ---- API -------------------------------------------------------------
    def submit(self, prompt: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        prompt = np.asarray(prompt, np.int32)
        self.queue.append((rid, prompt))
        self.records[rid] = RequestTiming(
            rid=rid, prompt_tokens=len(prompt), enqueue_s=self.clock())
        return rid

    def reset(self) -> None:
        """Return the server to its just-constructed state: drain any
        in-flight work (finishing it cleanly rather than abandoning slots
        mid-decode), then clear the queue, results, timing records and the
        request-id counter, and zero the decode state.  The compiled
        decode/prefill jits are KEPT — a reset server re-serves warm,
        which is the point of resetting instead of rebuilding (e.g. the
        cluster front end re-running a trace under a different routing
        policy on the same replicas)."""
        if self.pending_work():
            self.run_until_drained()
        self.queue.clear()
        self.results.clear()
        self.records.clear()
        self._next_id = 0
        self.slots = [_Slot() for _ in range(self.scfg.batch_size)]
        self.state = init_decode_state(
            self.cfg, self.scfg.batch_size, self.scfg.max_seq)
        self._tokens = np.zeros((self.scfg.batch_size, 1), np.int32)

    def active_count(self) -> int:
        """Occupied decode slots (the scheduler's in-flight signal)."""
        return sum(1 for s in self.slots if s.request_id is not None)

    def pending_work(self) -> bool:
        return bool(self.queue) or self.active_count() > 0

    def _prefill_into_slot(self, slot_idx: int, rid: int, prompt: np.ndarray):
        """Run the prompt through the model writing KV/state for this slot."""
        rec = self.records[rid]
        rec.prefill_start_s = self.clock()
        S = len(prompt)
        # batch the prompt across the full slot dim (only slot_idx's lanes
        # are kept — simple and correct; per-slot cache views are a perf
        # optimization on real hardware)
        toks = np.zeros((self.scfg.batch_size, S), np.int32)
        toks[slot_idx] = prompt
        logits, new_state, _ = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, self.state)
        self.state = self._merge_slot(self.state, new_state, slot_idx)
        nxt = int(jnp.argmax(logits[slot_idx, -1]))
        slot = self.slots[slot_idx]
        slot.request_id = rid
        slot.pos = S
        slot.generated = [nxt]
        self._tokens[slot_idx, 0] = nxt
        rec.prefill_done_s = self.clock()
        rec.generated = 1
        if self.scfg.max_new_tokens <= 1 or nxt == self.scfg.eos_id:
            self._finish_slot(slot_idx)

    def _finish_slot(self, slot_idx: int):
        slot = self.slots[slot_idx]
        rec = self.records[slot.request_id]
        rec.finish_s = self.clock()
        rec.generated = len(slot.generated)
        self.results[slot.request_id] = slot.generated
        self.slots[slot_idx] = _Slot()

    def _merge_slot(self, old, new, slot_idx: int):
        """Keep `new` only on the batch lane of this slot."""

        def pick(o, n):
            # batch dim differs per cache family; all our caches have the
            # batch dim right after the layer dim
            if o.ndim < 2 or o.shape != n.shape:
                return n
            sel = jnp.zeros((o.shape[1],), bool).at[slot_idx].set(True)
            shape = [1, o.shape[1]] + [1] * (o.ndim - 2)
            return jnp.where(sel.reshape(shape), n, o)

        return jax.tree.map(pick, old, new)

    def _refill(self):
        for i, slot in enumerate(self.slots):
            if slot.request_id is None and self.queue:
                rid, prompt = self.queue.popleft()
                self._prefill_into_slot(i, rid, prompt)

    def engine_step(self):
        self._refill()
        active = [i for i, s in enumerate(self.slots) if s.request_id is not None]
        if not active:
            return
        # all active slots decode at their own position; the cache mask uses
        # per-slot positions — we step them at the max position and rely on
        # each slot's own `pos` for emission bookkeeping (positions differ:
        # run per-distinct-position micro-batches)
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(self.slots[i].pos, []).append(i)
        for pos, idxs in sorted(by_pos.items()):
            step_start = self.clock()
            logits, self.state = self._decode(
                self.params, self.state, jnp.asarray(self._tokens),
                jnp.asarray(pos, jnp.int32),
            )
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            now = self.clock()
            for i in idxs:
                slot = self.slots[i]
                rec = self.records[slot.request_id]
                if rec.decode_start_s is None:
                    rec.decode_start_s = step_start
                tok = int(nxt[i])
                slot.generated.append(tok)
                slot.pos += 1
                self._tokens[i, 0] = tok
                rec.generated = len(slot.generated)
                done = (
                    len(slot.generated) >= self.scfg.max_new_tokens
                    or tok == self.scfg.eos_id
                    or slot.pos >= self.scfg.max_seq - 1
                )
                if done:
                    self._finish_slot(i)

    def run_until_drained(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or any(s.request_id is not None for s in self.slots)):
            self.engine_step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("server did not drain")
        return self.results

    def drain_report(self) -> Dict[str, Any]:
        """Per-request timestamps + aggregate latency/throughput stats for
        every finished request — the measured record the cluster layer
        compares against simulated :class:`~repro.cluster.sim.ClusterStats`.
        Aggregate-only stats block simulator-vs-measured validation; this
        report keeps every phase timestamp per request."""
        done = [r for r in self.records.values() if r.finish_s is not None]
        lat = [r.latency_s for r in done]
        ttft = [r.ttft_s for r in done if r.ttft_s is not None]
        toks = sum(r.generated for r in done)
        span = (max(r.finish_s for r in done) - min(r.enqueue_s for r in done)
                if done else 0.0)
        return {
            "requests": len(done),
            "tokens": toks,
            "makespan_s": span,
            "throughput_tok_s": (toks / span) if span > 0 else 0.0,
            "latency_p50_s": _percentile(lat, 50),
            "latency_p99_s": _percentile(lat, 99),
            "ttft_p50_s": _percentile(ttft, 50),
            "per_request": [r.to_json() for r in sorted(
                done, key=lambda r: r.rid)],
        }
