"""Fault-tolerant training runtime.

Production concerns handled here (scaled down to run offline):
  * checkpoint/restart — periodic async checkpoints; `run()` survives
    injected step failures by restoring the last committed checkpoint and
    replaying the data pipeline to the same batch;
  * straggler detection — per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA fire a hook (on a real cluster: report the
    slow host to the job scheduler / trigger hot-spare swap);
  * preemption — SIGTERM flips a flag; the loop checkpoints and exits
    cleanly at the next step boundary;
  * elasticity — `replan(world_size)` rebuilds the mesh and the OpTree
    collective factorization for a changed device count (the staged
    all-gather plan is re-derived; params are resharded by pjit on the next
    step).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs.base import ModelConfig
from ..core.planner import ICI_LINK, plan_staged_allgather
from ..models import loss_fn
from ..optim import OptimizerConfig, adamw_update

__all__ = ["TrainerConfig", "Trainer", "make_train_step"]


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_interval: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_interval: int = 10
    straggler_factor: float = 3.0
    ema_decay: float = 0.9


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    donate: bool = True) -> Callable:
    """jit'd (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        new_params, new_opt = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: OptimizerConfig,
        tcfg: TrainerConfig,
        *,
        params,
        opt_state,
        pipeline,
        train_step: Optional[Callable] = None,
        fault_injector: Optional[Callable[[int], None]] = None,
    ):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.params, self.opt_state = params, opt_state
        self.pipeline = pipeline
        self.train_step = train_step or make_train_step(cfg, opt_cfg)
        self.ckpt = Checkpointer(tcfg.ckpt_dir)
        self.fault_injector = fault_injector
        self.step = 0
        self.preempted = False
        self.max_restarts = 5
        self.step_time_ema: Optional[float] = None
        self.straggler_events: List[Dict] = []
        self.metrics_log: List[Dict] = []
        self.restarts = 0

    # ---- hooks --------------------------------------------------------
    def install_preemption_handler(self):
        def _handler(signum, frame):
            self.preempted = True

        signal.signal(signal.SIGTERM, _handler)

    def on_straggler(self, step: int, dt: float, ema: float):
        self.straggler_events.append({"step": step, "dt": dt, "ema": ema})

    # ---- checkpoint/restart --------------------------------------------
    def _state(self) -> Dict[str, Any]:
        return {
            "params": self.params,
            "opt_state": self.opt_state,
            "data_state": self.pipeline.state(),
        }

    def save(self, blocking: bool = False):
        self.ckpt.save(self.step, self._state(), blocking=blocking)

    def try_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        step, state = self.ckpt.restore(self._state())
        self.params = jax.tree.map(jnp.asarray, state["params"])
        self.opt_state = jax.tree.map(jnp.asarray, state["opt_state"])
        ds = state["data_state"]
        self.pipeline.restore({k: np.asarray(v).item() for k, v in ds.items()})
        self.step = step
        return True

    # ---- main loop ------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        self.install_preemption_handler()
        while self.step < self.tcfg.total_steps and not self.preempted:
            try:
                batch_np = next(self.pipeline)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                if self.fault_injector is not None:
                    self.fault_injector(self.step)  # may raise
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch
                )
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                if self.step_time_ema is not None and dt > (
                    self.tcfg.straggler_factor * self.step_time_ema
                ):
                    self.on_straggler(self.step, dt, self.step_time_ema)
                d = self.tcfg.ema_decay
                self.step_time_ema = (
                    dt if self.step_time_ema is None
                    else d * self.step_time_ema + (1 - d) * dt
                )
                self.metrics_log.append({"step": self.step, "loss": loss, "dt": dt})
                self.step += 1
                if self.step % self.tcfg.ckpt_interval == 0:
                    self.save(blocking=False)
            except (FloatingPointError, RuntimeError) as e:
                # node failure / injected fault: restart from last checkpoint
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts; last error: {e}"
                    ) from e
                if not self.try_restore():
                    self.step = 0
                    self.pipeline.restore({"step": 0, "seed": self.pipeline.cfg.seed})
        self.ckpt.wait()
        self.save(blocking=True)
        return {
            "final_step": self.step,
            "restarts": self.restarts,
            "stragglers": len(self.straggler_events),
            "losses": [m["loss"] for m in self.metrics_log],
        }


def replan(world_size: int, shard_bytes: float):
    """Elastic hook: re-derive the OpTree collective plan for a new world
    size (called when the scheduler grows/shrinks the job)."""
    return plan_staged_allgather(world_size, shard_bytes, ICI_LINK)
