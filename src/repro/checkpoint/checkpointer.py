"""Crash-safe checkpointing: sharded npz + JSON index, atomic commit,
async save thread, latest-checkpoint discovery for restart.

Layout:  <dir>/step_<N>.tmp/ -> arrays.npz + meta.json, renamed to
<dir>/step_<N>/ only after both files are fully written (the rename is the
commit point — a crashed save leaves only a .tmp that restore ignores).
Every file inside the tmp dir is itself written atomically (sibling .part
+ fsync + rename, meta.json last) and the parent directory is fsynced
after the commit rename, so a kill at ANY instant leaves either the
previous checkpoint set or the new one — never a torn file a restore
could load.  On a multi-host cluster each process writes
``arrays_<proc>.npz`` of its addressable shards; offline (single process)
that is one file.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _atomic_write(path: Path, write_fn) -> None:
    """Write ``path`` via a sibling ``.part`` temp file, fsync, rename.

    Readers can never observe a torn/partial file under the final name,
    and the bytes are durable before the name appears — the per-file half
    of the checkpointer's crash-safety story (the directory rename in
    ``_write`` is the other half).
    """
    tmp = path.with_name(path.name + ".part")
    with open(tmp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _fsync_dir(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in leaves_with_path:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        arr = flat[key]
        ref = np.asarray(leaf)  # template leaves may be python scalars
        leaves.append(np.asarray(arr, dtype=ref.dtype).reshape(ref.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, process_id: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.process_id = process_id
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Dict[str, Any], *, blocking: bool = True) -> None:
        """state: arbitrary pytree dict, e.g. {params, opt_state, data_state}."""
        host_state = jax.tree.map(lambda a: np.asarray(a), state)
        if blocking:
            self._write(step, host_state)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, state: Dict[str, Any]) -> None:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(state)
        _atomic_write(tmp / f"arrays_{self.process_id}.npz",
                      lambda f: np.savez(f, **flat))
        meta = {
            "step": step,
            "time": time.time(),
            "keys": sorted(flat.keys()),
            "process_count": 1,
        }
        # meta.json LAST: _steps() treats its presence as "files complete"
        _atomic_write(tmp / "meta.json",
                      lambda f: f.write(json.dumps(meta).encode()))
        if final.exists():  # same-step re-save (e.g. final save after async)
            shutil.rmtree(final)
        os.replace(tmp, final)  # commit point
        _fsync_dir(self.dir)  # make the commit rename itself durable
        self._gc()

    def _gc(self) -> None:
        done = sorted(self._steps())
        for s in done[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def _steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "meta.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return max(steps) if steps else None

    def restore(self, template: Dict[str, Any], step: Optional[int] = None
                ) -> Tuple[int, Dict[str, Any]]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        flat: Dict[str, np.ndarray] = {}
        for npz in sorted(path.glob("arrays_*.npz")):
            with np.load(npz) as z:
                flat.update({k: z[k] for k in z.files})
        return step, _unflatten(template, flat)
