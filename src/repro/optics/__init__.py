"""Optical WDM ring interconnect simulator (TeraRack-style, paper §IV)."""
from .simulator import SimReport, simulate  # noqa: F401
from .comparison import compare_algorithms  # noqa: F401
