"""Step-accurate simulator for schedules on the WDM ring.

Executes a :class:`~repro.core.schedule.Schedule` step by step, re-validating
conflict-freedom and causality *as it runs* (a schedule that passes the static
validators also passes here; the simulator is the independent execution path),
and accumulates wall time with the paper's Eq.-3 model — optionally the
detailed packet/flit variant.

This is the measurement backend for the Fig. 4/5/6 and Table I benchmarks.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Set, Tuple

from ..core.cost_model import OpticalSystem, schedule_step_times
from ..core.schedule import Schedule

__all__ = ["SimReport", "simulate"]


@dataclass(frozen=True)
class SimReport:
    algorithm: str
    n: int
    w: int
    steps: int
    transmissions: int
    time_s: float
    max_link_load: int  # peak per-(direction,link) wavelength usage in a step
    stage_steps: Tuple[int, ...]
    stage_times_s: Tuple[float, ...] = ()  # wall time attributed per stage
    reconfigurations: int = 0  # circuit/topology changes between stages
    reconfig_exposed_s: float = 0.0  # reconfig delay not hidden by overlap

    def speedup_vs(self, other: "SimReport") -> float:
        return other.time_s / self.time_s

    def reduction_vs(self, other: "SimReport") -> float:
        """Paper-style '% communication-time reduction' vs a baseline."""
        return 1.0 - self.time_s / other.time_s


def simulate(
    sched: Schedule,
    sys: OpticalSystem,
    message_bytes: float,
    *,
    detailed: bool = False,
    check: bool = True,
    health=None,
) -> SimReport:
    """Execute ``sched`` step by step.  ``message_bytes`` is the size of ONE
    schedule item (``plan_ir.optical_message_bytes`` for IR-lowered plans:
    the shard for gather traffic, a 1/n block for exchange traffic).

    ``sched.meta["semantics"]`` selects the item model: ``"gather"`` (the
    default) starts node i holding item i and requires every node to end
    with all n items; ``"exchange"`` (a2a) uses the n² (origin,
    destination) item space ``u·n + v`` — node u starts holding
    ``{u·n + v : v}`` and node v must end holding ``{u·n + v : u}``.

    ``health`` (a :class:`~repro.core.health.LinkHealth`) makes the run
    fault-aware: a transmission on a lost wavelength or a dead ring
    direction fails the simulation — the physical channel does not exist.
    ``schedule_from_ir(..., health=...)`` schedules around faults, so a
    consistent plan→schedule→simulate pipeline passes this check by
    construction (price==simulate under faults).
    """
    lost: Set[int] = set()
    dead_dirs: Set[int] = set()
    if health is not None and not health.is_healthy:
        axes = sched.meta.get("axes")
        lost = set(health.lost_for(axes))
        dead_dirs = set(health.dead_directions(axes))
    exchange = sched.meta.get("semantics") == "exchange"
    if exchange:
        holdings: List[Set[int]] = [
            {u * sched.n + v for v in range(sched.n)} for u in range(sched.n)
        ]
    else:
        holdings = [{i} for i in range(sched.n)]
    max_load = 0
    steps = sched.by_step()
    for step_txs in steps:
        wl_used: Dict[Tuple[int, int, int], Tuple[int, int]] = {}
        load: Dict[Tuple[int, int], Set[int]] = defaultdict(set)
        arrivals: Dict[int, Set[int]] = defaultdict(set)
        for tx in step_txs:
            if tx.wavelength in lost:
                raise AssertionError(
                    f"simulator: transmission on LOST wavelength "
                    f"{tx.wavelength} at step {tx.step} "
                    f"({tx.src}->{tx.dst}, links {list(tx.links)}); "
                    f"health: {health.describe()}")
            if tx.direction in dead_dirs:
                raise AssertionError(
                    f"simulator: transmission on DEAD ring direction "
                    f"{tx.direction} at step {tx.step} "
                    f"({tx.src}->{tx.dst}, wl={tx.wavelength}); "
                    f"health: {health.describe()}")
            if check:
                if tx.item not in holdings[tx.src]:
                    raise AssertionError(
                        f"simulator: node {tx.src} lacks item {tx.item} at step {tx.step}"
                    )
                for link in tx.links:
                    key = (tx.direction, link, tx.wavelength)
                    owner = wl_used.get(key)
                    # same-(src,dst) sharing is a serialized burst on one
                    # lightpath (exchange stages), not a collision — the
                    # Eq.-3 accounting charges the step for the full burst
                    if owner is not None and owner != (tx.src, tx.dst):
                        raise AssertionError(
                            f"simulator: wavelength collision {key} between "
                            f"{owner} and {(tx.src, tx.dst)}")
                    wl_used[key] = (tx.src, tx.dst)
            for link in tx.links:
                load[(tx.direction, link)].add(tx.wavelength)
            arrivals[tx.dst].add(tx.item)
        if load:
            max_load = max(max_load, max(len(v) for v in load.values()))
        for dst, items in arrivals.items():
            holdings[dst] |= items
    if check:
        for p, h in enumerate(holdings):
            if exchange:
                need = {u * sched.n + p for u in range(sched.n)}
                missing = need - h
                assert not missing, (
                    f"simulator: node {p} missing {len(missing)} destination "
                    f"blocks (e.g. {sorted(missing)[:4]})")
            else:
                assert len(h) == sched.n, \
                    f"simulator: node {p} incomplete ({len(h)}/{sched.n})"
    # shared Eq.-3 accounting with the optical pricer (burst-aware): the
    # price==simulate invariant is literal — both call this helper
    _, stage_times, total, reconf = schedule_step_times(
        sched, sys, message_bytes, detailed=detailed)
    return SimReport(
        algorithm=str(sched.meta.get("algorithm", "?")),
        n=sched.n,
        w=sched.w,
        steps=len(steps),
        transmissions=len(sched.txs),
        time_s=total,
        max_link_load=max_load,
        stage_steps=tuple(sched.stage_steps),
        stage_times_s=stage_times,
        reconfigurations=reconf.events,
        reconfig_exposed_s=reconf.exposed_s,
    )
