"""Analytic algorithm comparison backend for the paper's figures.

Large-N sweeps (N up to 4096, messages to 128 MB) use the closed-form step
counts + Eq. 3 — the same granularity as the paper's own model — because full
transmission enumeration at N=4096 is O(N^2) lightpaths.  Small-N cases are
cross-checked against the schedule-level simulator in tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

from ..core import steps as S
from ..core.cost_model import OpticalSystem, eq3_time

__all__ = ["AlgoResult", "compare_algorithms"]


@dataclass(frozen=True)
class AlgoResult:
    algorithm: str
    n: int
    w: int
    message_bytes: float
    steps: int
    time_s: float


def _steps_for(algorithm: str, n: int, w: int) -> Optional[int]:
    if algorithm == "ring":
        return S.ring_steps(n, w)
    if algorithm == "ne":
        return S.neighbor_exchange_steps(n, w)
    if algorithm == "one-stage":
        return S.one_stage_steps(n, w)
    if algorithm == "wrht":
        return S.wrht_steps_formula(n, w)
    if algorithm == "wrht-paper":
        return S.wrht_steps_paper_table(n, w)
    if algorithm == "optree":
        return S.optree_optimal_steps(n, w)[1]
    raise ValueError(f"unknown algorithm {algorithm!r}")


def compare_algorithms(
    n: int,
    w: int,
    message_bytes: float,
    sys: OpticalSystem,
    algorithms: Iterable[str] = ("optree", "wrht", "ring", "ne", "one-stage"),
) -> Dict[str, AlgoResult]:
    out: Dict[str, AlgoResult] = {}
    for algo in algorithms:
        steps = _steps_for(algo, n, w)
        if steps is None:
            continue
        out[algo] = AlgoResult(
            algorithm=algo,
            n=n,
            w=w,
            message_bytes=message_bytes,
            steps=steps,
            time_s=eq3_time(sys, message_bytes, steps),
        )
    return out
