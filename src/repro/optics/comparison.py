"""Analytic algorithm comparison backend for the paper's figures.

Large-N sweeps (N up to 4096, messages to 128 MB) use the closed-form step
counts + Eq. 3 — the same granularity as the paper's own model — because full
transmission enumeration at N=4096 is O(N^2) lightpaths.  Small-N cases are
cross-checked against the schedule-level simulator in tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

from ..core import steps as S
from ..core.cost_model import OpticalSystem, eq3_time

__all__ = ["AlgoResult", "compare_algorithms"]


@dataclass(frozen=True)
class AlgoResult:
    algorithm: str
    n: int
    w: int
    message_bytes: float
    steps: int
    time_s: float
    collective: str = "all-gather"


def _allgather_steps(algorithm: str, n: int, w: int) -> Optional[int]:
    if algorithm == "ring":
        return S.ring_steps(n, w)
    if algorithm == "ne":
        return S.neighbor_exchange_steps(n, w)
    if algorithm == "one-stage":
        return S.one_stage_steps(n, w)
    if algorithm == "wrht":
        return S.wrht_steps_formula(n, w)
    if algorithm == "wrht-paper":
        return S.wrht_steps_paper_table(n, w)
    if algorithm == "optree":
        return S.optree_optimal_steps(n, w)[1]
    raise ValueError(f"unknown algorithm {algorithm!r}")


def _steps_for(
    algorithm: str, n: int, w: int, collective: str = "all-gather"
) -> Optional[int]:
    """Step count for a collective built from the algorithm's schedule.

    reduce-scatter: the time-reversed all-gather schedule — each step's
    transmissions run backwards carrying partial sums, so the step count is
    identical (and for OpTree the stage order is the exact reverse: the
    shrinking payload leaves the slow stages last).  all-reduce: RS then AG
    back-to-back (2x; no step sharing across the scattered boundary).
    """
    ag = _allgather_steps(algorithm, n, w)
    if ag is None or collective == "all-gather":
        return ag
    if collective == "reduce-scatter":
        return ag
    if collective == "all-reduce":
        return 2 * ag
    raise ValueError(f"unknown collective {collective!r}")


def compare_algorithms(
    n: int,
    w: int,
    message_bytes: float,
    sys: OpticalSystem,
    algorithms: Iterable[str] = ("optree", "wrht", "ring", "ne", "one-stage"),
    *,
    collective: str = "all-gather",
) -> Dict[str, AlgoResult]:
    out: Dict[str, AlgoResult] = {}
    for algo in algorithms:
        steps = _steps_for(algo, n, w, collective)
        if steps is None:
            continue
        out[algo] = AlgoResult(
            algorithm=algo,
            n=n,
            w=w,
            message_bytes=message_bytes,
            steps=steps,
            time_s=eq3_time(sys, message_bytes, steps),
            collective=collective,
        )
    return out
