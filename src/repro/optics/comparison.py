"""Analytic algorithm comparison backend for the paper's figures.

Large-N sweeps (N up to 4096, messages to 128 MB) use the closed-form step
counts + Eq. 3 — the same granularity as the paper's own model — because full
transmission enumeration at N=4096 is O(N^2) lightpaths.  Small-N cases are
cross-checked against the schedule-level simulator in tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

from ..core import steps as S
from ..core.cost_model import OpticalSystem, eq3_time
from ..core.tree import OpTreePlan, balanced_factors

__all__ = ["AlgoResult", "compare_algorithms"]


@dataclass(frozen=True)
class AlgoResult:
    algorithm: str
    n: int
    w: int
    message_bytes: float
    steps: int
    time_s: float
    collective: str = "all-gather"
    # per-stage attribution (empty when the algorithm has no closed-form
    # stage split).  For OpTree this is the exact per-stage demand of the
    # balanced plan (sums to optree_steps_exact), while `steps` keeps the
    # paper's Theorem-1 closed form (real-valued m) — they can differ by
    # the continuous-relaxation rounding; single-stage baselines agree.
    stage_steps: Tuple[int, ...] = ()
    stage_times_s: Tuple[float, ...] = ()


def _allgather_steps(algorithm: str, n: int, w: int) -> Optional[int]:
    if algorithm == "ring":
        return S.ring_steps(n, w)
    if algorithm == "ne":
        return S.neighbor_exchange_steps(n, w)
    if algorithm == "one-stage":
        return S.one_stage_steps(n, w)
    if algorithm == "wrht":
        return S.wrht_steps_formula(n, w)
    if algorithm == "wrht-paper":
        return S.wrht_steps_paper_table(n, w)
    if algorithm == "optree":
        return S.optree_optimal_steps(n, w)[1]
    raise ValueError(f"unknown algorithm {algorithm!r}")


def _steps_for(
    algorithm: str, n: int, w: int, collective: str = "all-gather"
) -> Optional[int]:
    """Step count for a collective built from the algorithm's schedule.

    reduce-scatter: the time-reversed all-gather schedule — each step's
    transmissions run backwards carrying partial sums, so the step count is
    identical (and for OpTree the stage order is the exact reverse: the
    shrinking payload leaves the slow stages last).  all-reduce: RS then AG
    back-to-back (2x; no step sharing across the scattered boundary).
    """
    ag = _allgather_steps(algorithm, n, w)
    if ag is None or collective == "all-gather":
        return ag
    if collective == "reduce-scatter":
        return ag
    if collective == "all-reduce":
        return 2 * ag
    raise ValueError(f"unknown collective {collective!r}")


def _allgather_stage_steps(algorithm: str, n: int, w: int) -> Tuple[int, ...]:
    """Per-stage step split of the all-gather schedule, where the algorithm
    has one: OpTree's optimal plan splits over its k stages; the one-round
    baselines are a single stage.  Empty for WRHT (no closed per-round
    form in the paper)."""
    if algorithm == "optree":
        k, _ = S.optree_optimal_steps(n, w)
        plan = OpTreePlan(n, balanced_factors(n, k))
        return tuple(
            math.ceil(S.optree_stage_demand(plan, j) / w)
            for j in range(1, plan.k + 1)
        )
    if algorithm in ("ring", "ne", "one-stage"):
        steps = _allgather_steps(algorithm, n, w)
        return (steps,) if steps is not None else ()
    return ()


def _stage_steps_for(
    algorithm: str, n: int, w: int, collective: str
) -> Tuple[int, ...]:
    """Stage attribution for the collective: RS mirrors the AG split (time
    reversal — the shrinking payload leaves the slow stages last), AR is
    the RS split followed by the AG split."""
    ag = _allgather_stage_steps(algorithm, n, w)
    if collective == "all-gather":
        return ag
    if collective == "reduce-scatter":
        return tuple(reversed(ag))
    if collective == "all-reduce":
        return tuple(reversed(ag)) + ag
    return ()


def compare_algorithms(
    n: int,
    w: int,
    message_bytes: float,
    sys: OpticalSystem,
    algorithms: Iterable[str] = ("optree", "wrht", "ring", "ne", "one-stage"),
    *,
    collective: str = "all-gather",
) -> Dict[str, AlgoResult]:
    out: Dict[str, AlgoResult] = {}
    for algo in algorithms:
        steps = _steps_for(algo, n, w, collective)
        if steps is None:
            continue
        stage_steps = _stage_steps_for(algo, n, w, collective)
        per_step = eq3_time(sys, message_bytes, 1)
        out[algo] = AlgoResult(
            algorithm=algo,
            n=n,
            w=w,
            message_bytes=message_bytes,
            steps=steps,
            time_s=eq3_time(sys, message_bytes, steps),
            collective=collective,
            stage_steps=stage_steps,
            stage_times_s=tuple(per_step * s for s in stage_steps),
        )
    return out
