"""Serving driver: continuous-batching decode over a slot pool.

Submits a burst of prompts of mixed lengths to the BatchedServer and reports
per-request generations + aggregate decode throughput.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ModelConfig
from repro.models import init_params
from repro.runtime import BatchedServer, ServerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="serve-demo", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=4096,
        dtype="float32", remat=False,
    )
    params = init_params(jax.random.key(0), cfg)
    server = BatchedServer(cfg, params, ServerConfig(
        batch_size=4, max_seq=128, max_new_tokens=args.new_tokens,
    ))

    rng = np.random.default_rng(0)
    lengths = rng.integers(4, 24, size=args.requests)
    rids = [server.submit(rng.integers(0, cfg.vocab_size, size=int(n)))
            for n in lengths]
    print(f"submitted {len(rids)} requests (prompt lengths {list(lengths)}) "
          f"into {server.scfg.batch_size} slots")

    t0 = time.time()
    results = server.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    for rid in rids:
        print(f"  req {rid}: {len(results[rid])} tokens -> {results[rid][:8]}...")
    print(f"decoded {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
