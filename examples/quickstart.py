"""Quickstart: OpTree in 60 seconds.

1. Plan the optimal k-stage m-ary tree for an optical ring (paper Thm 2).
2. Build the transmission-level schedule, validate it, simulate its time.
3. Compare against Ring / Neighbor-Exchange / one-stage baselines.
4. Run the TPU-adapted staged all-gather on 8 (fake) devices and check it
   is bit-identical to XLA's one-shot collective.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    OpTreePlan,
    TERARACK,
    build_ne_schedule,
    build_one_stage_schedule,
    build_optree_schedule,
    build_ring_schedule,
    optree_optimal_steps,
    validate_schedule,
)
from repro.optics import simulate  # noqa: E402


def optical_demo():
    n, w, msg = 64, 8, 4 * 2**20
    k, steps = optree_optimal_steps(n, w)
    plan = OpTreePlan.balanced(n, w=w)
    print(f"== Optical ring: N={n} nodes, w={w} wavelengths, 4MB/node ==")
    print(f"Thm 2 optimal depth k*={k}; balanced factors={plan.factors}")

    sched = build_optree_schedule(plan, w)
    validate_schedule(sched)  # conflict-free + causal + complete
    rep = simulate(sched, TERARACK, msg)
    print(f"OpTree   : {rep.steps:4d} steps  {rep.time_s*1e3:8.2f} ms "
          f"({rep.transmissions} lightpaths)")

    for name, builder in (("one-stage", build_one_stage_schedule),
                          ("ring", build_ring_schedule),
                          ("neigh-exch", build_ne_schedule)):
        s = builder(n, w)
        validate_schedule(s)
        r = simulate(s, TERARACK, msg)
        print(f"{name:<9}: {r.steps:4d} steps  {r.time_s*1e3:8.2f} ms "
              f"(OpTree reduces {100*(1 - rep.time_s/r.time_s):5.1f}%)")


def tpu_demo():
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.comms import make_factorized_mesh, optree_all_gather

    print("\n== TPU adaptation: staged all-gather on a pod x data mesh ==")
    mesh = make_factorized_mesh([2, 4], ["pod", "data"])
    x = np.arange(32, dtype=np.float32).reshape(16, 2)
    xs = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
    got = optree_all_gather(xs, mesh, ("pod", "data"))
    assert np.array_equal(np.asarray(got), x)
    print(f"devices={len(jax.devices())}, mesh={dict(mesh.shape)}")
    print("optree_all_gather == global array:", np.array_equal(np.asarray(got), x))
    print("stage order planned slow-axis (pod) first; payload grows after.")


if __name__ == "__main__":
    optical_demo()
    tpu_demo()
