"""Quickstart: OpTree in 60 seconds.

1. Plan the optimal k-stage m-ary tree for an optical ring (paper Thm 2).
2. Build the transmission-level schedule, validate it, simulate its time.
3. Compare against Ring / Neighbor-Exchange / one-stage baselines.
4. Install a ``comm_context`` over 8 (fake) devices and run the whole
   gather-shaped family through the one context-scoped API
   (``repro.comms.api``) — bit-identical to XLA's one-shot collectives,
   with the planner's CollectivePlans cached on the context.
5. Swap in a fitted LinkSpec table (``ctx.update_links``) and watch the
   cache invalidate + re-plan — the auto-calibration loop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    OpTreePlan,
    TERARACK,
    build_ne_schedule,
    build_one_stage_schedule,
    build_optree_schedule,
    build_ring_schedule,
    optree_optimal_steps,
    validate_schedule,
)
from repro.optics import simulate  # noqa: E402


def optical_demo():
    n, w, msg = 64, 8, 4 * 2**20
    k, steps = optree_optimal_steps(n, w)
    plan = OpTreePlan.balanced(n, w=w)
    print(f"== Optical ring: N={n} nodes, w={w} wavelengths, 4MB/node ==")
    print(f"Thm 2 optimal depth k*={k}; balanced factors={plan.factors}")

    sched = build_optree_schedule(plan, w)
    validate_schedule(sched)  # conflict-free + causal + complete
    rep = simulate(sched, TERARACK, msg)
    print(f"OpTree   : {rep.steps:4d} steps  {rep.time_s*1e3:8.2f} ms "
          f"({rep.transmissions} lightpaths)")

    for name, builder in (("one-stage", build_one_stage_schedule),
                          ("ring", build_ring_schedule),
                          ("neigh-exch", build_ne_schedule)):
        s = builder(n, w)
        validate_schedule(s)
        r = simulate(s, TERARACK, msg)
        print(f"{name:<9}: {r.steps:4d} steps  {r.time_s*1e3:8.2f} ms "
              f"(OpTree reduces {100*(1 - rep.time_s/r.time_s):5.1f}%)")


def tpu_demo():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.comms import api, comm_context, make_factorized_mesh
    from repro.core.planner import LinkSpec

    print("\n== TPU adaptation: context-scoped collectives on a pod x data mesh ==")
    mesh = make_factorized_mesh([2, 4], ["pod", "data"])
    names = ("pod", "data")
    x = np.arange(32, dtype=np.float32).reshape(16, 2)
    xs = jax.device_put(x, NamedSharding(mesh, P(names)))

    with comm_context(mesh, names) as ctx:
        # one API for the whole gather-shaped family; the context plans,
        # caches and executes CollectivePlans behind each call
        g = api.all_gather(xs)                        # == all_gather(tiled)
        s = api.reduce_scatter(jnp.asarray(x))        # == psum_scatter
        r = api.all_reduce(jnp.asarray(x), axis=0)    # == psum
        print(f"devices={len(jax.devices())}, mesh={dict(mesh.shape)}")
        print("all_gather == global array:", np.array_equal(np.asarray(g), x))
        print("reduce_scatter == 8*x:     ", np.array_equal(np.asarray(s), 8 * x))
        print("all_reduce == 8*x:         ", np.array_equal(np.asarray(r), 8 * x))
        # same key the all_gather above cached under -> a cache HIT
        plan = ctx.plan("ag", x.nbytes / 8, shape=xs.shape, dtype=xs.dtype)
        print(f"cached AG plan: order={plan.axes} mode={plan.mode} "
              f"(slow pod axis first; payload grows after)")
        print(f"cache: {ctx.cache_stats}")

        # auto-calibration: a fitted links table invalidates + re-plans
        ctx.update_links({"pod": LinkSpec("dcn-fitted", 1e9, 5e-5)})
        api.all_gather(xs)
        print(f"after update_links: {ctx.cache_stats} (re-planned, same context)")


if __name__ == "__main__":
    optical_demo()
    tpu_demo()
