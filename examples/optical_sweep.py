"""Reproduce the paper's evaluation sweeps as ASCII charts (Figs. 4-6).

Run:  PYTHONPATH=src python examples/optical_sweep.py
"""
from repro.configs import optree_paper as paper
from repro.core import eq3_time
from repro.core import steps as S


def bar(frac, width=40):
    return "#" * max(1, int(frac * width))


def fig4():
    print("== Fig. 4: normalized time vs tree depth (w=64, 4MB) ==")
    for n in paper.FIG4_NODES:
        by_k = {k: S.optree_steps_thm1(n, k, 64) for k in range(1, 11)}
        best = min(by_k.values())
        print(f"N={n} (optimal k={min(by_k, key=by_k.get)}):")
        for k, s in by_k.items():
            if k == 1:
                continue  # one-stage dwarfs the chart
            print(f"  k={k:<2} {s/best:6.3f}x {bar(best/s)}")


def fig56():
    print("\n== Fig. 5/6: OpTree vs baselines, time for 4MB messages ==")
    for n, w in [(1024, 64), (2048, 64), (1024, 96), (1024, 128)]:
        rows = {
            "optree": S.optree_optimal_steps(n, w)[1],
            "ne": S.neighbor_exchange_steps(n),
            "ring": S.ring_steps(n),
            "one-stage": S.one_stage_steps(n, w),
        }
        tmax = max(rows.values())
        print(f"N={n} w={w}:")
        for name, s in rows.items():
            t = eq3_time(paper.SYSTEM, 4 * 2**20, s)
            print(f"  {name:<9} {t*1e3:9.1f} ms {bar(s/tmax)}")


if __name__ == "__main__":
    fig4()
    fig56()
