"""End-to-end training driver: data pipeline -> model -> AdamW -> fault-
tolerant trainer with periodic checkpoints.

Profiles:
  --size small   ~5M params  (default; a few minutes for 200 steps on CPU)
  --size 100m    ~100M params (the assignment's reference scale; run a few
                  hundred steps on real accelerators)

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import ModelConfig
from repro.data import DataConfig, SyntheticLMPipeline
from repro.models import init_params
from repro.optim import OptimizerConfig, adamw_init
from repro.runtime import Trainer, TrainerConfig

PROFILES = {
    "small": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                  head_dim=64, d_ff=1024, vocab_size=4096, seq=256, batch=4),
    "100m": dict(num_layers=10, d_model=640, num_heads=10, num_kv_heads=5,
                 head_dim=64, d_ff=2560, vocab_size=32768, seq=1024, batch=32),
}


def build_config(size: str) -> ModelConfig:
    p = dict(PROFILES[size])
    p.pop("seq"), p.pop("batch")
    return ModelConfig(
        name=f"example-{size}", family="dense", dtype="float32",
        remat=False, qkv_bias=False, qk_norm=True, **p,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(PROFILES), default="small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    prof = PROFILES[args.size]
    cfg = build_config(args.size)
    n_params_est = (
        cfg.vocab_size * cfg.d_model * 2
        + cfg.num_layers * (2 * cfg.d_model * (cfg.q_dim + cfg.kv_dim)
                            + 3 * cfg.d_model * cfg.d_ff)
    )
    print(f"config {cfg.name}: ~{n_params_est/1e6:.0f}M params, "
          f"seq={prof['seq']}, batch={prof['batch']}, {len(jax.devices())} device(s)")

    params = init_params(jax.random.key(0), cfg)
    opt_state = adamw_init(params)
    pipe = SyntheticLMPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=prof["seq"],
        global_batch=prof["batch"],
    )).start()

    trainer = Trainer(
        cfg,
        OptimizerConfig(peak_lr=3e-4, warmup_steps=20, decay_steps=args.steps),
        TrainerConfig(total_steps=args.steps, ckpt_interval=50,
                      ckpt_dir=args.ckpt_dir),
        params=params, opt_state=opt_state, pipeline=pipe,
    )
    t0 = time.time()
    out = trainer.run()
    pipe.stop()
    dt = time.time() - t0
    losses = out["losses"]
    print(f"steps={out['final_step']} restarts={out['restarts']} "
          f"time={dt:.1f}s ({dt/max(out['final_step'],1):.2f}s/step)")
    print(f"loss: first={losses[0]:.4f} min={min(losses):.4f} "
          f"last={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "training did not reduce the loss"
    print("OK: loss decreased; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
