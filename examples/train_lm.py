"""End-to-end training driver: data pipeline -> model -> AdamW -> fault-
tolerant trainer with periodic checkpoints.

Profiles:
  --size small   ~5M params  (default; a few minutes for 200 steps on CPU)
  --size 100m    ~100M params (the assignment's reference scale; run a few
                  hundred steps on real accelerators)

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200

``--tp-demo`` first runs one explicit tensor-parallel transformer block
over all visible devices through the context-scoped collectives API
(``repro.comms.api.comm_context`` + ``models.model.transformer_block_tp``)
and checks it against the single-device reference block — the same
machinery `launch/train.py --zero1 explicit` and `launch/perf.py
--tp-block` use at scale.  Spin up fake devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import ModelConfig
from repro.data import DataConfig, SyntheticLMPipeline
from repro.models import init_params
from repro.optim import OptimizerConfig, adamw_init
from repro.runtime import Trainer, TrainerConfig

PROFILES = {
    "small": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                  head_dim=64, d_ff=1024, vocab_size=4096, seq=256, batch=4),
    "100m": dict(num_layers=10, d_model=640, num_heads=10, num_kv_heads=5,
                 head_dim=64, d_ff=2560, vocab_size=32768, seq=1024, batch=32),
}


def build_config(size: str) -> ModelConfig:
    p = dict(PROFILES[size])
    p.pop("seq"), p.pop("batch")
    return ModelConfig(
        name=f"example-{size}", family="dense", dtype="float32",
        remat=False, qkv_bias=False, qk_norm=True, **p,
    )


def tp_demo():
    """One explicit-TP transformer block on the context-scoped API vs the
    reference block, over every visible device."""
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import shard_map
    from repro.comms import comm_context, make_factorized_mesh
    from repro.models.model import (
        _layer_init, transformer_block_ref, transformer_block_tp,
        tp_block_specs,
    )

    n = len(jax.devices())
    cfg = dataclasses.replace(
        build_config("small"), num_heads=n, num_kv_heads=n, head_dim=16,
        d_model=16 * n, d_ff=32 * n, qk_norm=False)
    layer = _layer_init(jax.random.key(0), cfg, dtype=jnp.float32)
    B, S = 2, 4 * n
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
    ref = transformer_block_ref(layer, cfg, x, positions=pos)

    mesh = make_factorized_mesh([n], ["tp"])
    with comm_context(mesh, ("tp",)) as ctx:
        for sp in (False, True):
            x_spec, l_spec = tp_block_specs(layer, ("tp",),
                                            sequence_parallel=sp)
            fn = shard_map(
                lambda lx, ll, sp=sp: transformer_block_tp(
                    ll, cfg, lx, positions=pos, sequence_parallel=sp),
                mesh=mesh, in_specs=(x_spec, l_spec), out_specs=x_spec)
            got = jax.jit(fn)(x, layer)
            ok = np.allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
            print(f"[tp-demo] {'SP' if sp else 'TP'} block over {n} device(s) "
                  f"== reference: {ok}")
            assert ok
        print(f"[tp-demo] context cached {len(ctx.plans())} CollectivePlans "
              f"({ctx.cache_stats})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(PROFILES), default="small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--tp-demo", action="store_true",
                    help="run the explicit-TP block demo (context-scoped "
                         "collectives API) before training")
    args = ap.parse_args()

    if args.tp_demo:
        tp_demo()

    prof = PROFILES[args.size]
    cfg = build_config(args.size)
    n_params_est = (
        cfg.vocab_size * cfg.d_model * 2
        + cfg.num_layers * (2 * cfg.d_model * (cfg.q_dim + cfg.kv_dim)
                            + 3 * cfg.d_model * cfg.d_ff)
    )
    print(f"config {cfg.name}: ~{n_params_est/1e6:.0f}M params, "
          f"seq={prof['seq']}, batch={prof['batch']}, {len(jax.devices())} device(s)")

    params = init_params(jax.random.key(0), cfg)
    opt_state = adamw_init(params)
    pipe = SyntheticLMPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=prof["seq"],
        global_batch=prof["batch"],
    )).start()

    trainer = Trainer(
        cfg,
        OptimizerConfig(peak_lr=3e-4, warmup_steps=20, decay_steps=args.steps),
        TrainerConfig(total_steps=args.steps, ckpt_interval=50,
                      ckpt_dir=args.ckpt_dir),
        params=params, opt_state=opt_state, pipeline=pipe,
    )
    t0 = time.time()
    out = trainer.run()
    pipe.stop()
    dt = time.time() - t0
    losses = out["losses"]
    print(f"steps={out['final_step']} restarts={out['restarts']} "
          f"time={dt:.1f}s ({dt/max(out['final_step'],1):.2f}s/step)")
    print(f"loss: first={losses[0]:.4f} min={min(losses):.4f} "
          f"last={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "training did not reduce the loss"
    print("OK: loss decreased; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
